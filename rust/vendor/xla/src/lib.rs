//! Stub of the PJRT/XLA native bindings used by the `runtime` layer.
//!
//! The offline build environment has no XLA shared library, so this crate
//! provides the exact API surface `byteps_compress::runtime` compiles
//! against and returns a descriptive error the moment anything would need
//! the real runtime (client construction, HLO parsing, execution). The
//! pure-rust system — compressors, PS fabric, pipeline, simnet, benches —
//! never touches these entry points; only artifact execution does, and the
//! artifact-driven tests skip themselves when artifacts are absent.

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} requires the native PJRT/XLA bindings, which are stubbed in this offline \
         build; run `make artifacts` on a host with the real `xla` crate to execute models"
    )))
}

/// Scalar element types literals can carry.
pub trait NativeType: Copy + Default + 'static {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// A host-side tensor literal (stub: carries no data).
#[derive(Clone, Debug, Default)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_entry_points_error_clearly() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("stubbed"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(Literal.to_vec::<f32>().is_err());
    }

    #[test]
    fn literal_construction_is_infallible() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2, 1]).is_ok());
        let _ = Literal::vec1(&[1i32]);
    }
}
