//! Minimal, dependency-free shim of the `anyhow` crate — just enough of
//! the API surface for this repository (the real crate is unavailable in
//! the offline build environment).
//!
//! Supported: [`Error`], [`Result`], [`anyhow!`], [`bail!`], the
//! [`Context`] extension trait (`context` / `with_context`), conversion
//! from any `std::error::Error`, and `{:#}` alternate formatting that
//! prints the full context chain (`outer: inner: root`).

use std::fmt;

/// A context-chained dynamic error. Like `anyhow::Error`, this type does
/// **not** implement `std::error::Error` itself, which is what makes the
/// blanket `From<E: std::error::Error>` conversion possible.
pub struct Error {
    /// Outermost context first, root cause last. Never empty.
    chain: Vec<String>,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Mirror anyhow's Debug: message plus a caused-by list.
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn context_chain_formats() {
        let e: Error = Err::<(), _>(io_err()).with_context(|| "read config").unwrap_err();
        assert_eq!(format!("{e}"), "read config");
        assert_eq!(format!("{e:#}"), "read config: missing file");
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("bad value {}", 42);
        assert_eq!(format!("{e}"), "bad value 42");
        fn f() -> Result<()> {
            bail!("nope");
        }
        assert!(f().is_err());
    }

    #[test]
    fn from_std_error_and_question_mark() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert_eq!(e.root_cause(), "missing file");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(format!("{e}"), "empty");
    }
}
