//! Bit-identity: the vectorized compressor kernels vs the frozen scalar
//! reference (`compress::reference`) across the full `paper_suite()`.
//!
//! The cluster/staged bit-exactness guarantees (staged == synchronous
//! server, fused == naive EF, multi-process == inproc) all assume the
//! compressors are pure functions of (input, RNG stream). The chunked
//! rewrites must therefore produce **byte-identical wire payloads** and
//! **f32-bit-identical** decompress / add_decompressed / EF-residual
//! results — including non-finite inputs, empty tensors, and tail-sized
//! blocks (`n % 8 != 0`).

use byteps_compress::compress::reference::{compress_cycle_scalar, scalar_suite};
use byteps_compress::compress::{ef, paper_suite, Compressor, Ctx};
use byteps_compress::util::rng::Xoshiro256;

/// Sizes straddling the chunk width: empty, sub-chunk, exact multiples,
/// off-by-one tails, and larger blocks.
const SIZES: [usize; 11] = [0, 1, 5, 7, 8, 9, 31, 64, 100, 1000, 1003];

fn bits_of(x: &[f32]) -> Vec<u32> {
    x.iter().map(|v| v.to_bits()).collect()
}

/// Test vectors for one size: gaussian data, all zeros, and a gaussian
/// block with NaN/±inf injected at scattered positions.
fn inputs(n: usize) -> Vec<Vec<f32>> {
    let mut rng = Xoshiro256::seed_from_u64(0xC0FFEE ^ n as u64);
    let mut base = vec![0.0f32; n];
    rng.fill_normal(&mut base, 1.5);
    let mut nonfinite = base.clone();
    for (i, v) in nonfinite.iter_mut().enumerate() {
        match i % 13 {
            3 => *v = f32::NAN,
            7 => *v = f32::INFINITY,
            11 => *v = f32::NEG_INFINITY,
            _ => {}
        }
    }
    vec![base, vec![0.0f32; n], nonfinite]
}

/// NaN-aware bit comparison: equal bits, or both NaN. (A NaN scale reaches
/// every lane through ±scale decode; IEEE negation and NaN-propagation sign
/// conventions are the one place x86/ARM scalar-vs-vector codegen may
/// legitimately differ in the *payload* of a NaN, never in a real value.)
fn same_f32(a: f32, b: f32) -> bool {
    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
}

fn assert_same_slice(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            same_f32(*x, *y),
            "{what}: bit mismatch at {i}: {:#010x} vs {:#010x}",
            x.to_bits(),
            y.to_bits()
        );
    }
}

fn pattern(n: usize) -> Vec<f32> {
    (0..n).map(|i| ((i as f32) * 0.37).sin() * 2.0).collect()
}

#[test]
fn wire_payloads_are_byte_identical() {
    for ((label, fast), (slabel, slow)) in paper_suite().iter().zip(scalar_suite().iter()) {
        assert_eq!(label, slabel, "suite order drifted");
        for &n in &SIZES {
            for (case, x) in inputs(n).into_iter().enumerate() {
                let mut r1 = Xoshiro256::seed_from_u64(42 + n as u64);
                let mut r2 = Xoshiro256::seed_from_u64(42 + n as u64);
                let cf = fast.compress(&x, &mut Ctx::new(&mut r1));
                let cs = slow.compress(&x, &mut Ctx::new(&mut r2));
                assert_eq!(cf.scheme, cs.scheme, "{label} n={n} case={case}");
                assert_eq!(cf.n, cs.n, "{label} n={n} case={case}");
                assert_eq!(cf.payload, cs.payload, "{label} n={n} case={case}: wire bytes differ");
                // Both RNGs must have consumed the same draw count.
                assert_eq!(r1.next_u64(), r2.next_u64(), "{label} n={n} case={case}: RNG drifted");
            }
        }
    }
}

#[test]
fn decompress_and_accumulate_are_bit_identical() {
    for ((label, fast), (_, slow)) in paper_suite().iter().zip(scalar_suite().iter()) {
        for &n in &SIZES {
            for (case, x) in inputs(n).into_iter().enumerate() {
                let mut rng = Xoshiro256::seed_from_u64(7 * n as u64 + 1);
                let c = fast.compress(&x, &mut Ctx::new(&mut rng));
                let what = format!("{label} n={n} case={case}");

                let mut of = pattern(n);
                let mut os = pattern(n);
                fast.decompress(&c, &mut of);
                slow.decompress(&c, &mut os);
                assert_same_slice(&of, &os, &format!("{what} decompress"));

                let mut af = pattern(n);
                let mut as_ = pattern(n);
                fast.add_decompressed(&c, &mut af);
                slow.add_decompressed(&c, &mut as_);
                assert_same_slice(&af, &as_, &format!("{what} add_decompressed"));
            }
        }
    }
}

#[test]
fn fused_ef_wire_and_residual_are_bit_identical() {
    for ((label, fast), (_, slow)) in paper_suite().iter().zip(scalar_suite().iter()) {
        for &n in &SIZES {
            for (case, x) in inputs(n).into_iter().enumerate() {
                let mut r1 = Xoshiro256::seed_from_u64(1000 + n as u64);
                let mut r2 = Xoshiro256::seed_from_u64(1000 + n as u64);
                let mut qf = x.clone();
                let mut qs = x.clone();
                let cf = fast.compress_ef_fused(&mut qf, &mut Ctx::new(&mut r1));
                let cs = slow.compress_ef_fused(&mut qs, &mut Ctx::new(&mut r2));
                let what = format!("{label} n={n} case={case} fused");
                assert_eq!(cf.payload, cs.payload, "{what}: wire bytes differ");
                assert_same_slice(&qf, &qs, &format!("{what} residual"));
            }
        }
    }
}

/// Multi-step EF cycles (Alg. 4): `ef::compress_cycle` (chunked
/// accumulate/decay) against the scalar cycle, residual carried across
/// iterations, both fused and naive.
#[test]
fn ef_cycle_matches_scalar_cycle_over_time() {
    for ((label, fast), (_, slow)) in paper_suite().iter().zip(scalar_suite().iter()) {
        for fused in [true, false] {
            for &n in &[0usize, 9, 100, 1003] {
                let mut r1 = Xoshiro256::seed_from_u64(77);
                let mut r2 = Xoshiro256::seed_from_u64(77);
                let mut data_rng = Xoshiro256::seed_from_u64(5 + n as u64);
                let mut ef_fast: Option<Vec<f32>> = None;
                let mut ef_slow: Option<Vec<f32>> = None;
                for step in 0..4 {
                    let mut g = vec![0.0f32; n];
                    data_rng.fill_normal(&mut g, 1.0);
                    let (cf, rf) = ef::compress_cycle(
                        fast.as_ref(),
                        fused,
                        &mut Ctx::new(&mut r1),
                        g.clone(),
                        ef_fast.as_deref(),
                    );
                    let (cs, rs) = compress_cycle_scalar(
                        slow.as_ref(),
                        fused,
                        &mut Ctx::new(&mut r2),
                        g,
                        ef_slow.as_deref(),
                    );
                    let what = format!("{label} n={n} fused={fused} step={step}");
                    assert_eq!(cf.payload, cs.payload, "{what}: wire bytes differ");
                    assert_same_slice(&rf, &rs, &format!("{what} residual"));
                    ef_fast = Some(rf);
                    ef_slow = Some(rs);
                }
            }
        }
    }
}
