//! Tier-1 gate for the static-invariants lint (`byteps_compress::lint`).
//!
//! Walks the real `rust/src/**` tree plus DESIGN.md and fails with one
//! line per broken invariant — `file:line: [rule] message` — so a red
//! run names exactly what drifted. The rule set and annotation grammar
//! are documented in DESIGN.md §Static invariants; the lint's own
//! behavior is covered by fixture tests inside `rust/src/lint/`.

use std::path::Path;

#[test]
fn static_invariants_hold() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let violations = match byteps_compress::lint::run_all(root) {
        Ok(v) => v,
        Err(e) => panic!("static-invariants lint could not walk the tree: {e}"),
    };
    if !violations.is_empty() {
        let mut report = String::new();
        for v in &violations {
            report.push_str(&format!("  {v}\n"));
        }
        panic!(
            "{} static invariant violation(s) in rust/src (see DESIGN.md §Static invariants):\n{report}",
            violations.len()
        );
    }
}
