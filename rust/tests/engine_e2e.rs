//! End-to-end engine test: train the tiny transformer through the full
//! stack (PJRT artifacts + compressed PS fabric + CLAN) and check that the
//! loss moves and CLAN tracks LANS. Requires `make artifacts`; skips
//! gracefully otherwise.

use byteps_compress::configx::{SyncMode, TrainConfig};
use byteps_compress::engine;
use std::path::Path;

fn art_dir() -> Option<std::path::PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

fn base_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.model = "transformer_tiny".into();
    cfg.steps = 12;
    cfg.cluster.nodes = 2;
    cfg.cluster.servers = 2;
    cfg.optimizer.name = "clan".into();
    cfg.optimizer.lr = 2e-3;
    cfg.log_every = 6;
    cfg.compression.size_threshold = 4096; // compress most tensors
    cfg
}

#[test]
fn clan_trains_tiny_transformer_end_to_end() {
    let Some(dir) = art_dir() else { return };
    let mut cfg = base_cfg();
    cfg.compression.scheme = "topk".into();
    cfg.compression.param = 0.01;
    cfg.compression.sync = SyncMode::CompressedEf;
    let report = engine::train(&cfg, &dir).unwrap();

    assert_eq!(report.losses.len(), 12);
    let first = report.losses[0].1;
    let last = report.final_loss();
    // MLM loss starts near log(vocab) ≈ 7.6 and must visibly decrease
    // within 12 steps on the coherent synthetic corpus.
    assert!(first > 5.0, "initial loss {first}");
    assert!(last < first - 0.2, "loss did not decrease: {first} -> {last}");
    assert!(report.wire_bytes > 0);
    // top-k at 1% + small-tensor bypass: still well under full precision.
    assert!(
        report.compression_rate() > 5.0,
        "compression rate {}",
        report.compression_rate()
    );
    assert!(!report.eval_losses.is_empty());
}

#[test]
fn clan_loss_tracks_lans_loss() {
    let Some(dir) = art_dir() else { return };
    // LANS (full precision)
    let mut lans_cfg = base_cfg();
    lans_cfg.compression.scheme = "identity".into();
    lans_cfg.compression.sync = SyncMode::Full;
    let lans = engine::train(&lans_cfg, &dir).unwrap();

    // CLAN (scaled 1-bit with EF — the paper's Fig. 5 variant)
    let mut clan_cfg = base_cfg();
    clan_cfg.compression.scheme = "onebit".into();
    clan_cfg.compression.sync = SyncMode::CompressedEf;
    let clan = engine::train(&clan_cfg, &dir).unwrap();

    let l = lans.final_loss();
    let c = clan.final_loss();
    // Identical data order; losses should track within a modest margin
    // this early in training (Fig. 5's "same convergence" claim).
    assert!((c - l).abs() < 0.8, "CLAN {c} vs LANS {l}");
    // And the wire volume must be dramatically smaller.
    assert!(clan.wire_bytes * 8 < lans.wire_bytes, "onebit {} vs full {}", clan.wire_bytes, lans.wire_bytes);
}

#[test]
fn classifier_engine_runs() {
    let Some(dir) = art_dir() else { return };
    let mut cfg = base_cfg();
    cfg.model = "classifier_tiny".into();
    cfg.steps = 6;
    cfg.compression.scheme = "onebit".into();
    cfg.compression.sync = SyncMode::CompressedEf;
    let report = engine::train(&cfg, &dir).unwrap();
    assert_eq!(report.losses.len(), 6);
    assert!(report.losses.iter().all(|(_, l)| l.is_finite()));
}
