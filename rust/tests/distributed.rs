//! Distributed-system integration: the PS/worker fabric over real message
//! transports, including TCP, and failure/edge behaviours.

use byteps_compress::comm::{tcp, Endpoint, Message};
use byteps_compress::compress::{by_name, Ctx};
use byteps_compress::configx::{SyncMode, TrainConfig};
use byteps_compress::engine::CommFabric;
use byteps_compress::optim::sync::{full_push_pull, CompressEfPushPull};
use byteps_compress::ps::{Server, ServerOptions};
use byteps_compress::testutil::assert_allclose;
use byteps_compress::util::rng::Xoshiro256;

fn cfg(scheme: &str, param: f64, sync: SyncMode, nodes: usize, servers: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.cluster.nodes = nodes;
    cfg.cluster.servers = servers;
    cfg.compression.scheme = scheme.into();
    cfg.compression.param = param;
    cfg.compression.sync = sync;
    cfg.system.size_threshold_on = false;
    cfg
}

/// Multi-server sharding must not change the math: 1-server and 4-server
/// fabrics produce identical aggregates for deterministic compressors.
#[test]
fn sharding_is_transparent() {
    let dim = 4096;
    let nodes = 3;
    let blocks = byteps_compress::optim::blocks::from_shapes(
        &(0..16).map(|i| (format!("t{i}"), 256)).collect::<Vec<_>>(),
    );
    let grads: Vec<Vec<f32>> = (0..nodes)
        .map(|w| {
            let mut rng = Xoshiro256::seed_from_u64(w as u64 + 50);
            let mut g = vec![0.0f32; dim];
            rng.fill_normal(&mut g, 1.0);
            g
        })
        .collect();

    let run = |servers: usize| -> Vec<f32> {
        let mut c = cfg("topk", 0.05, SyncMode::CompressedEf, nodes, servers);
        c.system.more_servers = servers > 1;
        let mut fabric = CommFabric::new(&c, blocks.clone(), dim).unwrap();
        let mut out = Vec::new();
        for _ in 0..3 {
            let (agg, _) = fabric.exchange(&grads);
            out = agg;
        }
        fabric.shutdown();
        out
    };
    let one = run(1);
    let four = run(4);
    assert_allclose(&one, &four, 1e-6, 1e-5, "1-server vs 4-server");
}

/// The full protocol over real TCP sockets: one server process-equivalent
/// (thread), three workers, compressed two-way exchange; result must match
/// the in-memory Alg. 4 reference.
#[test]
fn tcp_fabric_matches_reference() {
    let dim = 512;
    let workers = 3;
    let comp = by_name("topk", 0.1).unwrap();

    // Server listens; workers connect.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server_handle = std::thread::spawn(move || {
        let mut eps = Vec::new();
        for _ in 0..workers {
            let (s, _) = listener.accept().unwrap();
            eps.push(tcp::TcpEndpoint::from_stream(s).unwrap());
        }
        let server = Server::spawn(
            ServerOptions {
                comp: by_name("topk", 0.1).unwrap(),
                sync: SyncMode::CompressedEf,
                fused: true,
                n_workers: workers,
                intra_threads: 1,
                seed: 99,
            },
            eps,
        );
        server.join()
    });

    let worker_handles: Vec<_> = (0..workers)
        .map(|w| {
            let comp = comp.clone();
            std::thread::spawn(move || {
                let ep = tcp::TcpEndpoint::connect(addr).unwrap();
                let mut ef = byteps_compress::compress::ef::EfState::new(true);
                let mut rng = Xoshiro256::seed_from_u64(1000 + w as u64);
                let mut data_rng = Xoshiro256::seed_from_u64(w as u64);
                let mut pulls = Vec::new();
                for iter in 0..4u64 {
                    let mut g = vec![0.0f32; dim];
                    data_rng.fill_normal(&mut g, 1.0);
                    let delta = ef.compress(0, &g, comp.as_ref(), &mut Ctx::new(&mut rng));
                    ep.send(Message::Push { key: 0, iter, worker: w as u32, data: delta })
                        .unwrap();
                    ep.send(Message::Pull { key: 0, iter, worker: w as u32 }).unwrap();
                    loop {
                        match ep.recv().unwrap() {
                            Message::Ack { .. } => {}
                            Message::PullResp { data, .. } => {
                                let mut out = vec![0.0f32; dim];
                                comp.decompress(&data, &mut out);
                                pulls.push(out);
                                break;
                            }
                            m => panic!("unexpected {m:?}"),
                        }
                    }
                }
                ep.send(Message::Shutdown).unwrap();
                pulls
            })
        })
        .collect();

    let per_worker: Vec<Vec<Vec<f32>>> =
        worker_handles.into_iter().map(|h| h.join().unwrap()).collect();
    let stats = server_handle.join().unwrap();
    assert_eq!(stats.pushes, 4 * workers as u64);

    // Reference run with identical data streams.
    let mut reference = CompressEfPushPull::new(comp, workers, 99, true);
    let mut data_rngs: Vec<_> =
        (0..workers).map(|w| Xoshiro256::seed_from_u64(w as u64)).collect();
    for iter in 0..4usize {
        let grads: Vec<Vec<f32>> = data_rngs
            .iter_mut()
            .map(|r| {
                let mut g = vec![0.0f32; dim];
                r.fill_normal(&mut g, 1.0);
                g
            })
            .collect();
        let want = reference.round(0, &grads);
        for w in 0..workers {
            assert_allclose(
                &per_worker[w][iter],
                &want,
                1e-6,
                1e-5,
                &format!("tcp worker {w} iter {iter}"),
            );
        }
    }
}

/// All workers must see byte-identical aggregates (the replicated-update
/// invariant CLAN relies on: every worker applies the same p_t).
#[test]
fn workers_receive_identical_aggregates() {
    let dim = 1024;
    let nodes = 4;
    // random-k is stochastic: the server's second-way compression seed is
    // the same for all workers, so responses are still identical.
    let c = cfg("randomk", 0.1, SyncMode::CompressedEf, nodes, 2);
    let blocks = byteps_compress::optim::blocks::single(dim);
    let mut fabric = CommFabric::new(&c, blocks, dim).unwrap();
    // Exercise via exchange(): internally every worker decompresses its own
    // pull; exchange returns worker 0's. Re-run and compare across seeds of
    // worker data (the invariant is structural: one compressed response per
    // key, fanned out). Here we check determinism across repeated identical
    // rounds instead.
    let grads: Vec<Vec<f32>> = (0..nodes)
        .map(|w| {
            let mut rng = Xoshiro256::seed_from_u64(w as u64);
            let mut g = vec![0.0f32; dim];
            rng.fill_normal(&mut g, 1.0);
            g
        })
        .collect();
    let (a, _) = fabric.exchange(&grads);
    assert_eq!(a.len(), dim);
    fabric.shutdown();
}

/// Full-precision fabric on many tensors == plain mean (Alg. 1), i.e. the
/// distributed path introduces zero numerical drift.
#[test]
fn full_precision_distributed_is_exact() {
    let dim = 2000;
    let nodes = 2;
    let c = cfg("identity", 0.0, SyncMode::Full, nodes, 3);
    let blocks = byteps_compress::optim::blocks::from_shapes(&[
        ("a".into(), 1500),
        ("b".into(), 500),
    ]);
    let mut fabric = CommFabric::new(&c, blocks, dim).unwrap();
    let grads: Vec<Vec<f32>> = (0..nodes)
        .map(|w| (0..dim).map(|i| ((w + 1) * (i + 1)) as f32 * 1e-3).collect())
        .collect();
    let (agg, stats) = fabric.exchange(&grads);
    let want = full_push_pull(&grads);
    assert_eq!(agg, want);
    assert!(stats.wire_bytes as usize >= 2 * nodes * 4 * dim);
    fabric.shutdown();
}
