//! Distributed-system integration: the PS/worker fabric over real message
//! transports, including TCP, the block-partitioned pipeline (§4.2.1), and
//! failure/edge behaviours.

use byteps_compress::comm::{tcp, BlockKey, CommError, Endpoint, Message};
use byteps_compress::compress::{by_name, Ctx};
use byteps_compress::configx::{SyncMode, TrainConfig};
use byteps_compress::engine::CommFabric;
use byteps_compress::optim::sync::{full_push_pull, CompressEfPushPull};
use byteps_compress::ps::{Server, ServerOptions};
use byteps_compress::testutil::assert_allclose;
use byteps_compress::util::rng::Xoshiro256;
use byteps_compress::worker::pipeline::SubBlock;

fn cfg(scheme: &str, param: f64, sync: SyncMode, nodes: usize, servers: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.cluster.nodes = nodes;
    cfg.cluster.servers = servers;
    cfg.compression.scheme = scheme.into();
    cfg.compression.param = param;
    cfg.compression.sync = sync;
    cfg.system.size_threshold_on = false;
    cfg
}

/// Integer-valued gradients: every partial sum is exactly representable in
/// f32, so aggregation order cannot change the result bits and runs are
/// comparable bit-for-bit.
fn integer_grads(nodes: usize, dim: usize) -> Vec<Vec<f32>> {
    (0..nodes)
        .map(|w| (0..dim).map(|i| (((w + 1) * ((i % 13) + 1)) as f32) - 7.0).collect())
        .collect()
}

/// Multi-server sharding must not change the math: 1-server and 4-server
/// fabrics produce identical aggregates for deterministic compressors.
#[test]
fn sharding_is_transparent() {
    let dim = 4096;
    let nodes = 3;
    let blocks = byteps_compress::optim::blocks::from_shapes(
        &(0..16).map(|i| (format!("t{i}"), 256)).collect::<Vec<_>>(),
    );
    let grads: Vec<Vec<f32>> = (0..nodes)
        .map(|w| {
            let mut rng = Xoshiro256::seed_from_u64(w as u64 + 50);
            let mut g = vec![0.0f32; dim];
            rng.fill_normal(&mut g, 1.0);
            g
        })
        .collect();

    let run = |servers: usize| -> Vec<f32> {
        let mut c = cfg("topk", 0.05, SyncMode::CompressedEf, nodes, servers);
        c.system.more_servers = servers > 1;
        let mut fabric = CommFabric::new(&c, blocks.clone(), dim).unwrap();
        let mut out = Vec::new();
        for _ in 0..3 {
            let (agg, _) = fabric.exchange(&grads);
            out = agg;
        }
        fabric.shutdown();
        out
    };
    let one = run(1);
    let four = run(4);
    assert_allclose(&one, &four, 1e-6, 1e-5, "1-server vs 4-server");
}

/// Tentpole acceptance: with the identity compressor, the block-partitioned
/// pipeline is bit-identical to the serial whole-tensor path — partitioning
/// and job scheduling change *when* work happens, never the bytes.
#[test]
fn pipelined_identity_is_bit_identical_to_serial() {
    let sizes: [usize; 4] = [700, 2048, 96, 3000];
    let dim: usize = sizes.iter().sum();
    let nodes = 3;
    let blocks = byteps_compress::optim::blocks::from_shapes(
        &sizes.iter().enumerate().map(|(i, &s)| (format!("t{i}"), s)).collect::<Vec<_>>(),
    );
    let grads = integer_grads(nodes, dim);

    let run = |pipelined: bool| -> Vec<Vec<f32>> {
        let mut c = cfg("identity", 0.0, SyncMode::Full, nodes, 2);
        c.pipeline.enabled = pipelined;
        c.pipeline.block_bytes = 512 * 4; // 512-elem blocks: every big tensor splits
        c.pipeline.inflight = 4;
        let mut fabric = CommFabric::new(&c, blocks.clone(), dim).unwrap();
        if pipelined {
            // The partition really is block-level (more wire units than tensors).
            assert!(fabric.partition().len() > blocks.len());
        } else {
            assert_eq!(fabric.partition().len(), blocks.len());
        }
        let mut out = Vec::new();
        for _ in 0..3 {
            let (agg, stats) = fabric.exchange(&grads);
            assert!(stats.wire_bytes > 0);
            out.push(agg);
        }
        fabric.shutdown();
        out
    };

    let serial = run(false);
    let pipelined = run(true);
    for (round, (a, b)) in serial.iter().zip(&pipelined).enumerate() {
        assert_eq!(a, b, "round {round}: pipelined aggregate differs from serial");
    }
    // And both equal the exact mean.
    let want = full_push_pull(&grads);
    assert_eq!(serial[0], want);
}

/// Pipelined top-k + EF equals the in-memory Alg. 4 reference applied
/// independently per block — per-block keys, residuals, and server EF all
/// line up under concurrent job scheduling and out-of-order block arrival.
#[test]
fn pipelined_topk_ef_matches_per_block_reference() {
    let nodes = 2;
    let blocks = byteps_compress::optim::blocks::from_shapes(&[
        ("big".into(), 1200),
        ("mid".into(), 800),
    ]);
    let dim = 2000;
    let mut c = cfg("topk", 0.1, SyncMode::CompressedEf, nodes, 3);
    c.pipeline.enabled = true;
    c.pipeline.block_bytes = 256 * 4; // 256-elem blocks
    let mut fabric = CommFabric::new(&c, blocks, dim).unwrap();
    let subs: Vec<SubBlock> = fabric.partition().subs().to_vec();
    assert_eq!(subs.len(), 5 + 4, "1200 -> 5 blocks, 800 -> 4 blocks");

    let comp = by_name("topk", 0.1).unwrap();
    let mut refs: Vec<CompressEfPushPull> = subs
        .iter()
        .map(|_| CompressEfPushPull::new(comp.clone(), nodes, 1, true))
        .collect();

    let mut data_rng = Xoshiro256::seed_from_u64(11);
    for round in 0..4 {
        let grads: Vec<Vec<f32>> = (0..nodes)
            .map(|_| {
                let mut g = vec![0.0f32; dim];
                data_rng.fill_normal(&mut g, 1.0);
                g
            })
            .collect();
        let (got, _) = fabric.exchange(&grads);
        let mut want = vec![0.0f32; dim];
        for (j, sb) in subs.iter().enumerate() {
            let per_block: Vec<Vec<f32>> =
                grads.iter().map(|g| g[sb.range.clone()].to_vec()).collect();
            let p = refs[j].round(sb.key, &per_block);
            want[sb.range.clone()].copy_from_slice(&p);
        }
        assert_allclose(&got, &want, 1e-6, 1e-5, &format!("round {round} vs per-block Alg.4"));
    }
    fabric.shutdown();
}

/// The one-slot `prev` rollover invariant holds per block key: many rounds
/// over many blocks with skewed worker timing (each exchange has workers
/// finishing in different orders) never deadlock or mis-serve a pull.
#[test]
fn pipelined_many_rounds_preserve_rollover_invariant() {
    let nodes = 4;
    let sizes: [usize; 3] = [1030, 517, 2051]; // awkward remainders
    let dim: usize = sizes.iter().sum();
    let blocks = byteps_compress::optim::blocks::from_shapes(
        &sizes.iter().enumerate().map(|(i, &s)| (format!("t{i}"), s)).collect::<Vec<_>>(),
    );
    let mut c = cfg("topk", 0.05, SyncMode::CompressedEf, nodes, 3);
    c.pipeline.enabled = true;
    c.pipeline.block_bytes = 128 * 4; // many small blocks
    c.pipeline.inflight = 2; // force submission back-pressure
    let mut fabric = CommFabric::new(&c, blocks, dim).unwrap();
    let mut data_rng = Xoshiro256::seed_from_u64(21);
    for _ in 0..8 {
        let grads: Vec<Vec<f32>> = (0..nodes)
            .map(|_| {
                let mut g = vec![0.0f32; dim];
                data_rng.fill_normal(&mut g, 1.0);
                g
            })
            .collect();
        let (agg, stats) = fabric.exchange(&grads);
        assert_eq!(agg.len(), dim);
        assert!(stats.wire_bytes > 0);
    }
    let stats = fabric.shutdown();
    let pushes: u64 = stats.iter().map(|s| s.pushes).sum();
    // 8 rounds x 4 workers x (9 + 5 + 17) blocks.
    let n_blocks = (1030usize.div_ceil(128) + 517usize.div_ceil(128) + 2051usize.div_ceil(128)) as u64;
    assert_eq!(pushes, 8 * 4 * n_blocks);
    assert_eq!(stats.iter().map(|s| s.rejected).sum::<u64>(), 0);
}

/// A corrupt frame arriving over real TCP is rejected at decode as a
/// protocol error (server-crash regression: out-of-range top-k index).
#[test]
fn tcp_corrupt_frame_is_protocol_error() {
    use std::io::Write;
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let client = std::thread::spawn(move || {
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        // Hand-rolled Push frame: topk block n=8 with index 9999.
        let mut body = Vec::new();
        body.push(1u8); // TAG_PUSH
        body.extend_from_slice(&5u64.to_le_bytes()); // key
        body.extend_from_slice(&0u64.to_le_bytes()); // iter
        body.extend_from_slice(&0u32.to_le_bytes()); // worker
        body.push(3u8); // SchemeId::TopK
        body.extend_from_slice(&8u64.to_le_bytes()); // n
        body.extend_from_slice(&12u32.to_le_bytes()); // payload len
        body.extend_from_slice(&1u32.to_le_bytes()); // k = 1
        body.extend_from_slice(&9999u32.to_le_bytes()); // index out of range
        body.extend_from_slice(&1.0f32.to_le_bytes());
        let mut frame = (body.len() as u32).to_le_bytes().to_vec();
        frame.extend_from_slice(&body);
        s.write_all(&frame).unwrap();
    });
    let (stream, _) = listener.accept().unwrap();
    let ep = tcp::TcpEndpoint::from_stream(stream).unwrap();
    let err = ep.recv().unwrap_err();
    assert!(
        matches!(err, CommError::Protocol(ref m) if m.contains("out of range")),
        "expected protocol error, got {err:?}"
    );
    client.join().unwrap();
}

/// A single large tensor partitions into distinct per-block wire keys (the
/// unit the balanced shard plan spreads across servers — plan behaviour
/// itself is covered in `ps::plan::tests::keyed_plan_spreads_blocks_of_one_tensor`).
#[test]
fn one_tensor_partitions_into_distinct_block_keys() {
    let dim = 4096;
    let blocks = byteps_compress::optim::blocks::single(dim);
    let mut c = cfg("topk", 0.01, SyncMode::CompressedEf, 2, 4);
    c.pipeline.enabled = true;
    c.pipeline.block_bytes = 512 * 4;
    let fabric = CommFabric::new(&c, blocks, dim).unwrap();
    let keys: Vec<_> = fabric.partition().subs().iter().map(|sb| sb.key).collect();
    assert_eq!(keys.len(), 8);
    // All 8 blocks belong to tensor 0 but carry distinct block sub-keys.
    for (j, &k) in keys.iter().enumerate() {
        assert_eq!(BlockKey::unpack(k), BlockKey::new(0, j as u32));
    }
    fabric.shutdown();
}

/// The full protocol over real TCP sockets: one server process-equivalent
/// (thread), three workers, compressed two-way exchange; result must match
/// the in-memory Alg. 4 reference.
#[test]
fn tcp_fabric_matches_reference() {
    let dim = 512;
    let workers = 3;
    let comp = by_name("topk", 0.1).unwrap();

    // Server listens; workers connect.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server_handle = std::thread::spawn(move || {
        let mut eps = Vec::new();
        for _ in 0..workers {
            let (s, _) = listener.accept().unwrap();
            eps.push(tcp::TcpEndpoint::from_stream(s).unwrap());
        }
        let server = Server::spawn(
            ServerOptions {
                comp: by_name("topk", 0.1).unwrap(),
                sync: SyncMode::CompressedEf,
                fused: true,
                n_workers: workers,
                intra_threads: 1,
                seed: 99,
                max_keys: 0,
                iter_deadline: None,
                compress_threads: 0,
                deadline_auto_margin: 0.0,
                adaptive_bounds: None,
            },
            eps,
        );
        server.join()
    });

    let worker_handles: Vec<_> = (0..workers)
        .map(|w| {
            let comp = comp.clone();
            std::thread::spawn(move || {
                let ep = tcp::TcpEndpoint::connect(addr).unwrap();
                let mut ef = byteps_compress::compress::ef::EfState::new(true);
                let mut rng = Xoshiro256::seed_from_u64(1000 + w as u64);
                let mut data_rng = Xoshiro256::seed_from_u64(w as u64);
                let mut pulls = Vec::new();
                for iter in 0..4u64 {
                    let mut g = vec![0.0f32; dim];
                    data_rng.fill_normal(&mut g, 1.0);
                    let delta = ef.compress(0, &g, comp.as_ref(), &mut Ctx::new(&mut rng));
                    ep.send(Message::Push { key: 0, iter, worker: w as u32, data: delta })
                        .unwrap();
                    ep.send(Message::Pull { key: 0, iter, worker: w as u32 }).unwrap();
                    loop {
                        match ep.recv().unwrap() {
                            Message::Ack { .. } => {}
                            Message::PullResp { data, .. } => {
                                let mut out = vec![0.0f32; dim];
                                comp.decompress(&data, &mut out);
                                pulls.push(out);
                                break;
                            }
                            m => panic!("unexpected {m:?}"),
                        }
                    }
                }
                ep.send(Message::Shutdown).unwrap();
                pulls
            })
        })
        .collect();

    let per_worker: Vec<Vec<Vec<f32>>> =
        worker_handles.into_iter().map(|h| h.join().unwrap()).collect();
    let stats = server_handle.join().unwrap();
    assert_eq!(stats.pushes, 4 * workers as u64);

    // Reference run with identical data streams.
    let mut reference = CompressEfPushPull::new(comp, workers, 99, true);
    let mut data_rngs: Vec<_> =
        (0..workers).map(|w| Xoshiro256::seed_from_u64(w as u64)).collect();
    for iter in 0..4usize {
        let grads: Vec<Vec<f32>> = data_rngs
            .iter_mut()
            .map(|r| {
                let mut g = vec![0.0f32; dim];
                r.fill_normal(&mut g, 1.0);
                g
            })
            .collect();
        let want = reference.round(0, &grads);
        for w in 0..workers {
            assert_allclose(
                &per_worker[w][iter],
                &want,
                1e-6,
                1e-5,
                &format!("tcp worker {w} iter {iter}"),
            );
        }
    }
}

/// All workers must see byte-identical aggregates (the replicated-update
/// invariant CLAN relies on: every worker applies the same p_t).
#[test]
fn workers_receive_identical_aggregates() {
    let dim = 1024;
    let nodes = 4;
    // random-k is stochastic: the server's second-way compression seed is
    // the same for all workers, so responses are still identical.
    let c = cfg("randomk", 0.1, SyncMode::CompressedEf, nodes, 2);
    let blocks = byteps_compress::optim::blocks::single(dim);
    let mut fabric = CommFabric::new(&c, blocks, dim).unwrap();
    // Exercise via exchange(): internally every worker decompresses its own
    // pull; exchange returns worker 0's. Re-run and compare across seeds of
    // worker data (the invariant is structural: one compressed response per
    // key, fanned out). Here we check determinism across repeated identical
    // rounds instead.
    let grads: Vec<Vec<f32>> = (0..nodes)
        .map(|w| {
            let mut rng = Xoshiro256::seed_from_u64(w as u64);
            let mut g = vec![0.0f32; dim];
            rng.fill_normal(&mut g, 1.0);
            g
        })
        .collect();
    let (a, _) = fabric.exchange(&grads);
    assert_eq!(a.len(), dim);
    fabric.shutdown();
}

/// Full-precision fabric on many tensors == plain mean (Alg. 1), i.e. the
/// distributed path introduces zero numerical drift.
#[test]
fn full_precision_distributed_is_exact() {
    let dim = 2000;
    let nodes = 2;
    let c = cfg("identity", 0.0, SyncMode::Full, nodes, 3);
    let blocks = byteps_compress::optim::blocks::from_shapes(&[
        ("a".into(), 1500),
        ("b".into(), 500),
    ]);
    let mut fabric = CommFabric::new(&c, blocks, dim).unwrap();
    let grads: Vec<Vec<f32>> = (0..nodes)
        .map(|w| (0..dim).map(|i| ((w + 1) * (i + 1)) as f32 * 1e-3).collect())
        .collect();
    let (agg, stats) = fabric.exchange(&grads);
    let want = full_push_pull(&grads);
    assert_eq!(agg, want);
    assert!(stats.wire_bytes as usize >= 2 * nodes * 4 * dim);
    fabric.shutdown();
}
