//! Counting-allocator audit of the pooled wire path — the perf acceptance
//! check that the steady-state TCP send/recv loop does **no per-frame heap
//! allocation**: the per-connection scratch buffers absorb frame bodies,
//! and [`byteps_compress::comm::BufPool`] recycles block payloads.
//!
//! Lives in its own test binary: it installs a counting
//! `#[global_allocator]`, which must not leak into the other harnesses.

use byteps_compress::comm::tcp::TcpEndpoint;
use byteps_compress::comm::{BufPool, Endpoint, Message};
use byteps_compress::compress::{Compressed, SchemeId};
use std::alloc::{GlobalAlloc, Layout, System};
use std::net::TcpListener;
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn push(iter: u64, payload: Vec<u8>) -> Message {
    Message::Push {
        key: 3,
        iter,
        worker: 0,
        data: Compressed { scheme: SchemeId::Identity, n: payload.len() / 4, payload },
    }
}

/// Push → recv → ack → recv over loopback, fixed frame size. After a
/// warmup that grows every scratch buffer and primes the pool, the
/// measured window must allocate (close to) nothing — the pre-pool wire
/// path allocated at least three times per frame (encoded frame, recv
/// body, decoded payload), i.e. 600+ over this window.
#[test]
fn steady_state_tcp_path_does_not_allocate_per_frame() {
    const DIM_BYTES: usize = 4096;
    const WARMUP: u64 = 50;
    const MEASURED: u64 = 200;

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let client = TcpEndpoint::connect(addr).unwrap();
    let (stream, _) = listener.accept().unwrap();
    let server = TcpEndpoint::from_stream(stream).unwrap();

    let pool = BufPool::global();
    let roundtrip = |iter: u64| {
        // Payload rented from the pool; the send path recycles it after
        // serializing, and frame decode rents it back for the block.
        let payload = pool.rent_bytes(DIM_BYTES);
        client.send(push(iter, payload)).unwrap();
        match server.recv().unwrap() {
            Message::Push { data, .. } => {
                assert_eq!(data.payload.len(), DIM_BYTES);
                // What the server's decode stage does once the block is
                // consumed: hand the wire payload back to the pool.
                pool.give_bytes(data.payload);
            }
            m => panic!("unexpected {m:?}"),
        }
        server.send(Message::Ack { key: 3, iter }).unwrap();
        match client.recv().unwrap() {
            Message::Ack { .. } => {}
            m => panic!("unexpected {m:?}"),
        }
    };

    for i in 0..WARMUP {
        roundtrip(i);
    }
    let before = ALLOCS.load(Ordering::Relaxed);
    for i in 0..MEASURED {
        roundtrip(WARMUP + i);
    }
    let delta = ALLOCS.load(Ordering::Relaxed) - before;
    assert!(
        delta < 16,
        "steady-state wire path allocated {delta} times over {MEASURED} frames \
         (expected ~0: connection scratch and the BufPool absorb per-frame allocation)"
    );
}
