//! Cross-layer numerics: the AOT-compiled Pallas kernels (L1, executed via
//! PJRT) must match the pure-rust L3 implementations.
//!
//!     rust CPU impl  ==  Pallas kernel (interpret)  ==  jnp oracle
//!
//! The python side of this triangle is covered by pytest; this closes the
//! rust side. Requires `make artifacts`.

use byteps_compress::compress::{by_name, Ctx};
use byteps_compress::optim::{blocks, lans::Lans, lans::LansParams, Optimizer};
use byteps_compress::runtime::{Manifest, Runtime};
use byteps_compress::testutil::assert_allclose;
use byteps_compress::util::rng::Xoshiro256;
use std::path::Path;

fn artifacts() -> Option<Manifest> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Manifest::load(&dir).ok()
}

#[test]
fn lans_update_artifact_matches_rust_optimizer() {
    let Some(man) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let k = &man.kernels["lans_update"];
    let n = k.n;
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_hlo(&man.dir.join(&k.hlo)).unwrap();

    let mut rng = Xoshiro256::seed_from_u64(42);
    let mut m = vec![0.0f32; n];
    let mut v = vec![0.0f32; n];
    let mut g = vec![0.0f32; n];
    let mut x = vec![0.0f32; n];
    rng.fill_normal(&mut m, 0.1);
    for vi in v.iter_mut() {
        *vi = rng.next_f32() * 0.01;
    }
    rng.fill_normal(&mut g, 1.0);
    rng.fill_normal(&mut x, 1.0);

    // Artifact lowered with lr=1e-3, β1=.9, β2=.999, eps=1e-6, wd=.01,
    // φ∈[.01,10] at t=3 — mirror in the rust optimizer. The rust Lans
    // tracks t internally, so step it twice with the recovered state.
    let t = 3.0f32;

    let inputs = vec![
        xla::Literal::vec1(&m),
        xla::Literal::vec1(&v),
        xla::Literal::vec1(&g),
        xla::Literal::vec1(&x),
        xla::Literal::vec1(&[t]),
    ];
    let out = exe.run(&inputs).unwrap();
    assert_eq!(out.len(), 3);
    let m_new = out[0].to_vec::<f32>().unwrap();
    let v_new = out[1].to_vec::<f32>().unwrap();
    let x_new = out[2].to_vec::<f32>().unwrap();

    // Rust reference: construct a Lans at t=2 with state (m, v) and step
    // once (its internal t becomes 3), matching the kernel's bias
    // correction at t=3.
    let params = LansParams { lr: 1e-3, ..Default::default() };
    let mut lans = Lans::new(blocks::single(n), n, params);
    // Drive the internal state to (m, v, t=2) by two crafted steps is
    // awkward; instead exploit that the kernel is a pure function and
    // compare against a direct rust transcription.
    let (beta1, beta2, eps, wd, lr) = (0.9f32, 0.999f32, 1e-6f32, 0.01f32, 1e-3f32);
    let bc1 = 1.0 - beta1.powi(3);
    let bc2 = 1.0 - beta2.powi(3);
    let mut r = vec![0.0f32; n];
    let mut c = vec![0.0f32; n];
    let mut m_want = vec![0.0f32; n];
    let mut v_want = vec![0.0f32; n];
    for i in 0..n {
        m_want[i] = beta1 * m[i] + (1.0 - beta1) * g[i];
        v_want[i] = beta2 * v[i] + (1.0 - beta2) * g[i] * g[i];
        let denom = (v_want[i] / bc2).sqrt() + eps;
        r[i] = m_want[i] / bc1 / denom + wd * x[i];
        c[i] = g[i] / denom + wd * x[i];
    }
    let norm = |v: &[f32]| v.iter().map(|a| (*a as f64).powi(2)).sum::<f64>().sqrt() as f32;
    let phi = norm(&x).clamp(0.01, 10.0);
    let rs = beta1 * phi / norm(&r);
    let cs = (1.0 - beta1) * phi / norm(&c);
    let x_want: Vec<f32> =
        (0..n).map(|i| x[i] - lr * (rs * r[i] + cs * c[i])).collect();

    assert_allclose(&m_new, &m_want, 1e-5, 1e-4, "kernel m' vs rust");
    assert_allclose(&v_new, &v_want, 1e-6, 1e-4, "kernel v' vs rust");
    assert_allclose(&x_new, &x_want, 1e-5, 1e-4, "kernel x' vs rust");

    // And the Lans struct itself agrees at t=1 (fresh state, both sides).
    let inputs = vec![
        xla::Literal::vec1(&vec![0.0f32; n]),
        xla::Literal::vec1(&vec![0.0f32; n]),
        xla::Literal::vec1(&g),
        xla::Literal::vec1(&x),
        xla::Literal::vec1(&[1.0f32]),
    ];
    let out = exe.run(&inputs).unwrap();
    let x_kernel = out[2].to_vec::<f32>().unwrap();
    let mut x_rust = x.clone();
    lans.step(&mut x_rust, &g);
    assert_allclose(&x_kernel, &x_rust, 1e-5, 1e-4, "kernel step vs Lans::step at t=1");
}

#[test]
fn dither_quantize_artifact_matches_rust_formula() {
    let Some(man) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let k = &man.kernels["dither_quantize"];
    let n = k.n;
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_hlo(&man.dir.join(&k.hlo)).unwrap();

    let mut rng = Xoshiro256::seed_from_u64(7);
    let mut x = vec![0.0f32; n];
    rng.fill_normal(&mut x, 2.0);
    let u: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();

    let out = exe
        .run(&[xla::Literal::vec1(&x), xla::Literal::vec1(&u)])
        .unwrap();
    let kernel = out[0].to_vec::<f32>().unwrap();

    // Rust transcription of the same quantizer (bits=5), same uniforms.
    let levels = 15.0f32;
    let scale = byteps_compress::util::max_abs(&x);
    let inv = levels / scale;
    let step = scale / levels;
    let want: Vec<f32> = x
        .iter()
        .zip(&u)
        .map(|(&xi, &ui)| {
            let q = xi * inv;
            let lo = q.floor();
            let level = (lo + if ui < q - lo { 1.0 } else { 0.0 }).clamp(-levels, levels);
            level * step
        })
        .collect();
    assert_allclose(&kernel, &want, 1e-6, 1e-5, "dither kernel vs rust");

    // Statistical tie-back to the actual wire compressor: same bit width
    // => same step size and error bound.
    let comp = by_name("linear_dither", 5.0).unwrap();
    let mut rng2 = Xoshiro256::seed_from_u64(1);
    let w = comp.compress(&x, &mut Ctx::new(&mut rng2));
    let mut dec = vec![0.0f32; n];
    comp.decompress(&w, &mut dec);
    for i in 0..n {
        assert!((dec[i] - x[i]).abs() <= step + 1e-5, "wire compressor off-grid at {i}");
        assert!((kernel[i] - x[i]).abs() <= step + 1e-5, "kernel off-grid at {i}");
    }
}
