//! Schedule exploration for the staged server shard (mini-loom).
//!
//! The staged executor's determinism contract (DESIGN.md §Schedule
//! exploration) says the served aggregates are bit-identical to the
//! synchronous reference *for every order in which stage completions can
//! reach the control thread*. The per-PR staged tests witness one or two
//! orders per run; this test witnesses **all of them** for a small script
//! by driving `ServerCore`'s deterministic `on_event` API through every
//! linear extension of the completion poset.
//!
//! No dependency is needed: stage jobs are pure and report through an
//! mpsc sink, so the test gathers every outstanding completion, sorts
//! them by a canonical key, and lets a depth-first choice stack pick the
//! application order. Gathering until `jobs_in_flight()` events are
//! buffered makes the available set at each choice point exactly the
//! poset-available set, so the enumeration is exhaustive and counted.
//!
//! Script: 2 workers x 2 keys x 3 iterations, drained to quiescence
//! between iterations. Per iteration the poset is two decode pairs each
//! preceding their encode: 6!/(3*3) = 80 linear extensions. Each
//! iteration is explored exhaustively while the others take the
//! canonical order (the drain barrier makes iterations independent), so
//! the run count stays 3 x 80 instead of 80^3.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use byteps_compress::comm::{Key, Message};
use byteps_compress::compress::{by_name, Compressed, Compressor, Ctx};
use byteps_compress::configx::SyncMode;
use byteps_compress::parallel::ThreadPool;
use byteps_compress::ps::{seal_seed, EventSink, ServerCore, ServerOptions, ServerStats, StageEvent};
use byteps_compress::util::rng::Xoshiro256;

const WORKERS: u32 = 2;
const ITERS: u64 = 3;
const KEYS: [(Key, usize); 2] = [(0, 24), (1, 16)];
/// Linear extensions of one iteration's completion poset: 6 events,
/// each key's encode after its two decodes => 6!/(3*3).
const SCHEDULES_PER_ITER: usize = 80;

fn opts(comp: Arc<dyn Compressor>, compress_threads: usize) -> ServerOptions {
    ServerOptions {
        comp,
        sync: SyncMode::CompressedEf,
        fused: true,
        n_workers: WORKERS as usize,
        intra_threads: 1,
        seed: 7,
        max_keys: 0,
        iter_deadline: None,
        compress_threads,
        deadline_auto_margin: 0.0,
        adaptive_bounds: None,
    }
}

/// Per-(worker, key, iter) push payload, seeded the way the worker
/// pipeline seeds its jobs, so the script is deterministic.
fn push_data(comp: &dyn Compressor, w: u32, key: Key, iter: u64, dim: usize) -> Compressed {
    let mut rng = Xoshiro256::seed_from_u64(
        0x5EED ^ (w as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ seal_seed(0, key, iter),
    );
    let mut g = vec![0.0f32; dim];
    rng.fill_normal(&mut g, 1.0);
    let mut ctx = Ctx::new(&mut rng);
    comp.compress(&g, &mut ctx)
}

/// One iteration's messages: all pushes, then all pulls. The pulls queue
/// (their rounds seal only once decodes land), so every reply of the
/// iteration flows through `on_event` — the surface under test.
fn iteration_script(comp: &dyn Compressor, iter: u64) -> Vec<(u32, Message)> {
    let mut script = Vec::new();
    for &(key, dim) in &KEYS {
        for w in 0..WORKERS {
            let data = push_data(comp, w, key, iter, dim);
            script.push((w, Message::Push { key, iter, worker: w, data }));
        }
    }
    for &(key, _) in &KEYS {
        for w in 0..WORKERS {
            script.push((w, Message::Pull { key, iter, worker: w }));
        }
    }
    script
}

/// Canonical sort key for buffered completions, so "choice index i" names
/// the same event on every run regardless of thread timing.
fn event_key(ev: &StageEvent) -> (u8, Key, u64, u32) {
    match ev {
        StageEvent::Decoded { key, iter, from, .. } => (0, *key, *iter, *from),
        StageEvent::Encoded { key, iter, .. } => (1, *key, *iter, 0),
    }
}

/// Depth-first schedule enumerator: replays a recorded choice prefix,
/// takes branch 0 past it, and records (chosen, options) at every choice
/// point so the driver can advance to the next unexplored schedule.
struct Chooser {
    replay: Vec<usize>,
    cursor: usize,
    path: Vec<(usize, usize)>,
}

impl Chooser {
    fn new(replay: Vec<usize>) -> Chooser {
        Chooser { replay, cursor: 0, path: Vec::new() }
    }

    fn pick(&mut self, options: usize) -> usize {
        assert!(options > 0, "chooser consulted with no pending events");
        let c = if self.cursor < self.replay.len() { self.replay[self.cursor] } else { 0 };
        assert!(c < options, "schedule replay diverged from the recorded tree");
        self.cursor += 1;
        self.path.push((c, options));
        c
    }
}

/// Pop exhausted trailing choice points and advance the deepest one that
/// still has an unexplored branch. Returns false once the tree is done.
fn next_schedule(path: &mut Vec<(usize, usize)>) -> bool {
    while let Some((chosen, options)) = path.pop() {
        if chosen + 1 < options {
            path.push((chosen + 1, options));
            return true;
        }
    }
    false
}

struct Staged {
    core: ServerCore,
    rx: mpsc::Receiver<StageEvent>,
}

impl Staged {
    fn new(o: ServerOptions) -> Staged {
        let (tx, rx) = mpsc::channel();
        let sink: EventSink = Arc::new(move |ev| {
            let _ = tx.send(ev);
        });
        let pool = Arc::new(ThreadPool::new(2));
        Staged { core: ServerCore::new_staged(o, pool, sink), rx }
    }

    /// Drain to quiescence, applying completions in the order `choose`
    /// dictates. Buffering until `jobs_in_flight()` events are in hand
    /// before each pick makes the candidate set the full poset frontier.
    fn drain(&mut self, choose: &mut dyn FnMut(usize) -> usize) -> Vec<(u32, Message)> {
        let mut out = Vec::new();
        let mut pending: Vec<StageEvent> = Vec::new();
        loop {
            while pending.len() < self.core.jobs_in_flight() {
                let ev = self
                    .rx
                    .recv_timeout(Duration::from_secs(30))
                    .expect("stage job never reported back");
                pending.push(ev);
            }
            if pending.is_empty() {
                return out;
            }
            pending.sort_by_key(event_key);
            let ev = pending.remove(choose(pending.len()));
            out.extend(self.core.on_event(ev));
        }
    }
}

/// Sort key so reply *content* can be compared across executors whose
/// reply *timing* differs.
fn reply_key(to: u32, m: &Message) -> (u32, u8, u64, u64, u16, Vec<u8>) {
    match m {
        Message::Ack { key, iter } => (to, 0, *key, *iter, 0, Vec::new()),
        Message::PullResp { key, iter, served_with, data } => {
            let mut bytes = vec![data.scheme as u8];
            bytes.extend_from_slice(&(data.n as u64).to_le_bytes());
            bytes.extend_from_slice(&data.payload);
            (to, 1, *key, *iter, *served_with, bytes)
        }
        other => panic!("server emitted unexpected {other:?}"),
    }
}

fn sorted_replies(replies: &[(u32, Message)]) -> Vec<(u32, u8, u64, u64, u16, Vec<u8>)> {
    let mut keys: Vec<_> = replies.iter().map(|(to, m)| reply_key(*to, m)).collect();
    keys.sort();
    keys
}

fn assert_counters_match(a: &ServerStats, b: &ServerStats, label: &str) {
    assert_eq!(a.pushes, b.pushes, "{label}: pushes");
    assert_eq!(a.pulls, b.pulls, "{label}: pulls");
    assert_eq!(a.rejected, b.rejected, "{label}: rejected");
    assert_eq!(a.short_iters, b.short_iters, "{label}: short_iters");
    assert_eq!(a.stale_pulls, b.stale_pulls, "{label}: stale_pulls");
    assert_eq!(a.early_pulls, b.early_pulls, "{label}: early_pulls");
    assert_eq!(a.degraded_iters, b.degraded_iters, "{label}: degraded_iters");
    assert_eq!(a.late_pushes, b.late_pushes, "{label}: late_pushes");
    assert_eq!(a.unexpected, b.unexpected, "{label}: unexpected");
    assert_eq!(a.internal_errors, b.internal_errors, "{label}: internal_errors");
    assert_eq!(a.internal_errors, 0, "{label}: internal errors in a healthy run");
}

/// One full 3-iteration run of the script on a fresh staged core.
/// `target_iter`'s drain consults the chooser; the other iterations take
/// the canonical order (choice 0), so the chooser's tree covers exactly
/// one iteration's poset.
fn run_staged(
    comp: &Arc<dyn Compressor>,
    target_iter: u64,
    chooser: &mut Chooser,
) -> (Vec<(u32, Message)>, ServerStats) {
    let mut staged = Staged::new(opts(comp.clone(), 2));
    let mut replies = Vec::new();
    for iter in 0..ITERS {
        for (from, msg) in iteration_script(comp.as_ref(), iter) {
            replies.extend(staged.core.handle(from, msg));
        }
        if iter == target_iter {
            replies.extend(staged.drain(&mut |n| chooser.pick(n)));
        } else {
            replies.extend(staged.drain(&mut |_| 0));
        }
        assert_eq!(staged.core.jobs_in_flight(), 0, "iteration {iter} left jobs in flight");
    }
    (replies, staged.core.stats.clone())
}

/// The reference: the synchronous shard (`compress_threads = 0`) running
/// the identical script. Its replies come straight out of `handle`.
fn run_sync(comp: &Arc<dyn Compressor>) -> (Vec<(u32, Message)>, ServerStats) {
    let mut core = ServerCore::new(opts(comp.clone(), 0));
    let mut replies = Vec::new();
    for iter in 0..ITERS {
        for (from, msg) in iteration_script(comp.as_ref(), iter) {
            replies.extend(core.handle(from, msg));
        }
    }
    (replies, core.stats.clone())
}

/// The tentpole assertion: every completion schedule serves bit-identical
/// aggregates and identical counter totals, and the enumerator visits the
/// full 80-extension tree for each iteration.
#[test]
fn every_completion_schedule_is_bit_identical() {
    let comp = by_name("topk", 0.25).expect("paper-suite compressor");
    let (sync_replies, sync_stats) = run_sync(&comp);
    let expected = sorted_replies(&sync_replies);
    assert!(
        expected.iter().any(|k| k.1 == 1),
        "reference script produced no pull responses — script is vacuous"
    );

    for target_iter in 0..ITERS {
        let mut stack: Vec<(usize, usize)> = Vec::new();
        let mut schedules = 0usize;
        loop {
            let replay = stack.iter().map(|&(c, _)| c).collect();
            let mut chooser = Chooser::new(replay);
            let (replies, stats) = run_staged(&comp, target_iter, &mut chooser);
            schedules += 1;
            let label = format!(
                "iter {target_iter}, schedule {schedules} {:?}",
                chooser.path.iter().map(|&(c, _)| c).collect::<Vec<_>>()
            );
            assert_eq!(sorted_replies(&replies), expected, "{label}: replies diverged");
            assert_counters_match(&stats, &sync_stats, &label);
            stack = chooser.path;
            if !next_schedule(&mut stack) {
                break;
            }
        }
        assert_eq!(
            schedules, SCHEDULES_PER_ITER,
            "iter {target_iter}: enumerator did not visit the full poset"
        );
    }
}

/// Negative control for the harness itself: the same enumerator applied
/// to a plain f32 fold DOES observe order-dependent bits. If reordering
/// were invisible to this harness, the tentpole test above would be
/// vacuously green; this proves the instrument can see the failure mode
/// the staged shard is designed out of.
#[test]
fn schedule_enumerator_detects_order_dependence() {
    let values = [1.0e8f32, 1.0, -1.0e8];
    let mut stack: Vec<(usize, usize)> = Vec::new();
    let mut bit_patterns = std::collections::BTreeSet::new();
    let mut schedules = 0usize;
    loop {
        let replay: Vec<usize> = stack.iter().map(|&(c, _)| c).collect();
        let mut chooser = Chooser::new(replay);
        let mut remaining: Vec<f32> = values.to_vec();
        let mut acc = 0.0f32;
        while !remaining.is_empty() {
            let i = chooser.pick(remaining.len());
            acc += remaining.remove(i);
        }
        bit_patterns.insert(acc.to_bits());
        schedules += 1;
        stack = chooser.path;
        if !next_schedule(&mut stack) {
            break;
        }
    }
    assert_eq!(schedules, 6, "3 unordered items have 3! fold orders");
    assert!(
        bit_patterns.len() >= 2,
        "fold order had no observable effect — the harness could not detect a real schedule bug"
    );
}
