//! Cluster-mode integration: the multi-process TCP fabric (`bytepsc
//! server` / `bytepsc worker`) must produce the same training run as the
//! single-process inproc fabric — bit-identical aggregates with the
//! identity compressor, loss-matching with top-k/EF — and the server
//! shards must survive hostile/corrupt clients (regression tests for the
//! panic-on-untrusted-input class).

use byteps_compress::cluster;
use byteps_compress::comm::tcp::TcpEndpoint;
use byteps_compress::comm::{BlockKey, Endpoint, Message};
use byteps_compress::compress::controller::ppm_of;
use byteps_compress::compress::{by_name, Compressed, Ctx, SchemeId};
use byteps_compress::configx::{SyncMode, TrainConfig};
use byteps_compress::util::rng::Xoshiro256;
use byteps_compress::engine::CommFabric;
use byteps_compress::ps::{Server, ServerOptions};
use byteps_compress::testutil::assert_allclose;
use std::net::TcpListener;
use std::time::{Duration, Instant};

/// Base cluster config: `nodes` workers, shards given by `addresses`.
fn cluster_cfg(scheme: &str, param: f64, sync: SyncMode, nodes: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.cluster.nodes = nodes;
    cfg.compression.scheme = scheme.into();
    cfg.compression.param = param;
    cfg.compression.sync = sync;
    cfg.system.size_threshold_on = false;
    cfg.pipeline.block_bytes = 256 * 4; // force real block partitioning
    cfg.seed = 42;
    cfg
}

/// Reference: the same synthetic run over the single-process inproc fabric.
fn inproc_reference(cfg: &TrainConfig, dim: usize, tensors: usize, iters: usize) -> Vec<Vec<f32>> {
    let blocks = cluster::synthetic_blocks(dim, tensors);
    let mut fabric = CommFabric::new(cfg, blocks, dim).unwrap();
    let mut out = Vec::with_capacity(iters);
    for it in 0..iters as u64 {
        let grads: Vec<Vec<f32>> = (0..cfg.cluster.nodes)
            .map(|w| cluster::synthetic_grad(cfg.seed, w as u32, it, dim))
            .collect();
        let (agg, _) = fabric.exchange(&grads);
        out.push(agg);
    }
    fabric.shutdown();
    out
}

/// Run a full cluster (threads over real TCP sockets): `n_servers` shards
/// via [`cluster::serve`], `nodes` workers via [`cluster::run_worker`] —
/// optionally dropping one worker's push (`fault = (rank, drop)`).
/// Returns every worker's report and every shard's stats.
fn run_thread_cluster_with(
    mut cfg: TrainConfig,
    n_servers: usize,
    dim: usize,
    tensors: usize,
    iters: usize,
    fault: Option<(u32, cluster::PushDrop)>,
) -> (Vec<cluster::WorkerRunReport>, Vec<byteps_compress::ps::ServerStats>) {
    let listeners: Vec<TcpListener> =
        (0..n_servers).map(|_| TcpListener::bind("127.0.0.1:0").unwrap()).collect();
    let addrs: Vec<String> =
        listeners.iter().map(|l| l.local_addr().unwrap().to_string()).collect();
    cfg.cluster.addresses = addrs.clone();

    let mut server_handles = Vec::new();
    for (shard, listener) in listeners.into_iter().enumerate() {
        let cfg = cfg.clone();
        server_handles.push(std::thread::spawn(move || {
            cluster::serve(&cfg, listener, shard, dim, tensors).unwrap()
        }));
    }
    let worker_handles: Vec<_> = (0..cfg.cluster.nodes)
        .map(|rank| {
            let cfg = cfg.clone();
            let addrs = addrs.clone();
            let drop = match fault {
                Some((r, d)) if r == rank as u32 => Some(d),
                _ => None,
            };
            std::thread::spawn(move || {
                cluster::run_worker(&cfg, rank as u32, &addrs, dim, tensors, iters, None, drop)
                    .unwrap()
            })
        })
        .collect();
    let reports: Vec<cluster::WorkerRunReport> =
        worker_handles.into_iter().map(|h| h.join().unwrap()).collect();
    let stats: Vec<byteps_compress::ps::ServerStats> =
        server_handles.into_iter().map(|h| h.join().unwrap()).collect();
    (reports, stats)
}

/// Fault-free cluster run with the strict health assertions the original
/// tests rely on.
fn run_thread_cluster(
    cfg: TrainConfig,
    n_servers: usize,
    dim: usize,
    tensors: usize,
    iters: usize,
) -> Vec<cluster::WorkerRunReport> {
    let (reports, stats) = run_thread_cluster_with(cfg, n_servers, dim, tensors, iters, None);
    for s in &stats {
        assert_eq!(s.rejected, 0);
        assert_eq!(s.short_iters, 0);
    }
    reports
}

/// Run a hierarchical thread cluster (`cluster.groups = groups`): real TCP
/// shards via [`cluster::serve`], one [`cluster::run_leader`] relay per
/// group (each co-locating its group's first member), and the remaining
/// members as plain [`cluster::run_worker`]s that only ever dial their
/// leader. Reports come back in global rank order.
fn run_hier_thread_cluster(
    mut cfg: TrainConfig,
    n_servers: usize,
    groups: usize,
    dim: usize,
    tensors: usize,
    iters: usize,
) -> (Vec<cluster::WorkerRunReport>, Vec<byteps_compress::ps::ServerStats>) {
    let nodes = cfg.cluster.nodes;
    let m = nodes / groups;
    let listeners: Vec<TcpListener> =
        (0..n_servers).map(|_| TcpListener::bind("127.0.0.1:0").unwrap()).collect();
    let addrs: Vec<String> =
        listeners.iter().map(|l| l.local_addr().unwrap().to_string()).collect();
    cfg.cluster.addresses = addrs.clone();
    cfg.cluster.groups = groups;
    let leader_addrs: Vec<String> =
        (0..groups).map(|_| format!("127.0.0.1:{}", free_port())).collect();
    cfg.cluster.group_addresses = leader_addrs.clone();

    let mut server_handles = Vec::new();
    for (shard, listener) in listeners.into_iter().enumerate() {
        let cfg = cfg.clone();
        server_handles.push(std::thread::spawn(move || {
            cluster::serve(&cfg, listener, shard, dim, tensors).unwrap()
        }));
    }
    // One thread per process of the real deployment: G leaders plus the
    // out-of-group members, reports keyed by global rank.
    let mut handles: Vec<(usize, std::thread::JoinHandle<cluster::WorkerRunReport>)> = Vec::new();
    for g in 0..groups {
        let cfg = cfg.clone();
        let listen = leader_addrs[g].clone();
        let servers = addrs.clone();
        handles.push((
            g * m,
            std::thread::spawn(move || {
                cluster::run_leader(
                    &cfg, g as u32, &listen, &servers, dim, tensors, iters, None, None,
                )
                .unwrap()
            }),
        ));
        for r in g * m + 1..(g + 1) * m {
            let cfg = cfg.clone();
            let leader = vec![leader_addrs[g].clone()];
            handles.push((
                r,
                std::thread::spawn(move || {
                    cluster::run_worker(&cfg, r as u32, &leader, dim, tensors, iters, None, None)
                        .unwrap()
                }),
            ));
        }
    }
    let mut reports: Vec<Option<cluster::WorkerRunReport>> = (0..nodes).map(|_| None).collect();
    for (rank, h) in handles {
        reports[rank] = Some(h.join().unwrap());
    }
    let stats: Vec<_> = server_handles.into_iter().map(|h| h.join().unwrap()).collect();
    (reports.into_iter().map(|r| r.unwrap()).collect(), stats)
}

/// Tentpole acceptance (identity): a real TCP cluster completes a training
/// run whose per-iteration aggregates are bit-identical to the
/// single-process inproc fabric.
#[test]
fn tcp_cluster_identity_bit_identical_to_inproc() {
    let (dim, tensors, iters, nodes, servers) = (2048, 3, 4, 2, 2);
    let cfg = cluster_cfg("identity", 0.0, SyncMode::Full, nodes);
    let mut ref_cfg = cfg.clone();
    // Same shard count for the reference (addresses drive n_servers).
    ref_cfg.cluster.addresses = (0..servers).map(|s| format!("ref:{s}")).collect();
    let want = inproc_reference(&ref_cfg, dim, tensors, iters);

    let reports = run_thread_cluster(cfg, servers, dim, tensors, iters);
    for (rank, rep) in reports.iter().enumerate() {
        assert_eq!(rep.aggregates.len(), iters);
        for (it, (got, expect)) in rep.aggregates.iter().zip(&want).enumerate() {
            assert_eq!(
                got, expect,
                "worker {rank} iteration {it}: TCP aggregate differs from inproc"
            );
        }
        assert!(rep.wire_bytes > 0);
    }
}

/// Tentpole acceptance (top-k + EF): the compressed two-way path over TCP
/// matches the inproc fabric — aggregates allclose and the synthetic
/// training loss identical.
#[test]
fn tcp_cluster_topk_ef_matches_inproc() {
    let (dim, tensors, iters, nodes, servers) = (1536, 2, 4, 3, 2);
    let cfg = cluster_cfg("topk", 0.1, SyncMode::CompressedEf, nodes);
    let mut ref_cfg = cfg.clone();
    ref_cfg.cluster.addresses = (0..servers).map(|s| format!("ref:{s}")).collect();
    let want = inproc_reference(&ref_cfg, dim, tensors, iters);

    let reports = run_thread_cluster(cfg.clone(), servers, dim, tensors, iters);
    // Reference loss: the same SGD replica driven by the inproc aggregates.
    let lr = cfg.optimizer.lr as f32;
    let mut params = vec![0.0f32; dim];
    for agg in &want {
        for (p, a) in params.iter_mut().zip(agg) {
            *p -= lr * a;
        }
    }
    let want_loss = params.iter().map(|&p| p as f64 * p as f64).sum::<f64>() / dim as f64;
    for (rank, rep) in reports.iter().enumerate() {
        for (it, (got, expect)) in rep.aggregates.iter().zip(&want).enumerate() {
            assert_allclose(got, expect, 1e-6, 1e-5, &format!("worker {rank} iter {it}"));
        }
        assert!(
            (rep.final_loss - want_loss).abs() <= 1e-12 * want_loss.abs().max(1.0),
            "worker {rank} loss {} vs inproc {}",
            rep.final_loss,
            want_loss
        );
    }
}

/// Staged-shard acceptance over real TCP: servers running the
/// ingress → decode → reduce → seal → encode pipeline
/// (`server.compress_threads = 4`) produce aggregates **bit-identical**
/// to the synchronous inproc reference (`compress_threads = 0`) — for a
/// compressed two-way EF run, not just identity. Exact equality (not
/// allclose) is the point: the staged reduce sums in worker-index order,
/// so the f32 bits are independent of socket arrival order, executor, and
/// decode completion order.
#[test]
fn staged_server_thread_cluster_bit_identical_to_sync() {
    let (dim, tensors, iters, nodes, servers) = (1536, 2, 4, 3, 2);
    let mut cfg = cluster_cfg("topk", 0.1, SyncMode::CompressedEf, nodes);
    cfg.server.compress_threads = 4;
    let mut ref_cfg = cfg.clone();
    ref_cfg.server.compress_threads = 0; // the synchronous reference
    ref_cfg.cluster.addresses = (0..servers).map(|s| format!("ref:{s}")).collect();
    let want = inproc_reference(&ref_cfg, dim, tensors, iters);

    let reports = run_thread_cluster(cfg, servers, dim, tensors, iters);
    for (rank, rep) in reports.iter().enumerate() {
        assert_eq!(rep.aggregates.len(), iters);
        for (it, (got, expect)) in rep.aggregates.iter().zip(&want).enumerate() {
            assert_eq!(
                got, expect,
                "worker {rank} iteration {it}: staged TCP aggregate differs from the \
                 synchronous inproc shard"
            );
        }
    }
}

/// Tentpole acceptance (hierarchical, identity): a 2-group × 2-worker
/// two-level TCP cluster — each leader locally aggregating its members'
/// pushes and forwarding one `GroupPush` per (key, iteration) — produces
/// aggregates bit-identical to the FLAT 4-worker inproc reference, while
/// each server shard ingests G pushes per key instead of W.
#[test]
fn hierarchical_thread_cluster_identity_bit_identical_to_flat() {
    let (dim, tensors, iters, nodes, groups, servers) = (2048, 3, 4, 4, 2, 2);
    let cfg = cluster_cfg("identity", 0.0, SyncMode::Full, nodes);
    let mut ref_cfg = cfg.clone();
    ref_cfg.cluster.addresses = (0..servers).map(|s| format!("ref:{s}")).collect();
    // The reference is the FLAT topology: same fleet, no groups.
    ref_cfg.cluster.groups = 0;
    let want = inproc_reference(&ref_cfg, dim, tensors, iters);

    let (reports, stats) =
        run_hier_thread_cluster(cfg.clone(), servers, groups, dim, tensors, iters);
    for (rank, rep) in reports.iter().enumerate() {
        assert_eq!(rep.aggregates.len(), iters, "rank {rank} did not finish");
        for (it, (got, expect)) in rep.aggregates.iter().zip(&want).enumerate() {
            assert_eq!(
                got, expect,
                "rank {rank} iteration {it}: hierarchical aggregate differs from flat"
            );
        }
        assert!(rep.wire_bytes > 0);
    }
    // The fan-in cut itself: G group-pushes per (key, iteration) across
    // the shard pool — not W worker pushes.
    let blocks = cluster::synthetic_blocks(dim, tensors);
    let n_keys = byteps_compress::worker::pipeline::Partition::new(
        &blocks,
        cfg.pipeline.block_bytes,
        cfg.pipeline.enabled,
    )
    .len();
    assert_eq!(
        stats.iter().map(|s| s.pushes).sum::<u64>() as usize,
        groups * iters * n_keys,
        "server fan-in must scale with G, not W"
    );
    for s in &stats {
        assert_eq!(s.rejected, 0);
        assert_eq!(s.short_iters, 0);
        assert_eq!(s.members_clamped, 0);
    }
}

/// Tentpole acceptance (hierarchical, top-k + EF): the leader re-encodes
/// each group's partial aggregate as the exact sparse union of its
/// members' top-k blocks, so even the compressed two-way path stays
/// bit-identical to the flat 4-worker reference on the integer-valued
/// synthetic workload — and the training loss matches exactly.
#[test]
fn hierarchical_thread_cluster_topk_ef_bit_identical_to_flat() {
    let (dim, tensors, iters, nodes, groups, servers) = (1536, 2, 4, 4, 2, 2);
    let cfg = cluster_cfg("topk", 0.1, SyncMode::CompressedEf, nodes);
    let mut ref_cfg = cfg.clone();
    ref_cfg.cluster.addresses = (0..servers).map(|s| format!("ref:{s}")).collect();
    ref_cfg.cluster.groups = 0;
    let want = inproc_reference(&ref_cfg, dim, tensors, iters);

    let (reports, stats) =
        run_hier_thread_cluster(cfg.clone(), servers, groups, dim, tensors, iters);
    let lr = cfg.optimizer.lr as f32;
    let mut params = vec![0.0f32; dim];
    for agg in &want {
        for (p, a) in params.iter_mut().zip(agg) {
            *p -= lr * a;
        }
    }
    let want_loss = params.iter().map(|&p| p as f64 * p as f64).sum::<f64>() / dim as f64;
    for (rank, rep) in reports.iter().enumerate() {
        for (it, (got, expect)) in rep.aggregates.iter().zip(&want).enumerate() {
            assert_eq!(
                got, expect,
                "rank {rank} iteration {it}: hierarchical top-k aggregate differs from flat"
            );
        }
        assert!(
            (rep.final_loss - want_loss).abs() <= 1e-12 * want_loss.abs().max(1.0),
            "rank {rank} loss {} vs flat {}",
            rep.final_loss,
            want_loss
        );
    }
    for s in &stats {
        assert_eq!(s.rejected, 0);
        assert_eq!(s.short_iters, 0);
        assert_eq!(s.members_clamped, 0);
    }
}

/// Stray clients — one that sends a non-Hello frame, one that connects
/// and stays silent — are isolated on their own handshake threads; the
/// real workers still register and complete the run.
#[test]
fn hostile_connection_does_not_block_registration() {
    let (dim, tensors, iters, nodes) = (512, 2, 2, 2);
    let mut cfg = cluster_cfg("identity", 0.0, SyncMode::Full, nodes);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    cfg.cluster.addresses = vec![addr.clone()];

    let scfg = cfg.clone();
    let server =
        std::thread::spawn(move || cluster::serve(&scfg, listener, 0, dim, tensors).unwrap());

    // Hostile first contacts, before any real worker: a non-Hello frame
    // and a connection that never says anything. Neither may block the
    // workers' registration.
    let stray = TcpEndpoint::connect(&addr).unwrap();
    stray.send(Message::Ack { key: 0, iter: 0 }).unwrap();
    let _silent = std::net::TcpStream::connect(&addr).unwrap();

    let workers: Vec<_> = (0..nodes)
        .map(|rank| {
            let cfg = cfg.clone();
            let addrs = vec![addr.clone()];
            std::thread::spawn(move || {
                cluster::run_worker(&cfg, rank as u32, &addrs, dim, tensors, iters, None, None)
                    .unwrap()
            })
        })
        .collect();
    for w in workers {
        let rep = w.join().unwrap();
        assert_eq!(rep.aggregates.len(), iters);
    }
    let stats = server.join().unwrap();
    // Every block key pushed once per worker per iteration.
    let blocks = cluster::synthetic_blocks(dim, tensors);
    let n_keys = byteps_compress::worker::pipeline::Partition::new(
        &blocks,
        cfg.pipeline.block_bytes,
        cfg.pipeline.enabled,
    )
    .len();
    assert_eq!(stats.pushes as usize, nodes * iters * n_keys);
}

/// Tentpole acceptance (degraded rounds): a 2-server/2-worker cluster
/// where worker 1's push for one block of iteration 1 is dropped
/// *completes training* under the iteration deadline — the affected
/// (key, iteration) is served degraded (`served_with < n_workers`, the
/// block holding worker 0's contribution alone), every other value is
/// bit-identical to the fault-free inproc reference, every subsequent
/// iteration is full, and no pull hangs.
#[test]
fn degraded_round_thread_cluster_completes_and_recovers() {
    let (dim, tensors, iters, nodes, servers) = (2048usize, 3usize, 4usize, 2usize, 2usize);
    let mut cfg = cluster_cfg("identity", 0.0, SyncMode::Full, nodes);
    // Generous deadline: full rounds complete by count, so in a healthy
    // run it only fires for the faulted round — but it *would* fire for
    // any round left incomplete this long, so size it against worst-case
    // CI thread descheduling (the strict assertions below depend on no
    // spurious seal), not against test runtime: the faulted iteration
    // pays exactly one deadline of stall.
    cfg.server.iter_deadline_ms = 2000;
    let mut ref_cfg = cfg.clone();
    ref_cfg.cluster.addresses = (0..servers).map(|s| format!("ref:{s}")).collect();
    let want_full = inproc_reference(&ref_cfg, dim, tensors, iters);

    // cluster_cfg partitions at 256 elems; tensor 0 spans flat [0, 683),
    // so its block 1 covers flat [256, 512).
    let drop_key = BlockKey::new(0, 1).pack();
    let drop_iter = 1u64;
    let drop_range = 256usize..512;
    let (reports, stats) = run_thread_cluster_with(
        cfg.clone(),
        servers,
        dim,
        tensors,
        iters,
        Some((1, cluster::PushDrop { key: drop_key, iter: drop_iter })),
    );

    // The degraded block is worker 0's gradient alone (averaged over the
    // one contribution received) — bit-exact with integer-valued grads.
    let g0 = cluster::synthetic_grad(cfg.seed, 0, drop_iter, dim);
    for (rank, rep) in reports.iter().enumerate() {
        assert_eq!(rep.aggregates.len(), iters, "worker {rank} did not finish");
        for (it, (got, full)) in rep.aggregates.iter().zip(&want_full).enumerate() {
            for i in 0..dim {
                let expect = if it as u64 == drop_iter && drop_range.contains(&i) {
                    g0[i]
                } else {
                    full[i]
                };
                assert_eq!(
                    got[i], expect,
                    "worker {rank} iteration {it} element {i}: degraded run diverged"
                );
            }
        }
        // Exactly one degraded pull response per worker: the faulted
        // block at the faulted iteration; everything after is full.
        assert_eq!(rep.counters.degraded_responses, 1, "worker {rank}");
    }
    assert_eq!(reports[0].counters.dropped_pushes, 0);
    assert_eq!(reports[1].counters.dropped_pushes, 1);
    assert_eq!(stats.iter().map(|s| s.degraded_iters).sum::<u64>(), 1);
    // The sealed round was served, not discarded: no short iteration, no
    // rejected or resurrected push.
    assert_eq!(stats.iter().map(|s| s.short_iters).sum::<u64>(), 0);
    assert_eq!(stats.iter().map(|s| s.rejected).sum::<u64>(), 0);
    assert_eq!(stats.iter().map(|s| s.late_pushes).sum::<u64>(), 0);
}

/// With a deadline configured but no faults, the deadline never fires:
/// the run is bit-identical to the inproc reference and no degraded or
/// late counters move.
#[test]
fn degraded_deadline_idle_is_bit_identical() {
    let (dim, tensors, iters, nodes, servers) = (1024usize, 2usize, 3usize, 2usize, 2usize);
    let mut cfg = cluster_cfg("identity", 0.0, SyncMode::Full, nodes);
    cfg.server.iter_deadline_ms = 2000;
    let mut ref_cfg = cfg.clone();
    ref_cfg.cluster.addresses = (0..servers).map(|s| format!("ref:{s}")).collect();
    let want = inproc_reference(&ref_cfg, dim, tensors, iters);
    let (reports, stats) = run_thread_cluster_with(cfg, servers, dim, tensors, iters, None);
    for (rank, rep) in reports.iter().enumerate() {
        for (it, (got, expect)) in rep.aggregates.iter().zip(&want).enumerate() {
            assert_eq!(got, expect, "worker {rank} iteration {it}");
        }
        assert_eq!(rep.counters.degraded_responses, 0);
    }
    for s in &stats {
        assert_eq!(s.degraded_iters, 0);
        assert_eq!(s.late_pushes, 0);
        assert_eq!(s.short_iters, 0);
        assert_eq!(s.rejected, 0);
    }
}

/// Tentpole acceptance (adaptive controller): a 2-worker TCP cluster with
/// the per-key controller enabled negotiates its bounds at registration,
/// adapts `k` within them (adjustment counters move, the per-key ppm span
/// stays inside the grant, and a below-target gain pushes `k` upward from
/// the static starting ratio), never trips the servers' envelope check,
/// and produces the same aggregates as the adaptive inproc fabric — the
/// controller is deterministic per (worker, key), so transport must not
/// change the trajectory.
#[test]
fn adaptive_cluster_matches_inproc_and_stays_in_bounds() {
    let (dim, tensors, iters, nodes, servers) = (1536, 2, 4, 2, 2);
    let mut cfg = cluster_cfg("topk", 0.05, SyncMode::CompressedEf, nodes);
    cfg.adaptive.enabled = true;
    cfg.adaptive.k_min = 0.01;
    cfg.adaptive.k_max = 0.5;
    cfg.adaptive.ema = 0.5;
    // Integer-valued synthetic grads spread energy nearly uniformly, so
    // top-5% gain sits far below this target: every key must ratchet
    // toward k_max.
    cfg.adaptive.target_gain = 0.95;
    let mut ref_cfg = cfg.clone();
    ref_cfg.cluster.addresses = (0..servers).map(|s| format!("ref:{s}")).collect();
    let want = inproc_reference(&ref_cfg, dim, tensors, iters);

    let (reports, stats) = run_thread_cluster_with(cfg, servers, dim, tensors, iters, None);
    let (lo, hi) = (u64::from(ppm_of(0.01)), u64::from(ppm_of(0.5)));
    for (rank, rep) in reports.iter().enumerate() {
        assert_eq!(rep.aggregates.len(), iters);
        for (it, (got, expect)) in rep.aggregates.iter().zip(&want).enumerate() {
            assert_allclose(
                got,
                expect,
                1e-6,
                1e-5,
                &format!("adaptive worker {rank} iter {it}: TCP diverged from inproc"),
            );
        }
        let c = &rep.counters;
        assert!(c.k_adjustments > 0, "worker {rank}: controller never adjusted");
        assert!(
            c.k_ppm_lo >= lo && c.k_ppm_hi <= hi && c.k_ppm_lo <= c.k_ppm_hi,
            "worker {rank}: ppm span [{}, {}] outside granted [{lo}, {hi}]",
            c.k_ppm_lo,
            c.k_ppm_hi
        );
        assert!(
            c.k_ppm_hi > u64::from(ppm_of(0.05)),
            "worker {rank}: below-target gain must push k above the static ratio"
        );
    }
    for s in &stats {
        assert_eq!(s.bounds_rejected, 0, "honest adaptive workers must stay in the envelope");
        assert_eq!(s.rejected, 0);
        assert_eq!(s.short_iters, 0);
    }
}

/// Hostile adaptive client over real sockets: a structurally valid TopK
/// push whose `k` lies outside the granted envelope is dropped unacked and
/// counted as `bounds_rejected` (never `rejected` — the block parsed
/// fine), and the shard keeps serving in-bounds traffic for the same key.
#[test]
fn tcp_adaptive_out_of_bounds_push_rejected_and_counted() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let accept = std::thread::spawn(move || {
        let (s, _) = listener.accept().unwrap();
        let mut o = opts_identity(1);
        o.comp = by_name("topk", 0.10).unwrap();
        o.sync = SyncMode::CompressedEf;
        // Envelope [1%, 10%] over n = 100 elements → k ∈ [1, 10].
        o.adaptive_bounds = Some((ppm_of(0.01), ppm_of(0.10)));
        Server::spawn(o, vec![TcpEndpoint::from_stream(s).unwrap()])
    });
    let ep = TcpEndpoint::connect(addr).unwrap();
    let server = accept.join().unwrap();

    let g: Vec<f32> = (0..100).map(|i| (i as f32) - 50.0).collect();
    let mut rng = Xoshiro256::seed_from_u64(3);
    // k = 50 of n = 100: wire-valid, but far outside the granted [1, 10].
    let hostile = by_name("topk", 0.5).unwrap().compress(&g, &mut Ctx::new(&mut rng));
    ep.send(Message::Push { key: 0, iter: 0, worker: 0, data: hostile }).unwrap();
    // k = 10: exactly the envelope's upper edge — accepted and acked.
    let honest = by_name("topk", 0.10).unwrap().compress(&g, &mut Ctx::new(&mut rng));
    ep.send(Message::Push { key: 0, iter: 0, worker: 0, data: honest }).unwrap();
    // The first (and only) ack belongs to the in-bounds push: the hostile
    // one was dropped before it could touch the round.
    assert_eq!(ep.recv().unwrap(), Message::Ack { key: 0, iter: 0 });
    ep.send(Message::Pull { key: 0, iter: 0, worker: 0 }).unwrap();
    let Message::PullResp { served_with, data, .. } = recv_resp(&ep) else { panic!("no resp") };
    assert_eq!(served_with, 1);
    assert_eq!(data.n, 100);
    ep.send(Message::Shutdown).unwrap();
    let stats = server.join();
    assert_eq!(stats.bounds_rejected, 1);
    assert_eq!(stats.rejected, 0, "an envelope violation is not a corruption rejection");
    assert_eq!(stats.pushes, 1);
}

fn identity_block(vals: &[f32]) -> Compressed {
    let mut payload = Vec::with_capacity(4 * vals.len());
    for v in vals {
        payload.extend_from_slice(&v.to_le_bytes());
    }
    Compressed { scheme: SchemeId::Identity, n: vals.len(), payload }
}

fn opts_identity(workers: usize) -> ServerOptions {
    ServerOptions {
        comp: by_name("identity", 0.0).unwrap(),
        sync: SyncMode::Full,
        fused: true,
        n_workers: workers,
        intra_threads: 1,
        seed: 7,
        max_keys: 0,
        iter_deadline: None,
        compress_threads: 0,
        deadline_auto_margin: 0.0,
        adaptive_bounds: None,
    }
}

/// Wait for the next non-Ack message on `ep`.
fn recv_resp(ep: &TcpEndpoint) -> Message {
    loop {
        match ep.recv().unwrap() {
            Message::Ack { .. } => {}
            m => return m,
        }
    }
}

/// Server-panic regression over real sockets: a corrupt (self-consistent
/// but wrong-dimension) push is rejected, leaves the iteration short, and
/// the next iteration recovers instead of panicking the shard.
#[test]
fn tcp_corrupt_push_then_next_iteration_recovers() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let accept = std::thread::spawn(move || {
        let mut eps = Vec::new();
        for _ in 0..2 {
            let (s, _) = listener.accept().unwrap();
            eps.push(TcpEndpoint::from_stream(s).unwrap());
        }
        Server::spawn(opts_identity(2), eps)
    });
    // Connect order fixes worker index: a = worker 0, b = worker 1.
    let a = TcpEndpoint::connect(addr).unwrap();
    let b = TcpEndpoint::connect(addr).unwrap();
    let server = accept.join().unwrap();

    // Worker 0 establishes key 0 as 2-dimensional at iteration 0.
    a.send(Message::Push { key: 0, iter: 0, worker: 0, data: identity_block(&[1.0, 3.0]) })
        .unwrap();
    assert_eq!(a.recv().unwrap(), Message::Ack { key: 0, iter: 0 });
    // Worker 1's push is corrupt: wire-valid but the wrong element count.
    // No ack comes back; iteration 0 is now permanently short.
    b.send(Message::Push { key: 0, iter: 0, worker: 1, data: identity_block(&[9.0]) }).unwrap();
    // Both workers move to iteration 1 — this used to assert the shard down.
    a.send(Message::Push { key: 0, iter: 1, worker: 0, data: identity_block(&[10.0, 20.0]) })
        .unwrap();
    b.send(Message::Push { key: 0, iter: 1, worker: 1, data: identity_block(&[30.0, 40.0]) })
        .unwrap();
    a.send(Message::Pull { key: 0, iter: 1, worker: 0 }).unwrap();
    b.send(Message::Pull { key: 0, iter: 1, worker: 1 }).unwrap();
    for ep in [&a, &b] {
        let Message::PullResp { iter, data, .. } = recv_resp(ep) else { panic!("no resp") };
        assert_eq!(iter, 1);
        assert_eq!(data.n, 2);
        let comp = by_name("identity", 0.0).unwrap();
        let mut out = vec![0.0f32; 2];
        comp.decompress(&data, &mut out);
        assert_eq!(out, vec![20.0, 30.0]);
    }
    a.send(Message::Shutdown).unwrap();
    b.send(Message::Shutdown).unwrap();
    let stats = server.join();
    assert_eq!(stats.rejected, 1);
    assert_eq!(stats.short_iters, 1);
    assert_eq!(stats.pushes, 3);
}

/// Server-panic regression over real sockets: a pull for a key no push has
/// ever touched queues (previously `.expect("pull before any push")`
/// killed the shard) and is served once the key appears.
#[test]
fn tcp_pull_before_any_push_is_served_later() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let accept = std::thread::spawn(move || {
        let (s, _) = listener.accept().unwrap();
        Server::spawn(opts_identity(1), vec![TcpEndpoint::from_stream(s).unwrap()])
    });
    let ep = TcpEndpoint::connect(addr).unwrap();
    let server = accept.join().unwrap();

    // Pull first — reordered startup. The shard must stay alive.
    ep.send(Message::Pull { key: 3, iter: 0, worker: 0 }).unwrap();
    // Now the push arrives; the queued pull must be answered.
    ep.send(Message::Push { key: 3, iter: 0, worker: 0, data: identity_block(&[5.0, -2.0]) })
        .unwrap();
    let Message::PullResp { key, iter, served_with, data } = recv_resp(&ep) else {
        panic!("no resp")
    };
    assert_eq!(served_with, 1);
    assert_eq!((key, iter), (3, 0));
    let comp = by_name("identity", 0.0).unwrap();
    let mut out = vec![0.0f32; 2];
    comp.decompress(&data, &mut out);
    assert_eq!(out, vec![5.0, -2.0]);
    ep.send(Message::Shutdown).unwrap();
    let stats = server.join();
    assert_eq!(stats.pulls, 1);
    assert_eq!(stats.early_pulls, 1);
    assert_eq!(stats.pushes, 1);
}

/// Wait (bounded) for a child process and assert it exited cleanly.
fn wait_ok(mut child: std::process::Child, name: &str) {
    let deadline = Instant::now() + Duration::from_secs(180);
    loop {
        match child.try_wait().unwrap() {
            Some(status) => {
                assert!(status.success(), "{name} exited with {status}");
                return;
            }
            None => {
                if Instant::now() > deadline {
                    let _ = child.kill();
                    panic!("{name} timed out");
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

fn free_port() -> u16 {
    TcpListener::bind("127.0.0.1:0").unwrap().local_addr().unwrap().port()
}

/// The real thing: separate OS processes (`bytepsc server` x2 + `bytepsc
/// worker` x2) over localhost TCP, aggregates dumped to disk, compared
/// bit-for-bit against the single-process inproc fabric. The servers run
/// the *staged* shard pipeline (`--compress-threads 4`) while the inproc
/// reference runs synchronous shards — so this is also the end-to-end
/// staged-vs-synchronous bit-identity acceptance over real sockets and
/// real OS processes. (The degraded-round process test below keeps
/// `compress_threads = 0`, so CI exercises both paths.)
#[test]
fn process_cluster_staged_bit_identical_to_inproc() {
    let bin = env!("CARGO_BIN_EXE_bytepsc");
    let (dim, tensors, iters, nodes, servers) = (3000usize, 3usize, 4usize, 2usize, 2usize);
    let seed = 42u64;
    let addrs: Vec<String> =
        (0..servers).map(|_| format!("127.0.0.1:{}", free_port())).collect();
    let dir = std::env::temp_dir().join(format!("bytepsc-cluster-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let s = |v: &str| v.to_string();
    let mut children = Vec::new();
    for (shard, addr) in addrs.iter().enumerate() {
        let args: Vec<String> = vec![
            s("server"),
            s("--listen"), addr.clone(),
            s("--shard"), shard.to_string(),
            s("--shards"), servers.to_string(),
            s("--nodes"), nodes.to_string(),
            s("--scheme"), s("identity"),
            s("--dim"), dim.to_string(),
            s("--tensors"), tensors.to_string(),
            s("--seed"), seed.to_string(),
            s("--compress-threads"), s("4"),
        ];
        let child =
            std::process::Command::new(bin).args(&args).spawn().expect("spawn server");
        children.push((child, format!("server {shard}")));
    }
    let server_list = addrs.join(",");
    let mut dumps = Vec::new();
    for rank in 0..nodes {
        let dump = dir.join(format!("worker{rank}.aggs"));
        let args: Vec<String> = vec![
            s("worker"),
            s("--servers"), server_list.clone(),
            s("--rank"), rank.to_string(),
            s("--nodes"), nodes.to_string(),
            s("--scheme"), s("identity"),
            s("--dim"), dim.to_string(),
            s("--tensors"), tensors.to_string(),
            s("--iters"), iters.to_string(),
            s("--seed"), seed.to_string(),
            s("--dump"), dump.to_str().unwrap().to_string(),
        ];
        let child =
            std::process::Command::new(bin).args(&args).spawn().expect("spawn worker");
        children.push((child, format!("worker {rank}")));
        dumps.push(dump);
    }
    for (child, name) in children {
        wait_ok(child, &name);
    }

    // Reference: identical config through the inproc fabric. The CLI uses
    // TrainConfig::default() + the flags above; mirror that here.
    let mut cfg = TrainConfig::default();
    cfg.cluster.nodes = nodes;
    cfg.cluster.addresses = addrs;
    cfg.compression.scheme = "identity".into();
    cfg.seed = seed;
    let want = inproc_reference(&cfg, dim, tensors, iters);

    for (rank, dump) in dumps.iter().enumerate() {
        let got = cluster::read_aggregates(dump).unwrap();
        assert_eq!(got.len(), iters, "worker {rank} dumped {} iterations", got.len());
        for (it, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g, w, "worker {rank} iteration {it}: process aggregate != inproc");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance over real OS processes: `bytepsc server --iter-deadline-ms`
/// x2 + `bytepsc worker` x2 where worker 1's push for tensor 1 at
/// iteration 1 is dropped (`--drop-push`). Training completes (no hung
/// pull), the faulted (key, iteration) serves worker 0's contribution
/// alone, and everything else is bit-identical to the fault-free inproc
/// reference.
#[test]
fn degraded_round_process_cluster_completes() {
    let bin = env!("CARGO_BIN_EXE_bytepsc");
    let (dim, tensors, iters, nodes, servers) = (2048usize, 2usize, 3usize, 2usize, 2usize);
    let seed = 42u64;
    // Default 4 MiB blocks keep each tensor whole: tensor 1 is key 1 and
    // covers flat [1024, 2048).
    let drop_key = 1u64;
    let drop_iter = 1u64;
    let drop_range = 1024usize..2048;
    let addrs: Vec<String> =
        (0..servers).map(|_| format!("127.0.0.1:{}", free_port())).collect();
    let dir = std::env::temp_dir().join(format!("bytepsc-degraded-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let s = |v: &str| v.to_string();
    let mut children = Vec::new();
    for (shard, addr) in addrs.iter().enumerate() {
        let args: Vec<String> = vec![
            s("server"),
            s("--listen"), addr.clone(),
            s("--shard"), shard.to_string(),
            s("--shards"), servers.to_string(),
            s("--nodes"), nodes.to_string(),
            s("--scheme"), s("identity"),
            s("--dim"), dim.to_string(),
            s("--tensors"), tensors.to_string(),
            s("--seed"), seed.to_string(),
            // Sized against CI process-scheduling noise (a spurious seal
            // of a healthy round would break the bit-exact comparison);
            // only the faulted iteration waits it out.
            s("--iter-deadline-ms"), s("2000"),
        ];
        let child =
            std::process::Command::new(bin).args(&args).spawn().expect("spawn server");
        children.push((child, format!("server {shard}")));
    }
    let server_list = addrs.join(",");
    let mut dumps = Vec::new();
    for rank in 0..nodes {
        let dump = dir.join(format!("worker{rank}.aggs"));
        let mut args: Vec<String> = vec![
            s("worker"),
            s("--servers"), server_list.clone(),
            s("--rank"), rank.to_string(),
            s("--nodes"), nodes.to_string(),
            s("--scheme"), s("identity"),
            s("--dim"), dim.to_string(),
            s("--tensors"), tensors.to_string(),
            s("--iters"), iters.to_string(),
            s("--seed"), seed.to_string(),
            s("--dump"), dump.to_str().unwrap().to_string(),
        ];
        if rank == 1 {
            args.push(s("--drop-push"));
            args.push(format!("{drop_key}@{drop_iter}"));
        }
        let child =
            std::process::Command::new(bin).args(&args).spawn().expect("spawn worker");
        children.push((child, format!("worker {rank}")));
        dumps.push(dump);
    }
    // The liveness claim itself: every process exits within the bound
    // instead of hanging on the faulted iteration's pull.
    for (child, name) in children {
        wait_ok(child, &name);
    }

    let mut cfg = TrainConfig::default();
    cfg.cluster.nodes = nodes;
    cfg.cluster.addresses = addrs;
    cfg.compression.scheme = "identity".into();
    cfg.seed = seed;
    let want_full = inproc_reference(&cfg, dim, tensors, iters);
    let g0 = cluster::synthetic_grad(seed, 0, drop_iter, dim);
    for (rank, dump) in dumps.iter().enumerate() {
        let got = cluster::read_aggregates(dump).unwrap();
        assert_eq!(got.len(), iters, "worker {rank} dumped {} iterations", got.len());
        for (it, (g, full)) in got.iter().zip(&want_full).enumerate() {
            for i in 0..dim {
                let expect = if it as u64 == drop_iter && drop_range.contains(&i) {
                    g0[i]
                } else {
                    full[i]
                };
                assert_eq!(
                    g[i], expect,
                    "worker {rank} iteration {it} element {i}: degraded process run diverged"
                );
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The hierarchical topology over real OS processes: 2 *staged* server
/// shards (`--compress-threads 4`), 2 `bytepsc leader` relays, and 2
/// member `bytepsc worker`s that only ever dial their leader. All four
/// ranks dump their aggregates, which must be bit-identical to the FLAT
/// 4-worker inproc reference — the full deployment shape of the two-level
/// fan-in cut, exercised end to end over sockets, processes, and the
/// staged shard pipeline at once.
#[test]
fn hierarchical_process_cluster_bit_identical_to_flat() {
    let bin = env!("CARGO_BIN_EXE_bytepsc");
    let (dim, tensors, iters) = (3000usize, 3usize, 4usize);
    let (nodes, groups, servers) = (4usize, 2usize, 2usize);
    let m = nodes / groups;
    let seed = 42u64;
    let addrs: Vec<String> =
        (0..servers).map(|_| format!("127.0.0.1:{}", free_port())).collect();
    let leader_addrs: Vec<String> =
        (0..groups).map(|_| format!("127.0.0.1:{}", free_port())).collect();
    let dir = std::env::temp_dir().join(format!("bytepsc-hier-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let s = |v: &str| v.to_string();
    let mut children = Vec::new();
    for (shard, addr) in addrs.iter().enumerate() {
        let args: Vec<String> = vec![
            s("server"),
            s("--listen"), addr.clone(),
            s("--shard"), shard.to_string(),
            s("--shards"), servers.to_string(),
            s("--nodes"), nodes.to_string(),
            s("--groups"), groups.to_string(),
            s("--scheme"), s("identity"),
            s("--dim"), dim.to_string(),
            s("--tensors"), tensors.to_string(),
            s("--seed"), seed.to_string(),
            s("--compress-threads"), s("4"),
        ];
        let child =
            std::process::Command::new(bin).args(&args).spawn().expect("spawn server");
        children.push((child, format!("server {shard}")));
    }
    let server_list = addrs.join(",");
    let mut dumps = Vec::new();
    for g in 0..groups {
        // The leader co-locates its group's first member (rank g*m).
        let dump = dir.join(format!("rank{}.aggs", g * m));
        let args: Vec<String> = vec![
            s("leader"),
            s("--group"), g.to_string(),
            s("--listen"), leader_addrs[g].clone(),
            s("--servers"), server_list.clone(),
            s("--nodes"), nodes.to_string(),
            s("--groups"), groups.to_string(),
            s("--scheme"), s("identity"),
            s("--dim"), dim.to_string(),
            s("--tensors"), tensors.to_string(),
            s("--iters"), iters.to_string(),
            s("--seed"), seed.to_string(),
            s("--dump"), dump.to_str().unwrap().to_string(),
        ];
        let child =
            std::process::Command::new(bin).args(&args).spawn().expect("spawn leader");
        children.push((child, format!("leader {g}")));
        dumps.push((g * m, dump));
        for rank in g * m + 1..(g + 1) * m {
            let dump = dir.join(format!("rank{rank}.aggs"));
            let args: Vec<String> = vec![
                s("worker"),
                s("--servers"), leader_addrs[g].clone(),
                s("--rank"), rank.to_string(),
                s("--nodes"), nodes.to_string(),
                s("--groups"), groups.to_string(),
                s("--scheme"), s("identity"),
                s("--dim"), dim.to_string(),
                s("--tensors"), tensors.to_string(),
                s("--iters"), iters.to_string(),
                s("--seed"), seed.to_string(),
                s("--dump"), dump.to_str().unwrap().to_string(),
            ];
            let child =
                std::process::Command::new(bin).args(&args).spawn().expect("spawn member");
            children.push((child, format!("member {rank}")));
            dumps.push((rank, dump));
        }
    }
    for (child, name) in children {
        wait_ok(child, &name);
    }

    // Reference: the FLAT 4-worker fleet through the inproc fabric (same
    // CLI defaults, groups left at 0).
    let mut cfg = TrainConfig::default();
    cfg.cluster.nodes = nodes;
    cfg.cluster.addresses = addrs;
    cfg.compression.scheme = "identity".into();
    cfg.seed = seed;
    let want = inproc_reference(&cfg, dim, tensors, iters);

    for (rank, dump) in &dumps {
        let got = cluster::read_aggregates(dump).unwrap();
        assert_eq!(got.len(), iters, "rank {rank} dumped {} iterations", got.len());
        for (it, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(
                g, w,
                "rank {rank} iteration {it}: hierarchical process aggregate != flat inproc"
            );
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}
