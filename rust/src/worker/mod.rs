//! Worker node: the intra-node stage (simulated multi-GPU ring all-reduce
//! with FP16 conversion, §4.1.1) and the inter-node client side of
//! Algorithms 3/4 (EF-compress, push, pull, decompress) — serial per-key
//! ([`WorkerComm::push`]/[`pull`](WorkerComm::pull)) or block-pipelined
//! ([`WorkerComm::push_all`]/[`pull_all`](WorkerComm::pull_all), §4.2.1).

pub mod group;
pub mod pipeline;

use crate::comm::{Endpoint, Key, Message};
use crate::compress::controller::GainController;
use crate::compress::ef::EfState;
use crate::compress::{Compressor, Ctx};
use crate::configx::SyncMode;
use crate::parallel::{Semaphore, ThreadPool};
use crate::util::f16::f16_round;
use crate::util::rng::Xoshiro256;
use self::pipeline::{BlockEf, Partition, PushWindow};
use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// How long a windowed push phase waits on a full window before declaring
/// the phase stalled (counted in [`WorkerCounters::window_stalls`]) and
/// finishing it unwindowed. A full window that never drains means the
/// server stopped acking — e.g. it deadline-sealed the round and drops
/// this worker's late pushes unacked — and liveness beats the
/// staging-memory bound then; the stall is paid at most once per phase.
pub const ACK_STALL_TIMEOUT: Duration = Duration::from_secs(5);

/// Ring all-reduce (average) across the node's GPU ranks with the paper's
/// intra-node FP16 stage: every partial sum that crosses the (simulated)
/// NVLink is rounded to f16, exactly like reducing f16 tensors with NCCL.
/// All ranks end with the same averaged gradient; rank 0's copy is
/// returned.
pub fn ring_allreduce_fp16(rank_grads: &mut Vec<Vec<f32>>) -> Vec<f32> {
    let ranks = rank_grads.len();
    assert!(ranks >= 1);
    let dim = rank_grads[0].len();
    if ranks == 1 {
        return rank_grads[0].clone();
    }
    for g in rank_grads.iter() {
        assert_eq!(g.len(), dim);
    }
    // Reduce-scatter: chunk c accumulates around the ring in f16.
    let chunk = dim.div_ceil(ranks);
    let ranges: Vec<std::ops::Range<usize>> = (0..ranks)
        .map(|c| (c * chunk).min(dim)..((c + 1) * chunk).min(dim))
        .collect();
    for c in 0..ranks {
        // Chunk c is owned by rank c after the scatter; accumulate ranks
        // one hop at a time with f16 rounding on the wire.
        let mut acc: Vec<f32> =
            rank_grads[(c + 1) % ranks][ranges[c].clone()].iter().map(|&v| f16_round(v)).collect();
        for hop in 2..=ranks {
            let r = (c + hop) % ranks;
            for (a, &v) in acc.iter_mut().zip(&rank_grads[r][ranges[c].clone()]) {
                *a = f16_round(*a + f16_round(v));
            }
        }
        let inv = 1.0 / ranks as f32;
        for (i, a) in ranges[c].clone().zip(acc) {
            let avg = f16_round(a * inv);
            // All-gather: broadcast the reduced chunk to every rank.
            for g in rank_grads.iter_mut() {
                g[i] = avg;
            }
        }
    }
    rank_grads[0].clone()
}

/// Inter-node client: one per worker node. Owns the worker-side EF
/// residuals, the RNG stream for stochastic compressors, and (for the
/// pipelined path) the node's CPU compression pool.
pub struct WorkerComm {
    pub worker_id: u32,
    comp: Arc<dyn Compressor>,
    sync: SyncMode,
    fused: bool,
    /// Serial-path residuals (one caller at a time).
    ef: EfState,
    /// Pipelined-path residuals (per-block locks; see [`BlockEf`]).
    block_ef: Arc<BlockEf>,
    rng: Xoshiro256,
    seed: u64,
    intra_threads: usize,
    /// endpoints[s] talks to server s. Shared so pipeline jobs can send
    /// from pool threads (both transports lock internally).
    endpoints: Arc<Vec<Box<dyn Endpoint>>>,
    plan: Arc<crate::ps::ShardPlan>,
    /// This node's compression pool (§4.2.1 inter-task parallelism).
    pool: Arc<ThreadPool>,
    /// Bounds outstanding compress/push jobs (pipeline.inflight knob) on
    /// the phase-barrier path; the windowed path builds a fresh
    /// [`PushWindow`] of the same capacity per phase instead.
    inflight: Arc<Semaphore>,
    /// `pipeline.inflight` as a number (the window capacity).
    inflight_cap: usize,
    /// Windowed pushes (`pipeline.ack_window`): drain acks concurrently
    /// with the push phase so `inflight` is a true sliding window instead
    /// of a phase barrier that parks every ack in the socket buffer.
    ack_window: bool,
    /// Worker count of the run — how many contributions a full (non-
    /// degraded) aggregate carries; `served_with` below this marks a
    /// degraded round.
    n_workers: usize,
    /// Pull responses whose `served_with` was below `n_workers` — rounds
    /// the server completed degraded under its iteration deadline.
    degraded_responses: AtomicU64,
    /// Pushes this worker dropped via the fault-injection hook (shared
    /// with pipeline jobs, hence the Arc).
    dropped_pushes: Arc<AtomicU64>,
    /// Push phases whose window stalled past [`ACK_STALL_TIMEOUT`] and
    /// finished unwindowed (at most one count per phase).
    window_stalls: AtomicU64,
    /// Degraded pulls whose aggregate was folded into the block's EF
    /// residual (see [`WorkerComm::fold_factor`]).
    ef_folds: AtomicU64,
    /// Fault-injection hook: `(key, iter)` pushes to drop before the wire
    /// (each fires once). Tests use it to simulate a lost push.
    drop_pushes: Arc<Mutex<HashSet<(Key, u64)>>>,
    /// `(key, iter)` pushes the fault hook actually dropped — consulted
    /// (and consumed) by the degraded-pull fold: a worker whose *own* push
    /// never reached the server was not part of the served aggregate, so
    /// the overshoot the fold corrects never included it and folding would
    /// double-correct.
    dropped_log: Arc<Mutex<HashSet<(Key, u64)>>>,
    /// Per-key adaptive compression controller
    /// ([`crate::compress::controller`]), built from the bounds the
    /// handshake granted. `None` = static run: the pipelined push path is
    /// bit-identical to the pre-controller code. Only the *pipelined*
    /// CompressedEf push path consults it — the serial reference path
    /// ([`push`](WorkerComm::push)) stays static by design.
    adaptive: Option<Arc<GainController>>,
}

/// Worker-side liveness counters (see [`WorkerComm::counters`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerCounters {
    /// Pull responses served from a degraded round
    /// (`served_with < n_workers`).
    pub degraded_responses: u64,
    /// Pushes dropped by the fault-injection hook.
    pub dropped_pushes: u64,
    /// Push phases whose window stalled past [`ACK_STALL_TIMEOUT`]
    /// (acks stopped draining; the phase finished unwindowed). At most
    /// one per push phase.
    pub window_stalls: u64,
    /// Degraded pull responses whose aggregate this worker folded into
    /// the block's EF residual (`−(n − m)/m ×` the served aggregate) so
    /// cumulative updates track the Alg. 4 reference — CompressedEf runs
    /// only, and only when the worker's own push was in the aggregate.
    pub ef_folds: u64,
    /// Keep-ratio adjustments the adaptive controller made across all
    /// keys (0 on static runs, or when every key's gain sat inside the
    /// dead band the whole run).
    pub k_adjustments: u64,
    /// Smallest per-key keep ratio (parts-per-million) the controller
    /// currently holds — with `k_ppm_hi`, the observed trajectory span.
    /// On static runs both are 0.
    pub k_ppm_lo: u64,
    /// Largest per-key keep ratio (ppm) the controller currently holds.
    pub k_ppm_hi: u64,
}

/// The one canonical rendering of the worker counter set (mirrors
/// `ServerStats`'s Display): every shutdown line goes through here, and
/// the counter-registry lint keeps each field present, so a new counter
/// cannot be added and silently missed on a report surface.
impl std::fmt::Display for WorkerCounters {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} degraded pulls | {} dropped pushes | {} window stalls | \
             {} ef folds | {} k adjustments | k ppm span [{}, {}]",
            self.degraded_responses,
            self.dropped_pushes,
            self.window_stalls,
            self.ef_folds,
            self.k_adjustments,
            self.k_ppm_lo,
            self.k_ppm_hi
        )
    }
}

/// The fault hook applied to a compressed push about to hit the wire
/// (shared by the serial and pipelined paths so their drop semantics —
/// post-compression, counted, logged — can never diverge). Returns
/// whether the push was dropped; each `(key, iter)` entry fires once.
fn push_drop_faulted(
    worker_id: u32,
    drop_pushes: &Mutex<HashSet<(Key, u64)>>,
    dropped_log: &Mutex<HashSet<(Key, u64)>>,
    dropped: &AtomicU64,
    key: Key,
    iter: u64,
) -> bool {
    if drop_pushes.lock().unwrap().remove(&(key, iter)) {
        dropped.fetch_add(1, Ordering::Relaxed);
        // Remember the drop: the degraded-pull fold must not fire for a
        // round this worker knows it was absent from.
        dropped_log.lock().unwrap_or_else(|p| p.into_inner()).insert((key, iter));
        eprintln!("worker {worker_id}: fault injection dropped push key {key} iter {iter}");
        true
    } else {
        false
    }
}

/// RAII permit: releases its semaphore slot even if the job panics.
struct Permit(Arc<Semaphore>);

impl Drop for Permit {
    fn drop(&mut self) {
        self.0.release();
    }
}

impl WorkerComm {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        worker_id: u32,
        comp: Arc<dyn Compressor>,
        sync: SyncMode,
        fused: bool,
        intra_threads: usize,
        seed: u64,
        endpoints: Vec<Box<dyn Endpoint>>,
        plan: Arc<crate::ps::ShardPlan>,
        pool_threads: usize,
        inflight: usize,
        ack_window: bool,
        n_workers: usize,
        adaptive: Option<Arc<GainController>>,
    ) -> Self {
        WorkerComm {
            worker_id,
            comp,
            sync,
            fused,
            ef: EfState::new(fused),
            block_ef: Arc::new(BlockEf::new()),
            rng: Xoshiro256::seed_from_u64(seed ^ (worker_id as u64).wrapping_mul(0x9E3779B97F4A7C15)),
            seed,
            intra_threads,
            endpoints: Arc::new(endpoints),
            plan,
            pool: Arc::new(ThreadPool::new(pool_threads)),
            inflight: Arc::new(Semaphore::new(inflight)),
            inflight_cap: inflight.max(1),
            ack_window,
            n_workers,
            degraded_responses: AtomicU64::new(0),
            dropped_pushes: Arc::new(AtomicU64::new(0)),
            window_stalls: AtomicU64::new(0),
            ef_folds: AtomicU64::new(0),
            drop_pushes: Arc::new(Mutex::new(HashSet::new())),
            dropped_log: Arc::new(Mutex::new(HashSet::new())),
            adaptive,
        }
    }

    /// Fault-injection hook: drop this worker's push for `(key, iter)`
    /// before it reaches the wire, exactly once — simulating a lost push
    /// so tests can exercise the server's iteration deadline.
    pub fn inject_push_drop(&self, key: Key, iter: u64) {
        self.drop_pushes.lock().unwrap().insert((key, iter));
    }

    /// Worker-side liveness counters: degraded rounds seen, pushes
    /// dropped by fault injection, windowed-push stalls.
    pub fn counters(&self) -> WorkerCounters {
        let (k_adjustments, (k_ppm_lo, k_ppm_hi)) = match &self.adaptive {
            Some(ctl) => {
                let (lo, hi) = ctl.ppm_span();
                (ctl.adjustments(), (u64::from(lo), u64::from(hi)))
            }
            None => (0, (0, 0)),
        };
        WorkerCounters {
            degraded_responses: self.degraded_responses.load(Ordering::Relaxed),
            dropped_pushes: self.dropped_pushes.load(Ordering::Relaxed),
            window_stalls: self.window_stalls.load(Ordering::Relaxed),
            ef_folds: self.ef_folds.load(Ordering::Relaxed),
            k_adjustments,
            k_ppm_lo,
            k_ppm_hi,
        }
    }

    /// Degraded-pull EF fold factor (Alg. 4 catch-up; the ROADMAP
    /// "worker-side re-push" item). When this worker's own *delivered*
    /// push comes back in an aggregate averaged over `m = served_with <
    /// n_workers` contributions, the served value overshoots the
    /// reference mean (lost push = zero contribution, divisor
    /// `n_workers`) by `aggregate × (n − m)/n`; each of the `m` surviving
    /// workers folding `−(n − m)/m ×` the aggregate into its EF residual
    /// makes the next round's average cancel exactly that overshoot
    /// (`BlockEf::fold_scaled` has the algebra and the reference test).
    /// `None` when no fold applies: full round, retired marker, a non-EF
    /// sync mode (no residual to fold into), or a round this worker knows
    /// its own push never reached (fault-dropped) — it was not in the
    /// aggregate, so the overshoot never included it.
    fn fold_factor(&self, key: Key, iter: u64, served_with: u16) -> Option<f32> {
        if self.sync != SyncMode::CompressedEf {
            return None;
        }
        let m = usize::from(served_with);
        if m == 0 || m >= self.n_workers {
            return None;
        }
        if self.dropped_log.lock().unwrap_or_else(|p| p.into_inner()).remove(&(key, iter)) {
            return None;
        }
        self.ef_folds.fetch_add(1, Ordering::Relaxed);
        Some(-((self.n_workers - m) as f32) / m as f32)
    }

    /// Note a pull response's `served_with` tag (degraded-round metric).
    fn note_served_with(&self, served_with: u16) {
        if (served_with as usize) < self.n_workers {
            self.degraded_responses.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Push one tensor (Alg. 3/4 worker side, compress step included).
    /// Returns (compressed wire bytes, compression seconds).
    pub fn push(&mut self, key: Key, iter: u64, grad: &[f32]) -> (usize, f64) {
        let t = std::time::Instant::now();
        let data = match self.sync {
            SyncMode::CompressedEf => {
                let mut ctx = Ctx::with_threads(&mut self.rng, self.intra_threads);
                self.ef.compress(key, grad, self.comp.as_ref(), &mut ctx)
            }
            _ => {
                let mut ctx = Ctx::with_threads(&mut self.rng, self.intra_threads);
                self.comp.compress(grad, &mut ctx)
            }
        };
        let dt = t.elapsed().as_secs_f64();
        // Fault injection checks *after* compression: a lost push is lost
        // on the wire, not before the EF residual update — exactly the
        // failure the degraded-round protocol is specified against.
        if push_drop_faulted(
            self.worker_id,
            &self.drop_pushes,
            &self.dropped_log,
            &self.dropped_pushes,
            key,
            iter,
        ) {
            return (0, dt);
        }
        let nbytes = data.nbytes();
        let server = self.plan.server_of(key);
        self.endpoints[server]
            .send(Message::Push { key, iter, worker: self.worker_id, data })
            .expect("server alive");
        (nbytes, dt)
    }

    /// Pull one tensor's aggregate into `out`; blocks until available.
    /// Returns (received wire bytes, decompression seconds) — the pull
    /// direction of the two-way compression accounting.
    pub fn pull(&mut self, key: Key, iter: u64, out: &mut [f32]) -> (usize, f64) {
        let server = self.plan.server_of(key);
        self.endpoints[server]
            .send(Message::Pull { key, iter, worker: self.worker_id })
            .expect("server alive");
        loop {
            match self.endpoints[server].recv().expect("server alive") {
                Message::Ack { .. } => {}
                m @ Message::PullResp { .. } => {
                    let nbytes = crate::comm::frame::frame_bytes(&m);
                    let Message::PullResp { key: k, iter: i, served_with, data } = m else {
                        unreachable!()
                    };
                    assert_eq!((k, i), (key, iter), "out-of-order pull response");
                    assert_ne!(
                        served_with, 0,
                        "server retired iteration {iter} for key {key} before this \
                         worker's pull: the worker lagged past the deadline history \
                         and cannot continue consistently"
                    );
                    self.note_served_with(served_with);
                    let t = std::time::Instant::now();
                    self.comp.decompress(&data, out);
                    return (nbytes, t.elapsed().as_secs_f64());
                }
                m => panic!("worker got unexpected {m:?}"),
            }
        }
    }

    /// Pipelined push of every block in `parts` (§4.2.1): each block's
    /// EF-correct + compress + send runs as one pool job, so compression
    /// of block *i+1* overlaps the in-flight send of block *i*, and up to
    /// `pool_threads` blocks compress concurrently. Blocks for different
    /// server shards interleave, giving the servers work early (§4.2.4).
    ///
    /// With `pipeline.ack_window` on (the default), server acks drain
    /// *during* the phase and `pipeline.inflight` is a true sliding
    /// window over unacked pushes; off, the legacy phase barrier runs
    /// (slots free on send, acks wait in the socket until the pull
    /// phase). Both paths emit identical wire traffic — per-block job
    /// seeds make the streams independent of scheduling — so they are
    /// bit-identical for deterministic compressors.
    ///
    /// Returns summed compression seconds across jobs (CPU time, not
    /// wall time — under the pipeline the wall cost is what shrinks).
    /// Blocks until every push of this iteration is on the wire, which
    /// preserves the per-key push-then-pull FIFO order the server's
    /// one-slot rollover relies on.
    pub fn push_all(&self, iter: u64, grad: &[f32], parts: &Partition) -> f64 {
        let compress_ns = Arc::new(AtomicU64::new(0));
        if self.ack_window {
            self.push_all_windowed(iter, grad, parts, &compress_ns);
        } else {
            self.push_all_barrier(iter, grad, parts, &compress_ns);
        }
        let panics = self.pool.take_panics();
        assert!(panics == 0, "{panics} push pipeline job(s) panicked");
        compress_ns.load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// One push job: EF-correct + compress block `key`, then send it —
    /// unless the fault hook drops it, in which case `on_drop` runs (the
    /// windowed path frees the window slot: no ack will ever come).
    /// `on_drop` is dropped uncalled on the normal path, so a barrier
    /// permit captured in it still releases at job end either way.
    fn push_job(
        &self,
        iter: u64,
        key: Key,
        g: Vec<f32>,
        compress_ns: &Arc<AtomicU64>,
        on_drop: impl FnOnce() + Send + 'static,
    ) {
        let server = self.plan.server_of(key);
        let endpoints = Arc::clone(&self.endpoints);
        let block_ef = Arc::clone(&self.block_ef);
        let comp = Arc::clone(&self.comp);
        let drop_pushes = Arc::clone(&self.drop_pushes);
        let dropped_log = Arc::clone(&self.dropped_log);
        let dropped = Arc::clone(&self.dropped_pushes);
        let (sync, fused, intra, worker) =
            (self.sync, self.fused, self.intra_threads, self.worker_id);
        let adaptive = self.adaptive.clone();
        let seed = pipeline::job_seed(self.seed, worker, key, iter);
        let cns = Arc::clone(compress_ns);
        self.pool.execute(move || {
            let t = std::time::Instant::now();
            let mut rng = Xoshiro256::seed_from_u64(seed);
            let mut ctx = Ctx::with_threads(&mut rng, intra);
            let data = match sync {
                // EF keeps `g` as the block's new residual (recycling the
                // displaced one); otherwise the staging copy dies here.
                //
                // With a controller, this block compresses at the key's
                // *current* keep ratio, the achieved gain feeds back, and
                // the next iteration of this key sees the adjusted ratio.
                // The controller clamps into the granted bounds, so an
                // honest worker can never trip the server's
                // `bounds_rejected` ingress check.
                SyncMode::CompressedEf => match &adaptive {
                    Some(ctl) => {
                        let comp = ctl.compressor_for(key);
                        let (c, gain) =
                            block_ef.compress_gain(key, g, comp.as_ref(), fused, &mut ctx);
                        ctl.observe(key, gain);
                        c
                    }
                    None => block_ef.compress(key, g, comp.as_ref(), fused, &mut ctx),
                },
                _ => {
                    let c = comp.compress(&g, &mut ctx);
                    crate::comm::BufPool::global().give_f32(g);
                    c
                }
            };
            cns.fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
            // Fault injection after compression: the push is lost on the
            // wire, not before the EF residual update.
            if push_drop_faulted(worker, &drop_pushes, &dropped_log, &dropped, key, iter) {
                on_drop();
                return;
            }
            endpoints[server]
                .send(Message::Push { key, iter, worker, data })
                .expect("server alive");
        });
    }

    /// Legacy phase-barrier push: window slots free when the job ends
    /// (send returned); acks park in the transport until the pull phase
    /// reads past them.
    fn push_all_barrier(
        &self,
        iter: u64,
        grad: &[f32],
        parts: &Partition,
        compress_ns: &Arc<AtomicU64>,
    ) {
        for sb in parts.subs() {
            // Bound staging memory: wait for a slot before copying the
            // next block out of the gradient.
            self.inflight.acquire();
            let permit = Permit(Arc::clone(&self.inflight));
            // lint: transfers(push-job)
            let g = crate::comm::BufPool::global().rent_f32_copy(&grad[sb.range.clone()]);
            self.push_job(iter, sb.key, g, compress_ns, move || drop(permit));
        }
        self.pool.wait();
    }

    /// Windowed push: per-endpoint ack drainers run concurrently with the
    /// push jobs, freeing a window slot per ack — `pipeline.inflight`
    /// bounds *unacked* pushes, so the server→worker ack stream can never
    /// back up the socket however small `pipeline.block_bytes` gets.
    ///
    /// Safe to drain here: during a push phase the only server→worker
    /// traffic is this iteration's acks (per-connection FIFO means the
    /// server emits every ack for a worker's iteration-*t* pushes before
    /// any iteration-*t* `PullResp`, and the previous pull phase fully
    /// drained the stream).
    fn push_all_windowed(
        &self,
        iter: u64,
        grad: &[f32],
        parts: &Partition,
        compress_ns: &Arc<AtomicU64>,
    ) {
        // Fresh window per phase: slots cannot leak across iterations
        // even when acks go missing (a deadline-sealed round drops late
        // pushes unacked).
        let window = Arc::new(PushWindow::new(self.inflight_cap, ACK_STALL_TIMEOUT));
        let mut expect = vec![0usize; self.endpoints.len()];
        for sb in parts.subs() {
            expect[self.plan.server_of(sb.key)] += 1;
        }
        let stop = AtomicBool::new(false);
        std::thread::scope(|scope| {
            for (s, ep) in self.endpoints.iter().enumerate() {
                if expect[s] == 0 {
                    continue;
                }
                let want = expect[s];
                let window = Arc::clone(&window);
                let stop = &stop;
                scope.spawn(move || {
                    let mut acked = 0usize;
                    // Poll with exponential backoff (50 µs → 1 ms): a
                    // blocking read timeout cannot be used here — it
                    // could fire mid-frame and desync the stream — and
                    // the backoff keeps an idle drainer at ~1 kHz of
                    // try_recv syscalls instead of tens of kHz.
                    let min_idle = Duration::from_micros(50);
                    let max_idle = Duration::from_millis(1);
                    let mut idle = min_idle;
                    while acked < want {
                        match ep.try_recv() {
                            Ok(Some(Message::Ack { iter: i, .. })) => {
                                debug_assert_eq!(i, iter, "ack from a different iteration");
                                acked += 1;
                                window.close();
                                idle = min_idle;
                            }
                            Ok(Some(m)) => {
                                panic!("worker got unexpected {m:?} during push phase")
                            }
                            Ok(None) => {
                                if stop.load(Ordering::Acquire) {
                                    // Phase over; unarrived acks belong to
                                    // lost/late pushes and the pull phase
                                    // skips any stragglers.
                                    break;
                                }
                                std::thread::sleep(idle);
                                idle = (idle * 2).min(max_idle);
                            }
                            // Connection died: the send side will surface
                            // the error; don't spin on it here.
                            Err(_) => break,
                        }
                    }
                });
            }
            // One stall latches for the whole phase: a full window that
            // outlived ACK_STALL_TIMEOUT means acks stopped (the server
            // deadline-sealed a round and drops this worker's late pushes
            // unacked) — waiting the timeout again per block would turn
            // one degraded round into an O(blocks × timeout) stall, so
            // the rest of the phase proceeds unwindowed. The latch also
            // keeps the accounting honest: unslotted pushes' acks would
            // otherwise free slots they never held.
            let mut stalled = false;
            for sb in parts.subs() {
                if !stalled && !window.open() {
                    stalled = true;
                    self.window_stalls.fetch_add(1, Ordering::Relaxed);
                    eprintln!(
                        "worker {}: push window stalled (no acks for {:?}); \
                         finishing this phase unwindowed",
                        self.worker_id, ACK_STALL_TIMEOUT
                    );
                }
                // lint: transfers(push-job)
                let g = crate::comm::BufPool::global().rent_f32_copy(&grad[sb.range.clone()]);
                let window = Arc::clone(&window);
                self.push_job(iter, sb.key, g, compress_ns, move || window.close());
            }
            self.pool.wait();
            stop.store(true, Ordering::Release);
        });
    }

    /// Pipelined pull of every block in `parts`: all pull requests go out
    /// first, then one receive loop per server endpoint hands each
    /// response to the pool for decompression — so decompressing block *i*
    /// overlaps receiving block *i+1*. Decompressed blocks scatter into
    /// `out` by their partition ranges.
    ///
    /// Returns (received wire bytes, summed decompression seconds).
    pub fn pull_all(&self, iter: u64, out: &mut [f32], parts: &Partition) -> (u64, f64) {
        let mut expect = vec![0usize; self.endpoints.len()];
        for sb in parts.subs() {
            let s = self.plan.server_of(sb.key);
            self.endpoints[s]
                .send(Message::Pull { key: sb.key, iter, worker: self.worker_id })
                .expect("server alive");
            expect[s] += 1;
        }
        let ranges = parts.ranges_by_key();
        let (tx, rx) = std::sync::mpsc::channel::<(std::ops::Range<usize>, Vec<f32>)>();
        let rx_bytes = AtomicU64::new(0);
        let decompress_ns = Arc::new(AtomicU64::new(0));
        std::thread::scope(|scope| {
            let ranges = &ranges;
            let rx_bytes = &rx_bytes;
            let pool = &self.pool;
            let comp = &self.comp;
            let dns = &decompress_ns;
            let this = &*self;
            for (sidx, ep) in self.endpoints.iter().enumerate() {
                let want = expect[sidx];
                if want == 0 {
                    continue;
                }
                let tx = tx.clone();
                scope.spawn(move || {
                    let mut got = 0usize;
                    while got < want {
                        match ep.recv().expect("server alive") {
                            Message::Ack { .. } => {}
                            m @ Message::PullResp { .. } => {
                                rx_bytes.fetch_add(
                                    crate::comm::frame::frame_bytes(&m) as u64,
                                    Ordering::Relaxed,
                                );
                                let Message::PullResp { key, iter: i, served_with, data } = m
                                else {
                                    unreachable!()
                                };
                                assert_eq!(i, iter, "pull response for wrong iteration");
                                assert_ne!(
                                    served_with, 0,
                                    "server retired iteration {iter} for key {key} \
                                     before this worker's pull: the worker lagged \
                                     past the deadline history and cannot continue \
                                     consistently"
                                );
                                this.note_served_with(served_with);
                                let fold = this.fold_factor(key, iter, served_with);
                                let range = ranges
                                    .get(&key)
                                    .expect("pull response for unknown block key")
                                    .clone();
                                assert_eq!(data.n, range.len(), "block size mismatch on key {key}");
                                got += 1;
                                let tx = tx.clone();
                                let comp = Arc::clone(comp);
                                let dns = Arc::clone(dns);
                                let bef = Arc::clone(&this.block_ef);
                                pool.execute(move || {
                                    let t = std::time::Instant::now();
                                    let bp = crate::comm::BufPool::global();
                                    // lint: transfers(pull-scatter)
                                    let mut buf = bp.rent_f32(data.n);
                                    comp.decompress(&data, &mut buf);
                                    // Degraded round: fold the average
                                    // shift into this block's EF residual
                                    // before the aggregate is applied.
                                    if let Some(factor) = fold {
                                        bef.fold_scaled(key, &buf, factor);
                                    }
                                    dns.fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
                                    // The response payload dies with the
                                    // decode; recycle it.
                                    bp.give_bytes(data.payload);
                                    let _ = tx.send((range, buf));
                                });
                            }
                            m => panic!("worker got unexpected {m:?}"),
                        }
                    }
                });
            }
        });
        self.pool.wait();
        let panics = self.pool.take_panics();
        assert!(panics == 0, "{panics} pull pipeline job(s) panicked");
        drop(tx);
        for (range, buf) in rx {
            out[range].copy_from_slice(&buf);
            crate::comm::BufPool::global().give_f32(buf);
        }
        (rx_bytes.load(Ordering::Relaxed), decompress_ns.load(Ordering::Relaxed) as f64 * 1e-9)
    }

    /// Total bytes this worker has sent.
    pub fn bytes_sent(&self) -> u64 {
        self.endpoints.iter().map(|e| e.bytes_sent()).sum()
    }

    /// Send shutdown to every server this worker talks to.
    pub fn shutdown(&self) {
        for ep in &self.endpoints {
            let _ = ep.send(Message::Shutdown);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;

    #[test]
    fn ring_allreduce_is_mean_up_to_f16() {
        forall(50, 0x41b1u64, |g| {
            let ranks = g.usize_in(1, 8);
            let dim = g.usize_in(1, 300);
            let grads: Vec<Vec<f32>> = (0..ranks).map(|_| g.f32_vec(dim, 2.0)).collect();
            let mut work = grads.clone();
            let out = ring_allreduce_fp16(&mut work);
            for i in 0..dim {
                let mean: f32 = grads.iter().map(|gr| gr[i]).sum::<f32>() / ranks as f32;
                // f16 rounding at each of up to `ranks` hops: generous bound.
                let tol = 1e-2 * mean.abs().max(1.0) * ranks as f32;
                if (out[i] - mean).abs() > tol {
                    return Err(format!("i={i} out={} mean={mean} ranks={ranks}", out[i]));
                }
            }
            // all ranks converged to the same values
            for r in 1..ranks {
                if work[r] != work[0] {
                    return Err("ranks disagree after allgather".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn single_rank_is_identity() {
        let mut grads = vec![vec![1.0f32, -2.5, 3.25]];
        let out = ring_allreduce_fp16(&mut grads);
        assert_eq!(out, vec![1.0, -2.5, 3.25]);
    }

    #[test]
    fn allreduce_values_are_f16_representable() {
        let mut grads = vec![
            (0..100).map(|i| (i as f32) * 0.013).collect::<Vec<_>>(),
            (0..100).map(|i| (i as f32) * -0.007).collect::<Vec<_>>(),
        ];
        let out = ring_allreduce_fp16(&mut grads);
        for v in out {
            assert_eq!(v, f16_round(v), "output {v} not f16-representable");
        }
    }
}
