//! Worker node: the intra-node stage (simulated multi-GPU ring all-reduce
//! with FP16 conversion, §4.1.1) and the inter-node client side of
//! Algorithms 3/4 (EF-compress, push, pull, decompress).

use crate::comm::{Endpoint, Key, Message};
use crate::compress::ef::EfState;
use crate::compress::{Compressor, Ctx};
use crate::configx::SyncMode;
use crate::util::f16::f16_round;
use crate::util::rng::Xoshiro256;
use std::sync::Arc;

/// Ring all-reduce (average) across the node's GPU ranks with the paper's
/// intra-node FP16 stage: every partial sum that crosses the (simulated)
/// NVLink is rounded to f16, exactly like reducing f16 tensors with NCCL.
/// All ranks end with the same averaged gradient; rank 0's copy is
/// returned.
pub fn ring_allreduce_fp16(rank_grads: &mut Vec<Vec<f32>>) -> Vec<f32> {
    let ranks = rank_grads.len();
    assert!(ranks >= 1);
    let dim = rank_grads[0].len();
    if ranks == 1 {
        return rank_grads[0].clone();
    }
    for g in rank_grads.iter() {
        assert_eq!(g.len(), dim);
    }
    // Reduce-scatter: chunk c accumulates around the ring in f16.
    let chunk = dim.div_ceil(ranks);
    let ranges: Vec<std::ops::Range<usize>> = (0..ranks)
        .map(|c| (c * chunk).min(dim)..((c + 1) * chunk).min(dim))
        .collect();
    for c in 0..ranks {
        // Chunk c is owned by rank c after the scatter; accumulate ranks
        // one hop at a time with f16 rounding on the wire.
        let mut acc: Vec<f32> =
            rank_grads[(c + 1) % ranks][ranges[c].clone()].iter().map(|&v| f16_round(v)).collect();
        for hop in 2..=ranks {
            let r = (c + hop) % ranks;
            for (a, &v) in acc.iter_mut().zip(&rank_grads[r][ranges[c].clone()]) {
                *a = f16_round(*a + f16_round(v));
            }
        }
        let inv = 1.0 / ranks as f32;
        for (i, a) in ranges[c].clone().zip(acc) {
            let avg = f16_round(a * inv);
            // All-gather: broadcast the reduced chunk to every rank.
            for g in rank_grads.iter_mut() {
                g[i] = avg;
            }
        }
    }
    rank_grads[0].clone()
}

/// Inter-node client: one per worker node. Owns the worker-side EF
/// residuals and the RNG stream for stochastic compressors.
pub struct WorkerComm {
    pub worker_id: u32,
    comp: Arc<dyn Compressor>,
    sync: SyncMode,
    ef: EfState,
    rng: Xoshiro256,
    intra_threads: usize,
    /// endpoints[s] talks to server s.
    endpoints: Vec<Box<dyn Endpoint>>,
    plan: crate::ps::ShardPlan,
}

impl WorkerComm {
    pub fn new(
        worker_id: u32,
        comp: Arc<dyn Compressor>,
        sync: SyncMode,
        fused: bool,
        intra_threads: usize,
        seed: u64,
        endpoints: Vec<Box<dyn Endpoint>>,
        plan: crate::ps::ShardPlan,
    ) -> Self {
        WorkerComm {
            worker_id,
            comp,
            sync,
            ef: EfState::new(fused),
            rng: Xoshiro256::seed_from_u64(seed ^ (worker_id as u64).wrapping_mul(0x9E3779B97F4A7C15)),
            intra_threads,
            endpoints,
            plan,
        }
    }

    /// Push one tensor (Alg. 3/4 worker side, compress step included).
    /// Returns (compressed wire bytes, compression seconds).
    pub fn push(&mut self, key: Key, iter: u64, grad: &[f32]) -> (usize, f64) {
        let t = std::time::Instant::now();
        let data = match self.sync {
            SyncMode::CompressedEf => {
                let mut ctx = Ctx::with_threads(&mut self.rng, self.intra_threads);
                self.ef.compress(key, grad, self.comp.as_ref(), &mut ctx)
            }
            _ => {
                let mut ctx = Ctx::with_threads(&mut self.rng, self.intra_threads);
                self.comp.compress(grad, &mut ctx)
            }
        };
        let dt = t.elapsed().as_secs_f64();
        let nbytes = data.nbytes();
        let server = self.plan.server_of(key);
        self.endpoints[server]
            .send(Message::Push { key, iter, worker: self.worker_id, data })
            .expect("server alive");
        (nbytes, dt)
    }

    /// Pull one tensor's aggregate into `out`; blocks until available.
    /// Returns (received wire bytes, decompression seconds) — the pull
    /// direction of the two-way compression accounting.
    pub fn pull(&mut self, key: Key, iter: u64, out: &mut [f32]) -> (usize, f64) {
        let server = self.plan.server_of(key);
        self.endpoints[server]
            .send(Message::Pull { key, iter, worker: self.worker_id })
            .expect("server alive");
        loop {
            match self.endpoints[server].recv().expect("server alive") {
                Message::Ack { .. } => {}
                m @ Message::PullResp { .. } => {
                    let nbytes = crate::comm::frame::frame_bytes(&m);
                    let Message::PullResp { key: k, iter: i, data } = m else { unreachable!() };
                    assert_eq!((k, i), (key, iter), "out-of-order pull response");
                    let t = std::time::Instant::now();
                    self.comp.decompress(&data, out);
                    return (nbytes, t.elapsed().as_secs_f64());
                }
                m => panic!("worker got unexpected {m:?}"),
            }
        }
    }

    /// Total bytes this worker has sent.
    pub fn bytes_sent(&self) -> u64 {
        self.endpoints.iter().map(|e| e.bytes_sent()).sum()
    }

    /// Send shutdown to every server this worker talks to.
    pub fn shutdown(&self) {
        for ep in &self.endpoints {
            let _ = ep.send(Message::Shutdown);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;

    #[test]
    fn ring_allreduce_is_mean_up_to_f16() {
        forall(50, 0x41b1u64, |g| {
            let ranks = g.usize_in(1, 8);
            let dim = g.usize_in(1, 300);
            let grads: Vec<Vec<f32>> = (0..ranks).map(|_| g.f32_vec(dim, 2.0)).collect();
            let mut work = grads.clone();
            let out = ring_allreduce_fp16(&mut work);
            for i in 0..dim {
                let mean: f32 = grads.iter().map(|gr| gr[i]).sum::<f32>() / ranks as f32;
                // f16 rounding at each of up to `ranks` hops: generous bound.
                let tol = 1e-2 * mean.abs().max(1.0) * ranks as f32;
                if (out[i] - mean).abs() > tol {
                    return Err(format!("i={i} out={} mean={mean} ranks={ranks}", out[i]));
                }
            }
            // all ranks converged to the same values
            for r in 1..ranks {
                if work[r] != work[0] {
                    return Err("ranks disagree after allgather".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn single_rank_is_identity() {
        let mut grads = vec![vec![1.0f32, -2.5, 3.25]];
        let out = ring_allreduce_fp16(&mut grads);
        assert_eq!(out, vec![1.0, -2.5, 3.25]);
    }

    #[test]
    fn allreduce_values_are_f16_representable() {
        let mut grads = vec![
            (0..100).map(|i| (i as f32) * 0.013).collect::<Vec<_>>(),
            (0..100).map(|i| (i as f32) * -0.007).collect::<Vec<_>>(),
        ];
        let out = ring_allreduce_fp16(&mut grads);
        for v in out {
            assert_eq!(v, f16_round(v), "output {v} not f16-representable");
        }
    }
}
