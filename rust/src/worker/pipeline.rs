//! Block-partitioned push/pull pipeline (paper §4.2.1 / §4.2.3 / §4.2.4).
//!
//! The paper's headline system observation is that two-way compression
//! only pays off when (de)compression is *pipelined* with communication:
//! tensors are partitioned into fixed-size blocks, each block gets its own
//! wire key ([`crate::comm::BlockKey`]), and dozens of CPU compression jobs
//! run concurrently so that compressing block *i+1* overlaps the in-flight
//! send of block *i* (and symmetrically, decompression overlaps receive on
//! the pull side). Compressing each whole tensor inline on the step path —
//! the pre-pipeline behavior, still available as the serial reference path
//! — serializes CPU work behind the network, which is exactly what makes
//! naive compression a net loss (Agarwal et al. '21).
//!
//! This module owns the partitioning ([`Partition`]) and the shared
//! per-block error-feedback state ([`BlockEf`]) that lets many compression
//! jobs run concurrently: each block's residual is an independent
//! `Mutex<Vec<f32>>`, so jobs on different blocks never contend beyond a
//! brief map lookup. The driving loops live in
//! [`WorkerComm::push_all`](crate::worker::WorkerComm::push_all) /
//! [`pull_all`](crate::worker::WorkerComm::pull_all).

use crate::comm::{BlockKey, Key};
use crate::compress::{Compressed, Compressor, Ctx};
use crate::optim::blocks::Block;
use std::collections::HashMap;
use std::ops::Range;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One wire unit: a contiguous slice of the flat gradient vector with its
/// own packed block key.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SubBlock {
    /// Packed [`BlockKey`] — this block's identity on the wire and in the
    /// shard plan.
    pub key: Key,
    /// The slice of the flat parameter/gradient vector this block covers.
    pub range: Range<usize>,
}

impl SubBlock {
    pub fn len(&self) -> usize {
        self.range.end - self.range.start
    }

    pub fn is_empty(&self) -> bool {
        self.range.is_empty()
    }
}

/// A tensor list partitioned into fixed-size blocks (§4.2.3).
///
/// Tensors strictly larger than `block_elems` are split into
/// `ceil(len / block_elems)` chunks; smaller tensors stay whole (block 0).
/// With `split = false` every tensor is a single block whose key equals its
/// tensor id — bit-compatible with the pre-pipeline keyspace.
#[derive(Clone, Debug)]
pub struct Partition {
    subs: Vec<SubBlock>,
    block_elems: usize,
}

impl Partition {
    /// Partition `blocks` (the model's parameter tensors) with blocks of
    /// `block_bytes` bytes of f32 data. `split = false` disables
    /// partitioning (the serial/ablation arm) while keeping the same
    /// key/plan machinery.
    pub fn new(blocks: &[Block], block_bytes: usize, split: bool) -> Partition {
        let block_elems = (block_bytes / 4).max(1);
        let mut subs = Vec::new();
        for (t, b) in blocks.iter().enumerate() {
            let nb = if split && b.len > block_elems { b.len.div_ceil(block_elems) } else { 1 };
            let chunk = if nb == 1 { b.len } else { block_elems };
            for j in 0..nb {
                let lo = b.offset + j * chunk;
                let hi = (lo + chunk).min(b.offset + b.len);
                subs.push(SubBlock { key: BlockKey::new(t as u64, j as u32).pack(), range: lo..hi });
            }
        }
        Partition { subs, block_elems }
    }

    /// The wire units, in tensor order then block order.
    pub fn subs(&self) -> &[SubBlock] {
        &self.subs
    }

    pub fn block_elems(&self) -> usize {
        self.block_elems
    }

    /// Number of wire units (>= number of tensors).
    pub fn len(&self) -> usize {
        self.subs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.subs.is_empty()
    }

    /// key -> flat range lookup (used by the pull side to scatter
    /// decompressed blocks back into the output vector).
    pub fn ranges_by_key(&self) -> HashMap<Key, Range<usize>> {
        self.subs.iter().map(|sb| (sb.key, sb.range.clone())).collect()
    }
}

/// Concurrent per-block error-feedback store (worker side of Alg. 4 under
/// the pipeline). Unlike [`crate::compress::ef::EfState`], which assumes a
/// single caller, each block's residual lives behind its own mutex so
/// compression jobs for different blocks proceed in parallel.
#[derive(Default)]
pub struct BlockEf {
    residuals: Mutex<HashMap<Key, Arc<Mutex<Vec<f32>>>>>,
}

impl BlockEf {
    pub fn new() -> BlockEf {
        BlockEf::default()
    }

    fn slot(&self, key: Key, len: usize) -> Arc<Mutex<Vec<f32>>> {
        // Poison recovery (here and below): a panicking holder can leave a
        // residual numerically stale but never structurally broken, and
        // cascading the panic into every compression job would turn one
        // block's failure into a worker-wide crash.
        let mut map = self.residuals.lock().unwrap_or_else(|p| p.into_inner());
        Arc::clone(map.entry(key).or_insert_with(|| Arc::new(Mutex::new(vec![0.0f32; len]))))
    }

    /// One EF cycle for block `key` over the owned gradient chunk `g`:
    /// correct (`q = g + e`), compress, store the new residual. Mirrors
    /// `EfState::compress_owned`, block-locked.
    pub fn compress(
        &self,
        key: Key,
        g: Vec<f32>,
        comp: &dyn Compressor,
        fused: bool,
        ctx: &mut Ctx,
    ) -> Compressed {
        self.compress_inner(key, g, comp, fused, ctx, false).0
    }

    /// [`compress`](BlockEf::compress) plus the block's *compression gain*
    /// for the adaptive controller: with `q = g + e` the corrected input
    /// and `e'` the residual left behind, the gain is
    /// `1 − ‖e'‖² / ‖q‖²` — for the zero-filling sparsifiers
    /// (TopK/RandomK) the kept and dropped coordinates are disjoint, so
    /// this equals `‖compressed‖² / ‖q‖²` exactly, with no decode needed
    /// (see [`crate::compress::controller`]). `‖q‖² = 0` reports gain 1.
    pub fn compress_gain(
        &self,
        key: Key,
        g: Vec<f32>,
        comp: &dyn Compressor,
        fused: bool,
        ctx: &mut Ctx,
    ) -> (Compressed, f64) {
        self.compress_inner(key, g, comp, fused, ctx, true)
    }

    /// Shared EF cycle. `measure = false` skips both norm passes so the
    /// static path stays cost- and bit-identical to the pre-controller
    /// code (the reported gain is then a constant 1.0, unused).
    fn compress_inner(
        &self,
        key: Key,
        mut g: Vec<f32>,
        comp: &dyn Compressor,
        fused: bool,
        ctx: &mut Ctx,
        measure: bool,
    ) -> (Compressed, f64) {
        let slot = self.slot(key, g.len());
        let mut e = slot.lock().unwrap_or_else(|p| p.into_inner());
        // lint: allow(panic) — caller contract: a block's length is fixed by the partition; a size change is a harness bug, not a wire input
        assert_eq!(e.len(), g.len(), "block {key} changed size");
        crate::compress::kernels::add_assign(&mut g, &e);
        let t2 = if measure { crate::compress::controller::sumsq(&g) } else { 0.0 };
        let pool = crate::comm::BufPool::global();
        let c = if fused {
            comp.compress_ef_fused(&mut g, ctx)
        } else {
            let c = comp.compress(&g, ctx);
            let mut dec = pool.rent_f32(g.len());
            comp.decompress(&c, &mut dec);
            crate::compress::kernels::sub_assign(&mut g, &dec);
            pool.give_f32(dec);
            c
        };
        // After either branch `g` holds the new residual e'.
        let gain = if measure {
            crate::compress::controller::gain_from(t2, crate::compress::controller::sumsq(&g))
        } else {
            1.0
        };
        // `g` becomes the new residual; the displaced one is recycled (the
        // staging copy rented in push_all thus round-trips via the pool).
        pool.give_f32(std::mem::replace(&mut *e, g));
        (c, gain)
    }

    /// Fold a scaled copy of `agg` into block `key`'s residual:
    /// `e += factor × agg`, creating a zero residual first if the block
    /// has none yet (a non-fused or first-iteration block).
    ///
    /// This is the *degraded-pull fold* (ROADMAP's worker-side re-push
    /// item): when this worker's own delivered push comes back in an
    /// aggregate averaged over `m < n` workers, the served value
    /// overshoots the Alg. 4 reference mean (lost push = zero
    /// contribution, divisor `n`) by `agg × (n − m)/n`. Each of the `m`
    /// surviving workers folds `factor = −(n − m)/m` of the aggregate
    /// here, so the next round's average carries `m × factor / n = −(n −
    /// m)/n` of it — cancelling the overshoot exactly and making the
    /// *cumulative* applied updates match the reference (see the
    /// `degraded_fold_matches_alg4_reference` test).
    pub fn fold_scaled(&self, key: Key, agg: &[f32], factor: f32) {
        let slot = self.slot(key, agg.len());
        let mut e = slot.lock().unwrap_or_else(|p| p.into_inner());
        // lint: allow(panic) — caller contract: a block's length is fixed by the partition; a size change is a harness bug, not a wire input
        assert_eq!(e.len(), agg.len(), "block {key} changed size");
        for (ei, &ai) in e.iter_mut().zip(agg) {
            *ei += factor * ai;
        }
    }

    /// Total f32 elements held as residual state (memory accounting).
    pub fn state_elems(&self) -> usize {
        let map = self.residuals.lock().unwrap_or_else(|p| p.into_inner());
        map.values().map(|v| v.lock().unwrap_or_else(|p| p.into_inner()).len()).sum()
    }

    /// Peek at one block's residual (tests / diagnostics).
    pub fn residual(&self, key: Key) -> Option<Vec<f32>> {
        let map = self.residuals.lock().unwrap_or_else(|p| p.into_inner());
        map.get(&key).map(|v| v.lock().unwrap_or_else(|p| p.into_inner()).clone())
    }
}

/// Sliding send window for the pipelined push phase (`pipeline.inflight`
/// as a *real* window): bounds how many pushes are in flight — staged,
/// compressing, or sent-but-unacked — at once. Unlike the old
/// phase-barrier accounting (slot freed when the send returned), a slot
/// stays taken until the server's `Ack` drains back, so the window also
/// bounds the server→worker ack backlog and small `pipeline.block_bytes`
/// partitions no longer rely on socket buffers swallowing an unbounded
/// ack stream (DESIGN.md §Cluster mode, backpressure envelope).
///
/// One window is created per push phase, so slots can never leak across
/// iterations. [`open`](PushWindow::open) gives up after `stall_timeout`
/// and lets the caller proceed: a server that stops acking (it
/// deadline-sealed the round and drops late pushes unacked) degrades the
/// memory bound instead of deadlocking the phase. After a timed-out open
/// the caller must stop opening for the rest of the phase (the push
/// phase latches a stall — see `WorkerComm::push_all`): it bounds the
/// total stall to one timeout, and it keeps accounting exact, since an
/// unslotted push's eventual ack would free a slot it never held.
/// [`close`](PushWindow::close) additionally saturates at zero, so
/// surplus closes can never underflow the counter.
pub struct PushWindow {
    in_flight: Mutex<usize>,
    cv: Condvar,
    cap: usize,
    stall_timeout: Duration,
}

impl PushWindow {
    pub fn new(cap: usize, stall_timeout: Duration) -> PushWindow {
        PushWindow { in_flight: Mutex::new(0), cv: Condvar::new(), cap: cap.max(1), stall_timeout }
    }

    /// Take a slot, waiting for acks to free one. Returns `false` when the
    /// window stayed full past `stall_timeout` — the caller proceeds
    /// anyway (liveness over the memory bound) and should count the stall.
    pub fn open(&self) -> bool {
        let deadline = Instant::now() + self.stall_timeout;
        // Poison recovery: the slot counter is a plain usize whose holder
        // only increments/decrements it; a panicking holder cannot leave it
        // mid-update, and window accounting must outlive any one job.
        let mut in_flight = self.in_flight.lock().unwrap_or_else(|p| p.into_inner());
        while *in_flight >= self.cap {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, timeout) = self
                .cv
                .wait_timeout(in_flight, deadline - now)
                .unwrap_or_else(|p| p.into_inner());
            in_flight = guard;
            if timeout.timed_out() && *in_flight >= self.cap {
                return false;
            }
        }
        *in_flight += 1;
        true
    }

    /// Free a slot — an ack drained, or the push was dropped before the
    /// wire (fault injection) and no ack will ever come.
    pub fn close(&self) {
        let mut in_flight = self.in_flight.lock().unwrap_or_else(|p| p.into_inner());
        if *in_flight > 0 {
            *in_flight -= 1;
            self.cv.notify_one();
        }
    }

    /// Slots currently taken (tests / diagnostics).
    pub fn in_flight(&self) -> usize {
        *self.in_flight.lock().unwrap_or_else(|p| p.into_inner())
    }
}

/// Deterministic per-(worker, block, iteration) RNG seed for stochastic
/// compressors: pipeline job scheduling must never change the stream a
/// block sees.
pub fn job_seed(base: u64, worker: u32, key: Key, iter: u64) -> u64 {
    base ^ (worker as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ key.wrapping_mul(0xD1B5_4A32_D192_ED03)
        ^ iter.wrapping_mul(0x94D0_49BB_1331_11EB)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::by_name;
    use crate::optim::blocks::from_shapes;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn partition_tiles_exactly() {
        let blocks = from_shapes(&[
            ("a".into(), 1000), // 1000 > 256 -> 4 blocks
            ("b".into(), 256),  // == block_elems -> whole
            ("c".into(), 7),    // small -> whole
            ("d".into(), 513),  // -> 3 blocks (256, 256, 1)
        ]);
        let p = Partition::new(&blocks, 1024, true); // 256 elems per block
        assert_eq!(p.block_elems(), 256);
        assert_eq!(p.len(), 4 + 1 + 1 + 3);
        // Ranges tile [0, 1776) in order without gaps or overlap.
        let mut expect = 0usize;
        for sb in p.subs() {
            assert_eq!(sb.range.start, expect, "gap before {:?}", sb);
            assert!(!sb.is_empty());
            assert!(sb.len() <= 256);
            expect = sb.range.end;
        }
        assert_eq!(expect, 1776);
        // Keys are unique and carry the right tensor/block structure.
        let mut seen = std::collections::HashSet::new();
        for sb in p.subs() {
            assert!(seen.insert(sb.key), "duplicate key {}", sb.key);
        }
        let bk = BlockKey::unpack(p.subs()[1].key);
        assert_eq!((bk.tensor, bk.block), (0, 1));
        // Tensor "d" splits 256 + 256 + 1.
        let d: Vec<usize> = p.subs().iter().skip(6).map(|sb| sb.len()).collect();
        assert_eq!(d, vec![256, 256, 1]);
    }

    #[test]
    fn partition_disabled_matches_tensor_keys() {
        let blocks = from_shapes(&[("a".into(), 1000), ("b".into(), 50)]);
        let p = Partition::new(&blocks, 64, false);
        assert_eq!(p.len(), 2);
        assert_eq!(p.subs()[0].key, 0);
        assert_eq!(p.subs()[1].key, 1);
        assert_eq!(p.subs()[0].range, 0..1000);
        assert_eq!(p.subs()[1].range, 1000..1050);
    }

    #[test]
    fn block_ef_matches_single_threaded_efstate() {
        use crate::compress::ef::EfState;
        let comp = by_name("topk", 0.2).unwrap();
        let bef = BlockEf::new();
        let mut ef = EfState::new(true);
        let mut data_rng = Xoshiro256::seed_from_u64(3);
        for iter in 0..6u64 {
            let mut g = vec![0.0f32; 64];
            data_rng.fill_normal(&mut g, 1.0);
            let mut r1 = Xoshiro256::seed_from_u64(iter);
            let mut r2 = Xoshiro256::seed_from_u64(iter);
            let ca = bef.compress(5, g.clone(), comp.as_ref(), true, &mut Ctx::new(&mut r1));
            let cb = ef.compress(5, &g, comp.as_ref(), &mut Ctx::new(&mut r2));
            assert_eq!(ca, cb, "wire mismatch at iter {iter}");
            assert_eq!(bef.residual(5).unwrap(), ef.residual(5).unwrap().to_vec());
        }
    }

    /// The measuring variant is wire- and residual-identical to the plain
    /// one (the norm passes are read-only) and reports a gain in (0, 1].
    #[test]
    fn block_ef_compress_gain_matches_compress() {
        let comp = by_name("topk", 0.25).unwrap();
        let a = BlockEf::new();
        let b = BlockEf::new();
        let mut data_rng = Xoshiro256::seed_from_u64(9);
        for iter in 0..4u64 {
            let mut g = vec![0.0f32; 64];
            data_rng.fill_normal(&mut g, 1.0);
            let mut r1 = Xoshiro256::seed_from_u64(iter);
            let mut r2 = Xoshiro256::seed_from_u64(iter);
            let ca = a.compress(7, g.clone(), comp.as_ref(), true, &mut Ctx::new(&mut r1));
            let (cb, gain) = b.compress_gain(7, g, comp.as_ref(), true, &mut Ctx::new(&mut r2));
            assert_eq!(ca, cb, "measuring must not change the wire at iter {iter}");
            assert!(gain > 0.0 && gain <= 1.0, "gain {gain} out of range");
            assert_eq!(a.residual(7).unwrap(), b.residual(7).unwrap());
        }
    }

    #[test]
    fn block_ef_is_concurrency_safe_per_block() {
        let comp = by_name("topk", 0.25).unwrap();
        let bef = Arc::new(BlockEf::new());
        std::thread::scope(|s| {
            for key in 0..8u64 {
                let bef = Arc::clone(&bef);
                let comp = comp.clone();
                s.spawn(move || {
                    let mut rng = Xoshiro256::seed_from_u64(key);
                    for _ in 0..20 {
                        let g: Vec<f32> = (0..32).map(|i| (key as f32) + i as f32).collect();
                        let _ = bef.compress(key, g, comp.as_ref(), true, &mut Ctx::new(&mut rng));
                    }
                });
            }
        });
        assert_eq!(bef.state_elems(), 8 * 32);
    }

    /// The degraded-pull fold reproduces the Alg. 4 reference exactly:
    /// with a lost push modeled as a zero contribution over divisor `n`,
    /// the surviving workers' folds make the *cumulative* applied updates
    /// match the reference once the next full round lands. Identity
    /// compression and integer-valued gradients keep every sum exact, so
    /// the match is bitwise.
    #[test]
    fn degraded_fold_matches_alg4_reference() {
        let comp = by_name("identity", 1.0).unwrap();
        let key = 3u64;
        let dim = 4usize;
        let n = 2usize;
        // Integer-valued per-(worker, iter) gradients → exact f32 halves.
        let g = |w: usize, it: usize| vec![(2 + 4 * w + 8 * it) as f32; 4];
        // Worker-side EF state for the folding run (worker 1's iter-1 push
        // is lost on the wire *after* its residual update, exactly like
        // the fault hook).
        let efs: Vec<BlockEf> = (0..n).map(|_| BlockEf::new()).collect();
        let mut rng = Xoshiro256::seed_from_u64(0);
        let mut applied = vec![0.0f32; dim]; // folding run's cumulative update
        let mut reference = vec![0.0f32; dim]; // Alg. 4: lost = zero, divisor n
        for iter in 0..3usize {
            let mut sum = vec![0.0f32; dim];
            let mut refsum = vec![0.0f32; dim];
            let mut delivered = 0usize;
            for (w, ef) in efs.iter().enumerate() {
                let c = ef.compress(key, g(w, iter), comp.as_ref(), true, &mut Ctx::new(&mut rng));
                let mut wire = vec![0.0f32; dim];
                comp.decompress(&c, &mut wire);
                let lost = iter == 1 && w == 1;
                if !lost {
                    for (s, v) in sum.iter_mut().zip(&wire) {
                        *s += v;
                    }
                    delivered += 1;
                }
                // The reference sees the same wire stream minus the fold
                // (identity EF leaves zero residuals, so its wire is just
                // g(w, iter)); a lost push contributes zero.
                if !lost {
                    for (s, v) in refsum.iter_mut().zip(&g(w, iter)) {
                        *s += v;
                    }
                }
            }
            // Server: average over the pushes actually received.
            let agg: Vec<f32> = sum.iter().map(|s| s / delivered as f32).collect();
            for (a, v) in applied.iter_mut().zip(&agg) {
                *a += v;
            }
            // Reference: average over n, lost contribution = zero — but
            // the reference stream must not include the fold, so strip it:
            // the folding run's iter-2 wire is g + fold; the reference's
            // is g. Rebuild refsum from raw gradients above.
            for (r, v) in reference.iter_mut().zip(&refsum) {
                *r += v / n as f32;
            }
            // Degraded round: every *surviving* worker folds.
            if delivered < n {
                let m = delivered;
                let factor = -((n - m) as f32) / m as f32;
                for (w, ef) in efs.iter().enumerate() {
                    let lost = iter == 1 && w == 1;
                    if !lost {
                        ef.fold_scaled(key, &agg, factor);
                    }
                }
            }
        }
        assert_eq!(
            applied, reference,
            "cumulative folded updates must match the Alg. 4 reference"
        );
    }

    #[test]
    fn push_window_bounds_in_flight_and_saturates() {
        let w = PushWindow::new(2, Duration::from_millis(10));
        assert!(w.open());
        assert!(w.open());
        assert_eq!(w.in_flight(), 2);
        // Full window: open times out rather than blocking forever.
        let t = Instant::now();
        assert!(!w.open(), "third open must time out");
        // Lower bound is loose: condvar timeouts may round slightly.
        assert!(t.elapsed() >= Duration::from_millis(5));
        // An ack frees a slot.
        w.close();
        assert!(w.open());
        // close saturates at zero: surplus acks can never inflate capacity.
        for _ in 0..10 {
            w.close();
        }
        assert_eq!(w.in_flight(), 0);
        assert!(w.open());
        assert!(w.open());
        assert!(!w.open());
    }

    #[test]
    fn push_window_open_unblocks_on_concurrent_close() {
        let w = Arc::new(PushWindow::new(1, Duration::from_secs(10)));
        assert!(w.open());
        let w2 = Arc::clone(&w);
        let closer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            w2.close();
        });
        let t = Instant::now();
        assert!(w.open(), "open must succeed once the slot frees");
        assert!(t.elapsed() < Duration::from_secs(5));
        closer.join().unwrap();
    }

    #[test]
    fn job_seed_is_distinct_across_axes() {
        let base = 42;
        let a = job_seed(base, 0, 1, 0);
        assert_ne!(a, job_seed(base, 1, 1, 0), "worker must change the seed");
        assert_ne!(a, job_seed(base, 0, 2, 0), "key must change the seed");
        assert_ne!(a, job_seed(base, 0, 1, 1), "iter must change the seed");
        assert_eq!(a, job_seed(base, 0, 1, 0), "seed must be deterministic");
    }
}
