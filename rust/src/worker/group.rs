//! Hierarchical two-level aggregation: the group-leader relay.
//!
//! Flat cluster mode gives every server shard O(W) fan-in — W workers
//! each push every block every iteration, and the shard decodes W
//! compressed blocks per key per round. The two-level topology partitions
//! the W workers into G groups; each group elects a *leader* whose relay
//! (this module):
//!
//! 1. collects its members' compressed pushes (one per member per key),
//! 2. decodes and locally reduces them into the group's gradient **sum**
//!    in *global-rank order* (deterministic regardless of arrival order —
//!    the same discipline as the server's connection-index-ordered
//!    reduce),
//! 3. re-compresses the partial aggregate **once**, and
//! 4. forwards a single [`Message::GroupPush`] per key to the owning
//!    server shard, tagged with the number of members it folds in.
//!
//! The server weighs a group push `members`-fold (see
//! `ps::core::ServerCore`), so G group pushes average exactly like W flat
//! pushes — server fan-in, per-round decode count, and handshake load all
//! drop from O(W) to O(G). Pulls fan back leader → members: the relay
//! pulls each key once per iteration and forwards the `PullResp` clone to
//! every member, preserving the `served_with` weight tag so member-side
//! degraded-round accounting (EF folds) keeps its flat-W semantics.
//!
//! ## Re-compression and exactness
//!
//! The leader re-encodes the group sum by the scheme its members used:
//!
//! * **identity** blocks → an identity block of the sum — lossless.
//! * **top-k** blocks → an *exact-sparse* top-k block whose `k` is the
//!   sum's nonzero count (the union of member supports). The top-k wire
//!   format is self-describing (`[k][indices][values]`) and the server
//!   validates only `k ≤ n`, so the exact union encoding is legal on the
//!   wire — lossless, at the cost of a k that grows with the union.
//! * anything else (fp16, onebit, dither, randomk — formats that cannot
//!   express an exact sparse sum) → re-compress with the configured
//!   compressor, with a *leader-level* error-feedback residual absorbing
//!   the re-compression error across rounds (Alg. 4 applied at the middle
//!   tier). This arm is lossy per round and is counted
//!   ([`RelayStats::lossy_reencodes`]); no flat-equivalence guarantee.
//!
//! With identity or top-k members and the synthetic integer-valued
//! cluster workload, every partial sum is exact in f32, so the two-level
//! aggregate is bit-identical to the flat run (asserted by the engine and
//! cluster tests).
//!
//! ## Liveness
//!
//! A member that loses a push (fault injection, a dropped frame) still
//! *pulls* that key — per-connection FIFO means the relay seeing a pull
//! before the member's push proves the push is not coming. The relay then
//! seals the group round without that member (`members` shrinks; the
//! server's weighted round accounting and, if every group shrinks, its
//! iteration deadline handle the rest). A member whose connection dies is
//! marked permanently absent so one crash cannot wedge its group.
//!
//! The relay is single-threaded and lock-free: one poll loop multiplexes
//! member and upstream endpoints with `try_recv` + exponential backoff
//! (the same 50 µs → 1 ms ladder as the worker's ack drainers).

use crate::comm::{CommError, Endpoint, Key, Message};
use crate::compress::{validate_wire, Compressed, Compressor, Ctx, SchemeId};
use crate::configx::SyncMode;
use crate::ps::ShardPlan;
use crate::util::rng::Xoshiro256;
use crate::worker::pipeline::{job_seed, BlockEf};
use std::collections::HashMap;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Seed salt separating the leader's re-compression RNG stream from every
/// worker's per-block stream (`pipeline::job_seed` keyed by worker rank
/// could collide with a group index otherwise).
const GROUP_SEED_SALT: u64 = 0x6A09_E667_F3BC_C908;

/// Everything the relay must agree on with its members and its servers.
pub struct RelayOptions {
    /// This group's index — the rank the leader registered with upstream
    /// (servers see G registrants 0..G-1 in hierarchical mode).
    pub group_idx: u32,
    /// Global worker ranks, parallel to the member endpoint list. The
    /// leader's own co-located worker is just another member (connected
    /// over an in-process pair), so the relay itself holds no gradient
    /// state.
    pub member_ranks: Vec<u32>,
    /// The run's compressor (both ways of the two-way compression).
    pub comp: Arc<dyn Compressor>,
    pub sync: SyncMode,
    pub fused: bool,
    /// Run seed — the lossy re-encode stream derives from it.
    pub seed: u64,
    /// Key → upstream server shard.
    pub plan: Arc<ShardPlan>,
}

/// Relay liveness/volume counters, reported on shutdown next to the
/// worker counters (leader processes print both).
#[derive(Clone, Copy, Debug, Default)]
pub struct RelayStats {
    /// Combined `GroupPush` messages sent upstream (keys × iterations).
    pub group_pushes: u64,
    /// Member pushes received (and acked).
    pub member_pushes: u64,
    /// Member pulls received.
    pub member_pulls: u64,
    /// Member blocks dropped at the relay (wire-validation failure, block
    /// size mismatch) — the round seals without them, never a panic.
    pub rejected: u64,
    /// Member-round absences: a member's pull (or death) proved its push
    /// for a key was not coming and the group round sealed short.
    pub absent_members: u64,
    /// Group rounds re-encoded through the lossy path (leader-level EF)
    /// because the member scheme cannot express an exact sparse sum.
    pub lossy_reencodes: u64,
    /// Messages the relay should never receive (duplicate pushes, stale
    /// pulls, upstream junk) — dropped and counted, never a panic.
    pub unexpected: u64,
}

impl std::fmt::Display for RelayStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} group pushes | {} member pushes | {} member pulls | {} rejected | \
             {} absent members | {} lossy reencodes | {} unexpected",
            self.group_pushes,
            self.member_pushes,
            self.member_pulls,
            self.rejected,
            self.absent_members,
            self.lossy_reencodes,
            self.unexpected
        )
    }
}

/// Where a relay-emitted message goes.
#[derive(Debug, PartialEq, Eq)]
pub enum Dest {
    /// Index into the member endpoint list.
    Member(usize),
    /// Index into the upstream (server shard) endpoint list.
    Upstream(usize),
}

/// One group round of one key.
struct Round {
    iter: u64,
    /// Decoded member contributions, indexed like `member_ranks`.
    got: Vec<Option<(SchemeId, Vec<f32>)>>,
    n_got: usize,
    /// Members proven absent this round (early pull, dead connection).
    absent: Vec<bool>,
    n_absent: usize,
    /// Members waiting on this round's `PullResp`.
    waiters: Vec<usize>,
    /// The combined push went upstream (or was skipped for an all-absent
    /// round) and the upstream pull is outstanding.
    sealed: bool,
    /// Upstream response, cached for members that pull after it arrived.
    resp: Option<(u16, Compressed)>,
}

impl Round {
    fn new(iter: u64, n_members: usize, dead: &[bool]) -> Round {
        let mut r = Round {
            iter,
            got: (0..n_members).map(|_| None).collect(),
            n_got: 0,
            absent: vec![false; n_members],
            n_absent: 0,
            waiters: Vec::new(),
            sealed: false,
            resp: None,
        };
        // A dead member's pushes are never coming: pre-mark it so the
        // round can seal on the live members alone.
        for (m, &d) in dead.iter().enumerate() {
            if d {
                r.absent[m] = true;
                r.n_absent += 1;
            }
        }
        r
    }
}

struct KeyState {
    round: Round,
    /// Element count, pinned by the first accepted contribution.
    dim: Option<usize>,
    /// One-slot history: the previous round's `(iter, served_with, data)`
    /// for members that pull after the key rolled over.
    prev: Option<(u64, u16, Compressed)>,
}

/// The relay state machine. Transport-agnostic: `on_member` /
/// `on_upstream` consume one message and return the messages to send,
/// exactly like `ServerCore::handle` — the poll loop in [`run_relay`]
/// does the I/O.
pub struct GroupRelay {
    opts: RelayOptions,
    /// Member indices in ascending global-rank order (the reduce order).
    rank_order: Vec<usize>,
    keys: HashMap<Key, KeyState>,
    /// Leader-level EF residuals for the lossy re-encode arm.
    group_ef: BlockEf,
    /// Members whose connection died (permanently absent).
    dead: Vec<bool>,
    pub stats: RelayStats,
}

impl GroupRelay {
    pub fn new(opts: RelayOptions) -> GroupRelay {
        let mut rank_order: Vec<usize> = (0..opts.member_ranks.len()).collect();
        rank_order.sort_by_key(|&m| opts.member_ranks[m]);
        let n = opts.member_ranks.len();
        GroupRelay {
            opts,
            rank_order,
            keys: HashMap::new(),
            group_ef: BlockEf::new(),
            dead: vec![false; n],
            stats: RelayStats::default(),
        }
    }

    fn n_members(&self) -> usize {
        self.opts.member_ranks.len()
    }

    /// Handle one message from member `m`; returns the messages to send.
    pub fn on_member(&mut self, m: usize, msg: Message) -> Vec<(Dest, Message)> {
        let mut out = Vec::new();
        match msg {
            Message::Push { key, iter, worker: _, data } => {
                self.stats.member_pushes += 1;
                // Ack immediately: the member's push window frees a slot
                // per ack, and the relay never rejects an honest push.
                out.push((Dest::Member(m), Message::Ack { key, iter }));
                self.member_push(m, key, iter, data, &mut out);
            }
            Message::Pull { key, iter, worker: _ } => {
                self.stats.member_pulls += 1;
                self.member_pull(m, key, iter, &mut out);
            }
            _ => {
                self.stats.unexpected += 1;
                eprintln!("relay {}: unexpected member message {msg:?}", self.opts.group_idx);
            }
        }
        out
    }

    /// Handle one message from upstream shard `s`.
    pub fn on_upstream(&mut self, s: usize, msg: Message) -> Vec<(Dest, Message)> {
        let mut out = Vec::new();
        match msg {
            Message::PullResp { key, iter, served_with, data } => {
                let Some(st) = self.keys.get_mut(&key) else {
                    self.stats.unexpected += 1;
                    return out;
                };
                if st.round.iter == iter && st.round.sealed && st.round.resp.is_none() {
                    for w in std::mem::take(&mut st.round.waiters) {
                        out.push((
                            Dest::Member(w),
                            Message::PullResp { key, iter, served_with, data: data.clone() },
                        ));
                    }
                    st.round.resp = Some((served_with, data));
                } else {
                    // A duplicate, or a response for a round this relay
                    // never opened — shard-side drift; count it.
                    self.stats.unexpected += 1;
                    eprintln!(
                        "relay {}: stray upstream response for key {key} iteration {iter} \
                         from shard {s}",
                        self.opts.group_idx
                    );
                }
            }
            Message::Ack { .. } => {} // our own GroupPush acked
            _ => {
                self.stats.unexpected += 1;
                eprintln!("relay {}: unexpected upstream message {msg:?}", self.opts.group_idx);
            }
        }
        out
    }

    /// Member `m`'s connection died: everything it has not pushed is
    /// never coming. Mark it permanently absent and seal any round its
    /// silence was holding open.
    pub fn on_member_dead(&mut self, m: usize, out: &mut Vec<(Dest, Message)>) {
        if self.dead.get(m).copied().unwrap_or(true) {
            return;
        }
        self.dead[m] = true;
        let keys: Vec<Key> = self.keys.keys().copied().collect();
        for key in keys {
            let Some(st) = self.keys.get_mut(&key) else { continue };
            let r = &mut st.round;
            if !r.sealed && r.got[m].is_none() && !r.absent[m] {
                r.absent[m] = true;
                r.n_absent += 1;
                self.stats.absent_members += 1;
                self.try_seal(key, out);
            }
        }
    }

    fn member_push(
        &mut self,
        m: usize,
        key: Key,
        iter: u64,
        data: Compressed,
        out: &mut Vec<(Dest, Message)>,
    ) {
        let n_members = self.n_members();
        if m >= n_members {
            self.stats.unexpected += 1;
            return;
        }
        let st = self
            .keys
            .entry(key)
            .or_insert_with(|| KeyState {
                round: Round::new(iter, n_members, &self.dead),
                dim: None,
                prev: None,
            });
        // Rollover: a member can only push iteration t+1 after pulling
        // every key of t, so a next-iter push proves round t of this key
        // is fully answered upstream — retire it into the one-slot
        // history for the group's slower members.
        if iter == st.round.iter + 1 && st.round.sealed {
            if let Some((served, resp)) = st.round.resp.take() {
                st.prev = Some((st.round.iter, served, resp));
                st.round = Round::new(iter, n_members, &self.dead);
            }
        }
        let r = &mut st.round;
        if iter != r.iter || r.sealed || r.got[m].is_some() || r.absent[m] {
            self.stats.unexpected += 1;
            eprintln!(
                "relay {}: dropping out-of-round push for key {key} iteration {iter} \
                 from member {m} (round is at {})",
                self.opts.group_idx, r.iter
            );
            return;
        }
        // Same ingress discipline as the server: member payloads are wire
        // data; validate before decoding, reject (and seal around) corrupt
        // blocks instead of panicking.
        let dim_ok = st.dim.is_none_or(|d| d == data.n);
        if !dim_ok || validate_wire(&data).is_err() {
            self.stats.rejected += 1;
            r.absent[m] = true;
            r.n_absent += 1;
            eprintln!(
                "relay {}: rejecting invalid block for key {key} iteration {iter} \
                 from member {m}",
                self.opts.group_idx
            );
            self.try_seal(key, out);
            return;
        }
        st.dim = Some(data.n);
        let mut buf = vec![0.0f32; data.n];
        self.opts.comp.decompress(&data, &mut buf);
        // The member payload dies with the decode; recycle it for the
        // transport's future frames.
        crate::comm::BufPool::global().give_bytes(data.payload);
        let r = &mut st.round;
        r.got[m] = Some((data.scheme, buf));
        r.n_got += 1;
        self.try_seal(key, out);
    }

    fn member_pull(&mut self, m: usize, key: Key, iter: u64, out: &mut Vec<(Dest, Message)>) {
        let n_members = self.n_members();
        let st = self
            .keys
            .entry(key)
            .or_insert_with(|| KeyState {
                round: Round::new(iter, n_members, &self.dead),
                dim: None,
                prev: None,
            });
        // Late pull for a retired round: serve the cached bytes.
        if let Some((piter, served, resp)) = &st.prev {
            if *piter == iter {
                out.push((
                    Dest::Member(m),
                    Message::PullResp { key, iter, served_with: *served, data: resp.clone() },
                ));
                return;
            }
        }
        if iter != st.round.iter {
            // Neither current nor the retired slot — an honest BSP member
            // can never get here; answer with the retired marker so the
            // member fails loudly instead of hanging.
            self.stats.unexpected += 1;
            out.push((
                Dest::Member(m),
                Message::PullResp {
                    key,
                    iter,
                    served_with: 0,
                    data: Compressed { scheme: SchemeId::Identity, n: 0, payload: Vec::new() },
                },
            ));
            return;
        }
        // Per-connection FIFO: this member's pushes for iteration `iter`
        // all precede this pull, so a missing push is a *lost* push (the
        // fault the degraded-round protocol is specified against) — stop
        // waiting for it.
        let r = &mut st.round;
        if !r.sealed && m < n_members && r.got[m].is_none() && !r.absent[m] {
            r.absent[m] = true;
            r.n_absent += 1;
            self.stats.absent_members += 1;
        }
        match &st.round.resp {
            Some((served, resp)) => out.push((
                Dest::Member(m),
                Message::PullResp { key, iter, served_with: *served, data: resp.clone() },
            )),
            None => st.round.waiters.push(m),
        }
        self.try_seal(key, out);
    }

    /// Seal the group round for `key` if every member has either pushed
    /// or been proven absent: reduce in global-rank order, re-encode
    /// once, forward the combined push (then the group's single pull)
    /// upstream.
    fn try_seal(&mut self, key: Key, out: &mut Vec<(Dest, Message)>) {
        let Some(st) = self.keys.get_mut(&key) else { return };
        let r = &mut st.round;
        if r.sealed || r.n_got + r.n_absent < self.opts.member_ranks.len() {
            return;
        }
        r.sealed = true;
        let iter = r.iter;
        let shard = self.opts.plan.server_of(key);
        if r.n_got == 0 {
            // Every member absent: nothing to push. Still pull — the
            // other groups' pushes complete the round (possibly via the
            // server's deadline) and the waiters must be answered.
            out.push((
                Dest::Upstream(shard),
                Message::Pull { key, iter, worker: self.opts.group_idx },
            ));
            return;
        }
        let dim = st.dim.unwrap_or(0);
        // Reduce in global-rank order: arrival order never changes the
        // f32 bits (mirrors the server's connection-index-ordered sum).
        let mut acc = vec![0.0f32; dim];
        let mut schemes: Option<SchemeId> = None;
        let mut mixed = false;
        for &m in &self.rank_order {
            if let Some((scheme, buf)) = r.got[m].take() {
                crate::compress::kernels::add_assign(&mut acc, &buf);
                mixed |= schemes.is_some_and(|s| s != scheme);
                schemes = Some(scheme);
            }
        }
        // Group size is bounded by the worker count, validated small at
        // config load — the u16 weight cannot truncate.
        let members = r.n_got as u16;
        let data = self.reencode(key, iter, acc, if mixed { None } else { schemes });
        self.stats.group_pushes += 1;
        out.push((
            Dest::Upstream(shard),
            Message::GroupPush { key, iter, worker: self.opts.group_idx, members, data },
        ));
        // The group's one pull per key per iteration, strictly after the
        // combined push on the same FIFO connection — the shard sees the
        // key at `iter` before the pull can queue against it.
        out.push((
            Dest::Upstream(shard),
            Message::Pull { key, iter, worker: self.opts.group_idx },
        ));
    }

    /// Re-encode the group sum once (the tentpole's single middle-tier
    /// compression): exact for identity and top-k member blocks, EF-lossy
    /// otherwise.
    fn reencode(
        &mut self,
        key: Key,
        iter: u64,
        acc: Vec<f32>,
        scheme: Option<SchemeId>,
    ) -> Compressed {
        match scheme {
            Some(SchemeId::Identity) => {
                let mut payload = Vec::with_capacity(4 * acc.len());
                for &v in &acc {
                    crate::compress::put_f32(&mut payload, v);
                }
                Compressed { scheme: SchemeId::Identity, n: acc.len(), payload }
            }
            Some(SchemeId::TopK) => {
                // Exact-sparse union encoding: k = nonzero count of the
                // sum. Legal on the wire (top-k blocks are validated by
                // their own header, not the configured ratio) and decoded
                // by the server's ordinary sparse accumulate.
                let nz: Vec<(usize, f32)> = acc
                    .iter()
                    .enumerate()
                    .filter(|&(_, &v)| v != 0.0)
                    .map(|(i, &v)| (i, v))
                    .collect();
                // nnz and every index are bounded by the block element
                // count (MiB-scale blocks, far below 2^32).
                let mut payload = Vec::with_capacity(4 + 8 * nz.len());
                crate::compress::put_u32(&mut payload, nz.len() as u32);
                for &(i, _) in &nz {
                    crate::compress::put_u32(&mut payload, i as u32);
                }
                for &(_, v) in &nz {
                    crate::compress::put_f32(&mut payload, v);
                }
                Compressed { scheme: SchemeId::TopK, n: acc.len(), payload }
            }
            _ => {
                // Lossy arm: re-compress with the configured scheme. The
                // leader-level EF residual carries the re-compression
                // error forward (Alg. 4 at the middle tier); the RNG
                // stream is pinned per (group, key, iter) so scheduling
                // can never change the bytes.
                self.stats.lossy_reencodes += 1;
                let seed =
                    job_seed(self.opts.seed ^ GROUP_SEED_SALT, self.opts.group_idx, key, iter);
                let mut rng = Xoshiro256::seed_from_u64(seed);
                let mut ctx = Ctx::new(&mut rng);
                if self.opts.sync == SyncMode::CompressedEf {
                    self.group_ef.compress(
                        key,
                        acc,
                        self.opts.comp.as_ref(),
                        self.opts.fused,
                        &mut ctx,
                    )
                } else {
                    self.opts.comp.compress(&acc, &mut ctx)
                }
            }
        }
    }
}

/// A running relay thread (the leader's middle tier).
pub struct RelayHandle {
    handle: Option<JoinHandle<RelayStats>>,
}

impl RelayHandle {
    /// Wait for the relay to drain (members must send Shutdown first).
    pub fn join(mut self) -> RelayStats {
        match self.handle.take().map(|h| h.join()) {
            Some(Ok(stats)) => stats,
            _ => {
                eprintln!("relay: thread lost or panicked; reporting empty stats");
                RelayStats::default()
            }
        }
    }
}

/// Spawn [`run_relay`] on its own thread.
pub fn spawn_relay(
    opts: RelayOptions,
    members: Vec<Box<dyn Endpoint>>,
    upstream: Vec<Box<dyn Endpoint>>,
) -> RelayHandle {
    let handle = std::thread::Builder::new()
        .name("bytepsc-relay".into())
        .spawn(move || run_relay(GroupRelay::new(opts), &members, &upstream))
        .ok();
    if handle.is_none() {
        eprintln!("relay: failed to spawn thread");
    }
    RelayHandle { handle }
}

/// Drive a relay over its endpoints until every member shuts down, then
/// propagate the shutdown upstream and return the stats.
///
/// Single-threaded poll loop: `try_recv` across every endpoint with
/// exponential backoff (50 µs idle floor, 1 ms ceiling) — no locks, no
/// per-connection threads, and the relay stays deterministic because the
/// state machine orders reductions by rank, not by arrival.
pub fn run_relay(
    mut relay: GroupRelay,
    members: &[Box<dyn Endpoint>],
    upstream: &[Box<dyn Endpoint>],
) -> RelayStats {
    let send = |dest: Dest, msg: Message| {
        let ep: Option<&Box<dyn Endpoint>> = match dest {
            Dest::Member(m) => members.get(m),
            Dest::Upstream(s) => upstream.get(s),
        };
        if let Some(ep) = ep {
            // A peer that died mid-send surfaces as a recv error on the
            // next poll pass; nothing useful to do with the error here.
            let _ = ep.send(msg);
        }
    };
    let mut live: Vec<bool> = members.iter().map(|_| true).collect();
    let mut n_live = members.len();
    let min_idle = Duration::from_micros(50);
    let max_idle = Duration::from_millis(1);
    let mut idle = min_idle;
    while n_live > 0 {
        let mut progressed = false;
        for m in 0..members.len() {
            if !live[m] {
                continue;
            }
            loop {
                match members[m].try_recv() {
                    Ok(Some(Message::Shutdown)) => {
                        live[m] = false;
                        n_live -= 1;
                        progressed = true;
                        break;
                    }
                    Ok(Some(msg)) => {
                        progressed = true;
                        for (dest, reply) in relay.on_member(m, msg) {
                            send(dest, reply);
                        }
                    }
                    Ok(None) => break,
                    Err(CommError::Protocol(e)) => {
                        // Frame-aligned corruption (the transport consumed
                        // the frame): drop it, keep the member.
                        progressed = true;
                        relay.stats.rejected += 1;
                        eprintln!("relay: dropping corrupt frame from member {m}: {e}");
                    }
                    Err(_) => {
                        live[m] = false;
                        n_live -= 1;
                        progressed = true;
                        let mut out = Vec::new();
                        relay.on_member_dead(m, &mut out);
                        for (dest, reply) in out {
                            send(dest, reply);
                        }
                        break;
                    }
                }
            }
        }
        for (s, ep) in upstream.iter().enumerate() {
            loop {
                match ep.try_recv() {
                    Ok(Some(msg)) => {
                        progressed = true;
                        for (dest, reply) in relay.on_upstream(s, msg) {
                            send(dest, reply);
                        }
                    }
                    Ok(None) | Err(_) => break,
                }
            }
        }
        if progressed {
            idle = min_idle;
        } else {
            std::thread::sleep(idle);
            idle = (idle * 2).min(max_idle);
        }
    }
    for ep in upstream {
        let _ = ep.send(Message::Shutdown);
    }
    relay.stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::by_name;

    fn opts(scheme: &str, param: f64, ranks: &[u32], n_keys: usize) -> RelayOptions {
        let keys: Vec<Key> = (0..n_keys as u64).collect();
        RelayOptions {
            group_idx: 0,
            member_ranks: ranks.to_vec(),
            comp: by_name(scheme, param).unwrap(),
            sync: if scheme == "identity" { SyncMode::Full } else { SyncMode::CompressedEf },
            fused: true,
            seed: 7,
            plan: Arc::new(ShardPlan::round_robin_keyed(&keys, 1)),
        }
    }

    fn push(data: &[f32], comp: &Arc<dyn Compressor>, seed: u64) -> Compressed {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        comp.compress(data, &mut Ctx::new(&mut rng))
    }

    fn group_push_of(out: &[(Dest, Message)]) -> Option<(u16, Compressed)> {
        out.iter().find_map(|(d, m)| match (d, m) {
            (Dest::Upstream(_), Message::GroupPush { members, data, .. }) => {
                Some((*members, data.clone()))
            }
            _ => None,
        })
    }

    #[test]
    fn combines_identity_pushes_into_exact_sum() {
        let o = opts("identity", 0.0, &[0, 1], 1);
        let comp = Arc::clone(&o.comp);
        let mut relay = GroupRelay::new(o);
        let out = relay.on_member(
            0,
            Message::Push { key: 0, iter: 0, worker: 0, data: push(&[1.0, 2.0], &comp, 1) },
        );
        // Ack only — the round is still open.
        assert_eq!(out.len(), 1);
        assert!(matches!(out[0], (Dest::Member(0), Message::Ack { .. })));
        assert!(group_push_of(&out).is_none());
        let out = relay.on_member(
            1,
            Message::Push { key: 0, iter: 0, worker: 1, data: push(&[3.0, 6.0], &comp, 2) },
        );
        let (members, data) = group_push_of(&out).expect("round must seal");
        assert_eq!(members, 2);
        assert_eq!(data.scheme, SchemeId::Identity);
        let mut sum = vec![0.0f32; 2];
        comp.decompress(&data, &mut sum);
        assert_eq!(sum, vec![4.0, 8.0], "group push must carry the SUM, not the average");
        // The group's upstream pull follows the push on the same shard.
        let pull_pos = out
            .iter()
            .position(|(d, m)| matches!((d, m), (Dest::Upstream(0), Message::Pull { .. })));
        let push_pos = out
            .iter()
            .position(|(d, m)| matches!((d, m), (Dest::Upstream(0), Message::GroupPush { .. })));
        assert!(push_pos < pull_pos, "upstream pull must follow the group push (FIFO)");
        assert_eq!(relay.stats.group_pushes, 1);
        assert_eq!(relay.stats.member_pushes, 2);
    }

    #[test]
    fn reduce_order_is_rank_order_not_arrival_order() {
        // Ranks deliberately not aligned with member indices.
        let o = opts("identity", 0.0, &[5, 2], 1);
        let comp = Arc::clone(&o.comp);
        let run = |first: usize| -> Vec<f32> {
            let o = opts("identity", 0.0, &[5, 2], 1);
            let mut relay = GroupRelay::new(o);
            let grads = [vec![1.0e-8f32, 1.0], vec![1.0f32, -1.0]];
            let second = 1 - first;
            let _ = relay.on_member(
                first,
                Message::Push {
                    key: 0,
                    iter: 0,
                    worker: 0,
                    data: push(&grads[first], &comp, 1),
                },
            );
            let out = relay.on_member(
                second,
                Message::Push {
                    key: 0,
                    iter: 0,
                    worker: 1,
                    data: push(&grads[second], &comp, 2),
                },
            );
            let (_, data) = group_push_of(&out).unwrap();
            let mut sum = vec![0.0f32; 2];
            comp.decompress(&data, &mut sum);
            sum
        };
        assert_eq!(run(0), run(1), "arrival order must never change the reduced bits");
    }

    #[test]
    fn topk_reencode_is_exact_sparse_union() {
        let o = opts("topk", 0.25, &[0, 1], 1);
        let comp = Arc::clone(&o.comp);
        let mut relay = GroupRelay::new(o);
        // dim 4, ratio 0.25 → each member keeps exactly 1 coordinate.
        let a = push(&[9.0, 0.0, 0.0, 0.0], &comp, 1);
        let b = push(&[0.0, 0.0, 7.0, 0.0], &comp, 2);
        let _ = relay.on_member(0, Message::Push { key: 0, iter: 0, worker: 0, data: a });
        let out = relay.on_member(1, Message::Push { key: 0, iter: 0, worker: 1, data: b });
        let (members, data) = group_push_of(&out).unwrap();
        assert_eq!(members, 2);
        assert_eq!(data.scheme, SchemeId::TopK);
        validate_wire(&data).expect("exact-sparse union must be a valid top-k block");
        // k = union size 2, even though the configured ratio keeps 1.
        assert_eq!(u32::from_le_bytes(data.payload[0..4].try_into().unwrap()), 2);
        let mut sum = vec![0.0f32; 4];
        comp.decompress(&data, &mut sum);
        assert_eq!(sum, vec![9.0, 0.0, 7.0, 0.0]);
        assert_eq!(relay.stats.lossy_reencodes, 0, "top-k path must be exact");
    }

    #[test]
    fn early_pull_marks_member_absent_and_seals_short() {
        let o = opts("identity", 0.0, &[0, 1], 1);
        let comp = Arc::clone(&o.comp);
        let mut relay = GroupRelay::new(o);
        let _ = relay.on_member(
            0,
            Message::Push { key: 0, iter: 0, worker: 0, data: push(&[5.0], &comp, 1) },
        );
        // Member 1's pull without a push proves the push was lost.
        let out = relay.on_member(1, Message::Pull { key: 0, iter: 0, worker: 1 });
        let (members, data) = group_push_of(&out).expect("round must seal short");
        assert_eq!(members, 1, "absent member must not be claimed upstream");
        let mut sum = vec![0.0f32; 1];
        comp.decompress(&data, &mut sum);
        assert_eq!(sum, vec![5.0]);
        assert_eq!(relay.stats.absent_members, 1);
    }

    #[test]
    fn corrupt_member_block_is_rejected_never_panics() {
        let o = opts("identity", 0.0, &[0, 1], 1);
        let comp = Arc::clone(&o.comp);
        let mut relay = GroupRelay::new(o);
        let bad = Compressed { scheme: SchemeId::Identity, n: 8, payload: vec![0u8; 3] };
        let _ = relay.on_member(0, Message::Push { key: 0, iter: 0, worker: 0, data: bad });
        assert_eq!(relay.stats.rejected, 1);
        let out = relay.on_member(
            1,
            Message::Push { key: 0, iter: 0, worker: 1, data: push(&[2.0], &comp, 1) },
        );
        let (members, _) = group_push_of(&out).expect("round seals around the corrupt block");
        assert_eq!(members, 1);
    }

    #[test]
    fn pull_resp_fans_back_to_waiters_and_late_pullers() {
        let o = opts("identity", 0.0, &[0, 1], 1);
        let comp = Arc::clone(&o.comp);
        let mut relay = GroupRelay::new(o);
        for m in 0..2u32 {
            let _ = relay.on_member(
                m as usize,
                Message::Push { key: 0, iter: 0, worker: m, data: push(&[1.0], &comp, m as u64) },
            );
        }
        // Member 0 pulls before the upstream response: it waits.
        let out = relay.on_member(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        assert!(out.iter().all(|(_, m)| !matches!(m, Message::PullResp { .. })));
        // Upstream answers: the waiter is served.
        let resp = push(&[1.0], &comp, 9);
        let out = relay.on_upstream(
            0,
            Message::PullResp { key: 0, iter: 0, served_with: 4, data: resp },
        );
        assert_eq!(out.len(), 1);
        let (Dest::Member(0), Message::PullResp { served_with, .. }) = &out[0] else {
            panic!("waiter must be served: {out:?}");
        };
        assert_eq!(*served_with, 4, "served_with weight must pass through unchanged");
        // Member 1 pulls after: served from the cached response.
        let out = relay.on_member(1, Message::Pull { key: 0, iter: 0, worker: 1 });
        assert!(
            matches!(&out[0], (Dest::Member(1), Message::PullResp { served_with: 4, .. })),
            "{out:?}"
        );
    }

    #[test]
    fn rollover_serves_slow_member_from_prev_slot() {
        let o = opts("identity", 0.0, &[0, 1], 1);
        let comp = Arc::clone(&o.comp);
        let mut relay = GroupRelay::new(o);
        for m in 0..2u32 {
            let _ = relay.on_member(
                m as usize,
                Message::Push { key: 0, iter: 0, worker: m, data: push(&[1.0], &comp, 1) },
            );
        }
        let _ = relay.on_upstream(
            0,
            Message::PullResp { key: 0, iter: 0, served_with: 4, data: push(&[3.0], &comp, 2) },
        );
        // Fast member 0 pulls iter 0 and pushes iter 1, rolling the key.
        let _ = relay.on_member(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        let _ = relay.on_member(
            0,
            Message::Push { key: 0, iter: 1, worker: 0, data: push(&[2.0], &comp, 3) },
        );
        // Slow member 1 still pulls iter 0 — served from the prev slot.
        let out = relay.on_member(1, Message::Pull { key: 0, iter: 0, worker: 1 });
        let (Dest::Member(1), Message::PullResp { iter, data, .. }) = &out[0] else {
            panic!("slow member must be served: {out:?}");
        };
        assert_eq!(*iter, 0);
        let mut v = vec![0.0f32; 1];
        comp.decompress(data, &mut v);
        assert_eq!(v, vec![3.0]);
    }

    #[test]
    fn dead_member_cannot_wedge_the_group() {
        let o = opts("identity", 0.0, &[0, 1], 1);
        let comp = Arc::clone(&o.comp);
        let mut relay = GroupRelay::new(o);
        let _ = relay.on_member(
            0,
            Message::Push { key: 0, iter: 0, worker: 0, data: push(&[4.0], &comp, 1) },
        );
        let mut out = Vec::new();
        relay.on_member_dead(1, &mut out);
        let (members, _) = group_push_of(&out).expect("death must seal the round");
        assert_eq!(members, 1);
        // Future rounds pre-mark the dead member.
        let _ = relay.on_member(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        let _ = relay.on_upstream(
            0,
            Message::PullResp { key: 0, iter: 0, served_with: 1, data: push(&[4.0], &comp, 2) },
        );
        let out = relay.on_member(
            0,
            Message::Push { key: 0, iter: 1, worker: 0, data: push(&[6.0], &comp, 3) },
        );
        let (members, _) = group_push_of(&out).expect("iter 1 must seal with the live member");
        assert_eq!(members, 1);
    }

    /// End-to-end over real endpoints and the poll loop: two members, one
    /// fake upstream shard, one full push/pull round, clean shutdown.
    #[test]
    fn run_relay_roundtrip_over_inproc() {
        let o = opts("identity", 0.0, &[0, 1], 1);
        let comp = Arc::clone(&o.comp);
        let (m0, r0) = crate::comm::inproc::pair();
        let (m1, r1) = crate::comm::inproc::pair();
        let (relay_up, shard) = crate::comm::inproc::pair();
        let handle = spawn_relay(
            o,
            vec![Box::new(r0), Box::new(r1)],
            vec![Box::new(relay_up)],
        );
        // Fake shard: expect one GroupPush then one Pull; answer the pull.
        let comp2 = Arc::clone(&comp);
        let shard_thread = std::thread::spawn(move || {
            let Message::GroupPush { key, iter, members, data, .. } = shard.recv().unwrap()
            else {
                panic!("expected GroupPush first")
            };
            assert_eq!(members, 2);
            let mut sum = vec![0.0f32; data.n];
            comp2.decompress(&data, &mut sum);
            assert_eq!(sum, vec![30.0]);
            shard.send(Message::Ack { key, iter }).unwrap();
            assert!(matches!(shard.recv().unwrap(), Message::Pull { .. }));
            let avg = push(&[7.5], &comp2, 5);
            shard
                .send(Message::PullResp { key, iter, served_with: 2, data: avg })
                .unwrap();
            assert!(matches!(shard.recv().unwrap(), Message::Shutdown));
        });
        for (m, ep, v) in [(0u32, &m0, 10.0f32), (1, &m1, 20.0)] {
            ep.send(Message::Push { key: 0, iter: 0, worker: m, data: push(&[v], &comp, 1) })
                .unwrap();
        }
        for ep in [&m0, &m1] {
            ep.send(Message::Pull { key: 0, iter: 0, worker: 0 }).unwrap();
            let mut got = None;
            while got.is_none() {
                match ep.recv().unwrap() {
                    Message::Ack { .. } => {}
                    Message::PullResp { served_with, data, .. } => {
                        assert_eq!(served_with, 2);
                        got = Some(data);
                    }
                    m => panic!("unexpected {m:?}"),
                }
            }
            let mut v = vec![0.0f32; 1];
            comp.decompress(&got.unwrap(), &mut v);
            assert_eq!(v, vec![7.5]);
            ep.send(Message::Shutdown).unwrap();
        }
        let stats = handle.join();
        shard_thread.join().unwrap();
        assert_eq!(stats.group_pushes, 1);
        assert_eq!(stats.member_pushes, 2);
        assert_eq!(stats.member_pulls, 2);
        assert_eq!(stats.unexpected, 0);
    }
}
