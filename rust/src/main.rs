//! `bytepsc` — the BytePS-Compress launcher.
//!
//! Subcommands:
//!   train     run a training job (config file + flag overrides)
//!   inspect   print artifact manifest / model info
//!   calibrate measure compressor speeds on this host (feeds simnet)

use byteps_compress::cli::{usage, Args, Opt};
use byteps_compress::compress;
use byteps_compress::configx::{SyncMode, TrainConfig};
use byteps_compress::engine;
use byteps_compress::metrics::markdown_table;
use byteps_compress::runtime::Manifest;
use byteps_compress::simnet::CompressorProfile;
use std::path::{Path, PathBuf};

fn opts() -> Vec<Opt> {
    vec![
        Opt { name: "config", takes_value: true, help: "JSON config file (see configs/)" },
        Opt { name: "artifacts", takes_value: true, help: "artifacts directory (default: artifacts)" },
        Opt { name: "model", takes_value: true, help: "model name from the manifest" },
        Opt { name: "steps", takes_value: true, help: "training steps" },
        Opt { name: "nodes", takes_value: true, help: "worker nodes" },
        Opt { name: "servers", takes_value: true, help: "parameter servers" },
        Opt { name: "scheme", takes_value: true, help: "compressor: identity|fp16|onebit|topk|randomk|linear_dither|natural_dither" },
        Opt { name: "param", takes_value: true, help: "compressor parameter (ratio or bits)" },
        Opt { name: "sync", takes_value: true, help: "full|compressed|compressed_ef" },
        Opt { name: "optimizer", takes_value: true, help: "lans|clan|nag|adam|sgd" },
        Opt { name: "lr", takes_value: true, help: "learning rate" },
        Opt { name: "seed", takes_value: true, help: "RNG seed" },
        Opt { name: "log-every", takes_value: true, help: "logging interval" },
        Opt { name: "pipeline", takes_value: true, help: "block pipeline: on|off (default on)" },
        Opt { name: "block-bytes", takes_value: true, help: "pipeline partition block size in bytes" },
        Opt { name: "inflight", takes_value: true, help: "max in-flight compress jobs per worker" },
    ]
}

fn apply_overrides(cfg: &mut TrainConfig, a: &Args) -> Result<(), String> {
    if let Some(m) = a.get("model") {
        cfg.model = m.into();
    }
    cfg.steps = a.usize_or("steps", cfg.steps)?;
    cfg.cluster.nodes = a.usize_or("nodes", cfg.cluster.nodes)?;
    cfg.cluster.servers = a.usize_or("servers", cfg.cluster.servers)?;
    if let Some(s) = a.get("scheme") {
        cfg.compression.scheme = s.into();
    }
    cfg.compression.param = a.f64_or("param", cfg.compression.param)?;
    if let Some(s) = a.get("sync") {
        cfg.compression.sync = SyncMode::parse(s).map_err(|e| e.to_string())?;
    }
    if let Some(o) = a.get("optimizer") {
        cfg.optimizer.name = o.into();
    }
    cfg.optimizer.lr = a.f64_or("lr", cfg.optimizer.lr)?;
    cfg.seed = a.u64_or("seed", cfg.seed)?;
    cfg.log_every = a.usize_or("log-every", cfg.log_every)?;
    if let Some(p) = a.get("pipeline") {
        cfg.pipeline.enabled = match p {
            "on" | "true" | "1" => true,
            "off" | "false" | "0" => false,
            other => return Err(format!("--pipeline: expected on|off, got '{other}'")),
        };
    }
    cfg.pipeline.block_bytes = a.usize_or("block-bytes", cfg.pipeline.block_bytes)?;
    cfg.pipeline.inflight = a.usize_or("inflight", cfg.pipeline.inflight)?;
    cfg.validate().map_err(|e| e.to_string())?;
    Ok(())
}

fn cmd_train(a: &Args) -> anyhow::Result<()> {
    let mut cfg = match a.get("config") {
        Some(path) => TrainConfig::from_file(Path::new(path)).map_err(|e| anyhow::anyhow!("{e}"))?,
        None => TrainConfig::default(),
    };
    apply_overrides(&mut cfg, a).map_err(anyhow::Error::msg)?;
    let art = PathBuf::from(a.get_or("artifacts", "artifacts"));
    eprintln!(
        "training {} | {} steps x {} nodes | {} ({}, param {}) | optimizer {} | pipeline {}",
        cfg.model,
        cfg.steps,
        cfg.cluster.nodes,
        cfg.compression.scheme,
        cfg.compression.sync.name(),
        cfg.compression.param,
        cfg.optimizer.name,
        if cfg.pipeline.enabled {
            format!("on ({} KiB blocks)", cfg.pipeline.block_bytes / 1024)
        } else {
            "off".into()
        }
    );
    let report = engine::train(&cfg, &art)?;
    for (step, loss) in &report.losses {
        if cfg.log_every > 0 && (step % cfg.log_every == 0 || step + 1 == cfg.steps) {
            println!("step {step:>6}  loss {loss:.4}");
        }
    }
    println!(
        "\ndone in {:.1}s | final loss {:.4} | wire {} | compression rate vs f32: {:.1}x",
        report.elapsed_s,
        report.final_loss(),
        byteps_compress::util::human_bytes(report.wire_bytes as usize),
        report.compression_rate()
    );
    let b = &report.breakdown;
    println!(
        "breakdown: compute {:.2}s | compress {:.2}s | decompress {:.2}s | wire/other {:.2}s | optimizer {:.2}s",
        b.compute_s, b.compress_s, b.decompress_s, b.wire_s, b.optimizer_s
    );
    Ok(())
}

fn cmd_inspect(a: &Args) -> anyhow::Result<()> {
    let art = PathBuf::from(a.get_or("artifacts", "artifacts"));
    let man = Manifest::load(&art)?;
    let mut rows = Vec::new();
    for (name, e) in &man.models {
        rows.push(vec![
            name.clone(),
            format!("{:.2}M", e.total_params as f64 / 1e6),
            e.params.len().to_string(),
            format!("{}x{}", e.batch, e.seq),
            e.vocab.to_string(),
            if e.num_classes > 0 { format!("classifier({})", e.num_classes) } else { "mlm".into() },
        ]);
    }
    println!(
        "{}",
        markdown_table(&["model", "params", "tensors", "batch", "vocab", "head"], &rows)
    );
    println!("kernels: {:?}", man.kernels.keys().collect::<Vec<_>>());
    Ok(())
}

fn cmd_calibrate(_a: &Args) -> anyhow::Result<()> {
    let n = 1 << 21;
    println!("measuring compressor throughput on {} elements:\n", n);
    let mut rows = Vec::new();
    for (label, comp) in compress::paper_suite() {
        let p = CompressorProfile::measure(label, comp.as_ref(), n, 0.0);
        rows.push(vec![
            label.to_string(),
            format!("{:.2}", p.compress_ns_per_elem),
            format!("{:.2}", p.decompress_ns_per_elem),
            format!("{:.3}", p.param),
            format!("{:.0}x", 4.0 / p.param),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            &["method", "compress ns/elem", "decompress ns/elem", "wire B/elem", "rate vs f32"],
            &rows
        )
    );
    Ok(())
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let opts = opts();
    let subcommands = [
        ("train", "run a training job"),
        ("inspect", "print artifact manifest info"),
        ("calibrate", "measure compressor speeds on this host"),
    ];
    let args = match Args::parse(&argv, true, &opts) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n");
            eprintln!("{}", usage("bytepsc", "BytePS-Compress / CLAN reproduction", &subcommands, &opts));
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("calibrate") => cmd_calibrate(&args),
        _ => {
            println!("{}", usage("bytepsc", "BytePS-Compress / CLAN reproduction", &subcommands, &opts));
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
