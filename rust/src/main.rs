//! `bytepsc` — the BytePS-Compress launcher.
//!
//! Subcommands:
//!   train     run a training job (config file + flag overrides)
//!   server    run one parameter-server shard over TCP (cluster mode)
//!   worker    run one worker over TCP (cluster mode)
//!   leader    run one group-leader relay (hierarchical cluster mode)
//!   inspect   print artifact manifest / model info
//!   calibrate measure compressor speeds on this host (feeds simnet)

use byteps_compress::cli::{split_subcommand, usage, Args, Opt};
use byteps_compress::cluster;
use byteps_compress::compress;
use byteps_compress::configx::{SyncMode, TrainConfig};
use byteps_compress::engine;
use byteps_compress::metrics::markdown_table;
use byteps_compress::runtime::Manifest;
use byteps_compress::simnet::CompressorProfile;
use std::path::{Path, PathBuf};

fn opts() -> Vec<Opt> {
    vec![
        Opt { name: "config", takes_value: true, help: "JSON config file (see configs/)" },
        Opt { name: "artifacts", takes_value: true, help: "artifacts directory (default: artifacts)" },
        Opt { name: "model", takes_value: true, help: "model name from the manifest" },
        Opt { name: "steps", takes_value: true, help: "training steps" },
        Opt { name: "nodes", takes_value: true, help: "worker nodes" },
        Opt { name: "groups", takes_value: true, help: "hierarchical two-level aggregation: worker groups (0 = flat; must divide nodes)" },
        Opt { name: "servers", takes_value: true, help: "parameter servers" },
        Opt { name: "scheme", takes_value: true, help: "compressor: identity|fp16|onebit|topk|randomk|linear_dither|natural_dither" },
        Opt { name: "param", takes_value: true, help: "compressor parameter (ratio or bits)" },
        Opt { name: "sync", takes_value: true, help: "full|compressed|compressed_ef" },
        Opt { name: "optimizer", takes_value: true, help: "lans|clan|nag|adam|sgd" },
        Opt { name: "lr", takes_value: true, help: "learning rate" },
        Opt { name: "seed", takes_value: true, help: "RNG seed" },
        Opt { name: "log-every", takes_value: true, help: "logging interval" },
        Opt { name: "pipeline", takes_value: true, help: "block pipeline: on|off (default on)" },
        Opt { name: "block-bytes", takes_value: true, help: "pipeline partition block size in bytes" },
        Opt { name: "inflight", takes_value: true, help: "max in-flight (unacked) push jobs per worker" },
        Opt { name: "ack-window", takes_value: true, help: "drain acks during the push phase: on|off (default on)" },
        Opt { name: "iter-deadline-ms", takes_value: true, help: "server iteration deadline for degraded rounds (0 = strict BSP)" },
        Opt { name: "adaptive", takes_value: true, help: "per-key adaptive compression controller: on|off (default off; topk/randomk + compressed_ef only)" },
        Opt { name: "adaptive-k-min", takes_value: true, help: "adaptive controller: lower keep-ratio bound (fraction of elements)" },
        Opt { name: "adaptive-k-max", takes_value: true, help: "adaptive controller: upper keep-ratio bound" },
        Opt { name: "adaptive-ema", takes_value: true, help: "adaptive controller: gain EMA smoothing factor in (0, 1]" },
        Opt { name: "adaptive-target-gain", takes_value: true, help: "adaptive controller: target compression gain in (0, 1)" },
    ]
}

/// Flags shared by the cluster subcommands: the synthetic model both sides
/// exchange (must match across every process of a run).
fn cluster_shared_opts(o: &mut Vec<Opt>) {
    o.push(Opt { name: "dim", takes_value: true, help: "synthetic model size in f32 params (must match across processes)" });
    o.push(Opt { name: "tensors", takes_value: true, help: "synthetic tensor count (must match across processes)" });
}

fn server_opts() -> Vec<Opt> {
    let mut o = opts();
    cluster_shared_opts(&mut o);
    o.push(Opt { name: "listen", takes_value: true, help: "listen address (default: cluster.addresses[shard])" });
    o.push(Opt { name: "shard", takes_value: true, help: "this server's shard index (default 0)" });
    o.push(Opt { name: "shards", takes_value: true, help: "total server shards (default: cluster.addresses length)" });
    o.push(Opt { name: "compress-threads", takes_value: true, help: "staged shard pipeline: decode/encode pool threads (0 = synchronous reference)" });
    o.push(Opt { name: "deadline-auto-margin", takes_value: true, help: "auto-tune the iter deadline: p99 round latency x margin (0 = off; needs --iter-deadline-ms 0)" });
    o
}

fn worker_opts() -> Vec<Opt> {
    let mut o: Vec<Opt> = opts()
        .into_iter()
        // For the worker, --servers is the address list, not a count.
        .filter(|opt| opt.name != "servers")
        .collect();
    cluster_shared_opts(&mut o);
    o.push(Opt { name: "servers", takes_value: true, help: "comma-separated server addresses, shard order (default: cluster.addresses)" });
    o.push(Opt { name: "rank", takes_value: true, help: "this worker's rank in [0, nodes)" });
    o.push(Opt { name: "iters", takes_value: true, help: "synthetic training iterations (default 10)" });
    o.push(Opt { name: "dump", takes_value: true, help: "write per-iteration aggregates to this file" });
    o.push(Opt { name: "drop-push", takes_value: true, help: "fault injection: drop the push for KEY@ITER (tests the server deadline)" });
    o
}

fn leader_opts() -> Vec<Opt> {
    let mut o: Vec<Opt> = worker_opts()
        .into_iter()
        // The leader's rank is derived: it co-locates its group's first
        // member (global rank = group * group_size).
        .filter(|opt| opt.name != "rank")
        .collect();
    o.push(Opt { name: "group", takes_value: true, help: "this leader's group index in [0, groups)" });
    o.push(Opt { name: "listen", takes_value: true, help: "member listen address (default: cluster.group_addresses[group])" });
    o
}

fn apply_overrides(cfg: &mut TrainConfig, a: &Args, servers_is_count: bool) -> Result<(), String> {
    if let Some(m) = a.get("model") {
        cfg.model = m.into();
    }
    cfg.steps = a.usize_or("steps", cfg.steps)?;
    cfg.cluster.nodes = a.usize_or("nodes", cfg.cluster.nodes)?;
    cfg.cluster.groups = a.usize_or("groups", cfg.cluster.groups)?;
    if servers_is_count {
        cfg.cluster.servers = a.usize_or("servers", cfg.cluster.servers)?;
    }
    if let Some(s) = a.get("scheme") {
        cfg.compression.scheme = s.into();
    }
    cfg.compression.param = a.f64_or("param", cfg.compression.param)?;
    if let Some(s) = a.get("sync") {
        cfg.compression.sync = SyncMode::parse(s).map_err(|e| e.to_string())?;
    }
    if let Some(o) = a.get("optimizer") {
        cfg.optimizer.name = o.into();
    }
    cfg.optimizer.lr = a.f64_or("lr", cfg.optimizer.lr)?;
    cfg.seed = a.u64_or("seed", cfg.seed)?;
    cfg.log_every = a.usize_or("log-every", cfg.log_every)?;
    if let Some(p) = a.get("pipeline") {
        cfg.pipeline.enabled = match p {
            "on" | "true" | "1" => true,
            "off" | "false" | "0" => false,
            other => return Err(format!("--pipeline: expected on|off, got '{other}'")),
        };
    }
    cfg.pipeline.block_bytes = a.usize_or("block-bytes", cfg.pipeline.block_bytes)?;
    cfg.pipeline.inflight = a.usize_or("inflight", cfg.pipeline.inflight)?;
    if let Some(w) = a.get("ack-window") {
        cfg.pipeline.ack_window = match w {
            "on" | "true" | "1" => true,
            "off" | "false" | "0" => false,
            other => return Err(format!("--ack-window: expected on|off, got '{other}'")),
        };
    }
    cfg.server.iter_deadline_ms =
        a.u64_or("iter-deadline-ms", cfg.server.iter_deadline_ms)?;
    if let Some(v) = a.get("adaptive") {
        cfg.adaptive.enabled = match v {
            "on" | "true" | "1" => true,
            "off" | "false" | "0" => false,
            other => return Err(format!("--adaptive: expected on|off, got '{other}'")),
        };
    }
    cfg.adaptive.k_min = a.f64_or("adaptive-k-min", cfg.adaptive.k_min)?;
    cfg.adaptive.k_max = a.f64_or("adaptive-k-max", cfg.adaptive.k_max)?;
    cfg.adaptive.ema = a.f64_or("adaptive-ema", cfg.adaptive.ema)?;
    cfg.adaptive.target_gain = a.f64_or("adaptive-target-gain", cfg.adaptive.target_gain)?;
    cfg.validate().map_err(|e| e.to_string())?;
    Ok(())
}

fn load_config(a: &Args, servers_is_count: bool) -> anyhow::Result<TrainConfig> {
    let mut cfg = match a.get("config") {
        Some(path) => TrainConfig::from_file(Path::new(path)).map_err(|e| anyhow::anyhow!("{e}"))?,
        None => TrainConfig::default(),
    };
    apply_overrides(&mut cfg, a, servers_is_count).map_err(anyhow::Error::msg)?;
    Ok(cfg)
}

fn cmd_train(a: &Args) -> anyhow::Result<()> {
    let cfg = load_config(a, true)?;
    let art = PathBuf::from(a.get_or("artifacts", "artifacts"));
    eprintln!(
        "training {} | {} steps x {} nodes | {} ({}, param {}) | optimizer {} | pipeline {}",
        cfg.model,
        cfg.steps,
        cfg.cluster.nodes,
        cfg.compression.scheme,
        cfg.compression.sync.name(),
        cfg.compression.param,
        cfg.optimizer.name,
        if cfg.pipeline.enabled {
            format!("on ({} KiB blocks)", cfg.pipeline.block_bytes / 1024)
        } else {
            "off".into()
        }
    );
    let report = engine::train(&cfg, &art)?;
    for (step, loss) in &report.losses {
        if cfg.log_every > 0 && (step % cfg.log_every == 0 || step + 1 == cfg.steps) {
            println!("step {step:>6}  loss {loss:.4}");
        }
    }
    println!(
        "\ndone in {:.1}s | final loss {:.4} | wire {} | compression rate vs f32: {:.1}x",
        report.elapsed_s,
        report.final_loss(),
        byteps_compress::util::human_bytes(report.wire_bytes as usize),
        report.compression_rate()
    );
    let b = &report.breakdown;
    println!(
        "breakdown: compute {:.2}s | compress {:.2}s | decompress {:.2}s | wire/other {:.2}s | optimizer {:.2}s",
        b.compute_s, b.compress_s, b.decompress_s, b.wire_s, b.optimizer_s
    );
    Ok(())
}

fn cmd_server(a: &Args) -> anyhow::Result<()> {
    let mut cfg = load_config(a, true)?;
    cfg.server.compress_threads =
        a.usize_or("compress-threads", cfg.server.compress_threads).map_err(anyhow::Error::msg)?;
    cfg.server.iter_deadline_auto_margin = a
        .f64_or("deadline-auto-margin", cfg.server.iter_deadline_auto_margin)
        .map_err(anyhow::Error::msg)?;
    // The flags above can produce combinations load_config never saw
    // (e.g. an auto margin on top of a config-file deadline).
    cfg.validate().map_err(|e| anyhow::anyhow!("{e}"))?;
    let shard = a.usize_or("shard", 0).map_err(anyhow::Error::msg)?;
    if let Some(n) = a.get("shards") {
        // Address-less launch: pin the shard count explicitly. (With a
        // cluster.addresses section the count comes from the list.)
        let n: usize = n.parse().map_err(|_| anyhow::anyhow!("--shards: '{n}' is not an integer"))?;
        if n == 0 {
            anyhow::bail!("--shards must be >= 1");
        }
        cfg.cluster.servers = n;
        cfg.system.more_servers = n > 1;
    }
    let listen = match a.get("listen") {
        Some(l) => l.to_string(),
        None => cfg.cluster.addresses.get(shard).cloned().ok_or_else(|| {
            anyhow::anyhow!("no --listen and no cluster.addresses[{shard}] in the config")
        })?,
    };
    let dim = a.usize_or("dim", 1 << 16).map_err(anyhow::Error::msg)?;
    let tensors = a.usize_or("tensors", 8).map_err(anyhow::Error::msg)?;
    let stats = cluster::run_server(&cfg, &listen, shard, dim, tensors)?;
    // The full counter set (ServerStats's Display — one rendering shared
    // with cluster::serve's stderr line), flushed on clean shutdown, so a
    // cluster run is diagnosable from the process output alone: degraded/
    // late tell the deadline story, rejected/short/stale/early the
    // hostile-input one.
    println!("shard {shard}: {stats}");
    use std::io::Write;
    std::io::stdout().flush().ok();
    Ok(())
}

fn cmd_worker(a: &Args) -> anyhow::Result<()> {
    let cfg = load_config(a, false)?;
    let servers: Vec<String> = match a.get("servers") {
        Some(list) => list.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect(),
        None => cfg.cluster.addresses.clone(),
    };
    if servers.is_empty() {
        anyhow::bail!("no server addresses: pass --servers A,B,... or set cluster.addresses");
    }
    let rank = a.usize_or("rank", 0).map_err(anyhow::Error::msg)? as u32;
    let dim = a.usize_or("dim", 1 << 16).map_err(anyhow::Error::msg)?;
    let tensors = a.usize_or("tensors", 8).map_err(anyhow::Error::msg)?;
    let iters = a.usize_or("iters", 10).map_err(anyhow::Error::msg)?;
    let dump = a.get("dump").map(PathBuf::from);
    let drop = a.get("drop-push").map(cluster::PushDrop::parse).transpose().map_err(anyhow::Error::msg)?;
    let report =
        cluster::run_worker(&cfg, rank, &servers, dim, tensors, iters, dump.as_deref(), drop)?;
    // Counter tail rendered by WorkerCounters's Display — the one
    // canonical rendering, kept total by the counter-registry lint.
    println!(
        "worker {rank}: {} iterations done | final loss {:.9e} | wire {} | {}",
        iters,
        report.final_loss,
        byteps_compress::util::human_bytes(report.wire_bytes as usize),
        report.counters
    );
    use std::io::Write;
    std::io::stdout().flush().ok();
    Ok(())
}

fn cmd_leader(a: &Args) -> anyhow::Result<()> {
    let cfg = load_config(a, false)?;
    let servers: Vec<String> = match a.get("servers") {
        Some(list) => list.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect(),
        None => cfg.cluster.addresses.clone(),
    };
    if servers.is_empty() {
        anyhow::bail!("no server addresses: pass --servers A,B,... or set cluster.addresses");
    }
    let group = a.usize_or("group", 0).map_err(anyhow::Error::msg)? as u32;
    let listen = match a.get("listen") {
        Some(l) => l.to_string(),
        None => cfg.cluster.group_addresses.get(group as usize).cloned().ok_or_else(|| {
            anyhow::anyhow!("no --listen and no cluster.group_addresses[{group}] in the config")
        })?,
    };
    let dim = a.usize_or("dim", 1 << 16).map_err(anyhow::Error::msg)?;
    let tensors = a.usize_or("tensors", 8).map_err(anyhow::Error::msg)?;
    let iters = a.usize_or("iters", 10).map_err(anyhow::Error::msg)?;
    let dump = a.get("dump").map(PathBuf::from);
    let drop = a.get("drop-push").map(cluster::PushDrop::parse).transpose().map_err(anyhow::Error::msg)?;
    let report = cluster::run_leader(
        &cfg, group, &listen, &servers, dim, tensors, iters, dump.as_deref(), drop,
    )?;
    // Same tail as `worker` — the leader's co-located member reports like
    // any other worker; the relay's own stats went to stderr at shutdown.
    println!(
        "leader {group}: {} iterations done | final loss {:.9e} | wire {} | {}",
        iters,
        report.final_loss,
        byteps_compress::util::human_bytes(report.wire_bytes as usize),
        report.counters
    );
    use std::io::Write;
    std::io::stdout().flush().ok();
    Ok(())
}

fn cmd_inspect(a: &Args) -> anyhow::Result<()> {
    let art = PathBuf::from(a.get_or("artifacts", "artifacts"));
    let man = Manifest::load(&art)?;
    let mut rows = Vec::new();
    for (name, e) in &man.models {
        rows.push(vec![
            name.clone(),
            format!("{:.2}M", e.total_params as f64 / 1e6),
            e.params.len().to_string(),
            format!("{}x{}", e.batch, e.seq),
            e.vocab.to_string(),
            if e.num_classes > 0 { format!("classifier({})", e.num_classes) } else { "mlm".into() },
        ]);
    }
    println!(
        "{}",
        markdown_table(&["model", "params", "tensors", "batch", "vocab", "head"], &rows)
    );
    println!("kernels: {:?}", man.kernels.keys().collect::<Vec<_>>());
    Ok(())
}

fn cmd_calibrate(_a: &Args) -> anyhow::Result<()> {
    let n = 1 << 21;
    println!("measuring compressor throughput on {} elements:\n", n);
    let mut rows = Vec::new();
    for (label, comp) in compress::paper_suite() {
        let p = CompressorProfile::measure(label, comp.as_ref(), n, 0.0);
        rows.push(vec![
            label.to_string(),
            format!("{:.2}", p.compress_ns_per_elem),
            format!("{:.2}", p.decompress_ns_per_elem),
            format!("{:.3}", p.param),
            format!("{:.0}x", 4.0 / p.param),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            &["method", "compress ns/elem", "decompress ns/elem", "wire B/elem", "rate vs f32"],
            &rows
        )
    );
    Ok(())
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let subcommands = [
        ("train", "run a training job"),
        ("server", "run one parameter-server shard over TCP (cluster mode)"),
        ("worker", "run one cluster worker over TCP (cluster mode)"),
        ("leader", "run one group-leader relay (hierarchical cluster mode)"),
        ("inspect", "print artifact manifest info"),
        ("calibrate", "measure compressor speeds on this host"),
    ];
    // Resolve the subcommand first so each can declare its own flags (the
    // worker's --servers takes an address list, not a count).
    let (sub, rest) = split_subcommand(&argv);
    let opt_list = match sub.as_deref() {
        Some("server") => server_opts(),
        Some("worker") => worker_opts(),
        Some("leader") => leader_opts(),
        _ => opts(),
    };
    let args = match Args::parse(rest, false, &opt_list) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n");
            eprintln!("{}", usage("bytepsc", "BytePS-Compress / CLAN reproduction", &subcommands, &opt_list));
            std::process::exit(2);
        }
    };
    let result = match sub.as_deref() {
        Some("train") => cmd_train(&args),
        Some("server") => cmd_server(&args),
        Some("worker") => cmd_worker(&args),
        Some("leader") => cmd_leader(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("calibrate") => cmd_calibrate(&args),
        _ => {
            println!("{}", usage("bytepsc", "BytePS-Compress / CLAN reproduction", &subcommands, &opt_list));
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
