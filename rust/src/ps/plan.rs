//! Key → server assignment with workload balancing (§4.2.4).

use crate::comm::{BlockKey, Key};
use std::collections::HashMap;

/// Key → server assignment with workload balancing (§4.2.4).
///
/// Since the block pipeline, assignment is keyed by arbitrary (packed)
/// block keys rather than dense tensor indices: use [`balanced_keyed`] /
/// [`round_robin_keyed`] for block plans. The dense-index constructors
/// remain for whole-tensor plans (a tensor id *is* its block-0 key).
///
/// [`balanced_keyed`]: ShardPlan::balanced_keyed
/// [`round_robin_keyed`]: ShardPlan::round_robin_keyed
#[derive(Clone, Debug)]
pub struct ShardPlan {
    assignment: HashMap<Key, usize>,
    servers: usize,
}

impl ShardPlan {
    /// Greedy least-loaded assignment over dense tensor-id keys
    /// `0..costs.len()`. `cost(key)` should reflect server CPU work:
    /// compressed keys cost `numel × compress_factor`, bypassed keys just
    /// `numel` (decompress-free memcpy aggregation).
    pub fn balanced(costs: &[f64], servers: usize) -> ShardPlan {
        let items: Vec<(Key, f64)> =
            costs.iter().enumerate().map(|(k, &c)| (k as Key, c)).collect();
        Self::balanced_keyed(&items, servers)
    }

    /// Greedy least-loaded assignment over explicit `(key, cost)` pairs —
    /// the pipeline's per-block plan. Deterministic: ties in cost break by
    /// key, ties in load by server index.
    pub fn balanced_keyed(items: &[(Key, f64)], servers: usize) -> ShardPlan {
        assert!(servers >= 1);
        let mut order: Vec<usize> = (0..items.len()).collect();
        order.sort_by(|a, b| {
            items[*b]
                .1
                .partial_cmp(&items[*a].1)
                .unwrap()
                .then_with(|| items[*a].0.cmp(&items[*b].0))
        });
        let mut load = vec![0.0f64; servers];
        let mut assignment = HashMap::with_capacity(items.len());
        for i in order {
            let (key, cost) = items[i];
            let s = (0..servers).min_by(|a, b| load[*a].partial_cmp(&load[*b]).unwrap()).unwrap();
            assignment.insert(key, s);
            load[s] += cost;
        }
        ShardPlan { assignment, servers }
    }

    /// Naive round-robin over dense tensor-id keys (the ablation's "no
    /// workload balance" arm).
    pub fn round_robin(keys: usize, servers: usize) -> ShardPlan {
        let keys: Vec<Key> = (0..keys as u64).collect();
        Self::round_robin_keyed(&keys, servers)
    }

    /// Round-robin over explicit keys, in the order given.
    pub fn round_robin_keyed(keys: &[Key], servers: usize) -> ShardPlan {
        assert!(servers >= 1);
        let assignment = keys.iter().enumerate().map(|(i, &k)| (k, i % servers)).collect();
        ShardPlan { assignment, servers }
    }

    /// Rebuild a plan from explicit `(key, server)` pairs — the form the
    /// cluster handshake ships in [`crate::comm::Message::Welcome`].
    /// Assignments pointing past `servers` are rejected (untrusted input).
    pub fn from_assignments(entries: &[(Key, u32)], servers: usize) -> Result<ShardPlan, String> {
        if servers == 0 {
            return Err("shard plan needs at least one server".into());
        }
        let mut assignment = HashMap::with_capacity(entries.len());
        for &(key, s) in entries {
            if s as usize >= servers {
                return Err(format!("key {key} assigned to server {s} of {servers}"));
            }
            if assignment.insert(key, s as usize).is_some() {
                return Err(format!("key {key} assigned twice"));
            }
        }
        Ok(ShardPlan { assignment, servers })
    }

    /// Export the plan as `(key, server)` pairs, sorted by key so two
    /// plans can be compared structurally (workers cross-check that every
    /// server shard handed them the same plan).
    pub fn assignments(&self) -> Vec<(Key, u32)> {
        let mut out: Vec<(Key, u32)> =
            self.assignment.iter().map(|(&k, &s)| (k, s as u32)).collect();
        out.sort_unstable_by_key(|&(k, _)| k);
        out
    }

    /// Number of servers this plan shards across.
    pub fn servers(&self) -> usize {
        self.servers
    }

    /// Number of keys in the plan.
    pub fn len(&self) -> usize {
        self.assignment.len()
    }

    pub fn is_empty(&self) -> bool {
        self.assignment.is_empty()
    }

    /// Whether `key` has an assignment (cluster workers verify the plan
    /// they received covers their whole partition before trusting it).
    pub fn contains(&self, key: Key) -> bool {
        self.assignment.contains_key(&key)
    }

    pub fn server_of(&self, key: Key) -> usize {
        *self.assignment.get(&key).unwrap_or_else(|| {
            let bk = BlockKey::unpack(key);
            panic!("key {key} (tensor {}, block {}) not in the shard plan", bk.tensor, bk.block)
        })
    }

    /// Max/mean load ratio (1.0 = perfectly balanced), with per-key costs
    /// supplied by `cost_of`.
    pub fn imbalance_by<F: Fn(Key) -> f64>(&self, cost_of: F) -> f64 {
        let mut load = vec![0.0f64; self.servers];
        for (&k, &s) in &self.assignment {
            load[s] += cost_of(k);
        }
        let max = load.iter().cloned().fold(0.0f64, f64::max);
        let mean = load.iter().sum::<f64>() / self.servers.max(1) as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Max/mean load ratio for dense tensor-id plans (`key` indexes
    /// `costs`).
    pub fn imbalance(&self, costs: &[f64]) -> f64 {
        self.imbalance_by(|k| costs[k as usize])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_plan_balances_better_than_round_robin() {
        // One huge tensor + many small ones (a transformer's shape).
        let mut costs = vec![1000.0];
        costs.extend(std::iter::repeat(10.0).take(40));
        let bal = ShardPlan::balanced(&costs, 4);
        let rr = ShardPlan::round_robin(costs.len(), 4);
        assert!(bal.imbalance(&costs) <= rr.imbalance(&costs));
        // balanced puts the huge tensor alone-ish: its server gets few others
        let big_server = bal.server_of(0);
        let others = (1..costs.len()).filter(|&k| bal.server_of(k as Key) == big_server).count();
        assert!(others <= 5, "{others} small tensors share the big server");
    }

    #[test]
    fn shard_plan_covers_all_servers() {
        let costs = vec![1.0; 16];
        let plan = ShardPlan::balanced(&costs, 4);
        for s in 0..4 {
            assert!((0..16).any(|k| plan.server_of(k as Key) == s));
        }
        assert!((plan.imbalance(&costs) - 1.0).abs() < 1e-9);
    }

    /// Per-block sharding (§4.2.4 under the pipeline): one huge tensor's
    /// blocks spread over every server instead of pinning one shard.
    #[test]
    fn keyed_plan_spreads_blocks_of_one_tensor() {
        // Tensor 0: 8 blocks of cost 100; tensors 1..5: one block each.
        let mut items: Vec<(Key, f64)> =
            (0..8).map(|b| (BlockKey::new(0, b).pack(), 100.0)).collect();
        for t in 1..5u64 {
            items.push((BlockKey::new(t, 0).pack(), 10.0));
        }
        let plan = ShardPlan::balanced_keyed(&items, 4);
        assert_eq!(plan.len(), items.len());
        let servers_of_big: std::collections::HashSet<usize> =
            (0..8).map(|b| plan.server_of(BlockKey::new(0, b).pack())).collect();
        assert_eq!(servers_of_big.len(), 4, "big tensor's blocks should span all servers");
        // Deterministic: same inputs, same plan.
        let plan2 = ShardPlan::balanced_keyed(&items, 4);
        for &(k, _) in &items {
            assert_eq!(plan.server_of(k), plan2.server_of(k));
        }
        let imb = plan.imbalance_by(|k| {
            items.iter().find(|(key, _)| *key == k).map(|(_, c)| *c).unwrap()
        });
        let rr = ShardPlan::round_robin_keyed(
            &items.iter().map(|(k, _)| *k).collect::<Vec<_>>(),
            4,
        );
        let rr_imb = rr.imbalance_by(|k| {
            items.iter().find(|(key, _)| *key == k).map(|(_, c)| *c).unwrap()
        });
        assert!(imb <= rr_imb + 1e-9);
    }

    #[test]
    #[should_panic(expected = "not in the shard plan")]
    fn unknown_key_panics_with_context() {
        let plan = ShardPlan::balanced(&[1.0, 2.0], 2);
        let _ = plan.server_of(BlockKey::new(7, 3).pack());
    }

    #[test]
    fn shard_plan_assignments_roundtrip() {
        let plan = ShardPlan::balanced(&[5.0, 1.0, 3.0, 2.0], 3);
        let wire = plan.assignments();
        let back = ShardPlan::from_assignments(&wire, 3).unwrap();
        for k in 0..4u64 {
            assert_eq!(plan.server_of(k), back.server_of(k));
        }
        assert_eq!(back.assignments(), wire);
        // Untrusted input: out-of-range server and duplicate keys rejected.
        assert!(ShardPlan::from_assignments(&[(0, 3)], 3).is_err());
        assert!(ShardPlan::from_assignments(&[(0, 0), (0, 1)], 2).is_err());
        assert!(ShardPlan::from_assignments(&[], 0).is_err());
    }
}
