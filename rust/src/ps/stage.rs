//! Staged executor for a server shard (§4.2.1, server side): the pure
//! compute kernels of the ingress → decode → reduce → seal → encode
//! pipeline and the event plumbing that carries their results back to the
//! shard's single control thread.
//!
//! ## Determinism contract
//!
//! The staged shard must be **bit-identical** to the synchronous reference
//! (`server.compress_threads = 0`) for every compressor in
//! `compress::paper_suite()`. Three rules make that hold by construction:
//!
//! 1. **Decode is pure.** [`decode_contribution`] turns a validated wire
//!    block into a dense contribution vector with no shared state, so
//!    decode jobs can complete in any order.
//! 2. **Reduce runs in worker-index order.** The control thread defers the
//!    float sum to seal time and adds contributions sorted by connection
//!    index ([`crate::ps::ServerCore`]'s reduce step), so the f32 bits
//!    never depend on arrival or decode-completion order — on either path.
//! 3. **Encode draws from a per-(key, iteration) RNG.** [`seal_seed`]
//!    derives the second-way compression's stream the way the worker
//!    pipeline derives job seeds, so encodes of different keys can run
//!    concurrently without sharing an RNG, and both paths see the same
//!    stream. Encodes of *one* key are serialized by lending the key's EF
//!    residual to the in-flight job and only starting the next encode when
//!    it returns ([`StageEvent::Encoded`]).
//!
//! All *decisions* (validation, dedup, rollover, seal order, counters)
//! stay on the control thread at ingress, in message order — a decode or
//! encode job never touches shard state, it only computes.
// Wire-facing module: the static-invariants lint (rust/src/lint) keeps
// this file panic-free outside tests, and clippy enforces the same at
// the `unwrap`/`expect` level.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use crate::comm::Key;
use crate::compress::{Compressed, Compressor, Ctx};
use crate::configx::SyncMode;
use crate::parallel::ThreadPool;
use crate::util::rng::Xoshiro256;
use std::sync::Arc;

/// A stage job's completion, delivered back to the shard's control thread
/// (the I/O loop, or a test driver) which applies it via
/// [`crate::ps::ServerCore::on_event`]. `ns` is the job's self-measured
/// CPU nanoseconds, summed into the per-stage stats.
pub enum StageEvent {
    /// A push payload finished decoding into a dense contribution.
    Decoded { key: Key, iter: u64, from: u32, buf: Vec<f32>, ns: u64 },
    /// A sealed aggregate finished its second-way compression. `residual`
    /// returns the key's (possibly updated) server-EF residual; handing it
    /// back is what serializes encodes of the same key.
    Encoded {
        key: Key,
        iter: u64,
        served: u16,
        data: Compressed,
        residual: Option<Vec<f32>>,
        ns: u64,
    },
}

/// Where stage jobs deliver their [`StageEvent`]s. The I/O loop wraps its
/// own channel sender; tests wrap a plain `mpsc::Sender` and pump
/// manually.
pub type EventSink = Arc<dyn Fn(StageEvent) + Send + Sync>;

/// How a shard runs its decode/encode kernels: inline on the control
/// thread (`compress_threads = 0`, the synchronous reference) or as jobs
/// on a [`ThreadPool`] whose completions flow back through an
/// [`EventSink`].
pub(crate) enum Executor {
    Inline,
    Pool { pool: Arc<ThreadPool>, sink: EventSink },
}

/// Decode one validated push payload into a dense contribution vector:
/// a zero buffer plus the scheme's sparse-aware `add_decompressed`. Pure —
/// no shard state, safe to run on any thread in any order.
pub(crate) fn decode_contribution(comp: &dyn Compressor, data: &Compressed) -> Vec<f32> {
    // Rented, not allocated: the reduce step gives the contribution back to
    // the pool once it is summed into the aggregate (see ps::core).
    // lint: transfers(reduce)
    let mut buf = crate::comm::BufPool::global().rent_f32(data.n);
    comp.add_decompressed(data, &mut buf);
    buf
}

/// Deterministic RNG seed for the second-way compression of `(key, iter)`
/// under shard seed `seed`. Mirrors `worker::pipeline::job_seed`: encode
/// scheduling must never change what goes on the wire, so the stream is a
/// pure function of what is being encoded, not of when.
pub fn seal_seed(seed: u64, key: Key, iter: u64) -> u64 {
    seed ^ key.wrapping_mul(0xD1B5_4A32_D192_ED03)
        ^ (iter + 1).wrapping_mul(0x94D0_49BB_1331_11EB)
}

/// Second-way compression of a sealed aggregate (the *encode* stage),
/// including the server-side EF cycle (Alg. 4: correct with `ẽ`,
/// compress, store the new residual). `residual` is the key's residual
/// lent by the control thread (`None` on the first seal or for non-EF
/// sync modes); the updated residual is returned alongside the wire
/// block. The EF math itself is the one shared
/// [`crate::compress::ef::compress_cycle`] kernel — the same code
/// `EfState::compress_owned` runs — with the residual held per key
/// instead of in a shared map so encodes of different keys can run
/// concurrently.
pub(crate) fn encode_aggregate(
    comp: &dyn Compressor,
    sync: SyncMode,
    fused: bool,
    intra_threads: usize,
    seed: u64,
    acc: Vec<f32>,
    residual: Option<Vec<f32>>,
) -> (Compressed, Option<Vec<f32>>) {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut ctx = Ctx::with_threads(&mut rng, intra_threads);
    if sync != SyncMode::CompressedEf {
        let c = comp.compress(&acc, &mut ctx);
        // The aggregate dies here (EF keeps it as the residual instead).
        crate::comm::BufPool::global().give_f32(acc);
        return (c, residual);
    }
    let (c, e) =
        crate::compress::ef::compress_cycle(comp, fused, &mut ctx, acc, residual.as_deref());
    (c, Some(e))
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::comm::Message;
    use crate::compress::{by_name, paper_suite, validate_wire};
    use crate::ps::{ServerCore, ServerOptions, ServerStats};
    use std::sync::mpsc;
    use std::time::{Duration, Instant};

    fn opts(comp: Arc<dyn Compressor>, sync: SyncMode, workers: usize) -> ServerOptions {
        ServerOptions {
            comp,
            sync,
            fused: true,
            n_workers: workers,
            intra_threads: 1,
            seed: 7,
            max_keys: 0,
            iter_deadline: None,
            compress_threads: 0,
            deadline_auto_margin: 0.0,
            adaptive_bounds: None,
        }
    }

    /// A staged core plus the event channel a real I/O loop would own;
    /// `settle` pumps completions until no stage job is in flight.
    struct Staged {
        core: ServerCore,
        rx: mpsc::Receiver<StageEvent>,
    }

    impl Staged {
        fn new(o: ServerOptions, threads: usize) -> Staged {
            let (tx, rx) = mpsc::channel();
            let sink: EventSink = Arc::new(move |ev| {
                let _ = tx.send(ev);
            });
            let pool = Arc::new(ThreadPool::new(threads));
            Staged { core: ServerCore::new_staged(o, pool, sink), rx }
        }

        fn settle(&mut self) -> Vec<(u32, Message)> {
            let mut out = Vec::new();
            while self.core.jobs_in_flight() > 0 {
                let ev = self
                    .rx
                    .recv_timeout(Duration::from_secs(30))
                    .expect("stage job never reported back");
                out.extend(self.core.on_event(ev));
            }
            out
        }
    }

    /// Sort key so reply *content* can be compared across executors whose
    /// reply *timing* differs (the staged path answers sealed pulls from
    /// encode completions, the synchronous path inside `handle`).
    fn reply_key(to: u32, m: &Message) -> (u32, u8, u64, u64, u16, Vec<u8>) {
        match m {
            Message::Ack { key, iter } => (to, 0, *key, *iter, 0, Vec::new()),
            Message::PullResp { key, iter, served_with, data } => {
                let mut bytes = vec![data.scheme as u8];
                bytes.extend_from_slice(&(data.n as u64).to_le_bytes());
                bytes.extend_from_slice(&data.payload);
                (to, 1, *key, *iter, *served_with, bytes)
            }
            other => panic!("server emitted unexpected {other:?}"),
        }
    }

    fn sorted_replies(replies: Vec<(u32, Message)>) -> Vec<(u32, u8, u64, u64, u16, Vec<u8>)> {
        let mut keys: Vec<_> = replies.iter().map(|(to, m)| reply_key(*to, m)).collect();
        keys.sort();
        keys
    }

    fn assert_counters_match(a: &ServerStats, b: &ServerStats, label: &str) {
        assert_eq!(a.pushes, b.pushes, "{label}: pushes");
        assert_eq!(a.pulls, b.pulls, "{label}: pulls");
        assert_eq!(a.rejected, b.rejected, "{label}: rejected");
        assert_eq!(a.short_iters, b.short_iters, "{label}: short_iters");
        assert_eq!(a.stale_pulls, b.stale_pulls, "{label}: stale_pulls");
        assert_eq!(a.early_pulls, b.early_pulls, "{label}: early_pulls");
        assert_eq!(a.degraded_iters, b.degraded_iters, "{label}: degraded_iters");
        assert_eq!(a.late_pushes, b.late_pushes, "{label}: late_pushes");
        assert_eq!(a.unexpected, b.unexpected, "{label}: unexpected");
        assert_eq!(a.internal_errors, b.internal_errors, "{label}: internal_errors");
        assert_eq!(a.internal_errors, 0, "{label}: internal errors in a healthy run");
    }

    /// Per-(worker, key, iter) push payload, seeded like the worker
    /// pipeline seeds its jobs, so the script is deterministic.
    fn push_data(comp: &dyn Compressor, w: u32, key: Key, iter: u64, dim: usize) -> Compressed {
        let mut rng = Xoshiro256::seed_from_u64(
            0x5EED ^ (w as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ seal_seed(0, key, iter),
        );
        let mut g = vec![0.0f32; dim];
        rng.fill_normal(&mut g, 1.0);
        let mut ctx = Ctx::new(&mut rng);
        comp.compress(&g, &mut ctx)
    }

    /// The acceptance invariant: with any `compress_threads > 0`, every
    /// aggregate served is bit-identical to the synchronous shard, for the
    /// whole paper suite — including queued pulls, an early pull, a
    /// corrupt push rejected mid-flight, and a duplicate push.
    #[test]
    fn staged_matches_synchronous_across_paper_suite() {
        for (label, comp) in paper_suite() {
            let sync = if comp.name() == "identity" {
                SyncMode::Full
            } else {
                SyncMode::CompressedEf
            };
            let workers = 3usize;
            let keyspec: [(Key, usize); 3] = [(0, 96), (7, 33), (9, 64)];

            // Script: per iteration, the push order rotates by worker; one
            // worker's pull lands before its round completes (queued), the
            // rest after; iteration 1 throws in a corrupt push and a
            // duplicate, both of which must be rejected identically.
            let mut script: Vec<(u32, Message)> = Vec::new();
            // An early pull before any push establishes key 9.
            script.push((2, Message::Pull { key: 9, iter: 0, worker: 2 }));
            for iter in 0..4u64 {
                for &(key, dim) in &keyspec {
                    for j in 0..workers {
                        let w = ((j as u64 + iter) % workers as u64) as u32;
                        if iter == 1 && key == 7 && j == 1 {
                            // Wire-valid but wrong element count: rejected
                            // at ingress on both paths, then the honest
                            // push follows so the round still completes.
                            let bad = Compressed {
                                scheme: crate::compress::SchemeId::Identity,
                                n: 1,
                                payload: vec![0u8; 4],
                            };
                            validate_wire(&bad).unwrap();
                            script.push((w, Message::Push { key, iter, worker: w, data: bad }));
                        }
                        let data = push_data(comp.as_ref(), w, key, iter, dim);
                        if j == 0 {
                            // A pull racing ahead of the round: queues.
                            script.push((w, Message::Pull { key, iter, worker: w }));
                        }
                        script.push((w, Message::Push { key, iter, worker: w, data }));
                        if iter == 2 && key == 0 && j == 0 {
                            // Duplicate push from the same connection.
                            let dup = push_data(comp.as_ref(), w, key, iter, dim);
                            script.push((w, Message::Push { key, iter, worker: w, data: dup }));
                        }
                    }
                    for w in 0..workers as u32 {
                        script.push((w, Message::Pull { key, iter, worker: w }));
                    }
                }
            }

            let base = opts(comp.clone(), sync, workers);
            let mut sync_core = ServerCore::new(base.clone());
            let mut staged = Staged::new(
                ServerOptions { compress_threads: 4, ..base.clone() },
                4,
            );

            let mut sync_replies = Vec::new();
            let mut staged_replies = Vec::new();
            for (from, msg) in &script {
                sync_replies.extend(sync_core.handle(*from, msg.clone()));
                staged_replies.extend(staged.core.handle(*from, msg.clone()));
            }
            staged_replies.extend(staged.settle());

            assert_eq!(
                sorted_replies(sync_replies),
                sorted_replies(staged_replies),
                "{label}: staged shard diverged from the synchronous reference"
            );
            assert_counters_match(&sync_core.stats, &staged.core.stats, label);
            assert!(sync_core.stats.rejected >= 2, "{label}: script faults not exercised");
        }
    }

    /// A clock strictly past every configured test deadline.
    fn after_deadline() -> Instant {
        Instant::now() + Duration::from_secs(3600)
    }

    /// The deadline seals a round whose decodes are still in flight: the
    /// seal decision is taken immediately (no double-serving on a second
    /// sweep), the sum waits for the decode, and the degraded bytes are
    /// identical to the synchronous shard's.
    #[test]
    fn deadline_seals_round_with_decode_in_flight() {
        let comp = by_name("topk", 0.25).unwrap();
        let mut base = opts(comp.clone(), SyncMode::CompressedEf, 2);
        base.iter_deadline = Some(Duration::from_millis(50));

        let mut sync_core = ServerCore::new(base.clone());
        let mut staged = Staged::new(ServerOptions { compress_threads: 2, ..base }, 2);

        let data = push_data(comp.as_ref(), 0, 3, 0, 48);
        let mut sync_replies = sync_core.handle(0, Message::Push { key: 3, iter: 0, worker: 0, data: data.clone() });
        let mut staged_replies = staged.core.handle(0, Message::Push { key: 3, iter: 0, worker: 0, data });
        // Worker 1's pull queues on both (its push was "lost").
        sync_replies.extend(sync_core.handle(1, Message::Pull { key: 3, iter: 0, worker: 1 }));
        staged_replies.extend(staged.core.handle(1, Message::Pull { key: 3, iter: 0, worker: 1 }));
        // Seal before pumping any staged event: the decode job's result
        // has not been applied yet, so the staged sum must wait for it.
        sync_replies.extend(sync_core.poll_deadlines(after_deadline()));
        staged_replies.extend(staged.core.poll_deadlines(after_deadline()));
        // A second sweep must not re-seal on either path.
        assert!(sync_core.poll_deadlines(after_deadline()).is_empty());
        assert!(staged.core.poll_deadlines(after_deadline()).is_empty());
        staged_replies.extend(staged.settle());
        // And a sweep *after* the encode landed stays a no-op too.
        assert!(staged.core.poll_deadlines(after_deadline()).is_empty());

        assert_eq!(sorted_replies(sync_replies), sorted_replies(staged_replies));
        assert_eq!(staged.core.stats.degraded_iters, 1);
        assert_counters_match(&sync_core.stats, &staged.core.stats, "deadline mid-flight");

        // The straggler's late push after the seal changes nothing.
        let late = push_data(comp.as_ref(), 1, 3, 0, 48);
        let r = staged.core.handle(1, Message::Push { key: 3, iter: 0, worker: 1, data: late.clone() });
        assert!(r.is_empty());
        let r2 = sync_core.handle(1, Message::Push { key: 3, iter: 0, worker: 1, data: late });
        assert!(r2.is_empty());
        assert_eq!(staged.core.stats.late_pushes, 1);
        assert_eq!(sync_core.stats.late_pushes, 1);
    }

    /// A key that rolls over while its sealed round is still encoding:
    /// the encode result lands in the one-slot `prev` history, a straggler
    /// pull for the sealed iteration is served those exact bytes, and the
    /// next round completes full — no short-iteration miscount.
    #[test]
    fn rollover_mid_encode_lands_in_prev_slot() {
        let comp = by_name("identity", 0.0).unwrap();
        let mut base = opts(comp.clone(), SyncMode::Full, 2);
        base.iter_deadline = Some(Duration::from_millis(50));
        let mut staged = Staged::new(ServerOptions { compress_threads: 2, ..base.clone() }, 2);
        let mut sync_core = ServerCore::new(base);

        let mut srep = Vec::new();
        let mut trep = Vec::new();
        let mk = |w: u32, iter: u64| push_data(comp.as_ref(), w, 5, iter, 16);
        // Round 0: only worker 0 pushes; deadline seals it degraded.
        trep.extend(staged.core.handle(0, Message::Push { key: 5, iter: 0, worker: 0, data: mk(0, 0) }));
        srep.extend(sync_core.handle(0, Message::Push { key: 5, iter: 0, worker: 0, data: mk(0, 0) }));
        trep.extend(staged.core.poll_deadlines(after_deadline()));
        srep.extend(sync_core.poll_deadlines(after_deadline()));
        // While the staged encode for round 0 is (potentially) still in
        // flight, both workers push round 1 — the key rolls over with the
        // seal mid-pipeline.
        for w in 0..2u32 {
            trep.extend(staged.core.handle(w, Message::Push { key: 5, iter: 1, worker: w, data: mk(w, 1) }));
            srep.extend(sync_core.handle(w, Message::Push { key: 5, iter: 1, worker: w, data: mk(w, 1) }));
        }
        // Straggler pull for the sealed round 0 (now the retired slot) and
        // current pulls for round 1.
        trep.extend(staged.core.handle(1, Message::Pull { key: 5, iter: 0, worker: 1 }));
        srep.extend(sync_core.handle(1, Message::Pull { key: 5, iter: 0, worker: 1 }));
        for w in 0..2u32 {
            trep.extend(staged.core.handle(w, Message::Pull { key: 5, iter: 1, worker: w }));
            srep.extend(sync_core.handle(w, Message::Pull { key: 5, iter: 1, worker: w }));
        }
        trep.extend(staged.settle());

        assert_eq!(sorted_replies(srep), sorted_replies(trep));
        assert_eq!(staged.core.stats.degraded_iters, 1);
        assert_eq!(staged.core.stats.short_iters, 0, "sealed rollover must not count short");
        assert_counters_match(&sync_core.stats, &staged.core.stats, "rollover mid-encode");
    }

    #[test]
    fn seal_seed_is_distinct_across_axes() {
        let a = seal_seed(42, 1, 0);
        assert_ne!(a, seal_seed(42, 2, 0), "key must change the seed");
        assert_ne!(a, seal_seed(42, 1, 1), "iter must change the seed");
        assert_ne!(a, seal_seed(43, 1, 0), "shard seed must change the seed");
        assert_eq!(a, seal_seed(42, 1, 0), "seed must be deterministic");
    }

    /// The decode kernel matches the sparse-aware server aggregation it
    /// replaces: zero buffer + `add_decompressed` for every scheme.
    #[test]
    fn decode_contribution_matches_add_decompressed() {
        for (label, comp) in paper_suite() {
            let mut rng = Xoshiro256::seed_from_u64(3);
            let mut g = vec![0.0f32; 200];
            rng.fill_normal(&mut g, 1.0);
            let c = comp.compress(&g, &mut Ctx::new(&mut rng));
            let buf = decode_contribution(comp.as_ref(), &c);
            let mut want = vec![0.0f32; 200];
            comp.add_decompressed(&c, &mut want);
            assert_eq!(buf, want, "{label}");
        }
    }
}
