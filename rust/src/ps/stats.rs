//! Server-shard statistics: protocol counters, per-stage seconds for the
//! staged pipeline (ingress → decode → reduce → seal → encode), queue-depth
//! gauges, and the fixed-bucket round-latency histogram that feeds deadline
//! auto-tuning (`server.iter_deadline_auto_margin`).
//!
//! Everything here is updated on the shard's single control thread — stage
//! jobs report their own durations back through
//! [`StageEvent`](crate::ps::stage::StageEvent)s — so the numbers stay
//! truthful under concurrency: no counter is ever raced, and a stage's
//! seconds are the sum of its jobs' self-measured CPU time, not a wall
//! clock smeared across overlapping work.

use std::time::Duration;

/// Number of log2 buckets in [`LatencyHist`]: bucket `i` covers round
/// latencies in `[2^i, 2^(i+1))` microseconds, so 32 buckets span 1 µs to
/// ~71 minutes — far past any sane iteration deadline.
pub const HIST_BUCKETS: usize = 32;

/// Fixed-bucket (log2, microsecond-based) latency histogram.
///
/// Fixed buckets keep the type `Copy` (stats are returned by value on
/// shutdown) and make `record` O(1) with no allocation on the control
/// thread. Quantiles are read off the bucket *upper* edges, so a derived
/// deadline is conservative: never tighter than the true quantile.
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencyHist {
    buckets: [u64; HIST_BUCKETS],
    count: u64,
}

impl LatencyHist {
    /// Record one round latency.
    pub fn record(&mut self, d: Duration) {
        let us = (d.as_micros().max(1)).min(u64::MAX as u128) as u64;
        let idx = (63 - us.leading_zeros() as usize).min(HIST_BUCKETS - 1);
        self.buckets[idx] += 1;
        self.count += 1;
    }

    /// Rounds recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Upper-bound quantile `q` in [0, 1]: the smallest bucket upper edge
    /// below which at least `ceil(q * count)` recorded rounds fall.
    /// `Duration::ZERO` when empty.
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = ((self.count as f64) * q.clamp(0.0, 1.0)).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= target {
                return Duration::from_micros(1u64 << (i + 1).min(63));
            }
        }
        // Unreachable: the cumulative sum reaches `count >= target`.
        Duration::from_micros(1u64 << (HIST_BUCKETS as u32).min(63))
    }

    /// Fold another histogram in (multi-shard summaries).
    pub fn merge(&mut self, other: &LatencyHist) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
    }
}

/// Statistics returned on shutdown.
#[derive(Debug, Default, Clone, Copy)]
pub struct ServerStats {
    pub pushes: u64,
    pub pulls: u64,
    /// Corrupt push blocks dropped at ingress (wire-validation failures,
    /// wrong element counts, pushes for already-retired iterations).
    pub rejected: u64,
    /// Iterations that rolled over with fewer than `n_workers` pushes —
    /// a rejected corrupt push (or a dead worker) left the round short.
    /// The shard recovers by discarding the partial round instead
    /// of asserting; each occurrence is counted here.
    pub short_iters: u64,
    /// Structurally valid pushes whose sparsifier `k` fell outside the
    /// adaptive envelope this server granted at registration
    /// (`ServerOptions::adaptive_bounds`) — dropped and counted, never a
    /// panic. Disjoint from `rejected` (wire-validation failures): a
    /// bounds-rejected block parsed fine, it just claimed a keep ratio the
    /// negotiation never granted. Always 0 on static runs.
    pub bounds_rejected: u64,
    /// Pulls dropped because their iteration was already retired past the
    /// one-slot history (can only happen after a short iteration or a
    /// hostile client; honest BSP workers never lag two iterations).
    pub stale_pulls: u64,
    /// Pulls that arrived before any push had established their key —
    /// queued until the key appears (reordered cluster startup), where the
    /// shard previously died on `.expect("pull before any push")`.
    pub early_pulls: u64,
    /// Messages a server should never receive (`Welcome`, `PullResp`,
    /// mid-stream `Hello`, ...) — ignored and counted, never a panic.
    pub unexpected: u64,
    /// Rounds sealed by the iteration deadline with fewer than `n_workers`
    /// contributions and served degraded (`served_with < n_workers`).
    /// Disjoint from `short_iters`, which counts partial rounds that were
    /// *discarded unserved* at rollover — a deadline-sealed round is never
    /// double-counted there.
    pub degraded_iters: u64,
    /// Pushes that arrived for a round already sealed (completed normally
    /// or by the deadline) — dropped and counted, never merged
    /// retroactively into an aggregate other workers may have pulled.
    pub late_pushes: u64,
    /// Hierarchical-mode group pushes whose claimed `members` weight
    /// exceeded the round's remaining contributor capacity — a hostile or
    /// buggy leader overstating its group. The weight is clamped down to
    /// what the round can still absorb (the push itself is kept) and each
    /// occurrence is counted here, never a panic. Always 0 in flat runs
    /// and in honest hierarchical runs.
    pub members_clamped: u64,
    /// Shard-internal bookkeeping drift the server recovered from instead
    /// of panicking (a seal decision for an unknown key, a seal pipeline
    /// that lost its front seal or dimension). Always 0 in a healthy run;
    /// any nonzero value is a server bug worth a bisect, which is exactly
    /// why it is counted and printed rather than asserted away.
    pub internal_errors: u64,
    /// Control-thread seconds spent framing/validating messages and
    /// driving the round state machine — the *ingress* stage. Excludes
    /// decode/reduce/encode kernel time even on the synchronous path
    /// (`compress_threads = 0`), where those kernels run inline.
    pub ingress_s: f64,
    /// Summed job seconds decompressing push payloads (the *decode*
    /// stage). With `compress_threads > 0` these jobs overlap ingress and
    /// each other, so this is CPU time, not wall time.
    pub decode_s: f64,
    /// Control-thread seconds summing decoded contributions in
    /// worker-index order and averaging (the *reduce* stage).
    pub reduce_s: f64,
    /// Summed job seconds on the second-way compression of sealed
    /// aggregates (the *encode* stage).
    pub encode_s: f64,
    /// Peak number of decode jobs in flight at once (queue-depth gauge:
    /// how much decompression actually overlapped).
    pub decode_depth_peak: u64,
    /// Peak number of encode jobs in flight at once (bounded by the
    /// number of keys — encodes of one key serialize on its EF residual).
    pub encode_depth_peak: u64,
    /// Latency of every *full* (non-degraded) round, first push → round
    /// complete. Degraded rounds are excluded — they take exactly the
    /// deadline, and feeding them back would make auto-tuning
    /// self-referential. Under deadline *auto-tuning* only, one extra
    /// sample per degraded round may be added: the true arrival spread
    /// revealed by a straggler's late push (the anti-ratchet feedback
    /// that lets a too-tight derived deadline widen again).
    pub round_hist: LatencyHist,
}

/// The one canonical rendering of the counter set, shared by every
/// shutdown line (`bytepsc server` stdout, `cluster::serve` stderr) so a
/// new counter cannot be added to one surface and silently missed on the
/// other — EXPERIMENTS.md's degraded-round recipe reads these lines.
impl std::fmt::Display for ServerStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} pushes | {} pulls | {} rejected | {} bounds rejected | \
             {} short iterations | {} degraded iterations | {} late pushes | \
             {} stale pulls | {} early pulls | {} unexpected | \
             {} members clamped | {} internal errors",
            self.pushes,
            self.pulls,
            self.rejected,
            self.bounds_rejected,
            self.short_iters,
            self.degraded_iters,
            self.late_pushes,
            self.stale_pulls,
            self.early_pulls,
            self.unexpected,
            self.members_clamped,
            self.internal_errors
        )?;
        write!(
            f,
            " | stage s ingress/decode/reduce/encode \
             {:.3}/{:.3}/{:.3}/{:.3} | depth peak decode/encode {}/{}",
            self.ingress_s,
            self.decode_s,
            self.reduce_s,
            self.encode_s,
            self.decode_depth_peak,
            self.encode_depth_peak
        )?;
        if self.round_hist.count() > 0 {
            write!(
                f,
                " | round latency p50/p99 {:.1}/{:.1} ms over {} rounds",
                self.round_hist.quantile(0.5).as_secs_f64() * 1e3,
                self.round_hist.quantile(0.99).as_secs_f64() * 1e3,
                self.round_hist.count()
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hist_records_and_quantiles() {
        let mut h = LatencyHist::default();
        assert_eq!(h.quantile(0.99), Duration::ZERO);
        // 99 fast rounds (~100 µs) and one slow (~50 ms).
        for _ in 0..99 {
            h.record(Duration::from_micros(100));
        }
        h.record(Duration::from_millis(50));
        assert_eq!(h.count(), 100);
        // p50 sits in the fast bucket: [64, 128) µs → upper edge 128 µs.
        assert_eq!(h.quantile(0.5), Duration::from_micros(128));
        // p99 still in the fast bucket (99 of 100 rounds are fast)...
        assert_eq!(h.quantile(0.99), Duration::from_micros(128));
        // ...while p100 covers the straggler: [32768, 65536) µs.
        assert_eq!(h.quantile(1.0), Duration::from_micros(65536));
        // Quantiles are monotone in q.
        let mut prev = Duration::ZERO;
        for q in [0.0, 0.1, 0.5, 0.9, 0.99, 1.0] {
            let v = h.quantile(q);
            assert!(v >= prev, "quantile({q}) = {v:?} < {prev:?}");
            prev = v;
        }
    }

    #[test]
    fn hist_clamps_extremes() {
        let mut h = LatencyHist::default();
        h.record(Duration::ZERO); // clamps to the 1 µs bucket
        h.record(Duration::from_secs(1 << 40)); // clamps to the top bucket
        assert_eq!(h.count(), 2);
        assert_eq!(h.quantile(0.25), Duration::from_micros(2));
        assert_eq!(h.quantile(1.0), Duration::from_micros(1u64 << HIST_BUCKETS as u32));
    }

    #[test]
    fn hist_merges() {
        let mut a = LatencyHist::default();
        let mut b = LatencyHist::default();
        a.record(Duration::from_micros(10));
        b.record(Duration::from_micros(10_000));
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!(a.quantile(1.0) >= Duration::from_micros(10_000));
    }

    #[test]
    fn stats_display_appends_latency_only_when_recorded() {
        let mut s = ServerStats::default();
        let line = s.to_string();
        assert!(line.contains("pushes"));
        assert!(!line.contains("round latency"));
        s.round_hist.record(Duration::from_millis(3));
        let line = s.to_string();
        assert!(line.contains("round latency"), "{line}");
        assert!(line.contains("over 1 rounds"), "{line}");
    }
}
