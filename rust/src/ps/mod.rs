//! Parameter server (paper §4.1.2): key-sharded gradient aggregation with
//! two-way compression and server-side error feedback.
//!
//! One [`Server`] owns a shard of the keyspace. Per key and iteration it
//! collects one compressed push per worker, decompresses and averages them
//! (`Δ_t = 1/n Σ δ_t,i [+ ẽ_t]`), re-compresses the aggregate (`p_t =
//! C(Δ_t)`, the second "way"), and answers the workers' pulls. Exactly
//! Algorithm 3/4's server side; Algorithm 1 falls out with the identity
//! compressor.
//!
//! Shard assignment across multiple servers lives in [`ShardPlan`] and
//! implements the paper's workload balancing (§4.2.4): keys that undergo
//! compression carry extra CPU cost, so they are weighted heavier than
//! bypassed (small) keys when balancing. Since the §4.2.1 pipeline, the
//! unit of sharding is a *block* ([`crate::comm::BlockKey`]), not a whole
//! tensor: a large tensor's blocks spread across shards, so its server-side
//! decompress/aggregate/re-compress work runs on several shards at once.
//!
//! Incoming push payloads are untrusted wire data: the server validates
//! every block against its scheme ([`crate::compress::validate_wire`]) and
//! rejects corrupt blocks (counted in [`ServerStats::rejected`]) instead of
//! panicking mid-aggregation.
//!
//! ## Iteration deadline (degraded rounds)
//!
//! Strict BSP has a liveness hole: if one worker's push for iteration *t*
//! is lost or rejected, the round never reaches `n_workers` pushes and
//! every worker's pull for *t* waits forever. With
//! [`ServerOptions::iter_deadline`] set, a round that has at least one
//! push and has been open longer than the deadline is *sealed* with the
//! contributions it has: the partial sum is averaged over the pushes
//! actually received, second-way-compressed as usual, and served with
//! `served_with < n_workers` on the wire so workers can tell a degraded
//! round from a full one ([`ServerStats::degraded_iters`]). A push that
//! arrives after its round was sealed is dropped and counted
//! ([`ServerStats::late_pushes`]) — it is never merged retroactively,
//! which would hand different workers different aggregates for the same
//! iteration. With the deadline unset the server is bit-identical to the
//! strict-BSP aggregator (no timer, no polling, no wire change beyond the
//! constant `served_with == n_workers` tag).

use crate::comm::{BlockKey, CommError, Endpoint, Key, Message};
use crate::compress::ef::EfState;
use crate::compress::{Compressor, Ctx};
use crate::configx::SyncMode;
use crate::util::rng::Xoshiro256;
use std::collections::HashMap;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Server behaviour knobs.
#[derive(Clone)]
pub struct ServerOptions {
    pub comp: Arc<dyn Compressor>,
    pub sync: SyncMode,
    /// Fused EF residual update (§4.2.2).
    pub fused: bool,
    pub n_workers: usize,
    /// Intra-task threads for (de)compression (§4.2.1).
    pub intra_threads: usize,
    pub seed: u64,
    /// Cap on distinct keys this shard will materialize state for
    /// (0 = unlimited). The launchers set it to the partition size so a
    /// client inventing keys cannot grow server memory without bound.
    pub max_keys: usize,
    /// Iteration deadline for degraded rounds (`server.iter_deadline_ms`):
    /// a round with at least one push that stays incomplete this long is
    /// sealed and served partial (`served_with < n_workers`). `None` =
    /// strict BSP — a lost push stalls its iteration's pulls forever, but
    /// behavior is bit-identical to the pre-deadline server.
    pub iter_deadline: Option<Duration>,
}

struct KeyState {
    iter: u64,
    /// Canonical element count for this key, fixed by the first *push*
    /// (`None` while the key has only seen pulls — a pull-before-push
    /// queues rather than panicking the shard). Later pushes whose `n`
    /// disagrees are rejected at ingress — a self-consistent corrupt frame
    /// must not resize (or panic on) the accumulator.
    dim: Option<usize>,
    acc: Vec<f32>,
    /// Connection indices that contributed to the current round, in
    /// arrival order. The *connection* is the trusted identity (the wire
    /// `worker` field is not), and deduplicating on it keeps a
    /// retransmitting or hostile client from completing a round early
    /// with one worker double-counted — which would also make the
    /// `served_with` tag lie about how many workers the aggregate holds.
    contributors: Vec<u32>,
    /// When the current round's first push arrived — the iteration
    /// deadline's clock. `None` while the round is empty or already
    /// sealed.
    round_started: Option<Instant>,
    /// The sealed aggregate for `iter`, tagged with how many worker
    /// contributions it holds (`served_with`: `n_workers` for a full BSP
    /// round, fewer for a deadline-degraded one).
    ready: Option<(u16, crate::compress::Compressed)>,
    /// The previous iteration's aggregate. BSP lets a fast worker *push*
    /// iteration i+1 (which rolls this key over) before a slow worker has
    /// *pulled* iteration i — the slow pull must still be servable.
    /// Workers never lag more than one iteration (they pull i before
    /// pushing i+1), so one slot suffices.
    ///
    /// This invariant survives the block pipeline: keys are now per-block
    /// and blocks of one iteration arrive out of order across *different*
    /// keys, but each `KeyState` is keyed by one block, and every worker
    /// still completes pull(key, i) before it sends push(key, i+1) — the
    /// pipelined push phase starts only after the previous exchange's pull
    /// phase fully drained, and both transports preserve per-endpoint FIFO
    /// order. So per key the lag stays bounded by one iteration and the
    /// one-slot rollover is still sufficient (tested in
    /// `rust/tests/distributed.rs`).
    ///
    /// The *iteration deadline* is the one exception: it can seal rounds
    /// without a stalled worker's push, so the clock may advance two or
    /// more past a live-but-delayed worker. Such a worker's pull finds
    /// neither `ready` nor `prev` and is answered with the retired
    /// marker ([`retired_marker`], `served_with == 0`) so it fails
    /// loudly instead of hanging on a reply that cannot come.
    prev: Option<(u64, u16, crate::compress::Compressed)>,
    /// Queued pulls as (iter, connection index) — the endpoint to answer
    /// on, which is the server's ground truth for who is asking (the wire
    /// `worker` field is untrusted).
    pending: Vec<(u64, u32)>,
}

impl KeyState {
    /// Empty state at `iter` — no dimension yet (a *placeholder* until
    /// the first push establishes the element count).
    fn fresh(iter: u64) -> KeyState {
        KeyState {
            iter,
            dim: None,
            acc: Vec::new(),
            contributors: Vec::new(),
            round_started: None,
            ready: None,
            prev: None,
            pending: Vec::new(),
        }
    }
}

/// Statistics returned on shutdown.
#[derive(Debug, Default, Clone, Copy)]
pub struct ServerStats {
    pub pushes: u64,
    pub pulls: u64,
    /// Corrupt push blocks dropped at ingress (wire-validation failures,
    /// wrong element counts, pushes for already-retired iterations).
    pub rejected: u64,
    /// Iterations that rolled over with fewer than `n_workers` pushes —
    /// a rejected corrupt push (or a dead worker) left the round short.
    /// The shard recovers by discarding the partial accumulator instead
    /// of asserting; each occurrence is counted here.
    pub short_iters: u64,
    /// Pulls dropped because their iteration was already retired past the
    /// one-slot history (can only happen after a short iteration or a
    /// hostile client; honest BSP workers never lag two iterations).
    pub stale_pulls: u64,
    /// Pulls that arrived before any push had established their key —
    /// queued until the key appears (reordered cluster startup), where the
    /// shard previously died on `.expect("pull before any push")`.
    pub early_pulls: u64,
    /// Messages a server should never receive (`Welcome`, `PullResp`,
    /// mid-stream `Hello`, ...) — ignored and counted, never a panic.
    pub unexpected: u64,
    /// Rounds sealed by the iteration deadline with fewer than `n_workers`
    /// contributions and served degraded (`served_with < n_workers`).
    /// Disjoint from `short_iters`, which counts partial rounds that were
    /// *discarded unserved* at rollover — a deadline-sealed round is never
    /// double-counted there.
    pub degraded_iters: u64,
    /// Pushes that arrived for a round already sealed (completed normally
    /// or by the deadline) — dropped and counted, never merged
    /// retroactively into an aggregate other workers may have pulled.
    pub late_pushes: u64,
    pub decompress_s: f64,
    pub compress_s: f64,
}

/// Reply for an unservable pull: a `PullResp` whose `served_with` is 0
/// and whose block is empty. No real aggregate can have zero
/// contributors, so the marker is unambiguous on the wire. It exists
/// because the iteration deadline breaks strict BSP's guarantee that the
/// key clock never advances two past a live worker: a worker delayed
/// ~2 deadlines can ask for an iteration already evicted from the
/// one-slot history, and silently dropping that pull would hang it
/// forever — the marker lets it fail loudly instead.
fn retired_marker(key: Key, iter: u64) -> Message {
    Message::PullResp {
        key,
        iter,
        served_with: 0,
        data: crate::compress::Compressed {
            scheme: crate::compress::SchemeId::Identity,
            n: 0,
            payload: Vec::new(),
        },
    }
}

/// The one canonical rendering of the counter set, shared by every
/// shutdown line (`bytepsc server` stdout, `cluster::serve` stderr) so a
/// new counter cannot be added to one surface and silently missed on the
/// other — EXPERIMENTS.md's degraded-round recipe reads these lines.
impl std::fmt::Display for ServerStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} pushes | {} pulls | {} rejected | {} short iterations | \
             {} degraded iterations | {} late pushes | {} stale pulls | \
             {} early pulls | {} unexpected",
            self.pushes,
            self.pulls,
            self.rejected,
            self.short_iters,
            self.degraded_iters,
            self.late_pushes,
            self.stale_pulls,
            self.early_pulls,
            self.unexpected
        )
    }
}

/// The server's synchronous core: feed it messages, collect replies.
/// Separated from the I/O loop so tests can drive it deterministically.
pub struct ServerCore {
    opts: ServerOptions,
    ef: EfState,
    rng: Xoshiro256,
    keys: HashMap<Key, KeyState>,
    /// Keys whose dimension a push has established. Junk *placeholders*
    /// (pull-created, dim `None`) are budgeted separately so a client
    /// pulling made-up keys can never starve pushes for real keys.
    established_keys: usize,
    pub stats: ServerStats,
}

impl ServerCore {
    pub fn new(opts: ServerOptions) -> Self {
        let rng = Xoshiro256::seed_from_u64(opts.seed);
        ServerCore {
            ef: EfState::new(opts.fused),
            rng,
            keys: HashMap::new(),
            established_keys: 0,
            stats: ServerStats::default(),
            opts,
        }
    }

    /// Whether a push may establish one more key (the real keyspace is
    /// bounded by the partition; anything past `max_keys` is hostile).
    fn at_established_capacity(&self) -> bool {
        self.opts.max_keys > 0 && self.established_keys >= self.opts.max_keys
    }

    /// Whether creating one more pull-created placeholder would exceed its
    /// budget (equal to `max_keys`): total key state stays bounded even
    /// against a client pulling arbitrary made-up keys.
    fn at_placeholder_capacity(&self, key: Key) -> bool {
        self.opts.max_keys > 0
            && !self.keys.contains_key(&key)
            && self.keys.len() - self.established_keys >= self.opts.max_keys
    }

    /// Handle one message from connection `from`; returns
    /// `(connection index, reply)` pairs to send.
    pub fn handle(&mut self, from: u32, msg: Message) -> Vec<(u32, Message)> {
        match msg {
            // Replies are addressed by `from` — the connection the message
            // arrived on — never by the wire-supplied `worker` field. A
            // client lying about (or botching) its id must not be able to
            // steer replies to another worker or index the endpoint table
            // out of bounds; the field is kept for diagnostics only.
            Message::Push { key, iter, worker, data } => {
                // Untrusted wire data: reject corrupt blocks instead of
                // letting a bad index/length panic the aggregator. (The
                // TCP transport already rejects these at frame decode;
                // this also covers the in-process transport.)
                if let Err(e) = crate::compress::validate_wire(&data) {
                    eprintln!("server: rejecting corrupt push for key {key} from worker {worker}: {e}");
                    self.stats.rejected += 1;
                    return vec![];
                }
                // Every push targets (or establishes) an established key;
                // placeholders don't consume this budget until a push
                // gives them a dimension. Checked before touching the map
                // so a rejected junk push cannot leave a placeholder
                // behind either. (Hoisted: `st` below holds a &mut borrow
                // of the key map.)
                let at_established_cap = self.at_established_capacity();
                if at_established_cap && !self.keys.contains_key(&key) {
                    eprintln!(
                        "server: rejecting push for unknown key {key} from worker {worker}: \
                         shard is at its {}-key capacity",
                        self.opts.max_keys
                    );
                    self.stats.rejected += 1;
                    return vec![];
                }
                let st = self.keys.entry(key).or_insert_with(|| KeyState::fresh(iter));
                match st.dim {
                    // A self-consistent corrupt frame can still carry the
                    // wrong element count for this key; reject it rather
                    // than resize (or panic on) the accumulator.
                    Some(d) if data.n != d => {
                        eprintln!(
                            "server: rejecting push for key {key} from worker {worker}: \
                             n={} but the key has {d} elements",
                            data.n
                        );
                        self.stats.rejected += 1;
                        return vec![];
                    }
                    // First push fixes the key's element count. The state
                    // may be a placeholder from an earlier queued pull, so
                    // adopt the pusher's iteration clock too — and charge
                    // the establishment budget now.
                    None => {
                        if at_established_cap {
                            eprintln!(
                                "server: rejecting push establishing key {key} from worker \
                                 {worker}: shard is at its {}-key capacity",
                                self.opts.max_keys
                            );
                            self.stats.rejected += 1;
                            return vec![];
                        }
                        st.dim = Some(data.n);
                        st.acc = vec![0.0; data.n];
                        st.iter = iter;
                        self.established_keys += 1;
                    }
                    _ => {}
                }
                if iter < st.iter {
                    // A push for an iteration this key already retired.
                    // If it targets the just-retired (one-slot history)
                    // round, it is the honest straggler the degraded-round
                    // protocol tolerates — its round was sealed and rolled
                    // over before the push landed — and belongs in the
                    // `late_pushes` telemetry, not the corruption counter.
                    // Anything older is a hostile client or a straggler
                    // beyond BSP's lag bound. Unusable either way; drop.
                    if st.prev.as_ref().is_some_and(|(piter, _, _)| *piter == iter) {
                        eprintln!(
                            "server: dropping late push for key {key} iteration {iter} \
                             from worker {worker}: the round was sealed and retired"
                        );
                        self.stats.late_pushes += 1;
                    } else {
                        eprintln!(
                            "server: rejecting stale push for key {key} iteration {iter} \
                             from worker {worker} (key is at {})",
                            st.iter
                        );
                        self.stats.rejected += 1;
                    }
                    return vec![];
                }
                if st.iter != iter {
                    // New iteration for this key: retire the sealed
                    // aggregate (slow workers may still pull it) and reset
                    // the accumulator. A short round — a rejected corrupt
                    // push left `count` below n_workers and no deadline
                    // sealed it — is recovered by discarding the partial
                    // sum, never by asserting the shard down on untrusted
                    // input. A deadline-sealed degraded round has
                    // `ready.is_some()` and was already counted in
                    // `degraded_iters`; it must not be double-counted as
                    // short here.
                    if !st.contributors.is_empty()
                        && st.contributors.len() != self.opts.n_workers
                        && st.ready.is_none()
                    {
                        eprintln!(
                            "server: key {key} iteration {} was short ({}/{} pushes); \
                             discarding the partial aggregate",
                            st.iter,
                            st.contributors.len(),
                            self.opts.n_workers
                        );
                        self.stats.short_iters += 1;
                    }
                    if let Some((served, p)) = st.ready.take() {
                        st.prev = Some((st.iter, served, p));
                    }
                    st.iter = iter;
                    st.contributors.clear();
                    st.round_started = None;
                    st.acc.clear();
                    st.acc.resize(data.n, 0.0);
                } else if st.ready.is_some() {
                    // The round for `iter` is already sealed — by a full
                    // BSP completion (this is a duplicate push) or by the
                    // iteration deadline (this is the late straggler the
                    // degraded-round protocol tolerates). Either way the
                    // aggregate may already be in other workers' hands:
                    // merging retroactively would hand different workers
                    // different bytes for the same iteration. Drop it,
                    // counted — a rejected or late push is never
                    // resurrected.
                    eprintln!(
                        "server: dropping late push for key {key} iteration {iter} from \
                         worker {worker}: the round is already sealed"
                    );
                    self.stats.late_pushes += 1;
                    return vec![];
                }
                if st.contributors.contains(&from) {
                    // A second push from the same connection for an open
                    // round — a retransmitting or hostile client. Counting
                    // it would complete the round early with one worker
                    // double-counted (and `served_with` lying about it);
                    // the connection index is the trusted identity, never
                    // the wire `worker` field.
                    eprintln!(
                        "server: rejecting duplicate push for key {key} iteration {iter} \
                         from connection {from} (claims worker {worker})"
                    );
                    self.stats.rejected += 1;
                    return vec![];
                }
                let t = Instant::now();
                if st.contributors.is_empty() {
                    // First push of the round starts the deadline clock.
                    st.round_started = Some(t);
                }
                self.opts.comp.add_decompressed(&data, &mut st.acc);
                self.stats.decompress_s += t.elapsed().as_secs_f64();
                st.contributors.push(from);
                self.stats.pushes += 1;
                let complete = st.contributors.len() == self.opts.n_workers;
                let mut replies = vec![(from, Message::Ack { key, iter })];
                if complete {
                    self.seal_round(key, &mut replies);
                }
                replies
            }
            Message::Pull { key, iter, worker } => {
                self.stats.pulls += 1;
                if self.at_placeholder_capacity(key) {
                    eprintln!(
                        "server: dropping pull for unknown key {key} from worker {worker}: \
                         shard is at its placeholder capacity"
                    );
                    self.stats.rejected += 1;
                    // Unservable-pull policy: always answer (see
                    // retired_marker) — a dropped pull must never become
                    // a silent hang on the puller's side.
                    return vec![(from, retired_marker(key, iter))];
                }
                // A pull may precede any push for its key — a reordered
                // startup, or a client probing unknown keys. Queue it (as
                // a budgeted placeholder) until the key appears instead of
                // panicking the shard.
                let st = self.keys.entry(key).or_insert_with(|| KeyState::fresh(iter));
                if st.dim.is_none() {
                    self.stats.early_pulls += 1;
                }
                if st.dim.is_some() {
                    if st.iter == iter {
                        if let Some((served, p)) = &st.ready {
                            return vec![(
                                from,
                                Message::PullResp {
                                    key,
                                    iter,
                                    served_with: *served,
                                    data: p.clone(),
                                },
                            )];
                        }
                    } else if let Some((piter, served, p)) = &st.prev {
                        // A pull lagging one iteration behind a fast pusher.
                        if *piter == iter {
                            return vec![(
                                from,
                                Message::PullResp {
                                    key,
                                    iter,
                                    served_with: *served,
                                    data: p.clone(),
                                },
                            )];
                        }
                    }
                    if iter < st.iter {
                        // Older than the one-slot history: unservable.
                        // Under strict BSP only a hostile client gets
                        // here, but the iteration deadline can advance
                        // the key clock past a live worker that stalls
                        // for ~2 deadlines — answer with the retired
                        // marker so it fails loudly instead of waiting
                        // forever for a reply that cannot come.
                        eprintln!(
                            "server: retiring stale pull for key {key} iteration {iter} \
                             from worker {worker} (key is at {})",
                            st.iter
                        );
                        self.stats.stale_pulls += 1;
                        return vec![(from, retired_marker(key, iter))];
                    }
                    if iter > st.iter.saturating_add(1) {
                        // Impossible for honest traffic even with lost
                        // pushes: a worker only advances to iteration i+1
                        // after its pull for i completed, so its future
                        // lag is bounded by one. Queueing beyond that
                        // would let a flood of far-future pulls poison
                        // the pending queue forever — reject instead.
                        eprintln!(
                            "server: rejecting future pull for key {key} iteration {iter} \
                             from worker {worker} (key is at {})",
                            st.iter
                        );
                        self.stats.rejected += 1;
                        // Honest traffic cannot get here, but answer
                        // anyway — a dropped pull must never become a
                        // silent hang.
                        return vec![(from, retired_marker(key, iter))];
                    }
                    // iter == st.iter with no sealed aggregate falls
                    // through to the queue, as does iter == st.iter + 1:
                    // the puller's own push for that round may have been
                    // lost (per-connection FIFO no longer implies the
                    // key's clock reached `iter` once pushes can be
                    // dropped), and rejecting it would strand the worker
                    // forever — the deadline seal serves the queue.
                }
                // Honest traffic queues at most one pull per worker per
                // key; anything past a small multiple is a flood (pulls
                // for iterations that will never be served) — drop it
                // rather than grow the queue without bound.
                if st.pending.len() >= 2 * self.opts.n_workers.max(1) {
                    eprintln!(
                        "server: dropping pull for key {key} iteration {iter} from \
                         worker {worker}: pending queue full"
                    );
                    self.stats.stale_pulls += 1;
                    return vec![(from, retired_marker(key, iter))];
                }
                st.pending.push((iter, from));
                vec![]
            }
            Message::Shutdown => vec![],
            // Hello/Welcome/PullResp/Ack have no business arriving at a
            // running server; any client can send them, so they must never
            // panic the shard — ignore and count.
            other => {
                let tag = match other {
                    Message::Hello { .. } => "Hello",
                    Message::Welcome { .. } => "Welcome",
                    Message::PullResp { .. } => "PullResp",
                    Message::Ack { .. } => "Ack",
                    _ => "unknown",
                };
                eprintln!("server: ignoring unexpected {tag} message from worker {from}");
                self.stats.unexpected += 1;
                vec![]
            }
        }
    }

    /// Seal the current round of `key` with the contributions present:
    /// average over the pushes actually received, run the second-way
    /// compression, stash the aggregate (tagged with its `served_with`
    /// count) and answer every matching queued pull. Shared by normal BSP
    /// completion (`count == n_workers`) and the iteration deadline
    /// (`count < n_workers`, a degraded round). For a full round the
    /// averaging divisor equals `n_workers`, so the strict-BSP path is
    /// bit-identical to the pre-deadline server.
    fn seal_round(&mut self, key: Key, replies: &mut Vec<(u32, Message)>) {
        let st = self.keys.get_mut(&key).expect("sealing an unknown key");
        debug_assert!(st.ready.is_none(), "sealing an already-sealed round");
        debug_assert!(!st.contributors.is_empty(), "sealing an empty round");
        let count = st.contributors.len();
        let served = count.min(u16::MAX as usize) as u16;
        if count < self.opts.n_workers {
            eprintln!(
                "server: iteration deadline — serving key {key} iteration {} degraded \
                 ({}/{} pushes)",
                st.iter, count, self.opts.n_workers
            );
            self.stats.degraded_iters += 1;
        }
        let inv = 1.0 / count as f32;
        for a in &mut st.acc {
            *a *= inv;
        }
        let iter = st.iter;
        let t = Instant::now();
        let acc = std::mem::take(&mut st.acc);
        let p = match self.opts.sync {
            SyncMode::CompressedEf => self.ef.compress_owned(
                key,
                acc,
                self.opts.comp.as_ref(),
                &mut Ctx::with_threads(&mut self.rng, self.opts.intra_threads),
            ),
            _ => self
                .opts
                .comp
                .compress(&acc, &mut Ctx::with_threads(&mut self.rng, self.opts.intra_threads)),
        };
        self.stats.compress_s += t.elapsed().as_secs_f64();
        st.ready = Some((served, p.clone()));
        st.round_started = None;
        // The queue fully drains at every seal: matching pulls are served,
        // everything else (short-iteration leftovers, placeholder-era
        // junk) is unservable and dropped — nothing hostile can sit in
        // `pending` displacing honest pulls forever.
        let pending: Vec<(u64, u32)> = std::mem::take(&mut st.pending);
        for (piter, w) in pending {
            if piter == iter {
                replies.push((
                    w,
                    Message::PullResp { key, iter, served_with: served, data: p.clone() },
                ));
            } else {
                eprintln!(
                    "server: retiring unservable queued pull for key {key} \
                     iteration {piter} from worker {w} (key is at {iter})"
                );
                self.stats.stale_pulls += 1;
                replies.push((w, retired_marker(key, piter)));
            }
        }
    }

    /// Iteration-deadline sweep: seal every round that has at least one
    /// push, has not completed, and saw its first push at least
    /// [`ServerOptions::iter_deadline`] ago — serving pulls a *partial*
    /// aggregate marked `served_with < n_workers` instead of stalling
    /// every worker forever on a lost or rejected push. Returns the
    /// replies to send (queued pulls for the sealed iterations). No-op
    /// when the deadline is unset.
    ///
    /// `now` is an explicit argument so tests can drive the clock
    /// deterministically; the I/O loop passes `Instant::now()`.
    pub fn poll_deadlines(&mut self, now: Instant) -> Vec<(u32, Message)> {
        let Some(deadline) = self.opts.iter_deadline else {
            return Vec::new();
        };
        let mut due: Vec<Key> = self
            .keys
            .iter()
            .filter(|(_, st)| {
                !st.contributors.is_empty()
                    && st.ready.is_none()
                    && st
                        .round_started
                        .is_some_and(|t0| now.saturating_duration_since(t0) >= deadline)
            })
            .map(|(&k, _)| k)
            .collect();
        // Deterministic seal order (HashMap iteration order is not).
        due.sort_unstable();
        let mut replies = Vec::new();
        for key in due {
            self.seal_round(key, &mut replies);
        }
        replies
    }
}

/// A running server thread serving a set of worker endpoints.
pub struct Server {
    handle: Option<JoinHandle<ServerStats>>,
}

impl Server {
    /// Spawn the I/O loop: a receiver thread per worker endpoint feeding
    /// the single aggregator (the paper's servers are single-threaded per
    /// shard too; parallelism comes from having many servers/shards).
    pub fn spawn<E: Endpoint + Sync + 'static>(opts: ServerOptions, endpoints: Vec<E>) -> Server {
        let n = endpoints.len();
        let handle = std::thread::Builder::new()
            .name("bytepsc-server".into())
            .spawn(move || {
                let endpoints: Vec<Arc<E>> = endpoints.into_iter().map(Arc::new).collect();
                let (tx, rx) = std::sync::mpsc::channel::<(u32, Message)>();
                let mut recv_threads = Vec::new();
                for (i, ep) in endpoints.iter().enumerate() {
                    let ep = Arc::clone(ep);
                    let tx = tx.clone();
                    recv_threads.push(std::thread::spawn(move || loop {
                        match ep.recv() {
                            Ok(Message::Shutdown) => {
                                let _ = tx.send((i as u32, Message::Shutdown));
                                break;
                            }
                            // A corrupt frame is recoverable: recv consumed
                            // the whole length-prefixed frame before decode
                            // failed, so the stream is still frame-aligned.
                            // Drop the frame, keep the worker connected.
                            Err(CommError::Protocol(e)) => {
                                eprintln!("server: dropping corrupt frame from worker {i}: {e}");
                            }
                            Err(_) => {
                                let _ = tx.send((i as u32, Message::Shutdown));
                                break;
                            }
                            Ok(m) => {
                                if tx.send((i as u32, m)).is_err() {
                                    break;
                                }
                            }
                        }
                    }));
                }
                drop(tx);
                let mut core = ServerCore::new(opts);
                // With an iteration deadline the aggregator wakes at a
                // fraction of it to sweep for overdue rounds; without one
                // it blocks indefinitely — zero polling overhead, exactly
                // the strict-BSP loop.
                let tick = core.opts.iter_deadline.map(|d| (d / 4).max(Duration::from_millis(1)));
                let mut last_poll = Instant::now();
                let mut live = n;
                while live > 0 {
                    let received = match tick {
                        None => match rx.recv() {
                            Ok(m) => Some(m),
                            Err(_) => break,
                        },
                        Some(t) => match rx.recv_timeout(t) {
                            Ok(m) => Some(m),
                            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
                            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
                        },
                    };
                    let mut replies = Vec::new();
                    if let Some((from, msg)) = received {
                        if matches!(msg, Message::Shutdown) {
                            live -= 1;
                        } else {
                            replies = core.handle(from, msg);
                        }
                    }
                    if let Some(t) = tick {
                        // Sweep on idle ticks, and at most once per tick
                        // under a message flood (the sweep walks every
                        // key).
                        if last_poll.elapsed() >= t {
                            replies.extend(core.poll_deadlines(Instant::now()));
                            last_poll = Instant::now();
                        }
                    }
                    for (to, reply) in replies {
                        // `to` is always a connection index the core got
                        // from us, but never trust it enough to index out
                        // of bounds; a dropped worker is a shutdown in
                        // progress.
                        if let Some(ep) = endpoints.get(to as usize) {
                            let _ = ep.send(reply);
                        } else {
                            eprintln!("server: dropping reply to unknown connection {to}");
                        }
                    }
                }
                for t in recv_threads {
                    let _ = t.join();
                }
                core.stats
            })
            .expect("spawn server");
        Server { handle: Some(handle) }
    }

    /// Wait for the server to drain (workers must send Shutdown first).
    pub fn join(mut self) -> ServerStats {
        self.handle.take().unwrap().join().expect("server panicked")
    }
}

/// Key → server assignment with workload balancing (§4.2.4).
///
/// Since the block pipeline, assignment is keyed by arbitrary (packed)
/// block keys rather than dense tensor indices: use [`balanced_keyed`] /
/// [`round_robin_keyed`] for block plans. The dense-index constructors
/// remain for whole-tensor plans (a tensor id *is* its block-0 key).
///
/// [`balanced_keyed`]: ShardPlan::balanced_keyed
/// [`round_robin_keyed`]: ShardPlan::round_robin_keyed
#[derive(Clone, Debug)]
pub struct ShardPlan {
    assignment: HashMap<Key, usize>,
    servers: usize,
}

impl ShardPlan {
    /// Greedy least-loaded assignment over dense tensor-id keys
    /// `0..costs.len()`. `cost(key)` should reflect server CPU work:
    /// compressed keys cost `numel × compress_factor`, bypassed keys just
    /// `numel` (decompress-free memcpy aggregation).
    pub fn balanced(costs: &[f64], servers: usize) -> ShardPlan {
        let items: Vec<(Key, f64)> =
            costs.iter().enumerate().map(|(k, &c)| (k as Key, c)).collect();
        Self::balanced_keyed(&items, servers)
    }

    /// Greedy least-loaded assignment over explicit `(key, cost)` pairs —
    /// the pipeline's per-block plan. Deterministic: ties in cost break by
    /// key, ties in load by server index.
    pub fn balanced_keyed(items: &[(Key, f64)], servers: usize) -> ShardPlan {
        assert!(servers >= 1);
        let mut order: Vec<usize> = (0..items.len()).collect();
        order.sort_by(|a, b| {
            items[*b]
                .1
                .partial_cmp(&items[*a].1)
                .unwrap()
                .then_with(|| items[*a].0.cmp(&items[*b].0))
        });
        let mut load = vec![0.0f64; servers];
        let mut assignment = HashMap::with_capacity(items.len());
        for i in order {
            let (key, cost) = items[i];
            let s = (0..servers).min_by(|a, b| load[*a].partial_cmp(&load[*b]).unwrap()).unwrap();
            assignment.insert(key, s);
            load[s] += cost;
        }
        ShardPlan { assignment, servers }
    }

    /// Naive round-robin over dense tensor-id keys (the ablation's "no
    /// workload balance" arm).
    pub fn round_robin(keys: usize, servers: usize) -> ShardPlan {
        let keys: Vec<Key> = (0..keys as u64).collect();
        Self::round_robin_keyed(&keys, servers)
    }

    /// Round-robin over explicit keys, in the order given.
    pub fn round_robin_keyed(keys: &[Key], servers: usize) -> ShardPlan {
        assert!(servers >= 1);
        let assignment = keys.iter().enumerate().map(|(i, &k)| (k, i % servers)).collect();
        ShardPlan { assignment, servers }
    }

    /// Rebuild a plan from explicit `(key, server)` pairs — the form the
    /// cluster handshake ships in [`crate::comm::Message::Welcome`].
    /// Assignments pointing past `servers` are rejected (untrusted input).
    pub fn from_assignments(entries: &[(Key, u32)], servers: usize) -> Result<ShardPlan, String> {
        if servers == 0 {
            return Err("shard plan needs at least one server".into());
        }
        let mut assignment = HashMap::with_capacity(entries.len());
        for &(key, s) in entries {
            if s as usize >= servers {
                return Err(format!("key {key} assigned to server {s} of {servers}"));
            }
            if assignment.insert(key, s as usize).is_some() {
                return Err(format!("key {key} assigned twice"));
            }
        }
        Ok(ShardPlan { assignment, servers })
    }

    /// Export the plan as `(key, server)` pairs, sorted by key so two
    /// plans can be compared structurally (workers cross-check that every
    /// server shard handed them the same plan).
    pub fn assignments(&self) -> Vec<(Key, u32)> {
        let mut out: Vec<(Key, u32)> =
            self.assignment.iter().map(|(&k, &s)| (k, s as u32)).collect();
        out.sort_unstable_by_key(|&(k, _)| k);
        out
    }

    /// Number of servers this plan shards across.
    pub fn servers(&self) -> usize {
        self.servers
    }

    /// Number of keys in the plan.
    pub fn len(&self) -> usize {
        self.assignment.len()
    }

    pub fn is_empty(&self) -> bool {
        self.assignment.is_empty()
    }

    /// Whether `key` has an assignment (cluster workers verify the plan
    /// they received covers their whole partition before trusting it).
    pub fn contains(&self, key: Key) -> bool {
        self.assignment.contains_key(&key)
    }

    pub fn server_of(&self, key: Key) -> usize {
        *self.assignment.get(&key).unwrap_or_else(|| {
            let bk = BlockKey::unpack(key);
            panic!("key {key} (tensor {}, block {}) not in the shard plan", bk.tensor, bk.block)
        })
    }

    /// Max/mean load ratio (1.0 = perfectly balanced), with per-key costs
    /// supplied by `cost_of`.
    pub fn imbalance_by<F: Fn(Key) -> f64>(&self, cost_of: F) -> f64 {
        let mut load = vec![0.0f64; self.servers];
        for (&k, &s) in &self.assignment {
            load[s] += cost_of(k);
        }
        let max = load.iter().cloned().fold(0.0f64, f64::max);
        let mean = load.iter().sum::<f64>() / self.servers.max(1) as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Max/mean load ratio for dense tensor-id plans (`key` indexes
    /// `costs`).
    pub fn imbalance(&self, costs: &[f64]) -> f64 {
        self.imbalance_by(|k| costs[k as usize])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::by_name;

    fn opts(scheme: &str, sync: SyncMode, workers: usize) -> ServerOptions {
        ServerOptions {
            comp: by_name(scheme, 0.25).unwrap(),
            sync,
            fused: true,
            n_workers: workers,
            intra_threads: 1,
            seed: 7,
            max_keys: 0,
            iter_deadline: None,
        }
    }

    /// Same, with an iteration deadline. Tests drive `poll_deadlines`
    /// with explicit clocks, so the duration's magnitude is irrelevant.
    fn opts_deadline(scheme: &str, sync: SyncMode, workers: usize) -> ServerOptions {
        ServerOptions {
            iter_deadline: Some(std::time::Duration::from_millis(50)),
            ..opts(scheme, sync, workers)
        }
    }

    /// A clock strictly past every configured test deadline.
    fn after_deadline() -> Instant {
        Instant::now() + std::time::Duration::from_secs(3600)
    }

    fn push(core: &mut ServerCore, key: Key, iter: u64, worker: u32, g: &[f32]) -> Vec<(u32, Message)> {
        let mut rng = Xoshiro256::seed_from_u64(worker as u64 + 100);
        let data = core.opts.comp.compress(g, &mut Ctx::new(&mut rng));
        core.handle(worker, Message::Push { key, iter, worker, data })
    }

    #[test]
    fn aggregates_identity_to_exact_mean() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        let r1 = push(&mut core, 0, 0, 0, &[1.0, 2.0]);
        assert_eq!(r1.len(), 1); // just the ack
        let r2 = push(&mut core, 0, 0, 1, &[3.0, 6.0]);
        assert_eq!(r2.len(), 1);
        // Now pull
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        let Message::PullResp { data, .. } = &r[0].1 else { panic!() };
        let mut out = vec![0.0f32; 2];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![2.0, 4.0]);
    }

    #[test]
    fn pull_before_complete_is_queued() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        push(&mut core, 5, 0, 0, &[1.0]);
        let r = core.handle(1, Message::Pull { key: 5, iter: 0, worker: 1 });
        assert!(r.is_empty()); // queued
        let r = push(&mut core, 5, 0, 1, &[3.0]);
        // ack + the queued pull's response
        assert_eq!(r.len(), 2);
        assert!(matches!(r[1].1, Message::PullResp { .. }));
        assert_eq!(r[1].0, 1);
    }

    #[test]
    fn iterations_reset_accumulator() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 1));
        push(&mut core, 0, 0, 0, &[10.0]);
        push(&mut core, 0, 1, 0, &[2.0]);
        let r = core.handle(0, Message::Pull { key: 0, iter: 1, worker: 0 });
        let Message::PullResp { data, .. } = &r[0].1 else { panic!() };
        let mut out = vec![0.0f32; 1];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![2.0]); // not 12.0
    }

    #[test]
    fn server_ef_residual_accumulates_under_topk() {
        // Two workers with different dominant coordinates: the server's
        // second-way top-k can keep only one of them per round; ẽ must
        // carry the other forward and flush it on a later round
        // (Alg. 4's server side). Uses dim=8 so topk(0.25) keeps 2 of 8 —
        // workers' spikes at idx 0 and idx 1, aggregate keeps both unless
        // the residual game forces deferral; use k=1 via dim=4.
        let mut core = ServerCore::new(opts("topk", SyncMode::CompressedEf, 2));
        let ga = vec![1.0f32, 0.0, 0.0, 0.0]; // worker 0's spike
        let gb = vec![0.0f32, 0.9, 0.0, 0.0]; // worker 1's spike
        let mut seen_idx1 = false;
        for iter in 0..10u64 {
            push(&mut core, 0, iter, 0, &ga);
            push(&mut core, 0, iter, 1, &gb);
            let r = core.handle(0, Message::Pull { key: 0, iter, worker: 0 });
            let Message::PullResp { data, .. } = &r[0].1 else { panic!() };
            let mut p = vec![0.0f32; 4];
            core.opts.comp.decompress(data, &mut p);
            if iter == 0 {
                // Round 0: Δ = [0.5, 0.45, 0, 0]; top-1 keeps idx 0 only.
                assert_eq!(p, vec![0.5, 0.0, 0.0, 0.0]);
            }
            if p[1] > 0.0 {
                seen_idx1 = true;
            }
        }
        // Round 1: Δ = [0.5, 0.45 + 0.45(ẽ), 0, 0] → idx 1 wins and flushes.
        assert!(seen_idx1, "server EF never flushed the deferred coordinate");
    }

    /// Regression (deadlock found in CI): a fast worker may push iteration
    /// i+1 — rolling the key over — before a slow worker pulls iteration i.
    /// The retired aggregate must still be servable.
    #[test]
    fn late_pull_after_rollover_is_served() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        push(&mut core, 0, 0, 0, &[2.0]);
        push(&mut core, 0, 0, 1, &[4.0]); // iter 0 completes: mean = 3.0
        // Fast worker 0 pulls iter 0 and immediately pushes iter 1.
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        assert!(matches!(r[0].1, Message::PullResp { .. }));
        push(&mut core, 0, 1, 0, &[10.0]);
        // Slow worker 1 now pulls iter 0 — must be served from the retired
        // slot, not panic or hang.
        let r = core.handle(1, Message::Pull { key: 0, iter: 0, worker: 1 });
        assert_eq!(r.len(), 1);
        let Message::PullResp { iter, data, .. } = &r[0].1 else { panic!() };
        assert_eq!(*iter, 0);
        let mut out = vec![0.0f32; 1];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![3.0]);
        // And worker 1 proceeding to iter 1 still works.
        push(&mut core, 0, 1, 1, &[20.0]);
        let r = core.handle(1, Message::Pull { key: 0, iter: 1, worker: 1 });
        let Message::PullResp { data, .. } = &r[0].1 else { panic!() };
        let mut out = vec![0.0f32; 1];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![15.0]);
    }

    /// A pull that arrives before its iteration completes, while a previous
    /// iteration is retired, must queue (not be served stale data).
    #[test]
    fn pending_pull_for_future_iter_waits() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        push(&mut core, 0, 0, 0, &[1.0]);
        push(&mut core, 0, 0, 1, &[3.0]);
        let _ = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        push(&mut core, 0, 1, 0, &[5.0]);
        // worker 0 pulls iter 1 before worker 1 pushed it: queued.
        let r = core.handle(0, Message::Pull { key: 0, iter: 1, worker: 0 });
        assert!(r.is_empty());
        // worker 1 completes iter 1: the queued pull is answered with iter-1
        // data (not the retired iter-0 aggregate).
        let r = push(&mut core, 0, 1, 1, &[7.0]);
        let resp = r.iter().find(|(w, m)| *w == 0 && matches!(m, Message::PullResp { .. }));
        let Some((_, Message::PullResp { iter, data, .. })) = resp else { panic!("no resp") };
        assert_eq!(*iter, 1);
        let mut out = vec![0.0f32; 1];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![6.0]);
    }

    #[test]
    fn threaded_server_roundtrip_over_inproc() {
        let workers = 3;
        let dim = 64;
        let mut worker_eps = Vec::new();
        let mut server_eps = Vec::new();
        for _ in 0..workers {
            let (w, s) = crate::comm::inproc::pair();
            worker_eps.push(w);
            server_eps.push(s);
        }
        let server = Server::spawn(opts("identity", SyncMode::Full, workers), server_eps);
        let handles: Vec<_> = worker_eps
            .into_iter()
            .enumerate()
            .map(|(w, ep)| {
                std::thread::spawn(move || {
                    let comp = by_name("identity", 0.0).unwrap();
                    let mut rng = Xoshiro256::seed_from_u64(w as u64);
                    let g: Vec<f32> = (0..dim).map(|i| (w * dim + i) as f32).collect();
                    for iter in 0..5u64 {
                        let data = comp.compress(&g, &mut Ctx::new(&mut rng));
                        ep.send(Message::Push { key: 0, iter, worker: w as u32, data }).unwrap();
                        // ack may arrive before or after we pull; consume both.
                        ep.send(Message::Pull { key: 0, iter, worker: w as u32 }).unwrap();
                        let mut got_resp = None;
                        while got_resp.is_none() {
                            match ep.recv().unwrap() {
                                Message::Ack { .. } => {}
                                Message::PullResp { data, .. } => got_resp = Some(data),
                                m => panic!("unexpected {m:?}"),
                            }
                        }
                        let mut out = vec![0.0f32; dim];
                        comp.decompress(&got_resp.unwrap(), &mut out);
                        // mean over workers of (w*dim + i)
                        for (i, v) in out.iter().enumerate() {
                            let expect = (0..workers).map(|ww| (ww * dim + i) as f32).sum::<f32>()
                                / workers as f32;
                            assert!((v - expect).abs() < 1e-4);
                        }
                    }
                    ep.send(Message::Shutdown).unwrap();
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let stats = server.join();
        assert_eq!(stats.pushes, 15);
    }

    #[test]
    fn shard_plan_balances_better_than_round_robin() {
        // One huge tensor + many small ones (a transformer's shape).
        let mut costs = vec![1000.0];
        costs.extend(std::iter::repeat(10.0).take(40));
        let bal = ShardPlan::balanced(&costs, 4);
        let rr = ShardPlan::round_robin(costs.len(), 4);
        assert!(bal.imbalance(&costs) <= rr.imbalance(&costs));
        // balanced puts the huge tensor alone-ish: its server gets few others
        let big_server = bal.server_of(0);
        let others = (1..costs.len()).filter(|&k| bal.server_of(k as Key) == big_server).count();
        assert!(others <= 5, "{others} small tensors share the big server");
    }

    #[test]
    fn shard_plan_covers_all_servers() {
        let costs = vec![1.0; 16];
        let plan = ShardPlan::balanced(&costs, 4);
        for s in 0..4 {
            assert!((0..16).any(|k| plan.server_of(k as Key) == s));
        }
        assert!((plan.imbalance(&costs) - 1.0).abs() < 1e-9);
    }

    /// Per-block sharding (§4.2.4 under the pipeline): one huge tensor's
    /// blocks spread over every server instead of pinning one shard.
    #[test]
    fn keyed_plan_spreads_blocks_of_one_tensor() {
        // Tensor 0: 8 blocks of cost 100; tensors 1..5: one block each.
        let mut items: Vec<(Key, f64)> =
            (0..8).map(|b| (BlockKey::new(0, b).pack(), 100.0)).collect();
        for t in 1..5u64 {
            items.push((BlockKey::new(t, 0).pack(), 10.0));
        }
        let plan = ShardPlan::balanced_keyed(&items, 4);
        assert_eq!(plan.len(), items.len());
        let servers_of_big: std::collections::HashSet<usize> =
            (0..8).map(|b| plan.server_of(BlockKey::new(0, b).pack())).collect();
        assert_eq!(servers_of_big.len(), 4, "big tensor's blocks should span all servers");
        // Deterministic: same inputs, same plan.
        let plan2 = ShardPlan::balanced_keyed(&items, 4);
        for &(k, _) in &items {
            assert_eq!(plan.server_of(k), plan2.server_of(k));
        }
        let imb = plan.imbalance_by(|k| {
            items.iter().find(|(key, _)| *key == k).map(|(_, c)| *c).unwrap()
        });
        let rr = ShardPlan::round_robin_keyed(
            &items.iter().map(|(k, _)| *k).collect::<Vec<_>>(),
            4,
        );
        let rr_imb = rr.imbalance_by(|k| {
            items.iter().find(|(key, _)| *key == k).map(|(_, c)| *c).unwrap()
        });
        assert!(imb <= rr_imb + 1e-9);
    }

    #[test]
    #[should_panic(expected = "not in the shard plan")]
    fn unknown_key_panics_with_context() {
        let plan = ShardPlan::balanced(&[1.0, 2.0], 2);
        let _ = plan.server_of(BlockKey::new(7, 3).pack());
    }

    /// Corrupt push blocks are dropped at ingress, counted, and never panic
    /// the aggregator.
    #[test]
    fn corrupt_push_is_rejected_not_fatal() {
        let mut core = ServerCore::new(opts("topk", SyncMode::CompressedEf, 1));
        let mut payload = Vec::new();
        payload.extend_from_slice(&1u32.to_le_bytes());
        payload.extend_from_slice(&500u32.to_le_bytes()); // index >= n
        payload.extend_from_slice(&1.0f32.to_le_bytes());
        let bad = crate::compress::Compressed {
            scheme: crate::compress::SchemeId::TopK,
            n: 4,
            payload,
        };
        let replies =
            core.handle(0, Message::Push { key: 0, iter: 0, worker: 0, data: bad });
        assert!(replies.is_empty());
        assert_eq!(core.stats.rejected, 1);
        assert_eq!(core.stats.pushes, 0);
        // A valid push afterwards still works.
        let r = push(&mut core, 0, 0, 0, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(r.len(), 1);
        assert_eq!(core.stats.pushes, 1);
    }

    /// Regression (server panic on untrusted input): a rejected corrupt
    /// push leaves `count` short; the next iteration's rollover used to
    /// assert the aggregator down. It must recover — count the short
    /// iteration, discard the partial sum, and keep serving.
    #[test]
    fn short_iteration_after_corrupt_push_recovers() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        // Worker 0's push for iter 0 is corrupt (wrong element count after
        // the key is established) and gets rejected.
        push(&mut core, 0, 0, 1, &[1.0, 2.0]);
        let bad = crate::compress::Compressed {
            scheme: crate::compress::SchemeId::Identity,
            n: 1,
            payload: vec![0u8; 4],
        };
        let r = core.handle(0, Message::Push { key: 0, iter: 0, worker: 0, data: bad });
        assert!(r.is_empty());
        assert_eq!(core.stats.rejected, 1);
        // Iteration 0 is now permanently short (count == 1 of 2). Both
        // workers move on to iteration 1 — this used to panic.
        push(&mut core, 0, 1, 0, &[10.0, 20.0]);
        let r = push(&mut core, 0, 1, 1, &[30.0, 40.0]);
        assert!(!r.is_empty());
        assert_eq!(core.stats.short_iters, 1);
        // Iteration 1 completes and serves normally.
        let r = core.handle(0, Message::Pull { key: 0, iter: 1, worker: 0 });
        let Message::PullResp { data, .. } = &r[0].1 else { panic!("no resp: {r:?}") };
        let mut out = vec![0.0f32; 2];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![20.0, 30.0]);
    }

    /// Regression (server panic on untrusted input): a pull for a key with
    /// no prior push used to hit `.expect("pull before any push")`. It must
    /// queue and be served once the key appears.
    #[test]
    fn pull_before_any_push_queues_and_serves() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        let r = core.handle(1, Message::Pull { key: 7, iter: 0, worker: 1 });
        assert!(r.is_empty(), "queued, not panicked");
        assert_eq!(core.stats.early_pulls, 1);
        push(&mut core, 7, 0, 0, &[2.0]);
        let r = push(&mut core, 7, 0, 1, &[4.0]);
        // ack + the queued pull's response
        let resp = r.iter().find(|(w, m)| *w == 1 && matches!(m, Message::PullResp { .. }));
        let Some((_, Message::PullResp { data, .. })) = resp else { panic!("no resp: {r:?}") };
        let mut out = vec![0.0f32; 1];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![3.0]);
        // And the other worker's pull works as before.
        let r = core.handle(0, Message::Pull { key: 7, iter: 0, worker: 0 });
        assert!(matches!(r[0].1, Message::PullResp { .. }));
    }

    /// A pull whose iteration is older than the one-slot history is dropped
    /// and counted, never an assert.
    #[test]
    fn ancient_pull_is_counted_not_fatal() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 1));
        for iter in 0..4u64 {
            push(&mut core, 0, iter, 0, &[iter as f32]);
        }
        // Key is at iter 3; prev holds iter 2. A pull for iter 0 is stale
        // and answered with the retired marker (served_with == 0, empty
        // block) so the puller can fail loudly instead of hanging.
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        assert_eq!(r.len(), 1);
        let Message::PullResp { iter, served_with, data, .. } = &r[0].1 else { panic!("{r:?}") };
        assert_eq!((*iter, *served_with, data.n), (0, 0, 0));
        assert_eq!(core.stats.stale_pulls, 1);
        // Current iteration still serves.
        let r = core.handle(0, Message::Pull { key: 0, iter: 3, worker: 0 });
        assert!(matches!(r[0].1, Message::PullResp { .. }));
    }

    /// Handshake/reply messages leaking into a running server are ignored
    /// and counted, never a panic.
    #[test]
    fn unexpected_messages_are_counted_not_fatal() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 1));
        let r = core.handle(0, Message::Hello { worker: 0, n_keys: 3, config: 0 });
        assert!(r.is_empty());
        let r = core.handle(0, Message::Ack { key: 0, iter: 0 });
        assert!(r.is_empty());
        assert_eq!(core.stats.unexpected, 2);
        // Still fully functional afterwards.
        push(&mut core, 0, 0, 0, &[5.0]);
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        assert!(matches!(r[0].1, Message::PullResp { .. }));
    }

    /// A stale push (older than the key's current iteration) is rejected,
    /// not allowed to roll the key's clock backwards.
    #[test]
    fn backwards_push_is_rejected() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 1));
        push(&mut core, 0, 5, 0, &[1.0]);
        let r = push(&mut core, 0, 2, 0, &[9.0]);
        assert!(r.is_empty());
        assert_eq!(core.stats.rejected, 1);
        // The key still serves iteration 5.
        let r = core.handle(0, Message::Pull { key: 0, iter: 5, worker: 0 });
        assert!(matches!(r[0].1, Message::PullResp { .. }));
    }

    /// Replies route by the connection a message arrived on, never by the
    /// wire-supplied `worker` field — a spoofed (or out-of-range) id
    /// cannot steer replies to another worker or index the endpoint table
    /// out of bounds.
    #[test]
    fn replies_route_by_connection_not_wire_field() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        let mut rng = Xoshiro256::seed_from_u64(1);
        let data = core.opts.comp.compress(&[4.0, 6.0], &mut Ctx::new(&mut rng));
        // Connection 0 claims to be worker 999: ack still goes to 0.
        let r = core.handle(0, Message::Push { key: 0, iter: 0, worker: 999, data });
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].0, 0);
        assert!(matches!(r[0].1, Message::Ack { .. }));
        // A queued pull is answered on the connection it arrived on, not
        // at the spoofed id.
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 12345 });
        assert!(r.is_empty()); // queued: iteration incomplete
        let mut rng = Xoshiro256::seed_from_u64(2);
        let data = core.opts.comp.compress(&[1.0, 2.0], &mut Ctx::new(&mut rng));
        let r = core.handle(1, Message::Push { key: 0, iter: 0, worker: 42, data });
        assert!(r.iter().any(|(to, m)| *to == 1 && matches!(m, Message::Ack { .. })), "{r:?}");
        assert!(
            r.iter().any(|(to, m)| *to == 0 && matches!(m, Message::PullResp { .. })),
            "{r:?}"
        );
    }

    /// A client inventing keys cannot grow server memory without bound:
    /// pushes past `max_keys` established keys are rejected, pull-created
    /// placeholders have their own equal budget, and junk placeholders
    /// never starve traffic for real (established) keys.
    #[test]
    fn hostile_key_flood_is_bounded() {
        let mut o = opts("identity", SyncMode::Full, 1);
        o.max_keys = 2;
        let mut core = ServerCore::new(o);
        push(&mut core, 0, 0, 0, &[1.0]);
        push(&mut core, 1, 0, 0, &[2.0]);
        // Established keys at cap: a push for a third key bounces.
        let r = push(&mut core, 2, 0, 0, &[3.0]);
        assert!(r.is_empty());
        assert_eq!(core.stats.rejected, 1);
        // Pull-created placeholders have their own equal budget…
        assert!(core.handle(0, Message::Pull { key: 10, iter: 0, worker: 0 }).is_empty());
        assert!(core.handle(0, Message::Pull { key: 11, iter: 0, worker: 0 }).is_empty());
        // …beyond which junk-key pulls bounce with the retired marker…
        let r = core.handle(0, Message::Pull { key: 12, iter: 0, worker: 0 });
        assert_eq!(r.len(), 1);
        assert!(matches!(r[0].1, Message::PullResp { served_with: 0, .. }), "{r:?}");
        assert_eq!(core.stats.rejected, 2);
        // …and junk placeholders never block established keys.
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        assert!(matches!(r[0].1, Message::PullResp { .. }));
        let r = push(&mut core, 1, 1, 0, &[5.0]);
        assert!(!r.is_empty());
    }

    /// Hostile pulls cannot poison a key's pending queue: future-iteration
    /// pulls on established keys are rejected outright (honest traffic
    /// can never produce them — per-connection FIFO processes a worker's
    /// push before its pull), placeholder floods hit the pending cap, and
    /// the queue fully drains at every completion.
    #[test]
    fn pull_flood_on_one_key_is_bounded() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 1));
        push(&mut core, 0, 0, 0, &[1.0]);
        for _ in 0..5 {
            // Far-future pulls are rejected — answered with the retired
            // marker, never a silent drop.
            let r = core.handle(0, Message::Pull { key: 0, iter: 99, worker: 0 });
            assert_eq!(r.len(), 1);
            let Message::PullResp { served_with, .. } = &r[0].1 else { panic!("{r:?}") };
            assert_eq!(*served_with, 0);
        }
        assert_eq!(core.stats.rejected, 5);
        // Placeholder floods: pending cap is 2 * n_workers = 2, so of five
        // queue attempts three are dropped (marker-answered).
        for i in 0..5u64 {
            let r = core.handle(0, Message::Pull { key: 7, iter: i, worker: 0 });
            if i < 2 {
                assert!(r.is_empty(), "pull {i} should queue: {r:?}");
            } else {
                assert_eq!(r.len(), 1, "pull {i} should bounce with a marker: {r:?}");
            }
        }
        assert_eq!(core.stats.stale_pulls, 3);
        // Establishing key 7 at iteration 0 serves the matching queued
        // pull and drains the junk one with a retired marker — nothing
        // lingers, nothing is silently dropped.
        let r = push(&mut core, 7, 0, 0, &[1.0]);
        assert_eq!(r.len(), 3, "ack + served iter-0 pull + retired iter-1 marker: {r:?}");
        assert!(r
            .iter()
            .any(|(_, m)| matches!(m, Message::PullResp { served_with: 1.., .. })));
        assert!(r
            .iter()
            .any(|(_, m)| matches!(m, Message::PullResp { served_with: 0, .. })));
        assert_eq!(core.stats.stale_pulls, 4);
        // The original key still serves its real iteration.
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        assert!(matches!(r[0].1, Message::PullResp { .. }));
    }

    #[test]
    fn shard_plan_assignments_roundtrip() {
        let plan = ShardPlan::balanced(&[5.0, 1.0, 3.0, 2.0], 3);
        let wire = plan.assignments();
        let back = ShardPlan::from_assignments(&wire, 3).unwrap();
        for k in 0..4u64 {
            assert_eq!(plan.server_of(k), back.server_of(k));
        }
        assert_eq!(back.assignments(), wire);
        // Untrusted input: out-of-range server and duplicate keys rejected.
        assert!(ShardPlan::from_assignments(&[(0, 3)], 3).is_err());
        assert!(ShardPlan::from_assignments(&[(0, 0), (0, 1)], 2).is_err());
        assert!(ShardPlan::from_assignments(&[], 0).is_err());
    }

    /// A *self-consistent* corrupt frame whose n disagrees with the key's
    /// established size must be rejected at ingress, not resize or panic
    /// the accumulator.
    #[test]
    fn push_with_wrong_element_count_is_rejected() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        push(&mut core, 0, 0, 0, &[1.0, 2.0, 3.0, 4.0]); // key 0 is 4 elems
        // Internally-consistent identity block with only 2 elements.
        let bad = crate::compress::Compressed {
            scheme: crate::compress::SchemeId::Identity,
            n: 2,
            payload: vec![0u8; 8],
        };
        let r = core.handle(1, Message::Push { key: 0, iter: 0, worker: 1, data: bad });
        assert!(r.is_empty());
        assert_eq!(core.stats.rejected, 1);
        // The honest worker can still complete the iteration.
        let r = push(&mut core, 0, 0, 1, &[5.0, 6.0, 7.0, 8.0]);
        assert_eq!(r.len(), 1);
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        let Message::PullResp { data, .. } = &r[0].1 else { panic!() };
        let mut out = vec![0.0f32; 4];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![3.0, 4.0, 5.0, 6.0]);
    }

    /// The iteration deadline seals a round that has at least one push:
    /// the partial aggregate (averaged over the pushes received) is served
    /// with `served_with < n_workers`, and a full round still reports
    /// `served_with == n_workers`.
    #[test]
    fn deadline_seals_partial_round_and_serves_degraded() {
        let mut core = ServerCore::new(opts_deadline("identity", SyncMode::Full, 2));
        push(&mut core, 0, 0, 0, &[2.0, 4.0]);
        // Worker 1 pulls before its (lost) push completed the round: queued.
        let r = core.handle(1, Message::Pull { key: 0, iter: 0, worker: 1 });
        assert!(r.is_empty());
        let replies = core.poll_deadlines(after_deadline());
        assert_eq!(replies.len(), 1, "the queued pull must be answered: {replies:?}");
        let (to, Message::PullResp { iter, served_with, data, .. }) = &replies[0] else {
            panic!("not a PullResp: {replies:?}")
        };
        assert_eq!((*to, *iter, *served_with), (1, 0, 1));
        let mut out = vec![0.0f32; 2];
        core.opts.comp.decompress(data, &mut out);
        // Averaged over the one contribution received, not n_workers.
        assert_eq!(out, vec![2.0, 4.0]);
        assert_eq!(core.stats.degraded_iters, 1);
        assert_eq!(core.stats.short_iters, 0);
        // A later pull for the sealed iteration is served the same bytes.
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        let Message::PullResp { served_with, .. } = &r[0].1 else { panic!("{r:?}") };
        assert_eq!(*served_with, 1);
    }

    /// With no deadline configured, `poll_deadlines` is a strict no-op —
    /// the incomplete round keeps waiting (strict BSP).
    #[test]
    fn deadline_unset_poll_is_noop() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        push(&mut core, 0, 0, 0, &[1.0]);
        assert!(core.poll_deadlines(after_deadline()).is_empty());
        assert_eq!(core.stats.degraded_iters, 0);
        // The pull still queues rather than being served partial.
        let r = core.handle(1, Message::Pull { key: 0, iter: 0, worker: 1 });
        assert!(r.is_empty());
    }

    /// A round sealed by the deadline must not be counted *again* as a
    /// short iteration when the key rolls over, and the next iteration
    /// completes as a normal full round.
    #[test]
    fn deadline_does_not_double_count_short_iters() {
        let mut core = ServerCore::new(opts_deadline("identity", SyncMode::Full, 2));
        push(&mut core, 0, 0, 0, &[2.0]);
        assert!(core.poll_deadlines(after_deadline()).is_empty()); // nothing queued
        assert_eq!(core.stats.degraded_iters, 1);
        // Both workers proceed to iteration 1; the rollover must not see a
        // "short" round — the partial was served, not lost.
        push(&mut core, 0, 1, 0, &[10.0]);
        let r = push(&mut core, 0, 1, 1, &[20.0]);
        assert!(!r.is_empty());
        assert_eq!(core.stats.short_iters, 0);
        assert_eq!(core.stats.degraded_iters, 1);
        let r = core.handle(0, Message::Pull { key: 0, iter: 1, worker: 0 });
        let Message::PullResp { served_with, data, .. } = &r[0].1 else { panic!("{r:?}") };
        assert_eq!(*served_with, 2);
        let mut out = vec![0.0f32; 1];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![15.0]);
    }

    /// A push rejected before the deadline fired stays rejected: when the
    /// same worker re-sends a now-valid push for the sealed round, it is
    /// dropped as late (`late_pushes`) — the aggregate other workers may
    /// already hold never changes retroactively.
    #[test]
    fn deadline_does_not_resurrect_rejected_push() {
        let mut core = ServerCore::new(opts_deadline("identity", SyncMode::Full, 2));
        push(&mut core, 0, 0, 0, &[6.0, 8.0]);
        // Worker 1's push is corrupt (wrong element count) and rejected.
        let bad = crate::compress::Compressed {
            scheme: crate::compress::SchemeId::Identity,
            n: 1,
            payload: vec![0u8; 4],
        };
        let r = core.handle(1, Message::Push { key: 0, iter: 0, worker: 1, data: bad });
        assert!(r.is_empty());
        assert_eq!(core.stats.rejected, 1);
        // Deadline fires: round sealed with worker 0's contribution only.
        core.poll_deadlines(after_deadline());
        assert_eq!(core.stats.degraded_iters, 1);
        // Worker 1 retries with a valid push for the sealed iteration: no
        // ack, counted late, aggregate untouched.
        let r = push(&mut core, 0, 0, 1, &[100.0, 200.0]);
        assert!(r.is_empty(), "late push must not be acked: {r:?}");
        assert_eq!(core.stats.late_pushes, 1);
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        let Message::PullResp { served_with, data, .. } = &r[0].1 else { panic!("{r:?}") };
        assert_eq!(*served_with, 1);
        let mut out = vec![0.0f32; 2];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![6.0, 8.0]);
        // And a second sweep never re-seals the same round.
        assert!(core.poll_deadlines(after_deadline()).is_empty());
        assert_eq!(core.stats.degraded_iters, 1);
    }

    /// A degraded aggregate retires into the one-slot history like any
    /// other: a slow worker pulling the sealed iteration after a rollover
    /// still gets the partial aggregate with its `served_with` tag.
    #[test]
    fn degraded_aggregate_survives_rollover() {
        let mut core = ServerCore::new(opts_deadline("identity", SyncMode::Full, 2));
        push(&mut core, 0, 0, 0, &[4.0]);
        core.poll_deadlines(after_deadline());
        assert_eq!(core.stats.degraded_iters, 1);
        // The fast worker moves on, rolling the key over.
        push(&mut core, 0, 1, 0, &[10.0]);
        let r = core.handle(1, Message::Pull { key: 0, iter: 0, worker: 1 });
        let Message::PullResp { iter, served_with, data, .. } = &r[0].1 else {
            panic!("{r:?}")
        };
        assert_eq!((*iter, *served_with), (0, 1));
        let mut out = vec![0.0f32; 1];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![4.0]);
        assert_eq!(core.stats.short_iters, 0);
        // The straggler whose push finally lands after the rollover is
        // counted as a *late* push (the tolerated event), not rejected
        // (the corruption counter) — and still changes nothing.
        let r = push(&mut core, 0, 0, 1, &[99.0]);
        assert!(r.is_empty());
        assert_eq!(core.stats.late_pushes, 1);
        assert_eq!(core.stats.rejected, 0);
        let r = core.handle(1, Message::Pull { key: 0, iter: 0, worker: 1 });
        let Message::PullResp { served_with, .. } = &r[0].1 else { panic!("{r:?}") };
        assert_eq!(*served_with, 1);
    }

    /// The deadline never seals empty rounds or pull-created placeholders
    /// (`early_pulls` keys with no dimension), and the placeholder budget
    /// is unaffected by the sweep: the queued pull is still answered by
    /// the establishing push, not by the timer.
    #[test]
    fn deadline_ignores_placeholders_and_empty_rounds() {
        let mut o = opts_deadline("identity", SyncMode::Full, 2);
        o.max_keys = 2;
        let mut core = ServerCore::new(o);
        // Pull for a key no push has established: a budgeted placeholder.
        let r = core.handle(1, Message::Pull { key: 9, iter: 0, worker: 1 });
        assert!(r.is_empty());
        assert_eq!(core.stats.early_pulls, 1);
        // The sweep must not seal (or panic on) the dimension-less
        // placeholder, nor a fully-idle established key.
        assert!(core.poll_deadlines(after_deadline()).is_empty());
        assert_eq!(core.stats.degraded_iters, 0);
        // The placeholder still works once pushes establish it.
        push(&mut core, 9, 0, 0, &[1.0]);
        let r = push(&mut core, 9, 0, 1, &[3.0]);
        assert!(
            r.iter().any(|(w, m)| *w == 1 && matches!(m, Message::PullResp { .. })),
            "queued early pull unanswered: {r:?}"
        );
        // And the placeholder budget is still enforced after a sweep
        // (over-budget pulls bounce with the retired marker).
        assert!(core.handle(0, Message::Pull { key: 20, iter: 0, worker: 0 }).is_empty());
        assert!(core.handle(0, Message::Pull { key: 21, iter: 0, worker: 0 }).is_empty());
        let before = core.stats.rejected;
        let r = core.handle(0, Message::Pull { key: 22, iter: 0, worker: 0 });
        assert!(matches!(r[0].1, Message::PullResp { served_with: 0, .. }), "{r:?}");
        assert_eq!(core.stats.rejected, before + 1, "placeholder budget must still cap");
    }

    /// A worker that stalls ~2 deadlines while the deadline advances the
    /// key clock past it gets the retired marker (`served_with == 0`,
    /// empty block) for its late pull — never a silent drop that would
    /// hang it forever (strict BSP made this state unreachable; the
    /// deadline does not).
    #[test]
    fn deadline_lagged_worker_gets_retired_marker() {
        let mut core = ServerCore::new(opts_deadline("identity", SyncMode::Full, 2));
        // Round 0 completes fully; worker 1 then stalls before pulling.
        push(&mut core, 0, 0, 0, &[1.0]);
        push(&mut core, 0, 0, 1, &[3.0]);
        // Worker 0 pulls 0 and pushes 1; the deadline seals round 1
        // degraded; worker 0 pulls 1 and pushes 2 — evicting round 0
        // from the one-slot history.
        let _ = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        push(&mut core, 0, 1, 0, &[5.0]);
        core.poll_deadlines(after_deadline());
        let _ = core.handle(0, Message::Pull { key: 0, iter: 1, worker: 0 });
        push(&mut core, 0, 2, 0, &[7.0]);
        // Worker 1 finally asks for round 0 — two behind the clock.
        let r = core.handle(1, Message::Pull { key: 0, iter: 0, worker: 1 });
        assert_eq!(r.len(), 1);
        let Message::PullResp { iter, served_with, data, .. } = &r[0].1 else {
            panic!("{r:?}")
        };
        assert_eq!((*iter, *served_with, data.n), (0, 0, 0));
        assert_eq!(core.stats.stale_pulls, 1);
    }

    /// A duplicate push from one *connection* for an open round must not
    /// complete the round early with that worker double-counted — the
    /// `served_with` tag would lie about how many workers the aggregate
    /// holds. The connection index is the identity; the wire `worker`
    /// field is untrusted.
    #[test]
    fn duplicate_push_from_same_connection_is_rejected() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        push(&mut core, 0, 0, 0, &[4.0]);
        let r = push(&mut core, 0, 0, 0, &[4.0]);
        assert!(r.is_empty(), "duplicate must not be acked: {r:?}");
        assert_eq!(core.stats.rejected, 1);
        assert_eq!(core.stats.pushes, 1);
        // The honest peer still completes the round with the true mean
        // over *distinct* contributors.
        let r = push(&mut core, 0, 0, 1, &[8.0]);
        assert!(!r.is_empty());
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        let Message::PullResp { served_with, data, .. } = &r[0].1 else { panic!("{r:?}") };
        assert_eq!(*served_with, 2);
        let mut out = vec![0.0f32; 1];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![6.0]);
    }

    /// Race regression (found in review): a worker whose push for a round
    /// was lost can have its *pull* for that round reach the server
    /// before the surviving worker's push — the key is still one
    /// iteration behind, and the old "future pull" rejection stranded
    /// the worker forever (the deadline seal only answers *queued*
    /// pulls). One-iteration-ahead pulls must queue; further ahead stays
    /// rejected (honest lag is bounded by one even with losses).
    #[test]
    fn pull_ahead_of_lost_push_queues_and_deadline_serves_it() {
        let mut core = ServerCore::new(opts_deadline("identity", SyncMode::Full, 2));
        // Iteration 0 completes normally for both workers.
        push(&mut core, 0, 0, 0, &[1.0]);
        push(&mut core, 0, 0, 1, &[3.0]);
        // Worker 1's push for iteration 1 is lost; its pull arrives while
        // the key is still at iteration 0. It must queue, not be rejected.
        let r = core.handle(1, Message::Pull { key: 0, iter: 1, worker: 1 });
        assert!(r.is_empty());
        assert_eq!(core.stats.rejected, 0);
        // The surviving push arrives and the deadline seals the round:
        // the queued one-ahead pull is answered.
        push(&mut core, 0, 1, 0, &[10.0]);
        let replies = core.poll_deadlines(after_deadline());
        assert_eq!(replies.len(), 1, "queued pull unanswered: {replies:?}");
        let (to, Message::PullResp { iter, served_with, data, .. }) = &replies[0] else {
            panic!("not a PullResp: {replies:?}")
        };
        assert_eq!((*to, *iter, *served_with), (1, 1, 1));
        let mut out = vec![0.0f32; 1];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![10.0]);
        // Beyond the one-iteration lag bound is still rejected — with a
        // retired marker, never a silent drop.
        let r = core.handle(1, Message::Pull { key: 0, iter: 5, worker: 1 });
        assert_eq!(r.len(), 1);
        assert!(matches!(r[0].1, Message::PullResp { served_with: 0, .. }), "{r:?}");
        assert_eq!(core.stats.rejected, 1);
    }

    /// End-to-end over the threaded I/O loop: one worker of two goes
    /// silent for an iteration; the deadline completes the round and both
    /// the live worker's pull and the run itself finish (no hang). Named
    /// `degraded` so CI's liveness step (and the generic step's skip
    /// filter) catch it — it hangs, not fails, on regression.
    #[test]
    fn threaded_server_degraded_round_unblocks_pull() {
        let (w0, s0) = crate::comm::inproc::pair();
        let (w1, s1) = crate::comm::inproc::pair();
        let mut o = opts("identity", SyncMode::Full, 2);
        o.iter_deadline = Some(std::time::Duration::from_millis(50));
        let server = Server::spawn(o, vec![s0, s1]);
        // Worker 1 registers its presence with iteration 0 then goes
        // silent for iteration 1.
        let comp = by_name("identity", 0.0).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(1);
        let mk = |v: &[f32], rng: &mut Xoshiro256| {
            let mut c = Ctx::new(rng);
            comp.compress(v, &mut c)
        };
        let d0 = mk(&[1.0], &mut rng);
        let d1 = mk(&[3.0], &mut rng);
        w0.send(Message::Push { key: 0, iter: 0, worker: 0, data: d0 }).unwrap();
        w1.send(Message::Push { key: 0, iter: 0, worker: 1, data: d1 }).unwrap();
        // Pull iteration 0 and *wait for the response* before pushing
        // iteration 1: the two connections feed the aggregator through
        // independent reader threads, so without this barrier w0's
        // iter-1 push could overtake w1's iter-0 push and roll the round
        // over short (a real short_iter, failing the assertion below).
        let recv_resp = |ep: &crate::comm::inproc::InprocEndpoint| loop {
            match ep.recv().unwrap() {
                Message::Ack { .. } => {}
                m @ Message::PullResp { .. } => break m,
                m => panic!("unexpected {m:?}"),
            }
        };
        w0.send(Message::Pull { key: 0, iter: 0, worker: 0 }).unwrap();
        let _ = recv_resp(&w0);
        // Iteration 1: only worker 0 pushes, then pulls.
        let d2 = mk(&[10.0], &mut rng);
        w0.send(Message::Push { key: 0, iter: 1, worker: 0, data: d2 }).unwrap();
        w0.send(Message::Pull { key: 0, iter: 1, worker: 0 }).unwrap();
        let resp = recv_resp(&w0);
        let Message::PullResp { iter, served_with, data, .. } = resp else { unreachable!() };
        assert_eq!((iter, served_with), (1, 1));
        let mut out = vec![0.0f32; 1];
        comp.decompress(&data, &mut out);
        assert_eq!(out, vec![10.0]);
        w0.send(Message::Shutdown).unwrap();
        w1.send(Message::Shutdown).unwrap();
        let stats = server.join();
        assert_eq!(stats.degraded_iters, 1);
        assert_eq!(stats.short_iters, 0);
    }
}
