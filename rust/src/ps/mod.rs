//! Parameter server (paper §4.1.2): key-sharded gradient aggregation with
//! two-way compression and server-side error feedback, run as a staged
//! pipeline per shard.
//!
//! One [`Server`] owns a shard of the keyspace. Per key and iteration it
//! collects one compressed push per worker, decodes and averages them
//! (`Δ_t = 1/n Σ δ_t,i [+ ẽ_t]`), re-compresses the aggregate (`p_t =
//! C(Δ_t)`, the second "way"), and answers the workers' pulls. Exactly
//! Algorithm 3/4's server side; Algorithm 1 falls out with the identity
//! compressor.
//!
//! ## Module family
//!
//! * [`core`] — the round/rollover state machine ([`ServerCore`]): wire
//!   validation, key budgets, dedup, seal decisions, the one-slot `prev`
//!   history, deadline auto-tuning. Every decision runs on the shard's
//!   single control thread, in message order.
//! * [`stage`] — the staged executor: pure decode/encode kernels, the
//!   per-(key, iter) encode seeds, and the [`StageEvent`] plumbing that
//!   carries pool-job completions back to the control thread.
//! * [`plan`] — [`ShardPlan`], key → shard assignment with the §4.2.4
//!   workload balancing (blocks, cost-weighted).
//! * [`stats`] — [`ServerStats`]: protocol counters, per-stage seconds,
//!   queue-depth gauges, and the round-latency histogram.
//!
//! ## The shard stage pipeline (§4.2.1, server side)
//!
//! With `server.compress_threads > 0` a shard runs
//! ingress → decode → reduce → seal → encode: the I/O loop only frames,
//! validates and routes messages (*ingress*); each accepted push's
//! decompression runs as a pool job (*decode*), so decoding worker i+1's
//! push overlaps ingress of worker i+2's; the control thread sums decoded
//! contributions in worker-index order at seal time (*reduce*), making
//! the f32 bits independent of decode completion order; sealing (by count
//! or deadline) enqueues the second-way compression on the pool
//! (*encode*), so encoding key k overlaps reducing key k+1; completed
//! `PullResp`s flow back through the loop (*egress*). With
//! `compress_threads = 0` every stage runs inline — the synchronous
//! reference implementation — and the two paths are **bit-identical** for
//! the whole `compress::paper_suite()` (tested in [`stage`]).
//!
//! Incoming push payloads are untrusted wire data: the server validates
//! every block against its scheme ([`crate::compress::validate_wire`]) and
//! rejects corrupt blocks (counted in [`ServerStats::rejected`]) instead of
//! panicking mid-aggregation.
//!
//! ## Iteration deadline (degraded rounds)
//!
//! Strict BSP has a liveness hole: if one worker's push for iteration *t*
//! is lost or rejected, the round never reaches `n_workers` pushes and
//! every worker's pull for *t* waits forever. With
//! [`ServerOptions::iter_deadline`] set, a round that has at least one
//! push and has been open longer than the deadline is *sealed* with the
//! contributions it has: the partial sum is averaged over the pushes
//! actually received, second-way-compressed as usual, and served with
//! `served_with < n_workers` on the wire so workers can tell a degraded
//! round from a full one ([`ServerStats::degraded_iters`]). A push that
//! arrives after its round was sealed is dropped and counted
//! ([`ServerStats::late_pushes`]) — it is never merged retroactively,
//! which would hand different workers different aggregates for the same
//! iteration. With the deadline unset the server is bit-identical to the
//! strict-BSP aggregator (no timer, no polling, no wire change beyond the
//! constant `served_with == n_workers` tag) — unless
//! [`ServerOptions::deadline_auto_margin`] derives a deadline from the
//! observed p99 full-round latency (re-evaluated per sealed round).

mod core;
pub mod plan;
pub mod stage;
mod stats;

pub use self::core::{
    ServerCore, ServerOptions, AUTO_DEADLINE_FLOOR, AUTO_DEADLINE_MIN_ROUNDS,
};
pub use self::plan::ShardPlan;
pub use self::stage::{seal_seed, EventSink, StageEvent};
pub use self::stats::{LatencyHist, ServerStats, HIST_BUCKETS};

use crate::comm::{CommError, Endpoint, Message};
use crate::parallel::ThreadPool;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Everything the I/O loop multiplexes onto one channel: worker messages
/// from the per-endpoint reader threads, and stage-job completions from
/// the staged executor's sink.
enum LoopEvent {
    Msg(u32, Message),
    Stage(StageEvent),
}

/// A running server thread serving a set of worker endpoints.
pub struct Server {
    handle: Option<JoinHandle<ServerStats>>,
}

impl Server {
    /// Spawn the I/O loop: a receiver thread per worker endpoint feeding
    /// the single control thread. With `opts.compress_threads > 0` the
    /// shard builds its own decode/encode pool (the multi-process cluster
    /// shape: one shard per OS process owns its CPUs); `0` runs every
    /// stage inline — the synchronous reference.
    pub fn spawn<E: Endpoint + Sync + 'static>(opts: ServerOptions, endpoints: Vec<E>) -> Server {
        Self::spawn_with_pool(opts, endpoints, None)
    }

    /// Spawn with an explicit shared pool: the in-process fabric passes
    /// one pool to every shard so co-located shards share the machine's
    /// compression CPUs instead of oversubscribing them
    /// (`engine::CommFabric`). `None` + `compress_threads > 0` builds a
    /// private pool; `None` + `0` is the synchronous path.
    pub fn spawn_with_pool<E: Endpoint + Sync + 'static>(
        opts: ServerOptions,
        endpoints: Vec<E>,
        shared_pool: Option<Arc<ThreadPool>>,
    ) -> Server {
        let n = endpoints.len();
        let handle = std::thread::Builder::new()
            .name("bytepsc-server".into())
            .spawn(move || {
                let endpoints: Vec<Arc<E>> = endpoints.into_iter().map(Arc::new).collect();
                let (tx, rx) = std::sync::mpsc::channel::<LoopEvent>();
                let mut recv_threads = Vec::new();
                for (i, ep) in endpoints.iter().enumerate() {
                    let ep = Arc::clone(ep);
                    let tx = tx.clone();
                    recv_threads.push(std::thread::spawn(move || loop {
                        match ep.recv() {
                            Ok(Message::Shutdown) => {
                                let _ = tx.send(LoopEvent::Msg(i as u32, Message::Shutdown));
                                break;
                            }
                            // A corrupt frame is recoverable: recv consumed
                            // the whole length-prefixed frame before decode
                            // failed, so the stream is still frame-aligned.
                            // Drop the frame, keep the worker connected.
                            Err(CommError::Protocol(e)) => {
                                eprintln!("server: dropping corrupt frame from worker {i}: {e}");
                            }
                            Err(_) => {
                                let _ = tx.send(LoopEvent::Msg(i as u32, Message::Shutdown));
                                break;
                            }
                            Ok(m) => {
                                if tx.send(LoopEvent::Msg(i as u32, m)).is_err() {
                                    break;
                                }
                            }
                        }
                    }));
                }
                let staged = opts.compress_threads > 0 || shared_pool.is_some();
                let mut core = if staged {
                    let pool = shared_pool
                        .unwrap_or_else(|| Arc::new(ThreadPool::new(opts.compress_threads)));
                    let sink_tx = tx.clone();
                    let sink: EventSink = Arc::new(move |ev| {
                        let _ = sink_tx.send(LoopEvent::Stage(ev));
                    });
                    ServerCore::new_staged(opts, pool, sink)
                } else {
                    ServerCore::new(opts)
                };
                drop(tx);
                // With a deadline in force the control thread wakes at a
                // fraction of it to sweep for overdue rounds; without one
                // it blocks indefinitely — zero polling overhead, exactly
                // the strict-BSP loop. Re-evaluated each pass because
                // auto-tuning can arm a deadline mid-run.
                let mut last_poll = Instant::now();
                let mut live = n;
                while live > 0 {
                    let tick = core
                        .current_deadline()
                        .map(|d| (d / 4).max(Duration::from_millis(1)));
                    let received = match tick {
                        None => match rx.recv() {
                            Ok(ev) => Some(ev),
                            Err(_) => break,
                        },
                        Some(t) => match rx.recv_timeout(t) {
                            Ok(ev) => Some(ev),
                            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
                            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
                        },
                    };
                    let mut replies = Vec::new();
                    match received {
                        Some(LoopEvent::Msg(from, msg)) => {
                            if matches!(msg, Message::Shutdown) {
                                live -= 1;
                            } else {
                                replies = core.handle(from, msg);
                            }
                        }
                        Some(LoopEvent::Stage(ev)) => {
                            replies = core.on_event(ev);
                        }
                        None => {}
                    }
                    if let Some(t) = tick {
                        // Sweep on idle ticks, and at most once per tick
                        // under a message flood (the sweep walks every
                        // key).
                        if last_poll.elapsed() >= t {
                            replies.extend(core.poll_deadlines(Instant::now()));
                            last_poll = Instant::now();
                        }
                    }
                    for (to, reply) in replies {
                        // `to` is always a connection index the core got
                        // from us, but never trust it enough to index out
                        // of bounds; a dropped worker is a shutdown in
                        // progress.
                        if let Some(ep) = endpoints.get(to as usize) {
                            let _ = ep.send(reply);
                        } else {
                            eprintln!("server: dropping reply to unknown connection {to}");
                        }
                    }
                }
                // Drain in-flight stage jobs so the final stats (stage
                // seconds, queue peaks) are complete; straggler replies go
                // out best-effort (the workers may already be gone).
                while core.jobs_in_flight() > 0 {
                    match rx.recv_timeout(Duration::from_secs(10)) {
                        Ok(LoopEvent::Stage(ev)) => {
                            for (to, reply) in core.on_event(ev) {
                                if let Some(ep) = endpoints.get(to as usize) {
                                    let _ = ep.send(reply);
                                }
                            }
                        }
                        Ok(LoopEvent::Msg(..)) => {}
                        Err(_) => {
                            eprintln!(
                                "server: {} stage job(s) never reported back on shutdown",
                                core.jobs_in_flight()
                            );
                            break;
                        }
                    }
                }
                for t in recv_threads {
                    let _ = t.join();
                }
                core.stats
            })
            .expect("spawn server");
        Server { handle: Some(handle) }
    }

    /// Wait for the server to drain (workers must send Shutdown first).
    pub fn join(mut self) -> ServerStats {
        self.handle.take().unwrap().join().expect("server panicked")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{by_name, Ctx};
    use crate::configx::SyncMode;
    use crate::util::rng::Xoshiro256;

    fn opts(scheme: &str, sync: SyncMode, workers: usize) -> ServerOptions {
        ServerOptions {
            comp: by_name(scheme, 0.25).unwrap(),
            sync,
            fused: true,
            n_workers: workers,
            intra_threads: 1,
            seed: 7,
            max_keys: 0,
            iter_deadline: None,
            compress_threads: 0,
            deadline_auto_margin: 0.0,
            adaptive_bounds: None,
        }
    }

    /// Drive one threaded server end to end over inproc endpoints and
    /// return its stats; every worker checks the exact per-key means.
    fn roundtrip(compress_threads: usize, shared: Option<Arc<ThreadPool>>) -> ServerStats {
        let workers = 3;
        let dim = 64;
        let mut worker_eps = Vec::new();
        let mut server_eps = Vec::new();
        for _ in 0..workers {
            let (w, s) = crate::comm::inproc::pair();
            worker_eps.push(w);
            server_eps.push(s);
        }
        let mut o = opts("identity", SyncMode::Full, workers);
        o.compress_threads = compress_threads;
        let server = Server::spawn_with_pool(o, server_eps, shared);
        let handles: Vec<_> = worker_eps
            .into_iter()
            .enumerate()
            .map(|(w, ep)| {
                std::thread::spawn(move || {
                    let comp = by_name("identity", 0.0).unwrap();
                    let mut rng = Xoshiro256::seed_from_u64(w as u64);
                    let g: Vec<f32> = (0..dim).map(|i| (w * dim + i) as f32).collect();
                    for iter in 0..5u64 {
                        let data = comp.compress(&g, &mut Ctx::new(&mut rng));
                        ep.send(Message::Push { key: 0, iter, worker: w as u32, data }).unwrap();
                        // ack may arrive before or after we pull; consume both.
                        ep.send(Message::Pull { key: 0, iter, worker: w as u32 }).unwrap();
                        let mut got_resp = None;
                        while got_resp.is_none() {
                            match ep.recv().unwrap() {
                                Message::Ack { .. } => {}
                                Message::PullResp { data, .. } => got_resp = Some(data),
                                m => panic!("unexpected {m:?}"),
                            }
                        }
                        let mut out = vec![0.0f32; dim];
                        comp.decompress(&got_resp.unwrap(), &mut out);
                        // mean over workers of (w*dim + i)
                        for (i, v) in out.iter().enumerate() {
                            let expect = (0..workers).map(|ww| (ww * dim + i) as f32).sum::<f32>()
                                / workers as f32;
                            assert!((v - expect).abs() < 1e-4);
                        }
                    }
                    ep.send(Message::Shutdown).unwrap();
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        server.join()
    }

    #[test]
    fn threaded_server_roundtrip_over_inproc() {
        let stats = roundtrip(0, None);
        assert_eq!(stats.pushes, 15);
    }

    /// The staged I/O loop (decode/encode as pool jobs, completions
    /// multiplexed with ingress) serves the same exchange: same counters,
    /// same full-round history, and the loop drains its jobs before
    /// reporting stats.
    #[test]
    fn threaded_staged_server_roundtrip_over_inproc() {
        let stats = roundtrip(4, None);
        assert_eq!(stats.pushes, 15);
        assert_eq!(stats.pulls, 15);
        assert_eq!(stats.round_hist.count(), 5);
        assert_eq!(stats.rejected, 0);
    }

    /// Shards sharing one pool (the in-process fabric's shape) still
    /// drain cleanly — the pool outlives each server via its Arc.
    #[test]
    fn threaded_staged_server_with_shared_pool() {
        let pool = Arc::new(ThreadPool::new(2));
        let stats = roundtrip(2, Some(Arc::clone(&pool)));
        assert_eq!(stats.pushes, 15);
        pool.wait();
        assert_eq!(pool.take_panics(), 0);
    }

    /// End-to-end over the threaded I/O loop: one worker of two goes
    /// silent for an iteration; the deadline completes the round and both
    /// the live worker's pull and the run itself finish (no hang). Named
    /// `degraded` so CI's liveness step (and the generic step's skip
    /// filter) catch it — it hangs, not fails, on regression.
    #[test]
    fn threaded_server_degraded_round_unblocks_pull() {
        threaded_degraded_round(0);
    }

    /// Same liveness claim through the staged loop: the deadline tick,
    /// the seal-with-decodes-in-flight path, and egress all run with
    /// `compress_threads > 0`. Also named `degraded` for CI's step.
    #[test]
    fn threaded_staged_server_degraded_round_unblocks_pull() {
        threaded_degraded_round(4);
    }

    fn threaded_degraded_round(compress_threads: usize) {
        let (w0, s0) = crate::comm::inproc::pair();
        let (w1, s1) = crate::comm::inproc::pair();
        let mut o = opts("identity", SyncMode::Full, 2);
        o.iter_deadline = Some(std::time::Duration::from_millis(50));
        o.compress_threads = compress_threads;
        let server = Server::spawn(o, vec![s0, s1]);
        // Worker 1 registers its presence with iteration 0 then goes
        // silent for iteration 1.
        let comp = by_name("identity", 0.0).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(1);
        let mk = |v: &[f32], rng: &mut Xoshiro256| {
            let mut c = Ctx::new(rng);
            comp.compress(v, &mut c)
        };
        let d0 = mk(&[1.0], &mut rng);
        let d1 = mk(&[3.0], &mut rng);
        w0.send(Message::Push { key: 0, iter: 0, worker: 0, data: d0 }).unwrap();
        w1.send(Message::Push { key: 0, iter: 0, worker: 1, data: d1 }).unwrap();
        // Pull iteration 0 and *wait for the response* before pushing
        // iteration 1: the two connections feed the control thread through
        // independent reader threads, so without this barrier w0's
        // iter-1 push could overtake w1's iter-0 push and roll the round
        // over short (a real short_iter, failing the assertion below).
        let recv_resp = |ep: &crate::comm::inproc::InprocEndpoint| loop {
            match ep.recv().unwrap() {
                Message::Ack { .. } => {}
                m @ Message::PullResp { .. } => break m,
                m => panic!("unexpected {m:?}"),
            }
        };
        w0.send(Message::Pull { key: 0, iter: 0, worker: 0 }).unwrap();
        let _ = recv_resp(&w0);
        // Iteration 1: only worker 0 pushes, then pulls.
        let d2 = mk(&[10.0], &mut rng);
        w0.send(Message::Push { key: 0, iter: 1, worker: 0, data: d2 }).unwrap();
        w0.send(Message::Pull { key: 0, iter: 1, worker: 0 }).unwrap();
        let resp = recv_resp(&w0);
        let Message::PullResp { iter, served_with, data, .. } = resp else { unreachable!() };
        assert_eq!((iter, served_with), (1, 1));
        let mut out = vec![0.0f32; 1];
        comp.decompress(&data, &mut out);
        assert_eq!(out, vec![10.0]);
        w0.send(Message::Shutdown).unwrap();
        w1.send(Message::Shutdown).unwrap();
        let stats = server.join();
        assert_eq!(stats.degraded_iters, 1);
        assert_eq!(stats.short_iters, 0);
    }
}
