//! Parameter server (paper §4.1.2): key-sharded gradient aggregation with
//! two-way compression and server-side error feedback.
//!
//! One [`Server`] owns a shard of the keyspace. Per key and iteration it
//! collects one compressed push per worker, decompresses and averages them
//! (`Δ_t = 1/n Σ δ_t,i [+ ẽ_t]`), re-compresses the aggregate (`p_t =
//! C(Δ_t)`, the second "way"), and answers the workers' pulls. Exactly
//! Algorithm 3/4's server side; Algorithm 1 falls out with the identity
//! compressor.
//!
//! Shard assignment across multiple servers lives in [`ShardPlan`] and
//! implements the paper's workload balancing (§4.2.4): keys that undergo
//! compression carry extra CPU cost, so they are weighted heavier than
//! bypassed (small) keys when balancing. Since the §4.2.1 pipeline, the
//! unit of sharding is a *block* ([`crate::comm::BlockKey`]), not a whole
//! tensor: a large tensor's blocks spread across shards, so its server-side
//! decompress/aggregate/re-compress work runs on several shards at once.
//!
//! Incoming push payloads are untrusted wire data: the server validates
//! every block against its scheme ([`crate::compress::validate_wire`]) and
//! rejects corrupt blocks (counted in [`ServerStats::rejected`]) instead of
//! panicking mid-aggregation.

use crate::comm::{BlockKey, CommError, Endpoint, Key, Message};
use crate::compress::ef::EfState;
use crate::compress::{Compressor, Ctx};
use crate::configx::SyncMode;
use crate::util::rng::Xoshiro256;
use std::collections::HashMap;
use std::sync::Arc;
use std::thread::JoinHandle;

/// Server behaviour knobs.
#[derive(Clone)]
pub struct ServerOptions {
    pub comp: Arc<dyn Compressor>,
    pub sync: SyncMode,
    /// Fused EF residual update (§4.2.2).
    pub fused: bool,
    pub n_workers: usize,
    /// Intra-task threads for (de)compression (§4.2.1).
    pub intra_threads: usize,
    pub seed: u64,
    /// Cap on distinct keys this shard will materialize state for
    /// (0 = unlimited). The launchers set it to the partition size so a
    /// client inventing keys cannot grow server memory without bound.
    pub max_keys: usize,
}

struct KeyState {
    iter: u64,
    /// Canonical element count for this key, fixed by the first *push*
    /// (`None` while the key has only seen pulls — a pull-before-push
    /// queues rather than panicking the shard). Later pushes whose `n`
    /// disagrees are rejected at ingress — a self-consistent corrupt frame
    /// must not resize (or panic on) the accumulator.
    dim: Option<usize>,
    acc: Vec<f32>,
    count: usize,
    ready: Option<crate::compress::Compressed>,
    /// The previous iteration's aggregate. BSP lets a fast worker *push*
    /// iteration i+1 (which rolls this key over) before a slow worker has
    /// *pulled* iteration i — the slow pull must still be servable.
    /// Workers never lag more than one iteration (they pull i before
    /// pushing i+1), so one slot suffices.
    ///
    /// This invariant survives the block pipeline: keys are now per-block
    /// and blocks of one iteration arrive out of order across *different*
    /// keys, but each `KeyState` is keyed by one block, and every worker
    /// still completes pull(key, i) before it sends push(key, i+1) — the
    /// pipelined push phase starts only after the previous exchange's pull
    /// phase fully drained, and both transports preserve per-endpoint FIFO
    /// order. So per key the lag stays bounded by one iteration and the
    /// one-slot rollover is still sufficient (tested in
    /// `rust/tests/distributed.rs`).
    prev: Option<(u64, crate::compress::Compressed)>,
    /// Queued pulls as (iter, connection index) — the endpoint to answer
    /// on, which is the server's ground truth for who is asking (the wire
    /// `worker` field is untrusted).
    pending: Vec<(u64, u32)>,
}

impl KeyState {
    /// Empty state at `iter` — no dimension yet (a *placeholder* until
    /// the first push establishes the element count).
    fn fresh(iter: u64) -> KeyState {
        KeyState {
            iter,
            dim: None,
            acc: Vec::new(),
            count: 0,
            ready: None,
            prev: None,
            pending: Vec::new(),
        }
    }
}

/// Statistics returned on shutdown.
#[derive(Debug, Default, Clone, Copy)]
pub struct ServerStats {
    pub pushes: u64,
    pub pulls: u64,
    /// Corrupt push blocks dropped at ingress (wire-validation failures,
    /// wrong element counts, pushes for already-retired iterations).
    pub rejected: u64,
    /// Iterations that rolled over with fewer than `n_workers` pushes —
    /// a rejected corrupt push (or a dead worker) left the round short.
    /// The shard recovers by discarding the partial accumulator instead
    /// of asserting; each occurrence is counted here.
    pub short_iters: u64,
    /// Pulls dropped because their iteration was already retired past the
    /// one-slot history (can only happen after a short iteration or a
    /// hostile client; honest BSP workers never lag two iterations).
    pub stale_pulls: u64,
    /// Pulls that arrived before any push had established their key —
    /// queued until the key appears (reordered cluster startup), where the
    /// shard previously died on `.expect("pull before any push")`.
    pub early_pulls: u64,
    /// Messages a server should never receive (`Welcome`, `PullResp`,
    /// mid-stream `Hello`, ...) — ignored and counted, never a panic.
    pub unexpected: u64,
    pub decompress_s: f64,
    pub compress_s: f64,
}

/// The server's synchronous core: feed it messages, collect replies.
/// Separated from the I/O loop so tests can drive it deterministically.
pub struct ServerCore {
    opts: ServerOptions,
    ef: EfState,
    rng: Xoshiro256,
    keys: HashMap<Key, KeyState>,
    /// Keys whose dimension a push has established. Junk *placeholders*
    /// (pull-created, dim `None`) are budgeted separately so a client
    /// pulling made-up keys can never starve pushes for real keys.
    established_keys: usize,
    pub stats: ServerStats,
}

impl ServerCore {
    pub fn new(opts: ServerOptions) -> Self {
        let rng = Xoshiro256::seed_from_u64(opts.seed);
        ServerCore {
            ef: EfState::new(opts.fused),
            rng,
            keys: HashMap::new(),
            established_keys: 0,
            stats: ServerStats::default(),
            opts,
        }
    }

    /// Whether a push may establish one more key (the real keyspace is
    /// bounded by the partition; anything past `max_keys` is hostile).
    fn at_established_capacity(&self) -> bool {
        self.opts.max_keys > 0 && self.established_keys >= self.opts.max_keys
    }

    /// Whether creating one more pull-created placeholder would exceed its
    /// budget (equal to `max_keys`): total key state stays bounded even
    /// against a client pulling arbitrary made-up keys.
    fn at_placeholder_capacity(&self, key: Key) -> bool {
        self.opts.max_keys > 0
            && !self.keys.contains_key(&key)
            && self.keys.len() - self.established_keys >= self.opts.max_keys
    }

    /// Handle one message from connection `from`; returns
    /// `(connection index, reply)` pairs to send.
    pub fn handle(&mut self, from: u32, msg: Message) -> Vec<(u32, Message)> {
        match msg {
            // Replies are addressed by `from` — the connection the message
            // arrived on — never by the wire-supplied `worker` field. A
            // client lying about (or botching) its id must not be able to
            // steer replies to another worker or index the endpoint table
            // out of bounds; the field is kept for diagnostics only.
            Message::Push { key, iter, worker, data } => {
                // Untrusted wire data: reject corrupt blocks instead of
                // letting a bad index/length panic the aggregator. (The
                // TCP transport already rejects these at frame decode;
                // this also covers the in-process transport.)
                if let Err(e) = crate::compress::validate_wire(&data) {
                    eprintln!("server: rejecting corrupt push for key {key} from worker {worker}: {e}");
                    self.stats.rejected += 1;
                    return vec![];
                }
                // Every push targets (or establishes) an established key;
                // placeholders don't consume this budget until a push
                // gives them a dimension. Checked before touching the map
                // so a rejected junk push cannot leave a placeholder
                // behind either. (Hoisted: `st` below holds a &mut borrow
                // of the key map.)
                let at_established_cap = self.at_established_capacity();
                if at_established_cap && !self.keys.contains_key(&key) {
                    eprintln!(
                        "server: rejecting push for unknown key {key} from worker {worker}: \
                         shard is at its {}-key capacity",
                        self.opts.max_keys
                    );
                    self.stats.rejected += 1;
                    return vec![];
                }
                let st = self.keys.entry(key).or_insert_with(|| KeyState::fresh(iter));
                match st.dim {
                    // A self-consistent corrupt frame can still carry the
                    // wrong element count for this key; reject it rather
                    // than resize (or panic on) the accumulator.
                    Some(d) if data.n != d => {
                        eprintln!(
                            "server: rejecting push for key {key} from worker {worker}: \
                             n={} but the key has {d} elements",
                            data.n
                        );
                        self.stats.rejected += 1;
                        return vec![];
                    }
                    // First push fixes the key's element count. The state
                    // may be a placeholder from an earlier queued pull, so
                    // adopt the pusher's iteration clock too — and charge
                    // the establishment budget now.
                    None => {
                        if at_established_cap {
                            eprintln!(
                                "server: rejecting push establishing key {key} from worker \
                                 {worker}: shard is at its {}-key capacity",
                                self.opts.max_keys
                            );
                            self.stats.rejected += 1;
                            return vec![];
                        }
                        st.dim = Some(data.n);
                        st.acc = vec![0.0; data.n];
                        st.iter = iter;
                        self.established_keys += 1;
                    }
                    _ => {}
                }
                if iter < st.iter {
                    // A push for an iteration this key already retired — a
                    // hostile client or a straggler beyond BSP's one-slot
                    // lag. Unusable either way; drop it, counted.
                    eprintln!(
                        "server: rejecting stale push for key {key} iteration {iter} \
                         from worker {worker} (key is at {})",
                        st.iter
                    );
                    self.stats.rejected += 1;
                    return vec![];
                }
                if st.iter != iter {
                    // New iteration for this key: retire the completed
                    // aggregate (slow workers may still pull it) and reset
                    // the accumulator. A short round — a rejected corrupt
                    // push left `count` below n_workers — is recovered by
                    // discarding the partial sum, never by asserting the
                    // shard down on untrusted input.
                    if st.count != 0 && st.count != self.opts.n_workers {
                        eprintln!(
                            "server: key {key} iteration {} was short ({}/{} pushes); \
                             discarding the partial aggregate",
                            st.iter, st.count, self.opts.n_workers
                        );
                        self.stats.short_iters += 1;
                    }
                    if let Some(p) = st.ready.take() {
                        st.prev = Some((st.iter, p));
                    }
                    st.iter = iter;
                    st.count = 0;
                    st.acc.clear();
                    st.acc.resize(data.n, 0.0);
                }
                let t = std::time::Instant::now();
                self.opts.comp.add_decompressed(&data, &mut st.acc);
                self.stats.decompress_s += t.elapsed().as_secs_f64();
                st.count += 1;
                self.stats.pushes += 1;
                let mut replies = vec![(from, Message::Ack { key, iter })];
                if st.count == self.opts.n_workers {
                    // Aggregate complete: average + second-way compression.
                    let inv = 1.0 / self.opts.n_workers as f32;
                    for a in &mut st.acc {
                        *a *= inv;
                    }
                    let t = std::time::Instant::now();
                    let acc = std::mem::take(&mut st.acc);
                    let p = match self.opts.sync {
                        SyncMode::CompressedEf => self.ef.compress_owned(
                            key,
                            acc,
                            self.opts.comp.as_ref(),
                            &mut Ctx::with_threads(&mut self.rng, self.opts.intra_threads),
                        ),
                        _ => self.opts.comp.compress(
                            &acc,
                            &mut Ctx::with_threads(&mut self.rng, self.opts.intra_threads),
                        ),
                    };
                    self.stats.compress_s += t.elapsed().as_secs_f64();
                    st.ready = Some(p.clone());
                    // The queue fully drains at every completion: matching
                    // pulls are served, everything else (short-iteration
                    // leftovers below, placeholder-era junk above) is
                    // unservable and dropped — nothing hostile can sit in
                    // `pending` displacing honest pulls forever.
                    let served: Vec<(u64, u32)> = std::mem::take(&mut st.pending);
                    for (piter, w) in served {
                        if piter == iter {
                            replies.push((w, Message::PullResp { key, iter, data: p.clone() }));
                        } else {
                            eprintln!(
                                "server: dropping unservable queued pull for key {key} \
                                 iteration {piter} from worker {w} (key is at {iter})"
                            );
                            self.stats.stale_pulls += 1;
                        }
                    }
                }
                replies
            }
            Message::Pull { key, iter, worker } => {
                self.stats.pulls += 1;
                if self.at_placeholder_capacity(key) {
                    eprintln!(
                        "server: dropping pull for unknown key {key} from worker {worker}: \
                         shard is at its placeholder capacity"
                    );
                    self.stats.rejected += 1;
                    return vec![];
                }
                // A pull may precede any push for its key — a reordered
                // startup, or a client probing unknown keys. Queue it (as
                // a budgeted placeholder) until the key appears instead of
                // panicking the shard.
                let st = self.keys.entry(key).or_insert_with(|| KeyState::fresh(iter));
                if st.dim.is_none() {
                    self.stats.early_pulls += 1;
                }
                if st.dim.is_some() {
                    if st.iter == iter {
                        if let Some(p) = &st.ready {
                            return vec![(from, Message::PullResp { key, iter, data: p.clone() })];
                        }
                    } else if let Some((piter, p)) = &st.prev {
                        // A pull lagging one iteration behind a fast pusher.
                        if *piter == iter {
                            return vec![(from, Message::PullResp { key, iter, data: p.clone() })];
                        }
                    }
                    if iter < st.iter {
                        // Older than the one-slot history: unservable.
                        // Honest BSP workers never lag two iterations, so
                        // this is a short-iteration leftover or a hostile
                        // client — count it and drop instead of asserting.
                        eprintln!(
                            "server: dropping stale pull for key {key} iteration {iter} \
                             from worker {worker} (key is at {})",
                            st.iter
                        );
                        self.stats.stale_pulls += 1;
                        return vec![];
                    }
                    if iter > st.iter {
                        // Impossible for honest traffic: per-connection
                        // FIFO means a worker's push(key, i) is processed
                        // before its pull(key, i), so the key's clock has
                        // always reached `iter` by pull time. Queueing it
                        // would let a flood of far-future pulls poison the
                        // pending queue forever — reject instead.
                        eprintln!(
                            "server: rejecting future pull for key {key} iteration {iter} \
                             from worker {worker} (key is at {})",
                            st.iter
                        );
                        self.stats.rejected += 1;
                        return vec![];
                    }
                }
                // Honest traffic queues at most one pull per worker per
                // key; anything past a small multiple is a flood (pulls
                // for iterations that will never be served) — drop it
                // rather than grow the queue without bound.
                if st.pending.len() >= 2 * self.opts.n_workers.max(1) {
                    eprintln!(
                        "server: dropping pull for key {key} iteration {iter} from \
                         worker {worker}: pending queue full"
                    );
                    self.stats.stale_pulls += 1;
                    return vec![];
                }
                st.pending.push((iter, from));
                vec![]
            }
            Message::Shutdown => vec![],
            // Hello/Welcome/PullResp/Ack have no business arriving at a
            // running server; any client can send them, so they must never
            // panic the shard — ignore and count.
            other => {
                let tag = match other {
                    Message::Hello { .. } => "Hello",
                    Message::Welcome { .. } => "Welcome",
                    Message::PullResp { .. } => "PullResp",
                    Message::Ack { .. } => "Ack",
                    _ => "unknown",
                };
                eprintln!("server: ignoring unexpected {tag} message from worker {from}");
                self.stats.unexpected += 1;
                vec![]
            }
        }
    }
}

/// A running server thread serving a set of worker endpoints.
pub struct Server {
    handle: Option<JoinHandle<ServerStats>>,
}

impl Server {
    /// Spawn the I/O loop: a receiver thread per worker endpoint feeding
    /// the single aggregator (the paper's servers are single-threaded per
    /// shard too; parallelism comes from having many servers/shards).
    pub fn spawn<E: Endpoint + Sync + 'static>(opts: ServerOptions, endpoints: Vec<E>) -> Server {
        let n = endpoints.len();
        let handle = std::thread::Builder::new()
            .name("bytepsc-server".into())
            .spawn(move || {
                let endpoints: Vec<Arc<E>> = endpoints.into_iter().map(Arc::new).collect();
                let (tx, rx) = std::sync::mpsc::channel::<(u32, Message)>();
                let mut recv_threads = Vec::new();
                for (i, ep) in endpoints.iter().enumerate() {
                    let ep = Arc::clone(ep);
                    let tx = tx.clone();
                    recv_threads.push(std::thread::spawn(move || loop {
                        match ep.recv() {
                            Ok(Message::Shutdown) => {
                                let _ = tx.send((i as u32, Message::Shutdown));
                                break;
                            }
                            // A corrupt frame is recoverable: recv consumed
                            // the whole length-prefixed frame before decode
                            // failed, so the stream is still frame-aligned.
                            // Drop the frame, keep the worker connected.
                            Err(CommError::Protocol(e)) => {
                                eprintln!("server: dropping corrupt frame from worker {i}: {e}");
                            }
                            Err(_) => {
                                let _ = tx.send((i as u32, Message::Shutdown));
                                break;
                            }
                            Ok(m) => {
                                if tx.send((i as u32, m)).is_err() {
                                    break;
                                }
                            }
                        }
                    }));
                }
                drop(tx);
                let mut core = ServerCore::new(opts);
                let mut live = n;
                while live > 0 {
                    let Ok((from, msg)) = rx.recv() else { break };
                    if matches!(msg, Message::Shutdown) {
                        live -= 1;
                        continue;
                    }
                    for (to, reply) in core.handle(from, msg) {
                        // `to` is always a connection index the core got
                        // from us, but never trust it enough to index out
                        // of bounds; a dropped worker is a shutdown in
                        // progress.
                        if let Some(ep) = endpoints.get(to as usize) {
                            let _ = ep.send(reply);
                        } else {
                            eprintln!("server: dropping reply to unknown connection {to}");
                        }
                    }
                }
                for t in recv_threads {
                    let _ = t.join();
                }
                core.stats
            })
            .expect("spawn server");
        Server { handle: Some(handle) }
    }

    /// Wait for the server to drain (workers must send Shutdown first).
    pub fn join(mut self) -> ServerStats {
        self.handle.take().unwrap().join().expect("server panicked")
    }
}

/// Key → server assignment with workload balancing (§4.2.4).
///
/// Since the block pipeline, assignment is keyed by arbitrary (packed)
/// block keys rather than dense tensor indices: use [`balanced_keyed`] /
/// [`round_robin_keyed`] for block plans. The dense-index constructors
/// remain for whole-tensor plans (a tensor id *is* its block-0 key).
///
/// [`balanced_keyed`]: ShardPlan::balanced_keyed
/// [`round_robin_keyed`]: ShardPlan::round_robin_keyed
#[derive(Clone, Debug)]
pub struct ShardPlan {
    assignment: HashMap<Key, usize>,
    servers: usize,
}

impl ShardPlan {
    /// Greedy least-loaded assignment over dense tensor-id keys
    /// `0..costs.len()`. `cost(key)` should reflect server CPU work:
    /// compressed keys cost `numel × compress_factor`, bypassed keys just
    /// `numel` (decompress-free memcpy aggregation).
    pub fn balanced(costs: &[f64], servers: usize) -> ShardPlan {
        let items: Vec<(Key, f64)> =
            costs.iter().enumerate().map(|(k, &c)| (k as Key, c)).collect();
        Self::balanced_keyed(&items, servers)
    }

    /// Greedy least-loaded assignment over explicit `(key, cost)` pairs —
    /// the pipeline's per-block plan. Deterministic: ties in cost break by
    /// key, ties in load by server index.
    pub fn balanced_keyed(items: &[(Key, f64)], servers: usize) -> ShardPlan {
        assert!(servers >= 1);
        let mut order: Vec<usize> = (0..items.len()).collect();
        order.sort_by(|a, b| {
            items[*b]
                .1
                .partial_cmp(&items[*a].1)
                .unwrap()
                .then_with(|| items[*a].0.cmp(&items[*b].0))
        });
        let mut load = vec![0.0f64; servers];
        let mut assignment = HashMap::with_capacity(items.len());
        for i in order {
            let (key, cost) = items[i];
            let s = (0..servers).min_by(|a, b| load[*a].partial_cmp(&load[*b]).unwrap()).unwrap();
            assignment.insert(key, s);
            load[s] += cost;
        }
        ShardPlan { assignment, servers }
    }

    /// Naive round-robin over dense tensor-id keys (the ablation's "no
    /// workload balance" arm).
    pub fn round_robin(keys: usize, servers: usize) -> ShardPlan {
        let keys: Vec<Key> = (0..keys as u64).collect();
        Self::round_robin_keyed(&keys, servers)
    }

    /// Round-robin over explicit keys, in the order given.
    pub fn round_robin_keyed(keys: &[Key], servers: usize) -> ShardPlan {
        assert!(servers >= 1);
        let assignment = keys.iter().enumerate().map(|(i, &k)| (k, i % servers)).collect();
        ShardPlan { assignment, servers }
    }

    /// Rebuild a plan from explicit `(key, server)` pairs — the form the
    /// cluster handshake ships in [`crate::comm::Message::Welcome`].
    /// Assignments pointing past `servers` are rejected (untrusted input).
    pub fn from_assignments(entries: &[(Key, u32)], servers: usize) -> Result<ShardPlan, String> {
        if servers == 0 {
            return Err("shard plan needs at least one server".into());
        }
        let mut assignment = HashMap::with_capacity(entries.len());
        for &(key, s) in entries {
            if s as usize >= servers {
                return Err(format!("key {key} assigned to server {s} of {servers}"));
            }
            if assignment.insert(key, s as usize).is_some() {
                return Err(format!("key {key} assigned twice"));
            }
        }
        Ok(ShardPlan { assignment, servers })
    }

    /// Export the plan as `(key, server)` pairs, sorted by key so two
    /// plans can be compared structurally (workers cross-check that every
    /// server shard handed them the same plan).
    pub fn assignments(&self) -> Vec<(Key, u32)> {
        let mut out: Vec<(Key, u32)> =
            self.assignment.iter().map(|(&k, &s)| (k, s as u32)).collect();
        out.sort_unstable_by_key(|&(k, _)| k);
        out
    }

    /// Number of servers this plan shards across.
    pub fn servers(&self) -> usize {
        self.servers
    }

    /// Number of keys in the plan.
    pub fn len(&self) -> usize {
        self.assignment.len()
    }

    pub fn is_empty(&self) -> bool {
        self.assignment.is_empty()
    }

    /// Whether `key` has an assignment (cluster workers verify the plan
    /// they received covers their whole partition before trusting it).
    pub fn contains(&self, key: Key) -> bool {
        self.assignment.contains_key(&key)
    }

    pub fn server_of(&self, key: Key) -> usize {
        *self.assignment.get(&key).unwrap_or_else(|| {
            let bk = BlockKey::unpack(key);
            panic!("key {key} (tensor {}, block {}) not in the shard plan", bk.tensor, bk.block)
        })
    }

    /// Max/mean load ratio (1.0 = perfectly balanced), with per-key costs
    /// supplied by `cost_of`.
    pub fn imbalance_by<F: Fn(Key) -> f64>(&self, cost_of: F) -> f64 {
        let mut load = vec![0.0f64; self.servers];
        for (&k, &s) in &self.assignment {
            load[s] += cost_of(k);
        }
        let max = load.iter().cloned().fold(0.0f64, f64::max);
        let mean = load.iter().sum::<f64>() / self.servers.max(1) as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Max/mean load ratio for dense tensor-id plans (`key` indexes
    /// `costs`).
    pub fn imbalance(&self, costs: &[f64]) -> f64 {
        self.imbalance_by(|k| costs[k as usize])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::by_name;

    fn opts(scheme: &str, sync: SyncMode, workers: usize) -> ServerOptions {
        ServerOptions {
            comp: by_name(scheme, 0.25).unwrap(),
            sync,
            fused: true,
            n_workers: workers,
            intra_threads: 1,
            seed: 7,
            max_keys: 0,
        }
    }

    fn push(core: &mut ServerCore, key: Key, iter: u64, worker: u32, g: &[f32]) -> Vec<(u32, Message)> {
        let mut rng = Xoshiro256::seed_from_u64(worker as u64 + 100);
        let data = core.opts.comp.compress(g, &mut Ctx::new(&mut rng));
        core.handle(worker, Message::Push { key, iter, worker, data })
    }

    #[test]
    fn aggregates_identity_to_exact_mean() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        let r1 = push(&mut core, 0, 0, 0, &[1.0, 2.0]);
        assert_eq!(r1.len(), 1); // just the ack
        let r2 = push(&mut core, 0, 0, 1, &[3.0, 6.0]);
        assert_eq!(r2.len(), 1);
        // Now pull
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        let Message::PullResp { data, .. } = &r[0].1 else { panic!() };
        let mut out = vec![0.0f32; 2];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![2.0, 4.0]);
    }

    #[test]
    fn pull_before_complete_is_queued() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        push(&mut core, 5, 0, 0, &[1.0]);
        let r = core.handle(1, Message::Pull { key: 5, iter: 0, worker: 1 });
        assert!(r.is_empty()); // queued
        let r = push(&mut core, 5, 0, 1, &[3.0]);
        // ack + the queued pull's response
        assert_eq!(r.len(), 2);
        assert!(matches!(r[1].1, Message::PullResp { .. }));
        assert_eq!(r[1].0, 1);
    }

    #[test]
    fn iterations_reset_accumulator() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 1));
        push(&mut core, 0, 0, 0, &[10.0]);
        push(&mut core, 0, 1, 0, &[2.0]);
        let r = core.handle(0, Message::Pull { key: 0, iter: 1, worker: 0 });
        let Message::PullResp { data, .. } = &r[0].1 else { panic!() };
        let mut out = vec![0.0f32; 1];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![2.0]); // not 12.0
    }

    #[test]
    fn server_ef_residual_accumulates_under_topk() {
        // Two workers with different dominant coordinates: the server's
        // second-way top-k can keep only one of them per round; ẽ must
        // carry the other forward and flush it on a later round
        // (Alg. 4's server side). Uses dim=8 so topk(0.25) keeps 2 of 8 —
        // workers' spikes at idx 0 and idx 1, aggregate keeps both unless
        // the residual game forces deferral; use k=1 via dim=4.
        let mut core = ServerCore::new(opts("topk", SyncMode::CompressedEf, 2));
        let ga = vec![1.0f32, 0.0, 0.0, 0.0]; // worker 0's spike
        let gb = vec![0.0f32, 0.9, 0.0, 0.0]; // worker 1's spike
        let mut seen_idx1 = false;
        for iter in 0..10u64 {
            push(&mut core, 0, iter, 0, &ga);
            push(&mut core, 0, iter, 1, &gb);
            let r = core.handle(0, Message::Pull { key: 0, iter, worker: 0 });
            let Message::PullResp { data, .. } = &r[0].1 else { panic!() };
            let mut p = vec![0.0f32; 4];
            core.opts.comp.decompress(data, &mut p);
            if iter == 0 {
                // Round 0: Δ = [0.5, 0.45, 0, 0]; top-1 keeps idx 0 only.
                assert_eq!(p, vec![0.5, 0.0, 0.0, 0.0]);
            }
            if p[1] > 0.0 {
                seen_idx1 = true;
            }
        }
        // Round 1: Δ = [0.5, 0.45 + 0.45(ẽ), 0, 0] → idx 1 wins and flushes.
        assert!(seen_idx1, "server EF never flushed the deferred coordinate");
    }

    /// Regression (deadlock found in CI): a fast worker may push iteration
    /// i+1 — rolling the key over — before a slow worker pulls iteration i.
    /// The retired aggregate must still be servable.
    #[test]
    fn late_pull_after_rollover_is_served() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        push(&mut core, 0, 0, 0, &[2.0]);
        push(&mut core, 0, 0, 1, &[4.0]); // iter 0 completes: mean = 3.0
        // Fast worker 0 pulls iter 0 and immediately pushes iter 1.
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        assert!(matches!(r[0].1, Message::PullResp { .. }));
        push(&mut core, 0, 1, 0, &[10.0]);
        // Slow worker 1 now pulls iter 0 — must be served from the retired
        // slot, not panic or hang.
        let r = core.handle(1, Message::Pull { key: 0, iter: 0, worker: 1 });
        assert_eq!(r.len(), 1);
        let Message::PullResp { iter, data, .. } = &r[0].1 else { panic!() };
        assert_eq!(*iter, 0);
        let mut out = vec![0.0f32; 1];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![3.0]);
        // And worker 1 proceeding to iter 1 still works.
        push(&mut core, 0, 1, 1, &[20.0]);
        let r = core.handle(1, Message::Pull { key: 0, iter: 1, worker: 1 });
        let Message::PullResp { data, .. } = &r[0].1 else { panic!() };
        let mut out = vec![0.0f32; 1];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![15.0]);
    }

    /// A pull that arrives before its iteration completes, while a previous
    /// iteration is retired, must queue (not be served stale data).
    #[test]
    fn pending_pull_for_future_iter_waits() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        push(&mut core, 0, 0, 0, &[1.0]);
        push(&mut core, 0, 0, 1, &[3.0]);
        let _ = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        push(&mut core, 0, 1, 0, &[5.0]);
        // worker 0 pulls iter 1 before worker 1 pushed it: queued.
        let r = core.handle(0, Message::Pull { key: 0, iter: 1, worker: 0 });
        assert!(r.is_empty());
        // worker 1 completes iter 1: the queued pull is answered with iter-1
        // data (not the retired iter-0 aggregate).
        let r = push(&mut core, 0, 1, 1, &[7.0]);
        let resp = r.iter().find(|(w, m)| *w == 0 && matches!(m, Message::PullResp { .. }));
        let Some((_, Message::PullResp { iter, data, .. })) = resp else { panic!("no resp") };
        assert_eq!(*iter, 1);
        let mut out = vec![0.0f32; 1];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![6.0]);
    }

    #[test]
    fn threaded_server_roundtrip_over_inproc() {
        let workers = 3;
        let dim = 64;
        let mut worker_eps = Vec::new();
        let mut server_eps = Vec::new();
        for _ in 0..workers {
            let (w, s) = crate::comm::inproc::pair();
            worker_eps.push(w);
            server_eps.push(s);
        }
        let server = Server::spawn(opts("identity", SyncMode::Full, workers), server_eps);
        let handles: Vec<_> = worker_eps
            .into_iter()
            .enumerate()
            .map(|(w, ep)| {
                std::thread::spawn(move || {
                    let comp = by_name("identity", 0.0).unwrap();
                    let mut rng = Xoshiro256::seed_from_u64(w as u64);
                    let g: Vec<f32> = (0..dim).map(|i| (w * dim + i) as f32).collect();
                    for iter in 0..5u64 {
                        let data = comp.compress(&g, &mut Ctx::new(&mut rng));
                        ep.send(Message::Push { key: 0, iter, worker: w as u32, data }).unwrap();
                        // ack may arrive before or after we pull; consume both.
                        ep.send(Message::Pull { key: 0, iter, worker: w as u32 }).unwrap();
                        let mut got_resp = None;
                        while got_resp.is_none() {
                            match ep.recv().unwrap() {
                                Message::Ack { .. } => {}
                                Message::PullResp { data, .. } => got_resp = Some(data),
                                m => panic!("unexpected {m:?}"),
                            }
                        }
                        let mut out = vec![0.0f32; dim];
                        comp.decompress(&got_resp.unwrap(), &mut out);
                        // mean over workers of (w*dim + i)
                        for (i, v) in out.iter().enumerate() {
                            let expect = (0..workers).map(|ww| (ww * dim + i) as f32).sum::<f32>()
                                / workers as f32;
                            assert!((v - expect).abs() < 1e-4);
                        }
                    }
                    ep.send(Message::Shutdown).unwrap();
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let stats = server.join();
        assert_eq!(stats.pushes, 15);
    }

    #[test]
    fn shard_plan_balances_better_than_round_robin() {
        // One huge tensor + many small ones (a transformer's shape).
        let mut costs = vec![1000.0];
        costs.extend(std::iter::repeat(10.0).take(40));
        let bal = ShardPlan::balanced(&costs, 4);
        let rr = ShardPlan::round_robin(costs.len(), 4);
        assert!(bal.imbalance(&costs) <= rr.imbalance(&costs));
        // balanced puts the huge tensor alone-ish: its server gets few others
        let big_server = bal.server_of(0);
        let others = (1..costs.len()).filter(|&k| bal.server_of(k as Key) == big_server).count();
        assert!(others <= 5, "{others} small tensors share the big server");
    }

    #[test]
    fn shard_plan_covers_all_servers() {
        let costs = vec![1.0; 16];
        let plan = ShardPlan::balanced(&costs, 4);
        for s in 0..4 {
            assert!((0..16).any(|k| plan.server_of(k as Key) == s));
        }
        assert!((plan.imbalance(&costs) - 1.0).abs() < 1e-9);
    }

    /// Per-block sharding (§4.2.4 under the pipeline): one huge tensor's
    /// blocks spread over every server instead of pinning one shard.
    #[test]
    fn keyed_plan_spreads_blocks_of_one_tensor() {
        // Tensor 0: 8 blocks of cost 100; tensors 1..5: one block each.
        let mut items: Vec<(Key, f64)> =
            (0..8).map(|b| (BlockKey::new(0, b).pack(), 100.0)).collect();
        for t in 1..5u64 {
            items.push((BlockKey::new(t, 0).pack(), 10.0));
        }
        let plan = ShardPlan::balanced_keyed(&items, 4);
        assert_eq!(plan.len(), items.len());
        let servers_of_big: std::collections::HashSet<usize> =
            (0..8).map(|b| plan.server_of(BlockKey::new(0, b).pack())).collect();
        assert_eq!(servers_of_big.len(), 4, "big tensor's blocks should span all servers");
        // Deterministic: same inputs, same plan.
        let plan2 = ShardPlan::balanced_keyed(&items, 4);
        for &(k, _) in &items {
            assert_eq!(plan.server_of(k), plan2.server_of(k));
        }
        let imb = plan.imbalance_by(|k| {
            items.iter().find(|(key, _)| *key == k).map(|(_, c)| *c).unwrap()
        });
        let rr = ShardPlan::round_robin_keyed(
            &items.iter().map(|(k, _)| *k).collect::<Vec<_>>(),
            4,
        );
        let rr_imb = rr.imbalance_by(|k| {
            items.iter().find(|(key, _)| *key == k).map(|(_, c)| *c).unwrap()
        });
        assert!(imb <= rr_imb + 1e-9);
    }

    #[test]
    #[should_panic(expected = "not in the shard plan")]
    fn unknown_key_panics_with_context() {
        let plan = ShardPlan::balanced(&[1.0, 2.0], 2);
        let _ = plan.server_of(BlockKey::new(7, 3).pack());
    }

    /// Corrupt push blocks are dropped at ingress, counted, and never panic
    /// the aggregator.
    #[test]
    fn corrupt_push_is_rejected_not_fatal() {
        let mut core = ServerCore::new(opts("topk", SyncMode::CompressedEf, 1));
        let mut payload = Vec::new();
        payload.extend_from_slice(&1u32.to_le_bytes());
        payload.extend_from_slice(&500u32.to_le_bytes()); // index >= n
        payload.extend_from_slice(&1.0f32.to_le_bytes());
        let bad = crate::compress::Compressed {
            scheme: crate::compress::SchemeId::TopK,
            n: 4,
            payload,
        };
        let replies =
            core.handle(0, Message::Push { key: 0, iter: 0, worker: 0, data: bad });
        assert!(replies.is_empty());
        assert_eq!(core.stats.rejected, 1);
        assert_eq!(core.stats.pushes, 0);
        // A valid push afterwards still works.
        let r = push(&mut core, 0, 0, 0, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(r.len(), 1);
        assert_eq!(core.stats.pushes, 1);
    }

    /// Regression (server panic on untrusted input): a rejected corrupt
    /// push leaves `count` short; the next iteration's rollover used to
    /// assert the aggregator down. It must recover — count the short
    /// iteration, discard the partial sum, and keep serving.
    #[test]
    fn short_iteration_after_corrupt_push_recovers() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        // Worker 0's push for iter 0 is corrupt (wrong element count after
        // the key is established) and gets rejected.
        push(&mut core, 0, 0, 1, &[1.0, 2.0]);
        let bad = crate::compress::Compressed {
            scheme: crate::compress::SchemeId::Identity,
            n: 1,
            payload: vec![0u8; 4],
        };
        let r = core.handle(0, Message::Push { key: 0, iter: 0, worker: 0, data: bad });
        assert!(r.is_empty());
        assert_eq!(core.stats.rejected, 1);
        // Iteration 0 is now permanently short (count == 1 of 2). Both
        // workers move on to iteration 1 — this used to panic.
        push(&mut core, 0, 1, 0, &[10.0, 20.0]);
        let r = push(&mut core, 0, 1, 1, &[30.0, 40.0]);
        assert!(!r.is_empty());
        assert_eq!(core.stats.short_iters, 1);
        // Iteration 1 completes and serves normally.
        let r = core.handle(0, Message::Pull { key: 0, iter: 1, worker: 0 });
        let Message::PullResp { data, .. } = &r[0].1 else { panic!("no resp: {r:?}") };
        let mut out = vec![0.0f32; 2];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![20.0, 30.0]);
    }

    /// Regression (server panic on untrusted input): a pull for a key with
    /// no prior push used to hit `.expect("pull before any push")`. It must
    /// queue and be served once the key appears.
    #[test]
    fn pull_before_any_push_queues_and_serves() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        let r = core.handle(1, Message::Pull { key: 7, iter: 0, worker: 1 });
        assert!(r.is_empty(), "queued, not panicked");
        assert_eq!(core.stats.early_pulls, 1);
        push(&mut core, 7, 0, 0, &[2.0]);
        let r = push(&mut core, 7, 0, 1, &[4.0]);
        // ack + the queued pull's response
        let resp = r.iter().find(|(w, m)| *w == 1 && matches!(m, Message::PullResp { .. }));
        let Some((_, Message::PullResp { data, .. })) = resp else { panic!("no resp: {r:?}") };
        let mut out = vec![0.0f32; 1];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![3.0]);
        // And the other worker's pull works as before.
        let r = core.handle(0, Message::Pull { key: 7, iter: 0, worker: 0 });
        assert!(matches!(r[0].1, Message::PullResp { .. }));
    }

    /// A pull whose iteration is older than the one-slot history is dropped
    /// and counted, never an assert.
    #[test]
    fn ancient_pull_is_counted_not_fatal() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 1));
        for iter in 0..4u64 {
            push(&mut core, 0, iter, 0, &[iter as f32]);
        }
        // Key is at iter 3; prev holds iter 2. A pull for iter 0 is stale.
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        assert!(r.is_empty());
        assert_eq!(core.stats.stale_pulls, 1);
        // Current iteration still serves.
        let r = core.handle(0, Message::Pull { key: 0, iter: 3, worker: 0 });
        assert!(matches!(r[0].1, Message::PullResp { .. }));
    }

    /// Handshake/reply messages leaking into a running server are ignored
    /// and counted, never a panic.
    #[test]
    fn unexpected_messages_are_counted_not_fatal() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 1));
        let r = core.handle(0, Message::Hello { worker: 0, n_keys: 3, config: 0 });
        assert!(r.is_empty());
        let r = core.handle(0, Message::Ack { key: 0, iter: 0 });
        assert!(r.is_empty());
        assert_eq!(core.stats.unexpected, 2);
        // Still fully functional afterwards.
        push(&mut core, 0, 0, 0, &[5.0]);
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        assert!(matches!(r[0].1, Message::PullResp { .. }));
    }

    /// A stale push (older than the key's current iteration) is rejected,
    /// not allowed to roll the key's clock backwards.
    #[test]
    fn backwards_push_is_rejected() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 1));
        push(&mut core, 0, 5, 0, &[1.0]);
        let r = push(&mut core, 0, 2, 0, &[9.0]);
        assert!(r.is_empty());
        assert_eq!(core.stats.rejected, 1);
        // The key still serves iteration 5.
        let r = core.handle(0, Message::Pull { key: 0, iter: 5, worker: 0 });
        assert!(matches!(r[0].1, Message::PullResp { .. }));
    }

    /// Replies route by the connection a message arrived on, never by the
    /// wire-supplied `worker` field — a spoofed (or out-of-range) id
    /// cannot steer replies to another worker or index the endpoint table
    /// out of bounds.
    #[test]
    fn replies_route_by_connection_not_wire_field() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        let mut rng = Xoshiro256::seed_from_u64(1);
        let data = core.opts.comp.compress(&[4.0, 6.0], &mut Ctx::new(&mut rng));
        // Connection 0 claims to be worker 999: ack still goes to 0.
        let r = core.handle(0, Message::Push { key: 0, iter: 0, worker: 999, data });
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].0, 0);
        assert!(matches!(r[0].1, Message::Ack { .. }));
        // A queued pull is answered on the connection it arrived on, not
        // at the spoofed id.
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 12345 });
        assert!(r.is_empty()); // queued: iteration incomplete
        let mut rng = Xoshiro256::seed_from_u64(2);
        let data = core.opts.comp.compress(&[1.0, 2.0], &mut Ctx::new(&mut rng));
        let r = core.handle(1, Message::Push { key: 0, iter: 0, worker: 42, data });
        assert!(r.iter().any(|(to, m)| *to == 1 && matches!(m, Message::Ack { .. })), "{r:?}");
        assert!(
            r.iter().any(|(to, m)| *to == 0 && matches!(m, Message::PullResp { .. })),
            "{r:?}"
        );
    }

    /// A client inventing keys cannot grow server memory without bound:
    /// pushes past `max_keys` established keys are rejected, pull-created
    /// placeholders have their own equal budget, and junk placeholders
    /// never starve traffic for real (established) keys.
    #[test]
    fn hostile_key_flood_is_bounded() {
        let mut o = opts("identity", SyncMode::Full, 1);
        o.max_keys = 2;
        let mut core = ServerCore::new(o);
        push(&mut core, 0, 0, 0, &[1.0]);
        push(&mut core, 1, 0, 0, &[2.0]);
        // Established keys at cap: a push for a third key bounces.
        let r = push(&mut core, 2, 0, 0, &[3.0]);
        assert!(r.is_empty());
        assert_eq!(core.stats.rejected, 1);
        // Pull-created placeholders have their own equal budget…
        assert!(core.handle(0, Message::Pull { key: 10, iter: 0, worker: 0 }).is_empty());
        assert!(core.handle(0, Message::Pull { key: 11, iter: 0, worker: 0 }).is_empty());
        // …beyond which junk-key pulls are dropped…
        assert!(core.handle(0, Message::Pull { key: 12, iter: 0, worker: 0 }).is_empty());
        assert_eq!(core.stats.rejected, 2);
        // …and junk placeholders never block established keys.
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        assert!(matches!(r[0].1, Message::PullResp { .. }));
        let r = push(&mut core, 1, 1, 0, &[5.0]);
        assert!(!r.is_empty());
    }

    /// Hostile pulls cannot poison a key's pending queue: future-iteration
    /// pulls on established keys are rejected outright (honest traffic
    /// can never produce them — per-connection FIFO processes a worker's
    /// push before its pull), placeholder floods hit the pending cap, and
    /// the queue fully drains at every completion.
    #[test]
    fn pull_flood_on_one_key_is_bounded() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 1));
        push(&mut core, 0, 0, 0, &[1.0]);
        for _ in 0..5 {
            let r = core.handle(0, Message::Pull { key: 0, iter: 99, worker: 0 });
            assert!(r.is_empty());
        }
        assert_eq!(core.stats.rejected, 5);
        // Placeholder floods: pending cap is 2 * n_workers = 2, so of five
        // queue attempts three are dropped.
        for i in 0..5u64 {
            let r = core.handle(0, Message::Pull { key: 7, iter: i, worker: 0 });
            assert!(r.is_empty());
        }
        assert_eq!(core.stats.stale_pulls, 3);
        // Establishing key 7 at iteration 0 serves the matching queued
        // pull and drains (drops) the junk one — nothing lingers.
        let r = push(&mut core, 7, 0, 0, &[1.0]);
        assert_eq!(r.len(), 2, "ack + the queued iter-0 pull: {r:?}");
        assert!(r.iter().any(|(_, m)| matches!(m, Message::PullResp { .. })));
        assert_eq!(core.stats.stale_pulls, 4);
        // The original key still serves its real iteration.
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        assert!(matches!(r[0].1, Message::PullResp { .. }));
    }

    #[test]
    fn shard_plan_assignments_roundtrip() {
        let plan = ShardPlan::balanced(&[5.0, 1.0, 3.0, 2.0], 3);
        let wire = plan.assignments();
        let back = ShardPlan::from_assignments(&wire, 3).unwrap();
        for k in 0..4u64 {
            assert_eq!(plan.server_of(k), back.server_of(k));
        }
        assert_eq!(back.assignments(), wire);
        // Untrusted input: out-of-range server and duplicate keys rejected.
        assert!(ShardPlan::from_assignments(&[(0, 3)], 3).is_err());
        assert!(ShardPlan::from_assignments(&[(0, 0), (0, 1)], 2).is_err());
        assert!(ShardPlan::from_assignments(&[], 0).is_err());
    }

    /// A *self-consistent* corrupt frame whose n disagrees with the key's
    /// established size must be rejected at ingress, not resize or panic
    /// the accumulator.
    #[test]
    fn push_with_wrong_element_count_is_rejected() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        push(&mut core, 0, 0, 0, &[1.0, 2.0, 3.0, 4.0]); // key 0 is 4 elems
        // Internally-consistent identity block with only 2 elements.
        let bad = crate::compress::Compressed {
            scheme: crate::compress::SchemeId::Identity,
            n: 2,
            payload: vec![0u8; 8],
        };
        let r = core.handle(1, Message::Push { key: 0, iter: 0, worker: 1, data: bad });
        assert!(r.is_empty());
        assert_eq!(core.stats.rejected, 1);
        // The honest worker can still complete the iteration.
        let r = push(&mut core, 0, 0, 1, &[5.0, 6.0, 7.0, 8.0]);
        assert_eq!(r.len(), 1);
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        let Message::PullResp { data, .. } = &r[0].1 else { panic!() };
        let mut out = vec![0.0f32; 4];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![3.0, 4.0, 5.0, 6.0]);
    }
}
