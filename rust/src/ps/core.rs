//! The server shard's round/rollover state machine (paper §4.1.2), shared
//! by both executors: the synchronous reference path
//! (`server.compress_threads = 0`, every stage inline on the I/O thread)
//! and the staged pipeline (`> 0`, decode/encode as pool jobs — see
//! [`crate::ps::stage`]).
//!
//! All *decisions* — wire validation, key budgets, dedup, stale/late
//! classification, rollover, seal order — happen here on the control
//! thread in message order, so the two executors decide identically. The
//! float work is factored into three deterministic steps:
//!
//! * **decode** — each accepted push becomes a dense contribution vector
//!   ([`stage::decode_contribution`], pure);
//! * **reduce** — at seal time the contributions are summed in
//!   *connection-index order* and averaged, so the f32 bits never depend
//!   on arrival or decode-completion order;
//! * **encode** — the second-way compression draws from a per-(key, iter)
//!   RNG ([`stage::seal_seed`]) and carries the key's server-EF residual,
//!   which is *lent* to the in-flight encode job — the next encode of the
//!   same key cannot start until the residual returns, so EF state is
//!   never raced and per-key encode order is iteration order.
//!
//! A sealed round whose decodes or encode are still in flight lives in the
//! key's seal pipeline: late pushes for it are dropped (never merged), a
//! second deadline sweep cannot re-seal it, pulls for it join the seal's
//! waiter list and are answered with the exact sealed bytes when the
//! encode lands — including after a rollover retired it into the one-slot
//! `prev` history.
// Wire-facing module: the static-invariants lint (rust/src/lint) keeps
// this file panic-free outside tests, and clippy enforces the same at
// the `unwrap`/`expect` level.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use crate::comm::{Key, Message};
use crate::compress::{Compressed, Compressor};
use crate::configx::SyncMode;
use crate::parallel::ThreadPool;
use crate::ps::stage::{self, EventSink, Executor, StageEvent};
use crate::ps::stats::ServerStats;
use std::collections::{HashMap, VecDeque};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Full rounds a shard must observe before deadline auto-tuning
/// (`server.iter_deadline_auto_margin`) derives its first deadline — a
/// p99 over fewer rounds is noise.
pub const AUTO_DEADLINE_MIN_ROUNDS: u64 = 8;

/// Floor for an auto-tuned deadline: however tight the observed p99, the
/// derived deadline never drops below this (normal scheduling jitter at
/// sub-millisecond deadlines would seal healthy rounds).
pub const AUTO_DEADLINE_FLOOR: Duration = Duration::from_millis(1);

/// Server behaviour knobs.
#[derive(Clone)]
pub struct ServerOptions {
    pub comp: Arc<dyn Compressor>,
    pub sync: SyncMode,
    /// Fused EF residual update (§4.2.2).
    pub fused: bool,
    pub n_workers: usize,
    /// Intra-task threads for (de)compression (§4.2.1).
    pub intra_threads: usize,
    pub seed: u64,
    /// Cap on distinct keys this shard will materialize state for
    /// (0 = unlimited). The launchers set it to the partition size so a
    /// client inventing keys cannot grow server memory without bound.
    pub max_keys: usize,
    /// Iteration deadline for degraded rounds (`server.iter_deadline_ms`):
    /// a round with at least one push that stays incomplete this long is
    /// sealed and served partial (`served_with < n_workers`). `None` =
    /// strict BSP — a lost push stalls its iteration's pulls forever, but
    /// behavior is bit-identical to the pre-deadline server — unless
    /// `deadline_auto_margin` derives one from observed round latencies.
    pub iter_deadline: Option<Duration>,
    /// Width of the shard's staged decode/encode pool
    /// (`server.compress_threads`). `0` = the synchronous reference path:
    /// every stage runs inline on the I/O thread, exactly the
    /// pre-staged shard. Any value `> 0` is bit-identical to `0` for the
    /// whole `compress::paper_suite()` (tested in [`crate::ps::stage`]).
    pub compress_threads: usize,
    /// Deadline auto-tuning (`server.iter_deadline_auto_margin`): with
    /// `iter_deadline` unset and this margin `> 0`, the shard derives its
    /// deadline as observed p99 full-round latency × margin (floored at
    /// [`AUTO_DEADLINE_FLOOR`]), re-evaluated at every sealed full round
    /// once [`AUTO_DEADLINE_MIN_ROUNDS`] rounds are on record. `0` = off.
    pub deadline_auto_margin: f64,
    /// Granted adaptive keep-ratio envelope `(k_min_ppm, k_max_ppm)`, the
    /// same pair every `Welcome` on this shard carries (`adaptive.*`
    /// knobs; see [`crate::compress::controller`]). `Some` makes ingress
    /// enforce it: a structurally valid TopK/RandomK push whose element
    /// budget `k` falls outside `[k_for_ppm(lo, n), k_for_ppm(hi, n)]` is
    /// dropped and counted as `bounds_rejected`, never merged and never a
    /// panic. `None` = static run — zero behavioral change.
    pub adaptive_bounds: Option<(u32, u32)>,
}

/// A sealed round whose bytes are not ready yet: its seal was decided (by
/// count or by the deadline) but decodes may still be in flight, and the
/// encode behind them. Lives in its key's FIFO seal pipeline; at most the
/// front seal is ever being encoded.
struct Seal {
    iter: u64,
    /// Contributions in the aggregate — the wire `served_with` tag.
    served: u16,
    /// Averaging divisor (= contributor count; `served` saturates at
    /// `u16::MAX`, the divisor must not).
    count: usize,
    /// Connections to answer with the sealed bytes when the encode lands:
    /// pulls queued before the seal plus pulls that arrived while it was
    /// in flight.
    waiters: Vec<u32>,
    /// Decode results collected so far, in arrival order (sorted by
    /// connection index at reduce time).
    decoded: Vec<(u32, Vec<f32>)>,
    /// Decode jobs still in flight for this round.
    awaiting: usize,
}

/// An encode job in flight for this key (at most one; EF residual lending
/// serializes them). Pulls for `iter` arriving meanwhile join `waiters`.
struct EncodeSlot {
    iter: u64,
    waiters: Vec<u32>,
}

struct KeyState {
    iter: u64,
    /// Canonical element count for this key, fixed by the first *push*
    /// (`None` while the key has only seen pulls — a pull-before-push
    /// queues rather than panicking the shard). Later pushes whose `n`
    /// disagrees are rejected at ingress — a self-consistent corrupt frame
    /// must not resize (or panic on) the reducer.
    dim: Option<usize>,
    /// `(connection index, contribution weight)` pairs for the current
    /// round, in arrival order. The *connection* is the trusted identity
    /// (the wire `worker` field is not), and deduplicating on it keeps a
    /// retransmitting or hostile client from completing a round early
    /// with one worker double-counted — which would also make the
    /// `served_with` tag lie about how many workers the aggregate holds.
    /// A flat push weighs 1; a hierarchical group push weighs its clamped
    /// `members` claim — the round completes when the weights sum to
    /// `n_workers`, so a server fronted by G group leaders still averages
    /// exactly like one fronted by W flat workers.
    contributors: Vec<(u32, u16)>,
    /// Decode results for the current (open) round, in arrival order.
    /// The float sum is deferred to seal time so it can run in
    /// connection-index order — the price is holding up to `n_workers`
    /// decoded vectors per open round instead of one accumulator.
    decoded: Vec<(u32, Vec<f32>)>,
    /// Decode jobs in flight for the current round.
    inflight_decodes: usize,
    /// When the current round's first push arrived — the iteration
    /// deadline's clock. `None` while the round is empty or already
    /// sealed.
    round_started: Option<Instant>,
    /// Sealed rounds whose bytes are not ready yet, FIFO by iteration.
    /// Always empty on the synchronous path (seals complete inline).
    seals: VecDeque<Seal>,
    /// The encode job in flight for this key, if any.
    encoding: Option<EncodeSlot>,
    /// Server-side EF residual (`ẽ`, Alg. 4). `None` before the first
    /// EF seal — and while lent to an in-flight encode job, which is what
    /// serializes encodes of one key.
    residual: Option<Vec<f32>>,
    /// The sealed aggregate for `iter`, tagged with how many worker
    /// contributions it holds (`served_with`: `n_workers` for a full BSP
    /// round, fewer for a deadline-degraded one).
    ready: Option<(u16, Compressed)>,
    /// The previous iteration's aggregate. BSP lets a fast worker *push*
    /// iteration i+1 (which rolls this key over) before a slow worker has
    /// *pulled* iteration i — the slow pull must still be servable.
    /// Workers never lag more than one iteration (they pull i before
    /// pushing i+1), so one slot suffices.
    ///
    /// This invariant survives the block pipeline: keys are now per-block
    /// and blocks of one iteration arrive out of order across *different*
    /// keys, but each `KeyState` is keyed by one block, and every worker
    /// still completes pull(key, i) before it sends push(key, i+1) — the
    /// pipelined push phase starts only after the previous exchange's pull
    /// phase fully drained, and both transports preserve per-endpoint FIFO
    /// order. So per key the lag stays bounded by one iteration and the
    /// one-slot rollover is still sufficient (tested in
    /// `rust/tests/distributed.rs`).
    ///
    /// The *iteration deadline* is the one exception: it can seal rounds
    /// without a stalled worker's push, so the clock may advance two or
    /// more past a live-but-delayed worker. Such a worker's pull finds
    /// neither `ready` nor `prev` and is answered with the retired
    /// marker ([`retired_marker`], `served_with == 0`) so it fails
    /// loudly instead of hanging on a reply that cannot come.
    ///
    /// Under the staged executor the retiring round's bytes may still be
    /// encoding when the rollover happens: the encode completion routes
    /// here (`on_event`, `Encoded`) instead of into `ready`.
    prev: Option<(u64, u16, Compressed)>,
    /// Queued pulls as (iter, connection index) — the endpoint to answer
    /// on, which is the server's ground truth for who is asking (the wire
    /// `worker` field is untrusted).
    pending: Vec<(u64, u32)>,
    /// When the most recent *degraded* seal's round started. A late push
    /// for that round reveals the round's true arrival spread (it did
    /// complete, just slower than the deadline) — recorded into the
    /// latency histogram so auto-tuning can *widen* again. Without this
    /// the tuner ratchets: a too-tight derived deadline seals every round
    /// degraded, degraded seals never feed the histogram, and no full
    /// round ever re-runs the derivation.
    degraded_round_started: Option<(u64, Instant)>,
}

impl KeyState {
    /// Empty state at `iter` — no dimension yet (a *placeholder* until
    /// the first push establishes the element count).
    fn fresh(iter: u64) -> KeyState {
        KeyState {
            iter,
            dim: None,
            contributors: Vec::new(),
            decoded: Vec::new(),
            inflight_decodes: 0,
            round_started: None,
            seals: VecDeque::new(),
            encoding: None,
            residual: None,
            ready: None,
            prev: None,
            pending: Vec::new(),
            degraded_round_started: None,
        }
    }
}

/// Reply for an unservable pull: a `PullResp` whose `served_with` is 0
/// and whose block is empty. No real aggregate can have zero
/// contributors, so the marker is unambiguous on the wire. It exists
/// because the iteration deadline breaks strict BSP's guarantee that the
/// key clock never advances two past a live worker: a worker delayed
/// ~2 deadlines can ask for an iteration already evicted from the
/// one-slot history, and silently dropping that pull would hang it
/// forever — the marker lets it fail loudly instead.
fn retired_marker(key: Key, iter: u64) -> Message {
    Message::PullResp {
        key,
        iter,
        served_with: 0,
        data: crate::compress::Compressed {
            scheme: crate::compress::SchemeId::Identity,
            n: 0,
            payload: Vec::new(),
        },
    }
}

/// The server's round state machine: feed it messages (and, under the
/// staged executor, stage-completion events), collect replies. Separated
/// from the I/O loop so tests can drive it deterministically.
pub struct ServerCore {
    pub(crate) opts: ServerOptions,
    exec: Executor,
    keys: HashMap<Key, KeyState>,
    /// Keys whose dimension a push has established. Junk *placeholders*
    /// (pull-created, dim `None`) are budgeted separately so a client
    /// pulling made-up keys can never starve pushes for real keys.
    established_keys: usize,
    /// Stage jobs (decode + encode) submitted but not yet applied via
    /// [`on_event`](ServerCore::on_event). Always 0 on the synchronous
    /// path. The I/O loop drains to 0 before reporting final stats.
    jobs_in_flight: usize,
    decode_inflight: usize,
    encode_inflight: usize,
    /// Deadline derived by auto-tuning (`deadline_auto_margin`), if any.
    auto_deadline: Option<Duration>,
    pub stats: ServerStats,
}

impl ServerCore {
    /// Synchronous reference core: every stage runs inline on the caller's
    /// thread, exactly the pre-staged shard.
    pub fn new(opts: ServerOptions) -> Self {
        Self::with_executor(opts, Executor::Inline)
    }

    /// Staged core: decode/encode run as jobs on `pool`, completions are
    /// delivered to `sink` and must be fed back through
    /// [`on_event`](ServerCore::on_event) by the owning loop.
    pub fn new_staged(opts: ServerOptions, pool: Arc<ThreadPool>, sink: EventSink) -> Self {
        Self::with_executor(opts, Executor::Pool { pool, sink })
    }

    fn with_executor(opts: ServerOptions, exec: Executor) -> Self {
        ServerCore {
            opts,
            exec,
            keys: HashMap::new(),
            established_keys: 0,
            jobs_in_flight: 0,
            decode_inflight: 0,
            encode_inflight: 0,
            auto_deadline: None,
            stats: ServerStats::default(),
        }
    }

    /// Stage jobs submitted but not yet applied (0 on the synchronous
    /// path; the I/O loop drains this to 0 before reporting stats).
    pub fn jobs_in_flight(&self) -> usize {
        self.jobs_in_flight
    }

    /// The deadline in force: the static `server.iter_deadline_ms` knob,
    /// or the auto-tuned one (`deadline_auto_margin`) once enough full
    /// rounds are on record. `None` = strict BSP.
    pub fn current_deadline(&self) -> Option<Duration> {
        self.opts.iter_deadline.or(self.auto_deadline)
    }

    /// Whether a push may establish one more key (the real keyspace is
    /// bounded by the partition; anything past `max_keys` is hostile).
    fn at_established_capacity(&self) -> bool {
        self.opts.max_keys > 0 && self.established_keys >= self.opts.max_keys
    }

    /// Whether creating one more pull-created placeholder would exceed its
    /// budget (equal to `max_keys`): total key state stays bounded even
    /// against a client pulling arbitrary made-up keys.
    fn at_placeholder_capacity(&self, key: Key) -> bool {
        self.opts.max_keys > 0
            && !self.keys.contains_key(&key)
            && self.keys.len() - self.established_keys >= self.opts.max_keys
    }

    /// Whether the round `st` is currently at (`st.iter`) has been sealed
    /// — bytes ready, encode in flight, or seal waiting on decodes. A
    /// push for a sealed round is *late*, never merged.
    fn round_sealed(st: &KeyState) -> bool {
        st.ready.is_some()
            || st.encoding.as_ref().is_some_and(|e| e.iter == st.iter)
            || st.seals.iter().any(|s| s.iter == st.iter)
    }

    /// How long `iter`'s round had really been open when a late push for
    /// it arrived — `Some` only if `iter` is the key's most recent
    /// *degraded* seal, and at most once per sealed round (the slot is
    /// consumed): a retransmitting or hostile client re-sending the same
    /// late push must not record an ever-growing sample each time and
    /// drag the auto-tuned deadline toward the histogram ceiling. The
    /// first straggler proves the round would have completed, just slower
    /// than the deadline; its arrival time is the round's true spread.
    fn late_round_spread(st: &mut KeyState, iter: u64) -> Option<Duration> {
        match st.degraded_round_started {
            Some((di, t0)) if di == iter => {
                st.degraded_round_started = None;
                Some(Instant::now().saturating_duration_since(t0))
            }
            _ => None,
        }
    }

    /// Feed a late-push round spread into the latency histogram and
    /// re-derive the auto deadline. This is what lets auto-tuning *widen*
    /// after a too-tight derivation: with every round sealing degraded no
    /// full round would ever record a latency again, and the tuner would
    /// ratchet tight forever. Genuinely lost pushes never arrive, so true
    /// faults contribute nothing — the deadline does not inflate for them.
    /// Only active when auto-tuning is in force — with a static deadline
    /// (or none) the histogram keeps its pure full-round-latency meaning
    /// for the shutdown line and the bench, and a 10-second straggler
    /// cannot inflate the reported p99.
    fn note_late_spread(&mut self, spread: Option<Duration>) {
        if self.opts.iter_deadline.is_some() || self.opts.deadline_auto_margin <= 0.0 {
            return;
        }
        if let Some(d) = spread {
            self.stats.round_hist.record(d);
            self.retune_deadline();
        }
    }

    /// Attach a pull to the in-flight seal or encode for `iter`, if one
    /// exists: it will be answered with the sealed bytes when the encode
    /// lands. Returns whether the pull was taken.
    fn join_seal(st: &mut KeyState, iter: u64, from: u32) -> bool {
        match st.encoding.as_mut() {
            Some(slot) if slot.iter == iter => {
                slot.waiters.push(from);
                return true;
            }
            _ => {}
        }
        if let Some(seal) = st.seals.iter_mut().find(|s| s.iter == iter) {
            seal.waiters.push(from);
            return true;
        }
        false
    }

    /// Handle one message from connection `from`; returns
    /// `(connection index, reply)` pairs to send. On the synchronous path
    /// every consequence (decode, seal, encode, queued-pull answers) is in
    /// the returned replies; on the staged path the heavy stages complete
    /// later through [`on_event`](ServerCore::on_event).
    pub fn handle(&mut self, from: u32, msg: Message) -> Vec<(u32, Message)> {
        let t0 = Instant::now();
        // Ingress time excludes kernel seconds even when kernels run
        // inline (the synchronous path): subtract what the stages accrued
        // during this call.
        let k0 = self.stats.decode_s + self.stats.reduce_s + self.stats.encode_s;
        let replies = self.handle_inner(from, msg);
        let kernels = (self.stats.decode_s + self.stats.reduce_s + self.stats.encode_s) - k0;
        self.stats.ingress_s += (t0.elapsed().as_secs_f64() - kernels).max(0.0);
        replies
    }

    fn handle_inner(&mut self, from: u32, msg: Message) -> Vec<(u32, Message)> {
        match msg {
            // Replies are addressed by `from` — the connection the message
            // arrived on — never by the wire-supplied `worker` field. A
            // client lying about (or botching) its id must not be able to
            // steer replies to another worker or index the endpoint table
            // out of bounds; the field is kept for diagnostics only.
            Message::Push { key, iter, worker, data } => {
                self.ingest_push(from, key, iter, worker, 1, data)
            }
            // A group leader's combined push (hierarchical two-level
            // topology): the ingress decisions are identical to a flat
            // push, but it weighs `members` contributions — clamped to
            // the round's remaining capacity inside `ingest_push` —
            // toward round completion, the averaging divisor, and the
            // `served_with` tag.
            Message::GroupPush { key, iter, worker, members, data } => {
                self.ingest_push(from, key, iter, worker, members, data)
            }
            Message::Pull { key, iter, worker } => {
                self.stats.pulls += 1;
                if self.at_placeholder_capacity(key) {
                    eprintln!(
                        "server: dropping pull for unknown key {key} from worker {worker}: \
                         shard is at its placeholder capacity"
                    );
                    self.stats.rejected += 1;
                    // Unservable-pull policy: always answer (see
                    // retired_marker) — a dropped pull must never become
                    // a silent hang on the puller's side.
                    return vec![(from, retired_marker(key, iter))];
                }
                let n_workers = self.opts.n_workers;
                // A pull may precede any push for its key — a reordered
                // startup, or a client probing unknown keys. Queue it (as
                // a budgeted placeholder) until the key appears instead of
                // panicking the shard.
                let st = self.keys.entry(key).or_insert_with(|| KeyState::fresh(iter));
                if st.dim.is_none() {
                    self.stats.early_pulls += 1;
                }
                if st.dim.is_some() {
                    if st.iter == iter {
                        if let Some((served, p)) = &st.ready {
                            return vec![(
                                from,
                                Message::PullResp {
                                    key,
                                    iter,
                                    served_with: *served,
                                    data: p.clone(),
                                },
                            )];
                        }
                        // Sealed but still decoding/encoding (staged
                        // executor): answered with the sealed bytes when
                        // they land.
                        if Self::join_seal(st, iter, from) {
                            return vec![];
                        }
                    } else if let Some((piter, served, p)) = &st.prev {
                        // A pull lagging one iteration behind a fast pusher.
                        if *piter == iter {
                            return vec![(
                                from,
                                Message::PullResp {
                                    key,
                                    iter,
                                    served_with: *served,
                                    data: p.clone(),
                                },
                            )];
                        }
                    }
                    if iter < st.iter {
                        // The retired round's bytes may still be in the
                        // seal pipeline (rollover mid-encode): join it.
                        if Self::join_seal(st, iter, from) {
                            return vec![];
                        }
                        // Older than the one-slot history: unservable.
                        // Under strict BSP only a hostile client gets
                        // here, but the iteration deadline can advance
                        // the key clock past a live worker that stalls
                        // for ~2 deadlines — answer with the retired
                        // marker so it fails loudly instead of waiting
                        // forever for a reply that cannot come.
                        eprintln!(
                            "server: retiring stale pull for key {key} iteration {iter} \
                             from worker {worker} (key is at {})",
                            st.iter
                        );
                        self.stats.stale_pulls += 1;
                        return vec![(from, retired_marker(key, iter))];
                    }
                    if iter > st.iter.saturating_add(1) {
                        // Impossible for honest traffic even with lost
                        // pushes: a worker only advances to iteration i+1
                        // after its pull for i completed, so its future
                        // lag is bounded by one. Queueing beyond that
                        // would let a flood of far-future pulls poison
                        // the pending queue forever — reject instead.
                        eprintln!(
                            "server: rejecting future pull for key {key} iteration {iter} \
                             from worker {worker} (key is at {})",
                            st.iter
                        );
                        self.stats.rejected += 1;
                        // Honest traffic cannot get here, but answer
                        // anyway — a dropped pull must never become a
                        // silent hang.
                        return vec![(from, retired_marker(key, iter))];
                    }
                    // iter == st.iter with no sealed round falls through
                    // to the queue, as does iter == st.iter + 1: the
                    // puller's own push for that round may have been
                    // lost (per-connection FIFO no longer implies the
                    // key's clock reached `iter` once pushes can be
                    // dropped), and rejecting it would strand the worker
                    // forever — the deadline seal serves the queue.
                }
                // Honest traffic queues at most one pull per worker per
                // key; anything past a small multiple is a flood (pulls
                // for iterations that will never be served) — drop it
                // rather than grow the queue without bound.
                if st.pending.len() >= 2 * n_workers.max(1) {
                    eprintln!(
                        "server: dropping pull for key {key} iteration {iter} from \
                         worker {worker}: pending queue full"
                    );
                    self.stats.stale_pulls += 1;
                    return vec![(from, retired_marker(key, iter))];
                }
                st.pending.push((iter, from));
                vec![]
            }
            Message::Shutdown => vec![],
            // Hello/Welcome/PullResp/Ack have no business arriving at a
            // running server; any client can send them, so they must never
            // panic the shard — ignore and count.
            other => {
                let tag = match other {
                    Message::Hello { .. } => "Hello",
                    Message::Welcome { .. } => "Welcome",
                    Message::PullResp { .. } => "PullResp",
                    Message::Ack { .. } => "Ack",
                    _ => "unknown",
                };
                eprintln!("server: ignoring unexpected {tag} message from worker {from}");
                self.stats.unexpected += 1;
                vec![]
            }
        }
    }

    /// Shared ingress for flat pushes (`claimed` = 1) and hierarchical
    /// group pushes (`claimed` = the leader's `members` field): one code
    /// path, so the two kinds are validated, deduplicated, and
    /// late/stale-classified identically. The claim is *clamped* to the
    /// round's remaining contributor capacity before it counts — a
    /// hostile leader overstating its group cannot inflate the averaging
    /// divisor or `served_with` past the workers that exist, it can only
    /// complete the round (counted in `members_clamped`).
    fn ingest_push(
        &mut self,
        from: u32,
        key: Key,
        iter: u64,
        worker: u32,
        claimed: u16,
        data: Compressed,
    ) -> Vec<(u32, Message)> {
        // Untrusted wire data: reject corrupt blocks instead of
        // letting a bad index/length panic the decoder. (The
        // TCP transport already rejects these at frame decode;
        // this also covers the in-process transport.)
        if let Err(e) = crate::compress::validate_wire(&data) {
            eprintln!("server: rejecting corrupt push for key {key} from worker {worker}: {e}");
            self.stats.rejected += 1;
            return vec![];
        }
        // Adaptive envelope (negotiated at registration): a
        // structurally valid sparse block may still claim a keep
        // ratio the handshake never granted — an honest controller
        // stays inside the granted bounds (it clamps in ppm space
        // and shares `k_for_ppm` with this check), so anything
        // outside is a hostile or misconfigured client. Dropped
        // and counted, never merged. Empty blocks (`n == 0`) are
        // exempt: the sparsifiers emit `k == 0` for them while the
        // envelope floor is 1 element.
        if let Some((lo, hi)) = self.opts.adaptive_bounds {
            use crate::compress::controller::k_for_ppm;
            use crate::compress::SchemeId;
            if matches!(data.scheme, SchemeId::TopK | SchemeId::RandomK) && data.n > 0 {
                // validate_wire proved payload >= 4 bytes; the
                // leading u32 is the block's element budget `k`
                // for both sparse layouts.
                let k = crate::compress::get_u32(&data.payload, 0) as usize;
                let (k_lo, k_hi) = (k_for_ppm(lo, data.n), k_for_ppm(hi, data.n));
                if k < k_lo || k > k_hi {
                    eprintln!(
                        "server: rejecting out-of-bounds push for key {key} from \
                         worker {worker}: k={k} outside granted [{k_lo}, {k_hi}] \
                         (n={}, envelope [{lo}, {hi}] ppm)",
                        data.n
                    );
                    self.stats.bounds_rejected += 1;
                    return vec![];
                }
            }
        }
        // Every push targets (or establishes) an established key;
        // placeholders don't consume this budget until a push
        // gives them a dimension. Checked before touching the map
        // so a rejected junk push cannot leave a placeholder
        // behind either. (Hoisted: `st` below holds a &mut borrow
        // of the key map.)
        let at_established_cap = self.at_established_capacity();
        if at_established_cap && !self.keys.contains_key(&key) {
            eprintln!(
                "server: rejecting push for unknown key {key} from worker {worker}: \
                 shard is at its {}-key capacity",
                self.opts.max_keys
            );
            self.stats.rejected += 1;
            return vec![];
        }
        let n_workers = self.opts.n_workers;
        let max_keys = self.opts.max_keys;
        let st = self.keys.entry(key).or_insert_with(|| KeyState::fresh(iter));
        match st.dim {
            // A self-consistent corrupt frame can still carry the
            // wrong element count for this key; reject it rather
            // than resize (or panic on) the reducer.
            Some(d) if data.n != d => {
                eprintln!(
                    "server: rejecting push for key {key} from worker {worker}: \
                     n={} but the key has {d} elements",
                    data.n
                );
                self.stats.rejected += 1;
                return vec![];
            }
            // First push fixes the key's element count. The state
            // may be a placeholder from an earlier queued pull, so
            // adopt the pusher's iteration clock too — and charge
            // the establishment budget now.
            None => {
                if at_established_cap {
                    eprintln!(
                        "server: rejecting push establishing key {key} from worker \
                         {worker}: shard is at its {max_keys}-key capacity"
                    );
                    self.stats.rejected += 1;
                    return vec![];
                }
                st.dim = Some(data.n);
                st.iter = iter;
                self.established_keys += 1;
            }
            _ => {}
        }
        if iter < st.iter {
            // A push for an iteration this key already retired.
            // If it targets the just-retired (one-slot history)
            // round — whose bytes may still be encoding under the
            // staged executor — it is the honest straggler the
            // degraded-round protocol tolerates, and belongs in
            // the `late_pushes` telemetry, not the corruption
            // counter. Anything older is a hostile client or a
            // straggler beyond BSP's lag bound. Unusable either
            // way; drop.
            let retired_match = st.prev.as_ref().is_some_and(|(p, _, _)| *p == iter)
                || st.encoding.as_ref().is_some_and(|s| s.iter == iter)
                || st.seals.iter().any(|s| s.iter == iter);
            if retired_match {
                eprintln!(
                    "server: dropping late push for key {key} iteration {iter} \
                     from worker {worker}: the round was sealed and retired"
                );
                self.stats.late_pushes += 1;
                let spread = Self::late_round_spread(st, iter);
                self.note_late_spread(spread);
            } else {
                eprintln!(
                    "server: rejecting stale push for key {key} iteration {iter} \
                     from worker {worker} (key is at {})",
                    st.iter
                );
                self.stats.rejected += 1;
            }
            return vec![];
        }
        if st.iter != iter {
            // New iteration for this key: retire the sealed
            // aggregate (slow workers may still pull it) and reset
            // the round. A short round — a rejected corrupt push
            // left the round below n_workers and no deadline
            // sealed it — is recovered by discarding the partial
            // contributions, never by asserting the shard down on
            // untrusted input. A sealed round (bytes ready, or
            // still in the seal pipeline) was already counted
            // where it sealed; it must not be double-counted as
            // short here.
            let sealed = Self::round_sealed(st);
            let present: usize = st.contributors.iter().map(|&(_, w)| usize::from(w)).sum();
            if present > 0 && present != n_workers && !sealed {
                eprintln!(
                    "server: key {key} iteration {} was short ({present}/{n_workers} \
                     contribution weight); discarding the partial round",
                    st.iter
                );
                self.stats.short_iters += 1;
            }
            if let Some((served, p)) = st.ready.take() {
                st.prev = Some((st.iter, served, p));
            }
            // A seal still in the pipeline routes its bytes into
            // `prev` at encode completion (`on_event`); discarded
            // partial decodes are dropped here, and any of their
            // jobs still in flight become stale events.
            st.iter = iter;
            st.contributors.clear();
            st.decoded.clear();
            st.inflight_decodes = 0;
            st.round_started = None;
        } else if Self::round_sealed(st) {
            // The round for `iter` is already sealed — by a full
            // BSP completion (this is a duplicate push) or by the
            // iteration deadline (this is the late straggler the
            // degraded-round protocol tolerates). Either way the
            // aggregate may already be in other workers' hands:
            // merging retroactively would hand different workers
            // different bytes for the same iteration. Drop it,
            // counted — a rejected or late push is never
            // resurrected.
            eprintln!(
                "server: dropping late push for key {key} iteration {iter} from \
                 worker {worker}: the round is already sealed"
            );
            self.stats.late_pushes += 1;
            let spread = Self::late_round_spread(st, iter);
            self.note_late_spread(spread);
            return vec![];
        }
        if st.contributors.iter().any(|&(c, _)| c == from) {
            // A second push from the same connection for an open
            // round — a retransmitting or hostile client. Counting
            // it would complete the round early with one worker
            // double-counted (and `served_with` lying about it);
            // the connection index is the trusted identity, never
            // the wire `worker` field.
            eprintln!(
                "server: rejecting duplicate push for key {key} iteration {iter} \
                 from connection {from} (claims worker {worker})"
            );
            self.stats.rejected += 1;
            return vec![];
        }
        if st.contributors.is_empty() {
            // First push of the round starts the deadline clock.
            st.round_started = Some(Instant::now());
        }
        // Weighted contribution. An open round always has weight capacity
        // left (it seals the instant the weights reach `n_workers`), so
        // the clamped weight is at least 1 — a group push is never
        // silently zero-weighted. A claim of 0 (nonsensical: a leader
        // always carries at least itself) is treated as 1.
        let present: usize = st.contributors.iter().map(|&(_, w)| usize::from(w)).sum();
        let capacity = n_workers.saturating_sub(present).max(1);
        let weight = usize::from(claimed.max(1)).min(capacity);
        if usize::from(claimed) > weight {
            eprintln!(
                "server: clamping group push for key {key} iteration {iter} from \
                 worker {worker}: claimed {claimed} members, round capacity {capacity}"
            );
            self.stats.members_clamped += 1;
        }
        st.contributors.push((from, weight.min(usize::from(u16::MAX)) as u16));
        let complete = present + weight >= n_workers;
        self.stats.pushes += 1;
        let mut replies = vec![(from, Message::Ack { key, iter })];
        self.dispatch_decode(key, iter, from, data, &mut replies);
        if complete {
            self.decide_seal(key, &mut replies);
        }
        replies
    }

    /// Apply one stage-job completion. On the synchronous path this is
    /// called recursively from `handle`/`poll_deadlines`; the staged I/O
    /// loop calls it with events drained from its channel.
    pub fn on_event(&mut self, ev: StageEvent) -> Vec<(u32, Message)> {
        let mut replies = Vec::new();
        match ev {
            StageEvent::Decoded { key, iter, from, buf, ns } => {
                self.stats.decode_s += ns as f64 * 1e-9;
                self.jobs_in_flight -= 1;
                self.decode_inflight -= 1;
                let mut pump = false;
                if let Some(st) = self.keys.get_mut(&key) {
                    if let Some(seal) = st.seals.iter_mut().find(|s| s.iter == iter) {
                        // A decode landing for an already-sealed round
                        // (the deadline sealed it mid-flight, or the
                        // completing push's own decode under the pool).
                        debug_assert!(seal.awaiting > 0, "decode for a fully-decoded seal");
                        seal.decoded.push((from, buf));
                        seal.awaiting = seal.awaiting.saturating_sub(1);
                        pump = seal.awaiting == 0;
                    } else if st.iter == iter && st.inflight_decodes > 0 {
                        debug_assert!(
                            st.contributors.iter().any(|&(c, _)| c == from),
                            "decode for a non-contributor"
                        );
                        st.decoded.push((from, buf));
                        st.inflight_decodes -= 1;
                    }
                    // else: the round was discarded (short) at rollover
                    // before this decode landed — drop the result.
                }
                if pump {
                    self.pump_seals(key, &mut replies);
                }
            }
            StageEvent::Encoded { key, iter, served, data, residual, ns } => {
                self.stats.encode_s += ns as f64 * 1e-9;
                self.jobs_in_flight -= 1;
                self.encode_inflight -= 1;
                if let Some(st) = self.keys.get_mut(&key) {
                    // Returning the residual is what lets the next encode
                    // of this key start (EF encodes serialize per key).
                    st.residual = residual;
                    if let Some(slot) = st.encoding.take() {
                        debug_assert_eq!(slot.iter, iter, "encode completion out of order");
                        for w in slot.waiters {
                            replies.push((
                                w,
                                Message::PullResp {
                                    key,
                                    iter,
                                    served_with: served,
                                    data: data.clone(),
                                },
                            ));
                        }
                    }
                    if st.iter == iter {
                        st.ready = Some((served, data));
                    } else if st.iter == iter + 1 {
                        // The key rolled over while this round was
                        // encoding: the bytes land straight in the
                        // one-slot history.
                        st.prev = Some((iter, served, data));
                    }
                    // else: the key advanced two or more mid-encode (only
                    // hostile traffic can — honest workers pull `iter`
                    // first, which this completion just answered). The
                    // bytes are retired; matching pulls were answered
                    // above, later ones get the retired marker.
                }
                self.pump_seals(key, &mut replies);
            }
        }
        replies
    }

    /// Seal the current round of `key` with the contributions present —
    /// the *decision*, shared by normal BSP completion
    /// (`count == n_workers`) and the iteration deadline
    /// (`count < n_workers`, a degraded round). Drains the pending-pull
    /// queue exactly like the pre-staged server (matching pulls become
    /// waiters on the sealed bytes, everything else is unservable and
    /// marker-answered), then hands the round to the seal pipeline: the
    /// reduce runs once its decodes land, the encode after that. For a
    /// full round the averaging divisor equals `n_workers`, so the
    /// strict-BSP path is bit-identical to the pre-deadline server.
    fn decide_seal(&mut self, key: Key, replies: &mut Vec<(u32, Message)>) {
        let n_workers = self.opts.n_workers;
        let now = Instant::now();
        let Some(st) = self.keys.get_mut(&key) else {
            // Every caller just touched this key's state, so a miss here
            // means shard-internal bookkeeping drifted — count it and keep
            // the shard serving instead of taking the whole process down.
            self.stats.internal_errors += 1;
            eprintln!("server: internal error — sealing unknown key {key}");
            return;
        };
        debug_assert!(!Self::round_sealed(st), "sealing an already-sealed round");
        debug_assert!(!st.contributors.is_empty(), "sealing an empty round");
        // Weighted: a group push counts its (clamped) member weight toward
        // both the averaging divisor and the `served_with` tag, so G
        // leaders fronting W workers average exactly like W flat pushes.
        let count: usize = st.contributors.iter().map(|&(_, w)| usize::from(w)).sum();
        let served = count.min(u16::MAX as usize) as u16;
        let iter = st.iter;
        let mut full_latency = None;
        if count < n_workers {
            eprintln!(
                "server: iteration deadline — serving key {key} iteration {iter} degraded \
                 ({count}/{n_workers} pushes)"
            );
            self.stats.degraded_iters += 1;
            // Remember when this round opened: a straggler's late push
            // will reveal the round's true spread (see note_late_spread).
            st.degraded_round_started = st.round_started.map(|t0| (iter, t0));
        } else if let Some(t0) = st.round_started {
            // Full rounds feed the latency histogram (and deadline
            // auto-tuning); degraded rounds would just echo the deadline
            // back.
            full_latency = Some(now.saturating_duration_since(t0));
        }
        // The queue fully drains at every seal: matching pulls wait for
        // the sealed bytes, everything else (short-iteration leftovers,
        // placeholder-era junk) is unservable and dropped — nothing
        // hostile can sit in `pending` displacing honest pulls forever.
        let pending: Vec<(u64, u32)> = std::mem::take(&mut st.pending);
        let mut waiters = Vec::new();
        for (piter, w) in pending {
            if piter == iter {
                waiters.push(w);
            } else {
                eprintln!(
                    "server: retiring unservable queued pull for key {key} \
                     iteration {piter} from worker {w} (key is at {iter})"
                );
                self.stats.stale_pulls += 1;
                replies.push((w, retired_marker(key, piter)));
            }
        }
        st.seals.push_back(Seal {
            iter,
            served,
            count,
            waiters,
            decoded: std::mem::take(&mut st.decoded),
            awaiting: st.inflight_decodes,
        });
        st.inflight_decodes = 0;
        st.round_started = None;
        if let Some(lat) = full_latency {
            self.stats.round_hist.record(lat);
            self.retune_deadline();
        }
        self.pump_seals(key, replies);
    }

    /// Advance `key`'s seal pipeline: while the front seal has every
    /// decode in hand and no encode is in flight for this key, run the
    /// *reduce* (sum in connection-index order, average) and dispatch the
    /// *encode*. On the synchronous path the encode completes inline and
    /// the loop naturally drains the whole pipeline.
    fn pump_seals(&mut self, key: Key, replies: &mut Vec<(u32, Message)>) {
        loop {
            let Some(st) = self.keys.get_mut(&key) else { return };
            if st.encoding.is_some() {
                return;
            }
            let Some(front) = st.seals.front() else { return };
            if front.awaiting > 0 {
                return;
            }
            let (Some(seal), Some(dim)) = (st.seals.pop_front(), st.dim) else {
                // `front()` above proved a seal exists, and no push is
                // accepted before the key's dimension is pinned — losing
                // either here is internal drift, not client input. Count
                // it and abandon this key's pipeline rather than panic.
                self.stats.internal_errors += 1;
                eprintln!("server: internal error — seal pipeline for key {key} lost its state");
                return;
            };
            // Reduce: deterministic regardless of arrival or decode
            // completion order — contributions are summed sorted by
            // connection index, then averaged over the pushes actually
            // received.
            let t = Instant::now();
            let mut decoded = seal.decoded;
            decoded.sort_by_key(|(from, _)| *from);
            // lint: transfers(encode)
            let mut acc = crate::comm::BufPool::global().rent_f32(dim);
            for (_, buf) in decoded {
                crate::compress::kernels::add_assign(&mut acc, &buf);
                // The contribution dies here; recycle it for future decodes.
                crate::comm::BufPool::global().give_f32(buf);
            }
            let inv = 1.0 / seal.count as f32;
            crate::compress::kernels::scale_assign(&mut acc, inv);
            self.stats.reduce_s += t.elapsed().as_secs_f64();
            let residual = st.residual.take();
            st.encoding = Some(EncodeSlot { iter: seal.iter, waiters: seal.waiters });
            self.dispatch_encode(key, seal.iter, seal.served, acc, residual, replies);
            // Inline executor: the encode (and its on_event) already ran —
            // loop to drain any further ready seals. Pool executor: the
            // encode slot is occupied, so the next iteration returns.
        }
    }

    /// Run or submit one decode job for an accepted push.
    fn dispatch_decode(
        &mut self,
        key: Key,
        iter: u64,
        from: u32,
        data: Compressed,
        replies: &mut Vec<(u32, Message)>,
    ) {
        self.jobs_in_flight += 1;
        self.decode_inflight += 1;
        self.stats.decode_depth_peak =
            self.stats.decode_depth_peak.max(self.decode_inflight as u64);
        if let Some(st) = self.keys.get_mut(&key) {
            st.inflight_decodes += 1;
        }
        if let Executor::Pool { pool, sink } = &self.exec {
            let comp = Arc::clone(&self.opts.comp);
            let sink = Arc::clone(sink);
            pool.execute(move || {
                let t = Instant::now();
                let buf = stage::decode_contribution(comp.as_ref(), &data);
                let ns = t.elapsed().as_nanos() as u64;
                // The wire payload dies with the decode; recycle it.
                crate::comm::BufPool::global().give_bytes(data.payload);
                sink(StageEvent::Decoded { key, iter, from, buf, ns });
            });
        } else {
            let t = Instant::now();
            let buf = stage::decode_contribution(self.opts.comp.as_ref(), &data);
            let ns = t.elapsed().as_nanos() as u64;
            crate::comm::BufPool::global().give_bytes(data.payload);
            let evs = self.on_event(StageEvent::Decoded { key, iter, from, buf, ns });
            replies.extend(evs);
        }
    }

    /// Run or submit one encode (second-way compression) job for a sealed,
    /// reduced aggregate.
    fn dispatch_encode(
        &mut self,
        key: Key,
        iter: u64,
        served: u16,
        acc: Vec<f32>,
        residual: Option<Vec<f32>>,
        replies: &mut Vec<(u32, Message)>,
    ) {
        self.jobs_in_flight += 1;
        self.encode_inflight += 1;
        self.stats.encode_depth_peak =
            self.stats.encode_depth_peak.max(self.encode_inflight as u64);
        let seed = stage::seal_seed(self.opts.seed, key, iter);
        if let Executor::Pool { pool, sink } = &self.exec {
            let comp = Arc::clone(&self.opts.comp);
            let (sync, fused, intra) = (self.opts.sync, self.opts.fused, self.opts.intra_threads);
            let sink = Arc::clone(sink);
            pool.execute(move || {
                let t = Instant::now();
                let (data, residual) =
                    stage::encode_aggregate(comp.as_ref(), sync, fused, intra, seed, acc, residual);
                let ns = t.elapsed().as_nanos() as u64;
                sink(StageEvent::Encoded { key, iter, served, data, residual, ns });
            });
        } else {
            let t = Instant::now();
            let (data, residual) = stage::encode_aggregate(
                self.opts.comp.as_ref(),
                self.opts.sync,
                self.opts.fused,
                self.opts.intra_threads,
                seed,
                acc,
                residual,
            );
            let ns = t.elapsed().as_nanos() as u64;
            let evs = self.on_event(StageEvent::Encoded { key, iter, served, data, residual, ns });
            replies.extend(evs);
        }
    }

    /// Re-derive the auto-tuned deadline from the round-latency histogram
    /// (called at every sealed full round). Static `iter_deadline` wins;
    /// below [`AUTO_DEADLINE_MIN_ROUNDS`] observations nothing is derived.
    fn retune_deadline(&mut self) {
        if self.opts.iter_deadline.is_some() || self.opts.deadline_auto_margin <= 0.0 {
            return;
        }
        if self.stats.round_hist.count() < AUTO_DEADLINE_MIN_ROUNDS {
            return;
        }
        let p99 = self.stats.round_hist.quantile(0.99);
        let derived =
            Duration::from_secs_f64(p99.as_secs_f64() * self.opts.deadline_auto_margin);
        self.auto_deadline = Some(derived.max(AUTO_DEADLINE_FLOOR));
    }

    /// Iteration-deadline sweep: seal every round that has at least one
    /// push, has not been sealed, and saw its first push at least
    /// [`current_deadline`](ServerCore::current_deadline) ago — serving
    /// pulls a *partial* aggregate marked `served_with < n_workers`
    /// instead of stalling every worker forever on a lost or rejected
    /// push. Returns the replies to send. No-op when no deadline is in
    /// force (static or auto-tuned).
    ///
    /// `now` is an explicit argument so tests can drive the clock
    /// deterministically; the I/O loop passes `Instant::now()`. A sealed
    /// round clears its deadline clock, so a second sweep can never
    /// double-seal — even while the first seal's decodes or encode are
    /// still in flight on the staged path.
    pub fn poll_deadlines(&mut self, now: Instant) -> Vec<(u32, Message)> {
        let Some(deadline) = self.current_deadline() else {
            return Vec::new();
        };
        let mut due: Vec<Key> = self
            .keys
            .iter()
            .filter(|(_, st)| {
                !st.contributors.is_empty()
                    && st
                        .round_started
                        .is_some_and(|t0| now.saturating_duration_since(t0) >= deadline)
            })
            .map(|(&k, _)| k)
            .collect();
        // Deterministic seal order (HashMap iteration order is not).
        due.sort_unstable();
        let mut replies = Vec::new();
        for key in due {
            self.decide_seal(key, &mut replies);
        }
        replies
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::compress::{by_name, Ctx};
    use crate::util::rng::Xoshiro256;

    fn opts(scheme: &str, sync: SyncMode, workers: usize) -> ServerOptions {
        ServerOptions {
            comp: by_name(scheme, 0.25).unwrap(),
            sync,
            fused: true,
            n_workers: workers,
            intra_threads: 1,
            seed: 7,
            max_keys: 0,
            iter_deadline: None,
            compress_threads: 0,
            deadline_auto_margin: 0.0,
            adaptive_bounds: None,
        }
    }

    /// Same, with an iteration deadline. Tests drive `poll_deadlines`
    /// with explicit clocks, so the duration's magnitude is irrelevant.
    fn opts_deadline(scheme: &str, sync: SyncMode, workers: usize) -> ServerOptions {
        ServerOptions {
            iter_deadline: Some(std::time::Duration::from_millis(50)),
            ..opts(scheme, sync, workers)
        }
    }

    /// A clock strictly past every configured test deadline.
    fn after_deadline() -> Instant {
        Instant::now() + std::time::Duration::from_secs(3600)
    }

    fn push(core: &mut ServerCore, key: Key, iter: u64, worker: u32, g: &[f32]) -> Vec<(u32, Message)> {
        let mut rng = Xoshiro256::seed_from_u64(worker as u64 + 100);
        let data = core.opts.comp.compress(g, &mut Ctx::new(&mut rng));
        core.handle(worker, Message::Push { key, iter, worker, data })
    }

    #[test]
    fn aggregates_identity_to_exact_mean() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        let r1 = push(&mut core, 0, 0, 0, &[1.0, 2.0]);
        assert_eq!(r1.len(), 1); // just the ack
        let r2 = push(&mut core, 0, 0, 1, &[3.0, 6.0]);
        assert_eq!(r2.len(), 1);
        // Now pull
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        let Message::PullResp { data, .. } = &r[0].1 else { panic!() };
        let mut out = vec![0.0f32; 2];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![2.0, 4.0]);
    }

    #[test]
    fn pull_before_complete_is_queued() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        push(&mut core, 5, 0, 0, &[1.0]);
        let r = core.handle(1, Message::Pull { key: 5, iter: 0, worker: 1 });
        assert!(r.is_empty()); // queued
        let r = push(&mut core, 5, 0, 1, &[3.0]);
        // ack + the queued pull's response
        assert_eq!(r.len(), 2);
        assert!(matches!(r[1].1, Message::PullResp { .. }));
        assert_eq!(r[1].0, 1);
    }

    #[test]
    fn iterations_reset_round() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 1));
        push(&mut core, 0, 0, 0, &[10.0]);
        push(&mut core, 0, 1, 0, &[2.0]);
        let r = core.handle(0, Message::Pull { key: 0, iter: 1, worker: 0 });
        let Message::PullResp { data, .. } = &r[0].1 else { panic!() };
        let mut out = vec![0.0f32; 1];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![2.0]); // not 12.0
    }

    #[test]
    fn server_ef_residual_accumulates_under_topk() {
        // Two workers with different dominant coordinates: the server's
        // second-way top-k can keep only one of them per round; ẽ must
        // carry the other forward and flush it on a later round
        // (Alg. 4's server side). Uses dim=4 so topk(0.25) keeps 1.
        let mut core = ServerCore::new(opts("topk", SyncMode::CompressedEf, 2));
        let ga = vec![1.0f32, 0.0, 0.0, 0.0]; // worker 0's spike
        let gb = vec![0.0f32, 0.9, 0.0, 0.0]; // worker 1's spike
        let mut seen_idx1 = false;
        for iter in 0..10u64 {
            push(&mut core, 0, iter, 0, &ga);
            push(&mut core, 0, iter, 1, &gb);
            let r = core.handle(0, Message::Pull { key: 0, iter, worker: 0 });
            let Message::PullResp { data, .. } = &r[0].1 else { panic!() };
            let mut p = vec![0.0f32; 4];
            core.opts.comp.decompress(data, &mut p);
            if iter == 0 {
                // Round 0: Δ = [0.5, 0.45, 0, 0]; top-1 keeps idx 0 only.
                assert_eq!(p, vec![0.5, 0.0, 0.0, 0.0]);
            }
            if p[1] > 0.0 {
                seen_idx1 = true;
            }
        }
        // Round 1: Δ = [0.5, 0.45 + 0.45(ẽ), 0, 0] → idx 1 wins and flushes.
        assert!(seen_idx1, "server EF never flushed the deferred coordinate");
    }

    /// Regression (deadlock found in CI): a fast worker may push iteration
    /// i+1 — rolling the key over — before a slow worker pulls iteration i.
    /// The retired aggregate must still be servable.
    #[test]
    fn late_pull_after_rollover_is_served() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        push(&mut core, 0, 0, 0, &[2.0]);
        push(&mut core, 0, 0, 1, &[4.0]); // iter 0 completes: mean = 3.0
        // Fast worker 0 pulls iter 0 and immediately pushes iter 1.
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        assert!(matches!(r[0].1, Message::PullResp { .. }));
        push(&mut core, 0, 1, 0, &[10.0]);
        // Slow worker 1 now pulls iter 0 — must be served from the retired
        // slot, not panic or hang.
        let r = core.handle(1, Message::Pull { key: 0, iter: 0, worker: 1 });
        assert_eq!(r.len(), 1);
        let Message::PullResp { iter, data, .. } = &r[0].1 else { panic!() };
        assert_eq!(*iter, 0);
        let mut out = vec![0.0f32; 1];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![3.0]);
        // And worker 1 proceeding to iter 1 still works.
        push(&mut core, 0, 1, 1, &[20.0]);
        let r = core.handle(1, Message::Pull { key: 0, iter: 1, worker: 1 });
        let Message::PullResp { data, .. } = &r[0].1 else { panic!() };
        let mut out = vec![0.0f32; 1];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![15.0]);
    }

    /// A pull that arrives before its iteration completes, while a previous
    /// iteration is retired, must queue (not be served stale data).
    #[test]
    fn pending_pull_for_future_iter_waits() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        push(&mut core, 0, 0, 0, &[1.0]);
        push(&mut core, 0, 0, 1, &[3.0]);
        let _ = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        push(&mut core, 0, 1, 0, &[5.0]);
        // worker 0 pulls iter 1 before worker 1 pushed it: queued.
        let r = core.handle(0, Message::Pull { key: 0, iter: 1, worker: 0 });
        assert!(r.is_empty());
        // worker 1 completes iter 1: the queued pull is answered with iter-1
        // data (not the retired iter-0 aggregate).
        let r = push(&mut core, 0, 1, 1, &[7.0]);
        let resp = r.iter().find(|(w, m)| *w == 0 && matches!(m, Message::PullResp { .. }));
        let Some((_, Message::PullResp { iter, data, .. })) = resp else { panic!("no resp") };
        assert_eq!(*iter, 1);
        let mut out = vec![0.0f32; 1];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![6.0]);
    }

    /// Corrupt push blocks are dropped at ingress, counted, and never panic
    /// the shard.
    #[test]
    fn corrupt_push_is_rejected_not_fatal() {
        let mut core = ServerCore::new(opts("topk", SyncMode::CompressedEf, 1));
        let mut payload = Vec::new();
        payload.extend_from_slice(&1u32.to_le_bytes());
        payload.extend_from_slice(&500u32.to_le_bytes()); // index >= n
        payload.extend_from_slice(&1.0f32.to_le_bytes());
        let bad = crate::compress::Compressed {
            scheme: crate::compress::SchemeId::TopK,
            n: 4,
            payload,
        };
        let replies =
            core.handle(0, Message::Push { key: 0, iter: 0, worker: 0, data: bad });
        assert!(replies.is_empty());
        assert_eq!(core.stats.rejected, 1);
        assert_eq!(core.stats.pushes, 0);
        // A valid push afterwards still works.
        let r = push(&mut core, 0, 0, 0, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(r.len(), 1);
        assert_eq!(core.stats.pushes, 1);
    }

    /// Regression (server panic on untrusted input): a rejected corrupt
    /// push leaves the round short; the next iteration's rollover used to
    /// assert the shard down. It must recover — count the short
    /// iteration, discard the partial round, and keep serving.
    #[test]
    fn short_iteration_after_corrupt_push_recovers() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        // Worker 0's push for iter 0 is corrupt (wrong element count after
        // the key is established) and gets rejected.
        push(&mut core, 0, 0, 1, &[1.0, 2.0]);
        let bad = crate::compress::Compressed {
            scheme: crate::compress::SchemeId::Identity,
            n: 1,
            payload: vec![0u8; 4],
        };
        let r = core.handle(0, Message::Push { key: 0, iter: 0, worker: 0, data: bad });
        assert!(r.is_empty());
        assert_eq!(core.stats.rejected, 1);
        // Iteration 0 is now permanently short (count == 1 of 2). Both
        // workers move on to iteration 1 — this used to panic.
        push(&mut core, 0, 1, 0, &[10.0, 20.0]);
        let r = push(&mut core, 0, 1, 1, &[30.0, 40.0]);
        assert!(!r.is_empty());
        assert_eq!(core.stats.short_iters, 1);
        // Iteration 1 completes and serves normally.
        let r = core.handle(0, Message::Pull { key: 0, iter: 1, worker: 0 });
        let Message::PullResp { data, .. } = &r[0].1 else { panic!("no resp: {r:?}") };
        let mut out = vec![0.0f32; 2];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![20.0, 30.0]);
    }

    /// Regression (server panic on untrusted input): a pull for a key with
    /// no prior push used to hit `.expect("pull before any push")`. It must
    /// queue and be served once the key appears.
    #[test]
    fn pull_before_any_push_queues_and_serves() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        let r = core.handle(1, Message::Pull { key: 7, iter: 0, worker: 1 });
        assert!(r.is_empty(), "queued, not panicked");
        assert_eq!(core.stats.early_pulls, 1);
        push(&mut core, 7, 0, 0, &[2.0]);
        let r = push(&mut core, 7, 0, 1, &[4.0]);
        // ack + the queued pull's response
        let resp = r.iter().find(|(w, m)| *w == 1 && matches!(m, Message::PullResp { .. }));
        let Some((_, Message::PullResp { data, .. })) = resp else { panic!("no resp: {r:?}") };
        let mut out = vec![0.0f32; 1];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![3.0]);
        // And the other worker's pull works as before.
        let r = core.handle(0, Message::Pull { key: 7, iter: 0, worker: 0 });
        assert!(matches!(r[0].1, Message::PullResp { .. }));
    }

    /// A pull whose iteration is older than the one-slot history is dropped
    /// and counted, never an assert.
    #[test]
    fn ancient_pull_is_counted_not_fatal() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 1));
        for iter in 0..4u64 {
            push(&mut core, 0, iter, 0, &[iter as f32]);
        }
        // Key is at iter 3; prev holds iter 2. A pull for iter 0 is stale
        // and answered with the retired marker (served_with == 0, empty
        // block) so the puller can fail loudly instead of hanging.
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        assert_eq!(r.len(), 1);
        let Message::PullResp { iter, served_with, data, .. } = &r[0].1 else { panic!("{r:?}") };
        assert_eq!((*iter, *served_with, data.n), (0, 0, 0));
        assert_eq!(core.stats.stale_pulls, 1);
        // Current iteration still serves.
        let r = core.handle(0, Message::Pull { key: 0, iter: 3, worker: 0 });
        assert!(matches!(r[0].1, Message::PullResp { .. }));
    }

    /// Handshake/reply messages leaking into a running server are ignored
    /// and counted, never a panic.
    #[test]
    fn unexpected_messages_are_counted_not_fatal() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 1));
        let r = core.handle(
            0,
            Message::Hello { worker: 0, n_keys: 3, config: 0, k_min_ppm: 0, k_max_ppm: 0 },
        );
        assert!(r.is_empty());
        let r = core.handle(0, Message::Ack { key: 0, iter: 0 });
        assert!(r.is_empty());
        assert_eq!(core.stats.unexpected, 2);
        // Still fully functional afterwards.
        push(&mut core, 0, 0, 0, &[5.0]);
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        assert!(matches!(r[0].1, Message::PullResp { .. }));
    }

    /// A stale push (older than the key's current iteration) is rejected,
    /// not allowed to roll the key's clock backwards.
    #[test]
    fn backwards_push_is_rejected() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 1));
        push(&mut core, 0, 5, 0, &[1.0]);
        let r = push(&mut core, 0, 2, 0, &[9.0]);
        assert!(r.is_empty());
        assert_eq!(core.stats.rejected, 1);
        // The key still serves iteration 5.
        let r = core.handle(0, Message::Pull { key: 0, iter: 5, worker: 0 });
        assert!(matches!(r[0].1, Message::PullResp { .. }));
    }

    /// Replies route by the connection a message arrived on, never by the
    /// wire-supplied `worker` field — a spoofed (or out-of-range) id
    /// cannot steer replies to another worker or index the endpoint table
    /// out of bounds.
    #[test]
    fn replies_route_by_connection_not_wire_field() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        let mut rng = Xoshiro256::seed_from_u64(1);
        let data = core.opts.comp.compress(&[4.0, 6.0], &mut Ctx::new(&mut rng));
        // Connection 0 claims to be worker 999: ack still goes to 0.
        let r = core.handle(0, Message::Push { key: 0, iter: 0, worker: 999, data });
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].0, 0);
        assert!(matches!(r[0].1, Message::Ack { .. }));
        // A queued pull is answered on the connection it arrived on, not
        // at the spoofed id.
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 12345 });
        assert!(r.is_empty()); // queued: iteration incomplete
        let mut rng = Xoshiro256::seed_from_u64(2);
        let data = core.opts.comp.compress(&[1.0, 2.0], &mut Ctx::new(&mut rng));
        let r = core.handle(1, Message::Push { key: 0, iter: 0, worker: 42, data });
        assert!(r.iter().any(|(to, m)| *to == 1 && matches!(m, Message::Ack { .. })), "{r:?}");
        assert!(
            r.iter().any(|(to, m)| *to == 0 && matches!(m, Message::PullResp { .. })),
            "{r:?}"
        );
    }

    /// A client inventing keys cannot grow server memory without bound:
    /// pushes past `max_keys` established keys are rejected, pull-created
    /// placeholders have their own equal budget, and junk placeholders
    /// never starve traffic for real (established) keys.
    #[test]
    fn hostile_key_flood_is_bounded() {
        let mut o = opts("identity", SyncMode::Full, 1);
        o.max_keys = 2;
        let mut core = ServerCore::new(o);
        push(&mut core, 0, 0, 0, &[1.0]);
        push(&mut core, 1, 0, 0, &[2.0]);
        // Established keys at cap: a push for a third key bounces.
        let r = push(&mut core, 2, 0, 0, &[3.0]);
        assert!(r.is_empty());
        assert_eq!(core.stats.rejected, 1);
        // Pull-created placeholders have their own equal budget…
        assert!(core.handle(0, Message::Pull { key: 10, iter: 0, worker: 0 }).is_empty());
        assert!(core.handle(0, Message::Pull { key: 11, iter: 0, worker: 0 }).is_empty());
        // …beyond which junk-key pulls bounce with the retired marker…
        let r = core.handle(0, Message::Pull { key: 12, iter: 0, worker: 0 });
        assert_eq!(r.len(), 1);
        assert!(matches!(r[0].1, Message::PullResp { served_with: 0, .. }), "{r:?}");
        assert_eq!(core.stats.rejected, 2);
        // …and junk placeholders never block established keys.
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        assert!(matches!(r[0].1, Message::PullResp { .. }));
        let r = push(&mut core, 1, 1, 0, &[5.0]);
        assert!(!r.is_empty());
    }

    /// Hostile pulls cannot poison a key's pending queue: future-iteration
    /// pulls on established keys are rejected outright (honest traffic
    /// can never produce them — per-connection FIFO processes a worker's
    /// push before its pull), placeholder floods hit the pending cap, and
    /// the queue fully drains at every completion.
    #[test]
    fn pull_flood_on_one_key_is_bounded() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 1));
        push(&mut core, 0, 0, 0, &[1.0]);
        for _ in 0..5 {
            // Far-future pulls are rejected — answered with the retired
            // marker, never a silent drop.
            let r = core.handle(0, Message::Pull { key: 0, iter: 99, worker: 0 });
            assert_eq!(r.len(), 1);
            let Message::PullResp { served_with, .. } = &r[0].1 else { panic!("{r:?}") };
            assert_eq!(*served_with, 0);
        }
        assert_eq!(core.stats.rejected, 5);
        // Placeholder floods: pending cap is 2 * n_workers = 2, so of five
        // queue attempts three are dropped (marker-answered).
        for i in 0..5u64 {
            let r = core.handle(0, Message::Pull { key: 7, iter: i, worker: 0 });
            if i < 2 {
                assert!(r.is_empty(), "pull {i} should queue: {r:?}");
            } else {
                assert_eq!(r.len(), 1, "pull {i} should bounce with a marker: {r:?}");
            }
        }
        assert_eq!(core.stats.stale_pulls, 3);
        // Establishing key 7 at iteration 0 serves the matching queued
        // pull and drains the junk one with a retired marker — nothing
        // lingers, nothing is silently dropped.
        let r = push(&mut core, 7, 0, 0, &[1.0]);
        assert_eq!(r.len(), 3, "ack + served iter-0 pull + retired iter-1 marker: {r:?}");
        assert!(r
            .iter()
            .any(|(_, m)| matches!(m, Message::PullResp { served_with: 1.., .. })));
        assert!(r
            .iter()
            .any(|(_, m)| matches!(m, Message::PullResp { served_with: 0, .. })));
        assert_eq!(core.stats.stale_pulls, 4);
        // The original key still serves its real iteration.
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        assert!(matches!(r[0].1, Message::PullResp { .. }));
    }

    /// A *self-consistent* corrupt frame whose n disagrees with the key's
    /// established size must be rejected at ingress, not resize or panic
    /// the reducer.
    #[test]
    fn push_with_wrong_element_count_is_rejected() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        push(&mut core, 0, 0, 0, &[1.0, 2.0, 3.0, 4.0]); // key 0 is 4 elems
        // Internally-consistent identity block with only 2 elements.
        let bad = crate::compress::Compressed {
            scheme: crate::compress::SchemeId::Identity,
            n: 2,
            payload: vec![0u8; 8],
        };
        let r = core.handle(1, Message::Push { key: 0, iter: 0, worker: 1, data: bad });
        assert!(r.is_empty());
        assert_eq!(core.stats.rejected, 1);
        // The honest worker can still complete the iteration.
        let r = push(&mut core, 0, 0, 1, &[5.0, 6.0, 7.0, 8.0]);
        assert_eq!(r.len(), 1);
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        let Message::PullResp { data, .. } = &r[0].1 else { panic!() };
        let mut out = vec![0.0f32; 4];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![3.0, 4.0, 5.0, 6.0]);
    }

    /// The iteration deadline seals a round that has at least one push:
    /// the partial aggregate (averaged over the pushes received) is served
    /// with `served_with < n_workers`, and a full round still reports
    /// `served_with == n_workers`.
    #[test]
    fn deadline_seals_partial_round_and_serves_degraded() {
        let mut core = ServerCore::new(opts_deadline("identity", SyncMode::Full, 2));
        push(&mut core, 0, 0, 0, &[2.0, 4.0]);
        // Worker 1 pulls before its (lost) push completed the round: queued.
        let r = core.handle(1, Message::Pull { key: 0, iter: 0, worker: 1 });
        assert!(r.is_empty());
        let replies = core.poll_deadlines(after_deadline());
        assert_eq!(replies.len(), 1, "the queued pull must be answered: {replies:?}");
        let (to, Message::PullResp { iter, served_with, data, .. }) = &replies[0] else {
            panic!("not a PullResp: {replies:?}")
        };
        assert_eq!((*to, *iter, *served_with), (1, 0, 1));
        let mut out = vec![0.0f32; 2];
        core.opts.comp.decompress(data, &mut out);
        // Averaged over the one contribution received, not n_workers.
        assert_eq!(out, vec![2.0, 4.0]);
        assert_eq!(core.stats.degraded_iters, 1);
        assert_eq!(core.stats.short_iters, 0);
        // A later pull for the sealed iteration is served the same bytes.
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        let Message::PullResp { served_with, .. } = &r[0].1 else { panic!("{r:?}") };
        assert_eq!(*served_with, 1);
    }

    /// With no deadline configured, `poll_deadlines` is a strict no-op —
    /// the incomplete round keeps waiting (strict BSP).
    #[test]
    fn deadline_unset_poll_is_noop() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        push(&mut core, 0, 0, 0, &[1.0]);
        assert!(core.poll_deadlines(after_deadline()).is_empty());
        assert_eq!(core.stats.degraded_iters, 0);
        // The pull still queues rather than being served partial.
        let r = core.handle(1, Message::Pull { key: 0, iter: 0, worker: 1 });
        assert!(r.is_empty());
    }

    /// A round sealed by the deadline must not be counted *again* as a
    /// short iteration when the key rolls over, and the next iteration
    /// completes as a normal full round.
    #[test]
    fn deadline_does_not_double_count_short_iters() {
        let mut core = ServerCore::new(opts_deadline("identity", SyncMode::Full, 2));
        push(&mut core, 0, 0, 0, &[2.0]);
        assert!(core.poll_deadlines(after_deadline()).is_empty()); // nothing queued
        assert_eq!(core.stats.degraded_iters, 1);
        // Both workers proceed to iteration 1; the rollover must not see a
        // "short" round — the partial was served, not lost.
        push(&mut core, 0, 1, 0, &[10.0]);
        let r = push(&mut core, 0, 1, 1, &[20.0]);
        assert!(!r.is_empty());
        assert_eq!(core.stats.short_iters, 0);
        assert_eq!(core.stats.degraded_iters, 1);
        let r = core.handle(0, Message::Pull { key: 0, iter: 1, worker: 0 });
        let Message::PullResp { served_with, data, .. } = &r[0].1 else { panic!("{r:?}") };
        assert_eq!(*served_with, 2);
        let mut out = vec![0.0f32; 1];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![15.0]);
    }

    /// A push rejected before the deadline fired stays rejected: when the
    /// same worker re-sends a now-valid push for the sealed round, it is
    /// dropped as late (`late_pushes`) — the aggregate other workers may
    /// already hold never changes retroactively.
    #[test]
    fn deadline_does_not_resurrect_rejected_push() {
        let mut core = ServerCore::new(opts_deadline("identity", SyncMode::Full, 2));
        push(&mut core, 0, 0, 0, &[6.0, 8.0]);
        // Worker 1's push is corrupt (wrong element count) and rejected.
        let bad = crate::compress::Compressed {
            scheme: crate::compress::SchemeId::Identity,
            n: 1,
            payload: vec![0u8; 4],
        };
        let r = core.handle(1, Message::Push { key: 0, iter: 0, worker: 1, data: bad });
        assert!(r.is_empty());
        assert_eq!(core.stats.rejected, 1);
        // Deadline fires: round sealed with worker 0's contribution only.
        core.poll_deadlines(after_deadline());
        assert_eq!(core.stats.degraded_iters, 1);
        // Worker 1 retries with a valid push for the sealed iteration: no
        // ack, counted late, aggregate untouched.
        let r = push(&mut core, 0, 0, 1, &[100.0, 200.0]);
        assert!(r.is_empty(), "late push must not be acked: {r:?}");
        assert_eq!(core.stats.late_pushes, 1);
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        let Message::PullResp { served_with, data, .. } = &r[0].1 else { panic!("{r:?}") };
        assert_eq!(*served_with, 1);
        let mut out = vec![0.0f32; 2];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![6.0, 8.0]);
        // And a second sweep never re-seals the same round.
        assert!(core.poll_deadlines(after_deadline()).is_empty());
        assert_eq!(core.stats.degraded_iters, 1);
    }

    /// A degraded aggregate retires into the one-slot history like any
    /// other: a slow worker pulling the sealed iteration after a rollover
    /// still gets the partial aggregate with its `served_with` tag.
    #[test]
    fn degraded_aggregate_survives_rollover() {
        let mut core = ServerCore::new(opts_deadline("identity", SyncMode::Full, 2));
        push(&mut core, 0, 0, 0, &[4.0]);
        core.poll_deadlines(after_deadline());
        assert_eq!(core.stats.degraded_iters, 1);
        // The fast worker moves on, rolling the key over.
        push(&mut core, 0, 1, 0, &[10.0]);
        let r = core.handle(1, Message::Pull { key: 0, iter: 0, worker: 1 });
        let Message::PullResp { iter, served_with, data, .. } = &r[0].1 else {
            panic!("{r:?}")
        };
        assert_eq!((*iter, *served_with), (0, 1));
        let mut out = vec![0.0f32; 1];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![4.0]);
        assert_eq!(core.stats.short_iters, 0);
        // The straggler whose push finally lands after the rollover is
        // counted as a *late* push (the tolerated event), not rejected
        // (the corruption counter) — and still changes nothing.
        let r = push(&mut core, 0, 0, 1, &[99.0]);
        assert!(r.is_empty());
        assert_eq!(core.stats.late_pushes, 1);
        assert_eq!(core.stats.rejected, 0);
        let r = core.handle(1, Message::Pull { key: 0, iter: 0, worker: 1 });
        let Message::PullResp { served_with, .. } = &r[0].1 else { panic!("{r:?}") };
        assert_eq!(*served_with, 1);
    }

    /// The deadline never seals empty rounds or pull-created placeholders
    /// (`early_pulls` keys with no dimension), and the placeholder budget
    /// is unaffected by the sweep: the queued pull is still answered by
    /// the establishing push, not by the timer.
    #[test]
    fn deadline_ignores_placeholders_and_empty_rounds() {
        let mut o = opts_deadline("identity", SyncMode::Full, 2);
        o.max_keys = 2;
        let mut core = ServerCore::new(o);
        // Pull for a key no push has established: a budgeted placeholder.
        let r = core.handle(1, Message::Pull { key: 9, iter: 0, worker: 1 });
        assert!(r.is_empty());
        assert_eq!(core.stats.early_pulls, 1);
        // The sweep must not seal (or panic on) the dimension-less
        // placeholder, nor a fully-idle established key.
        assert!(core.poll_deadlines(after_deadline()).is_empty());
        assert_eq!(core.stats.degraded_iters, 0);
        // The placeholder still works once pushes establish it.
        push(&mut core, 9, 0, 0, &[1.0]);
        let r = push(&mut core, 9, 0, 1, &[3.0]);
        assert!(
            r.iter().any(|(w, m)| *w == 1 && matches!(m, Message::PullResp { .. })),
            "queued early pull unanswered: {r:?}"
        );
        // And the placeholder budget is still enforced after a sweep
        // (over-budget pulls bounce with the retired marker).
        assert!(core.handle(0, Message::Pull { key: 20, iter: 0, worker: 0 }).is_empty());
        assert!(core.handle(0, Message::Pull { key: 21, iter: 0, worker: 0 }).is_empty());
        let before = core.stats.rejected;
        let r = core.handle(0, Message::Pull { key: 22, iter: 0, worker: 0 });
        assert!(matches!(r[0].1, Message::PullResp { served_with: 0, .. }), "{r:?}");
        assert_eq!(core.stats.rejected, before + 1, "placeholder budget must still cap");
    }

    /// A worker that stalls ~2 deadlines while the deadline advances the
    /// key clock past it gets the retired marker (`served_with == 0`,
    /// empty block) for its late pull — never a silent drop that would
    /// hang it forever (strict BSP made this state unreachable; the
    /// deadline does not).
    #[test]
    fn deadline_lagged_worker_gets_retired_marker() {
        let mut core = ServerCore::new(opts_deadline("identity", SyncMode::Full, 2));
        // Round 0 completes fully; worker 1 then stalls before pulling.
        push(&mut core, 0, 0, 0, &[1.0]);
        push(&mut core, 0, 0, 1, &[3.0]);
        // Worker 0 pulls 0 and pushes 1; the deadline seals round 1
        // degraded; worker 0 pulls 1 and pushes 2 — evicting round 0
        // from the one-slot history.
        let _ = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        push(&mut core, 0, 1, 0, &[5.0]);
        core.poll_deadlines(after_deadline());
        let _ = core.handle(0, Message::Pull { key: 0, iter: 1, worker: 0 });
        push(&mut core, 0, 2, 0, &[7.0]);
        // Worker 1 finally asks for round 0 — two behind the clock.
        let r = core.handle(1, Message::Pull { key: 0, iter: 0, worker: 1 });
        assert_eq!(r.len(), 1);
        let Message::PullResp { iter, served_with, data, .. } = &r[0].1 else {
            panic!("{r:?}")
        };
        assert_eq!((*iter, *served_with, data.n), (0, 0, 0));
        assert_eq!(core.stats.stale_pulls, 1);
    }

    /// A duplicate push from one *connection* for an open round must not
    /// complete the round early with that worker double-counted — the
    /// `served_with` tag would lie about how many workers the aggregate
    /// holds. The connection index is the identity; the wire `worker`
    /// field is untrusted.
    #[test]
    fn duplicate_push_from_same_connection_is_rejected() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        push(&mut core, 0, 0, 0, &[4.0]);
        let r = push(&mut core, 0, 0, 0, &[4.0]);
        assert!(r.is_empty(), "duplicate must not be acked: {r:?}");
        assert_eq!(core.stats.rejected, 1);
        assert_eq!(core.stats.pushes, 1);
        // The honest peer still completes the round with the true mean
        // over *distinct* contributors.
        let r = push(&mut core, 0, 0, 1, &[8.0]);
        assert!(!r.is_empty());
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        let Message::PullResp { served_with, data, .. } = &r[0].1 else { panic!("{r:?}") };
        assert_eq!(*served_with, 2);
        let mut out = vec![0.0f32; 1];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![6.0]);
    }

    /// Race regression (found in review): a worker whose push for a round
    /// was lost can have its *pull* for that round reach the server
    /// before the surviving worker's push — the key is still one
    /// iteration behind, and the old "future pull" rejection stranded
    /// the worker forever (the deadline seal only answers *queued*
    /// pulls). One-iteration-ahead pulls must queue; further ahead stays
    /// rejected (honest lag is bounded by one even with losses).
    #[test]
    fn pull_ahead_of_lost_push_queues_and_deadline_serves_it() {
        let mut core = ServerCore::new(opts_deadline("identity", SyncMode::Full, 2));
        // Iteration 0 completes normally for both workers.
        push(&mut core, 0, 0, 0, &[1.0]);
        push(&mut core, 0, 0, 1, &[3.0]);
        // Worker 1's push for iteration 1 is lost; its pull arrives while
        // the key is still at iteration 0. It must queue, not be rejected.
        let r = core.handle(1, Message::Pull { key: 0, iter: 1, worker: 1 });
        assert!(r.is_empty());
        assert_eq!(core.stats.rejected, 0);
        // The surviving push arrives and the deadline seals the round:
        // the queued one-ahead pull is answered.
        push(&mut core, 0, 1, 0, &[10.0]);
        let replies = core.poll_deadlines(after_deadline());
        assert_eq!(replies.len(), 1, "queued pull unanswered: {replies:?}");
        let (to, Message::PullResp { iter, served_with, data, .. }) = &replies[0] else {
            panic!("not a PullResp: {replies:?}")
        };
        assert_eq!((*to, *iter, *served_with), (1, 1, 1));
        let mut out = vec![0.0f32; 1];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![10.0]);
        // Beyond the one-iteration lag bound is still rejected — with a
        // retired marker, never a silent drop.
        let r = core.handle(1, Message::Pull { key: 0, iter: 5, worker: 1 });
        assert_eq!(r.len(), 1);
        assert!(matches!(r[0].1, Message::PullResp { served_with: 0, .. }), "{r:?}");
        assert_eq!(core.stats.rejected, 1);
    }

    /// Deadline auto-tuning (`deadline_auto_margin`): below
    /// [`AUTO_DEADLINE_MIN_ROUNDS`] full rounds no deadline is in force;
    /// once enough are on record the shard derives p99 × margin (floored
    /// at [`AUTO_DEADLINE_FLOOR`]), re-evaluated per sealed round, and a
    /// partial round seals degraded under it.
    #[test]
    fn auto_deadline_derives_from_round_latency() {
        let mut o = opts("identity", SyncMode::Full, 2);
        o.deadline_auto_margin = 3.0;
        let mut core = ServerCore::new(o);
        assert!(core.current_deadline().is_none());
        for iter in 0..AUTO_DEADLINE_MIN_ROUNDS {
            push(&mut core, 0, iter, 0, &[1.0]);
            // Below the warmup no sweep can fire, however late the clock.
            if iter + 1 < AUTO_DEADLINE_MIN_ROUNDS {
                assert!(core.poll_deadlines(after_deadline()).is_empty());
            }
            push(&mut core, 0, iter, 1, &[3.0]);
        }
        assert_eq!(core.stats.round_hist.count(), AUTO_DEADLINE_MIN_ROUNDS);
        let derived = core.current_deadline().expect("auto deadline after warmup");
        assert!(derived >= AUTO_DEADLINE_FLOOR, "floor not applied: {derived:?}");
        // A partial round now seals degraded under the derived deadline.
        let next = AUTO_DEADLINE_MIN_ROUNDS;
        push(&mut core, 0, next, 0, &[5.0]);
        assert!(core.poll_deadlines(after_deadline()).is_empty()); // no queued pull
        assert_eq!(core.stats.degraded_iters, 1);
        // Degraded rounds never feed the histogram back directly (they
        // take exactly the deadline — self-referential)…
        assert_eq!(core.stats.round_hist.count(), AUTO_DEADLINE_MIN_ROUNDS);
        let r = core.handle(0, Message::Pull { key: 0, iter: next, worker: 0 });
        let Message::PullResp { served_with, .. } = &r[0].1 else { panic!("{r:?}") };
        assert_eq!(*served_with, 1);
        // …but a straggler's *late push* for the sealed round reveals the
        // round's true spread and is recorded, so a too-tight derived
        // deadline can widen again instead of ratcheting degraded forever.
        let r = push(&mut core, 0, next, 1, &[7.0]);
        assert!(r.is_empty(), "late push must not be acked: {r:?}");
        assert_eq!(core.stats.late_pushes, 1);
        assert_eq!(
            core.stats.round_hist.count(),
            AUTO_DEADLINE_MIN_ROUNDS + 1,
            "late-push spread must feed the histogram (anti-ratchet)"
        );
        assert!(core.current_deadline().is_some());
        // One sample per degraded round: a retransmitting (or hostile)
        // client re-sending the same late push must not record an
        // ever-growing spread each time and drag the derived deadline up.
        let r = push(&mut core, 0, next, 1, &[7.0]);
        assert!(r.is_empty());
        assert_eq!(core.stats.late_pushes, 2);
        assert_eq!(
            core.stats.round_hist.count(),
            AUTO_DEADLINE_MIN_ROUNDS + 1,
            "repeated late pushes must not re-record"
        );
    }

    /// A static `iter_deadline` always wins over auto-tuning, and with
    /// margin 0 nothing is ever derived.
    #[test]
    fn auto_deadline_precedence_and_off_switch() {
        let mut o = opts_deadline("identity", SyncMode::Full, 2);
        o.deadline_auto_margin = 100.0;
        let static_d = o.iter_deadline.unwrap();
        let mut core = ServerCore::new(o);
        for iter in 0..2 * AUTO_DEADLINE_MIN_ROUNDS {
            push(&mut core, 0, iter, 0, &[1.0]);
            push(&mut core, 0, iter, 1, &[3.0]);
        }
        assert_eq!(core.current_deadline(), Some(static_d), "static deadline must win");
        // margin 0: plain strict BSP, full rounds notwithstanding.
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        for iter in 0..2 * AUTO_DEADLINE_MIN_ROUNDS {
            push(&mut core, 0, iter, 0, &[1.0]);
            push(&mut core, 0, iter, 1, &[3.0]);
        }
        assert!(core.current_deadline().is_none());
        assert!(core.poll_deadlines(after_deadline()).is_empty());
    }

    /// The round-latency histogram records full rounds on every key and
    /// the stage seconds accumulate even on the synchronous path.
    #[test]
    fn stats_track_rounds_and_stage_seconds() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        for key in 0..3u64 {
            push(&mut core, key, 0, 0, &[1.0, 2.0]);
            push(&mut core, key, 0, 1, &[3.0, 4.0]);
        }
        assert_eq!(core.stats.round_hist.count(), 3);
        assert_eq!(core.stats.decode_depth_peak, 1, "inline decodes never overlap");
        assert_eq!(core.stats.encode_depth_peak, 1);
        assert!(core.stats.ingress_s >= 0.0);
        assert_eq!(core.jobs_in_flight(), 0);
    }

    /// With a granted adaptive envelope, a structurally valid sparse push
    /// whose `k` lies outside it is dropped and counted as
    /// `bounds_rejected` (disjoint from `rejected`), and an in-bounds push
    /// for the same key is still served normally afterwards.
    #[test]
    fn adaptive_envelope_rejects_out_of_bounds_k() {
        use crate::compress::controller::{k_for_ppm, ppm_of};
        // Envelope [1%, 10%] over n=100 elements → k ∈ [1, 10].
        let (lo, hi) = (ppm_of(0.01), ppm_of(0.10));
        let mut o = opts("topk", SyncMode::CompressedEf, 1);
        o.adaptive_bounds = Some((lo, hi));
        let n = 100usize;
        assert_eq!((k_for_ppm(lo, n), k_for_ppm(hi, n)), (1, 10));
        let mut core = ServerCore::new(o);
        let g: Vec<f32> = (0..n).map(|i| i as f32).collect();
        // A TopK(0.5) block claims k=50 — outside the granted [1, 10].
        let hostile = crate::compress::topk::TopK::new(0.5);
        let mut rng = Xoshiro256::seed_from_u64(1);
        let data = hostile.compress(&g, &mut Ctx::new(&mut rng));
        let r = core.handle(0, Message::Push { key: 0, iter: 0, worker: 0, data });
        assert!(r.is_empty(), "out-of-bounds push must get no ack");
        assert_eq!(core.stats.bounds_rejected, 1);
        assert_eq!(core.stats.rejected, 0, "bounds rejections are counted separately");
        assert_eq!(core.stats.pushes, 0);
        // An in-bounds push (k = 10) completes the round and serves pulls.
        let honest = crate::compress::topk::TopK::new(0.10);
        let data = honest.compress(&g, &mut Ctx::new(&mut rng));
        let r = core.handle(0, Message::Push { key: 0, iter: 0, worker: 0, data });
        assert!(!r.is_empty(), "in-bounds push must be acked");
        assert_eq!(core.stats.bounds_rejected, 1);
        assert_eq!(core.stats.pushes, 1);
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        assert!(matches!(r[0].1, Message::PullResp { .. }));
        // A static server (bounds None) accepts the same hostile block.
        let mut core = ServerCore::new(opts("topk", SyncMode::CompressedEf, 1));
        let data = hostile.compress(&g, &mut Ctx::new(&mut rng));
        let r = core.handle(0, Message::Push { key: 0, iter: 0, worker: 0, data });
        assert!(!r.is_empty());
        assert_eq!(core.stats.bounds_rejected, 0);
    }

    fn gpush(
        core: &mut ServerCore,
        key: Key,
        iter: u64,
        worker: u32,
        members: u16,
        g: &[f32],
    ) -> Vec<(u32, Message)> {
        let mut rng = Xoshiro256::seed_from_u64(worker as u64 + 100);
        let data = core.opts.comp.compress(g, &mut Ctx::new(&mut rng));
        core.handle(worker, Message::GroupPush { key, iter, worker, members, data })
    }

    /// A round of G group pushes (each carrying its group's gradient SUM
    /// and member weight) averages exactly like W flat pushes: the server
    /// divides by the summed weights, not the number of connections.
    #[test]
    fn group_pushes_average_by_member_weight() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 4));
        // Group 0 = {[1,1], [1,3]} → sum [2,4]; group 1 = {[3,3], [3,5]} → [6,8].
        let r = gpush(&mut core, 0, 0, 0, 2, &[2.0, 4.0]);
        assert_eq!(r.len(), 1, "first group push just acks: {r:?}");
        let r = gpush(&mut core, 0, 0, 1, 2, &[6.0, 8.0]);
        assert!(!r.is_empty(), "weights 2+2 must complete the 4-worker round");
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        let Message::PullResp { served_with, data, .. } = &r[0].1 else { panic!("{r:?}") };
        assert_eq!(*served_with, 4, "served_with reports workers, not connections");
        let mut out = vec![0.0f32; 2];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![2.0, 3.0], "mean over 4 workers, not 2 pushes");
        assert_eq!(core.stats.members_clamped, 0);
    }

    /// Flat pushes and group pushes mix: weights 1 and 3 complete a
    /// 4-worker round together and the divisor is the weight sum.
    #[test]
    fn flat_and_group_pushes_mix() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 4));
        push(&mut core, 0, 0, 0, &[1.0, 2.0]);
        let r = gpush(&mut core, 0, 0, 1, 3, &[3.0, 6.0]);
        assert!(!r.is_empty());
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        let Message::PullResp { served_with, data, .. } = &r[0].1 else { panic!("{r:?}") };
        assert_eq!(*served_with, 4);
        let mut out = vec![0.0f32; 2];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![1.0, 2.0]);
    }

    /// A hostile leader overstating `members` is clamped to the round's
    /// remaining capacity — counted, never a panic, and the averaging
    /// divisor / `served_with` never exceed the workers that exist. A
    /// nonsensical claim of 0 weighs 1 and also never panics.
    #[test]
    fn hostile_members_claim_is_clamped_and_counted() {
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 4));
        gpush(&mut core, 0, 0, 0, 2, &[4.0]);
        // Claims 60000 members into a round with capacity 2.
        let r = gpush(&mut core, 0, 0, 1, 60_000, &[8.0]);
        assert!(!r.is_empty(), "clamped push still completes the round");
        assert_eq!(core.stats.members_clamped, 1);
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        let Message::PullResp { served_with, data, .. } = &r[0].1 else { panic!("{r:?}") };
        assert_eq!(*served_with, 4, "clamped weight caps served_with at n_workers");
        let mut out = vec![0.0f32; 1];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![3.0], "divisor is the clamped weight sum (4), not the claim");
        // members == 0 (a leader always carries at least itself): weighs 1.
        let mut core = ServerCore::new(opts("identity", SyncMode::Full, 2));
        gpush(&mut core, 0, 0, 0, 0, &[2.0]);
        let r = gpush(&mut core, 0, 0, 1, 1, &[4.0]);
        assert!(!r.is_empty(), "0+1 claims weigh 1+1 and complete the 2-worker round");
        assert_eq!(core.stats.members_clamped, 0, "understating is not a clamp event");
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        let Message::PullResp { data, .. } = &r[0].1 else { panic!("{r:?}") };
        let mut out = vec![0.0f32; 1];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![3.0]);
    }

    /// Degraded-group semantics: when a whole group misses the deadline,
    /// the round seals with the present groups' weight — `served_with`
    /// reports the member weight (not the connection count) and the
    /// average divides by it.
    #[test]
    fn deadline_seals_missing_group_with_weighted_served() {
        let mut core = ServerCore::new(opts_deadline("identity", SyncMode::Full, 4));
        gpush(&mut core, 0, 0, 0, 2, &[4.0, 8.0]); // group 0's sum of 2 members
        // Group 1 never arrives; the deadline seals the round degraded.
        core.poll_deadlines(after_deadline());
        assert_eq!(core.stats.degraded_iters, 1);
        let r = core.handle(0, Message::Pull { key: 0, iter: 0, worker: 0 });
        let Message::PullResp { served_with, data, .. } = &r[0].1 else { panic!("{r:?}") };
        assert_eq!(*served_with, 2, "served_with is the present member weight");
        let mut out = vec![0.0f32; 2];
        core.opts.comp.decompress(data, &mut out);
        assert_eq!(out, vec![2.0, 4.0], "average over the 2 members present");
    }
}
