//! Run metrics: step-time breakdowns, throughput counters, and the
//! markdown/CSV emitters the benchmark harnesses use to print paper-style
//! tables.

use std::collections::BTreeMap;
use std::time::Duration;

/// Per-step wall-time breakdown (Fig. 2's computation/communication split;
/// compression counts as communication, as in the paper §5.1.1).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Breakdown {
    pub compute_s: f64,
    pub compress_s: f64,
    pub decompress_s: f64,
    pub wire_s: f64,
    pub optimizer_s: f64,
    pub other_s: f64,
}

impl Breakdown {
    pub fn total(&self) -> f64 {
        self.compute_s
            + self.compress_s
            + self.decompress_s
            + self.wire_s
            + self.optimizer_s
            + self.other_s
    }

    /// Paper convention: "communication" = wire + (de)compression.
    pub fn communication(&self) -> f64 {
        self.compress_s + self.decompress_s + self.wire_s
    }

    pub fn add(&mut self, o: &Breakdown) {
        self.compute_s += o.compute_s;
        self.compress_s += o.compress_s;
        self.decompress_s += o.decompress_s;
        self.wire_s += o.wire_s;
        self.optimizer_s += o.optimizer_s;
        self.other_s += o.other_s;
    }

    pub fn scale(&self, f: f64) -> Breakdown {
        Breakdown {
            compute_s: self.compute_s * f,
            compress_s: self.compress_s * f,
            decompress_s: self.decompress_s * f,
            wire_s: self.wire_s * f,
            optimizer_s: self.optimizer_s * f,
            other_s: self.other_s * f,
        }
    }
}

/// Accumulates named durations, counters and series over a run.
#[derive(Default, Debug)]
pub struct Metrics {
    durations: BTreeMap<String, (u64, Duration)>,
    counters: BTreeMap<String, u64>,
    series: BTreeMap<String, Vec<(f64, f64)>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, name: &str, d: Duration) {
        let e = self.durations.entry(name.to_string()).or_insert((0, Duration::ZERO));
        e.0 += 1;
        e.1 += d;
    }

    pub fn count(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Append an (x, y) point to a named series (e.g. loss vs step).
    pub fn point(&mut self, series: &str, x: f64, y: f64) {
        self.series.entry(series.to_string()).or_default().push((x, y));
    }

    pub fn total_seconds(&self, name: &str) -> f64 {
        self.durations.get(name).map(|(_, d)| d.as_secs_f64()).unwrap_or(0.0)
    }

    pub fn mean_seconds(&self, name: &str) -> f64 {
        self.durations
            .get(name)
            .map(|(n, d)| if *n > 0 { d.as_secs_f64() / *n as f64 } else { 0.0 })
            .unwrap_or(0.0)
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn series(&self, name: &str) -> &[(f64, f64)] {
        self.series.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Dump everything as JSON (run provenance; consumed by EXPERIMENTS.md
    /// tooling).
    pub fn to_json(&self) -> crate::configx::json::Json {
        use crate::configx::json::Json;
        let mut obj = BTreeMap::new();
        let mut dur = BTreeMap::new();
        for (k, (n, d)) in &self.durations {
            dur.insert(
                k.clone(),
                Json::obj(vec![
                    ("count", Json::num(*n as f64)),
                    ("total_s", Json::num(d.as_secs_f64())),
                ]),
            );
        }
        obj.insert("durations".to_string(), Json::Obj(dur));
        let mut ctr = BTreeMap::new();
        for (k, v) in &self.counters {
            ctr.insert(k.clone(), Json::num(*v as f64));
        }
        obj.insert("counters".to_string(), Json::Obj(ctr));
        let mut ser = BTreeMap::new();
        for (k, pts) in &self.series {
            ser.insert(
                k.clone(),
                Json::Arr(
                    pts.iter()
                        .map(|(x, y)| Json::Arr(vec![Json::num(*x), Json::num(*y)]))
                        .collect(),
                ),
            );
        }
        obj.insert("series".to_string(), Json::Obj(ser));
        Json::Obj(obj)
    }
}

/// Render a markdown table: header row + rows. Column widths auto-sized.
pub fn markdown_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        assert_eq!(row.len(), ncol, "row arity mismatch");
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::from("|");
        for (c, w) in cells.iter().zip(widths) {
            line.push_str(&format!(" {:<w$} |", c, w = w));
        }
        line.push('\n');
        line
    };
    let mut out = String::new();
    out.push_str(&fmt_row(
        &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
        &widths,
    ));
    let mut sep = String::from("|");
    for w in &widths {
        sep.push_str(&format!("{:-<w$}|", "", w = w + 2));
    }
    sep.push('\n');
    out.push_str(&sep);
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
    }
    out
}

/// ASCII bar chart for quick terminal visualisation of a breakdown figure.
pub fn ascii_bars(items: &[(String, f64)], width: usize) -> String {
    let max = items.iter().map(|(_, v)| *v).fold(0.0f64, f64::max).max(1e-12);
    let name_w = items.iter().map(|(n, _)| n.len()).max().unwrap_or(0);
    let mut out = String::new();
    for (name, v) in items {
        let n = ((v / max) * width as f64).round() as usize;
        out.push_str(&format!("{:<name_w$} |{:<width$}| {:.3}\n", name, "█".repeat(n), v));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_totals() {
        let b = Breakdown {
            compute_s: 1.0,
            compress_s: 0.25,
            decompress_s: 0.25,
            wire_s: 0.5,
            optimizer_s: 0.1,
            other_s: 0.0,
        };
        assert!((b.total() - 2.1).abs() < 1e-12);
        assert!((b.communication() - 1.0).abs() < 1e-12);
        let d = b.scale(2.0);
        assert!((d.total() - 4.2).abs() < 1e-12);
    }

    #[test]
    fn metrics_accumulate() {
        let mut m = Metrics::new();
        m.record("step", Duration::from_millis(100));
        m.record("step", Duration::from_millis(300));
        m.count("bytes", 42);
        m.count("bytes", 8);
        m.point("loss", 1.0, 9.0);
        assert!((m.total_seconds("step") - 0.4).abs() < 1e-9);
        assert!((m.mean_seconds("step") - 0.2).abs() < 1e-9);
        assert_eq!(m.counter("bytes"), 50);
        assert_eq!(m.series("loss"), &[(1.0, 9.0)]);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn metrics_json_parses() {
        let mut m = Metrics::new();
        m.record("x", Duration::from_secs(1));
        m.count("c", 3);
        m.point("s", 0.0, 1.5);
        let j = m.to_json();
        let s = j.pretty();
        let back = crate::configx::json::Json::parse(&s).unwrap();
        assert_eq!(back.get("counters").unwrap().get("c").unwrap().as_usize(), Some(3));
    }

    #[test]
    fn markdown_table_shape() {
        let t = markdown_table(
            &["Algorithm", "Time"],
            &[
                vec!["NAG".into(), "148.88 m".into()],
                vec!["Top-k with EF".into(), "145.00 m".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("Algorithm"));
        assert!(lines[1].starts_with("|-"));
        assert!(lines[3].contains("Top-k"));
    }

    #[test]
    fn ascii_bars_render() {
        let s = ascii_bars(&[("a".into(), 1.0), ("bb".into(), 2.0)], 10);
        assert!(s.lines().count() == 2);
        assert!(s.contains("██████████"));
    }
}
