//! Analytic cluster model ("simnet") — projects measured single-host
//! compressor/step timings onto the paper's testbed (n× Amazon P3.16xlarge,
//! 8× V100 + 25 Gb/s Ethernet) to regenerate Fig. 2, Fig. 3 and Table 5.
//!
//! What is *real* vs *modeled* here (see DESIGN.md §Substitutions):
//!
//! * compressor speeds — **measured** on the real rust compressors via
//!   [`CompressorProfile::measure`], then scaled by `cpu_scale` to account
//!   for the paper's many-core servers vs this single-core testbed;
//! * wire time — **modeled** as `bytes / bandwidth + latency` with the
//!   BytePS two-stage topology (NVLink all-reduce intra-node, sharded PS
//!   push/pull inter-node);
//! * GPU compute — **parameterized** per workload (V100-calibrated
//!   `tfp`/`tbp`), since the testbed has no GPU.
//!
//! The paper's own "ideal scaling" formula (§5.1.2) is implemented verbatim
//! in [`ideal_scaling`].

use crate::compress::{Compressor, Ctx};
use crate::metrics::Breakdown;
use crate::util::rng::Xoshiro256;

/// Table 1 — communication volume of collective primitives, in units of the
/// tensor size d, as a function of worker count n (per-worker traffic).
pub mod primitives {
    /// All-Gather / Broadcast: every worker receives n−1 other shards of
    /// size d — O(n) growth.
    pub fn all_gather(n: usize) -> f64 {
        (n.max(1) - 1) as f64
    }

    /// Ring All-Reduce: 2(n−1)/n · d per worker — O(1).
    pub fn all_reduce(n: usize) -> f64 {
        if n <= 1 {
            0.0
        } else {
            2.0 * (n - 1) as f64 / n as f64
        }
    }

    /// PS Push/Pull: d up + d down per worker — O(1). With servers
    /// co-located on worker nodes, the shard owned by the local server
    /// never crosses the NIC: factor (n−1)/n each way.
    pub fn push_pull(n: usize) -> f64 {
        if n <= 1 {
            0.0
        } else {
            2.0 * (n - 1) as f64 / n as f64
        }
    }
}

/// A training workload: model size + V100-node compute times.
///
/// `tfp`/`tbp` are per-iteration forward/backward times for one 8-GPU node
/// at the paper's per-node batch size, calibrated so the paper's reported
/// ideal-scaling numbers come out (ResNet50 → 100%, VGG16 → 40.4%, §5.1.2).
#[derive(Clone, Debug)]
pub struct Workload {
    pub name: &'static str,
    /// Gradient elements (f32).
    pub d_elems: usize,
    pub tfp_s: f64,
    pub tbp_s: f64,
    /// Samples processed per node per iteration.
    pub batch_per_node: usize,
    /// Fraction of communication hideable behind backprop (CNNs with
    /// per-layer NCCL overlap ≈ 1.0; BERT+LANS syncs after the full
    /// backward ≈ 0.0 — calibrated so the paper's Table 3/5 numbers come
    /// out).
    pub overlap: f64,
    /// Gradient-accumulation sync rounds per optimizer step (the paper's
    /// BERT-large configs sync each micro-accumulation round, which is
    /// what makes their 437M-model throughput collapse to 31 seq/s).
    pub sync_rounds: f64,
}

impl Workload {
    pub fn resnet50() -> Self {
        // 25.56M params; 8xV100 node ≈ 2300 img/s => 0.111 s per 256-img iter.
        Workload { name: "ResNet50", d_elems: 25_560_000, tfp_s: 0.037, tbp_s: 0.074, batch_per_node: 256, overlap: 1.0, sync_rounds: 1.0 }
    }

    pub fn vgg16() -> Self {
        // 138.36M params (528 MB); τ calibrated to the paper's 40.4% ideal
        // scaling at 25 Gb/s (see module docs).
        Workload { name: "VGG16", d_elems: 138_360_000, tfp_s: 0.055, tbp_s: 0.110, batch_per_node: 256, overlap: 1.0, sync_rounds: 1.0 }
    }

    pub fn bert_base() -> Self {
        // 110M params; LANS @ 4 nodes = 4613 seq/s => 0.444 s per 2048-seq
        // global batch => per-node compute ≈ 0.35 s with comm in the rest.
        Workload { name: "BERT-Base", d_elems: 110_000_000, tfp_s: 0.117, tbp_s: 0.233, batch_per_node: 512, overlap: 0.0, sync_rounds: 1.0 }
    }

    pub fn bert_large() -> Self {
        // 336M params; heavy gradient accumulation in the paper (613 seq/s).
        Workload { name: "BERT-Large", d_elems: 336_000_000, tfp_s: 0.67, tbp_s: 1.33, batch_per_node: 512, overlap: 0.0, sync_rounds: 4.0 }
    }

    pub fn bert_large_32l() -> Self {
        // 437M params (32-layer BERT-large variant).
        Workload { name: "BERT-Large (32 layers)", d_elems: 437_000_000, tfp_s: 9.0, tbp_s: 18.0, batch_per_node: 512, overlap: 0.0, sync_rounds: 32.0 }
    }

    pub fn grad_bytes(&self) -> usize {
        4 * self.d_elems
    }
}

/// Measured (or assumed) per-element compressor speed + wire volume.
#[derive(Clone, Debug)]
pub struct CompressorProfile {
    pub name: String,
    pub compress_ns_per_elem: f64,
    pub decompress_ns_per_elem: f64,
    /// Wire bytes for an n-element tensor.
    pub wire_bytes_fn: fn(usize, f64) -> usize,
    /// Scheme parameter forwarded to `wire_bytes_fn`.
    pub param: f64,
}

impl CompressorProfile {
    /// Time the real compressor on this host (one intra-thread) and build a
    /// profile from it. `n` should be large enough to amortize constants
    /// (≥ 1M elements).
    pub fn measure(label: &str, comp: &dyn Compressor, n: usize, _param: f64) -> Self {
        let mut rng = Xoshiro256::seed_from_u64(0xCAFE);
        let mut x = vec![0.0f32; n];
        rng.fill_normal(&mut x, 1.0);
        // Warm-up + measure compress.
        let mut ctx = Ctx::new(&mut rng);
        let _ = comp.compress(&x, &mut ctx);
        let t = std::time::Instant::now();
        let reps = 3;
        let mut c = None;
        for _ in 0..reps {
            c = Some(comp.compress(&x, &mut ctx));
        }
        let compress_ns = t.elapsed().as_nanos() as f64 / (reps * n) as f64;
        let c = c.unwrap();
        let mut out = vec![0.0f32; n];
        comp.decompress(&c, &mut out);
        let t = std::time::Instant::now();
        for _ in 0..reps {
            comp.decompress(&c, &mut out);
        }
        let decompress_ns = t.elapsed().as_nanos() as f64 / (reps * n) as f64;
        fn measured_wire(_n: usize, _p: f64) -> usize {
            0 // replaced below via actual_bytes
        }
        let mut prof = CompressorProfile {
            name: label.to_string(),
            compress_ns_per_elem: compress_ns,
            decompress_ns_per_elem: decompress_ns,
            wire_bytes_fn: measured_wire,
            param: c.nbytes() as f64 / n as f64, // bytes per element, measured
        };
        prof.wire_bytes_fn = |n, bytes_per_elem| (n as f64 * bytes_per_elem).ceil() as usize;
        prof
    }

    pub fn wire_bytes(&self, n: usize) -> usize {
        (self.wire_bytes_fn)(n, self.param)
    }
}

/// Cluster shape + knobs (paper testbed defaults).
#[derive(Clone, Debug)]
pub struct Cluster {
    pub nodes: usize,
    pub gpus_per_node: usize,
    /// Inter-node bandwidth, Gbit/s (paper: 25).
    pub net_gbps: f64,
    /// Intra-node NVLink bandwidth, Gbit/s (V100 NVLink ≈ 300 GB/s ring;
    /// effective all-reduce bw per paper-era NCCL ≈ 130 GB/s => 1040 Gb/s).
    pub nvlink_gbps: f64,
    /// One-way message latency, seconds.
    pub latency_s: f64,
    /// PS instances per node (paper §4.2.5: 2 with "More Servers").
    pub servers_per_node: usize,
    /// CPU threads available for compression per node.
    pub compress_threads: usize,
    /// Effective parallel-CPU speedup of the paper's 64-vCPU nodes over
    /// this host's single core (dozens of concurrent compression jobs,
    /// §4.2.1) — projects measured compressor ns/elem onto the testbed.
    pub cpu_scale: f64,
    /// §4.2.1 block pipeline: overlap CPU (de)compression with the wire.
    /// Off = compression serializes behind the network (the
    /// "compression w/o pipelining" ablation arm).
    pub pipeline: bool,
    /// Staged server shards (`server.compress_threads > 0`): the shard's
    /// decode/encode CPU work overlaps its ingress (and the wire) like
    /// the worker pipeline does. Off = the 1-thread reference shard,
    /// whose aggregation CPU serializes *after* the wire — the
    /// Agarwal-et-al failure mode on the aggregator side.
    pub server_pipeline: bool,
    /// Partition block size in bytes for the pipeline depth estimate.
    pub pipeline_block_bytes: usize,
    /// Probability that any single block-push is lost or rejected in a
    /// round (models the degraded-round protocol; 0 = perfect network,
    /// the default — the model is then bit-identical to the lossless
    /// one).
    pub push_loss: f64,
    /// Server iteration deadline in seconds (`server.iter_deadline_ms`):
    /// a round with a lost push stalls for the deadline, then completes
    /// *degraded* instead of hanging. Only meaningful with
    /// `push_loss > 0`.
    pub iter_deadline_s: f64,
}

impl Default for Cluster {
    fn default() -> Self {
        Cluster {
            nodes: 8,
            gpus_per_node: 8,
            net_gbps: 25.0,
            nvlink_gbps: 1040.0,
            latency_s: 25e-6,
            servers_per_node: 2,
            compress_threads: 16,
            cpu_scale: 48.0,
            pipeline: true,
            server_pipeline: true,
            pipeline_block_bytes: 4 << 20,
            push_loss: 0.0,
            iter_deadline_s: 0.0,
        }
    }
}

/// Number of pushes one sync round carries (every node pushes every wire
/// unit of the gradient). With the pipeline on, the wire unit is a block
/// of `pipeline_block_bytes`; off, whole tensors ship — the workload
/// abstraction has no tensor count, so the model conservatively treats
/// the unpipelined gradient as one push per node (a lower bound on loss
/// exposure, mirroring how `step_breakdown` gates its block math on
/// `c.pipeline`).
fn round_pushes(w: &Workload, c: &Cluster) -> f64 {
    let blocks = if c.pipeline {
        (w.grad_bytes() as f64 / c.pipeline_block_bytes.max(1) as f64).ceil().max(1.0)
    } else {
        1.0
    };
    blocks * c.nodes as f64
}

/// Probability a sync round completes *degraded* under the iteration-
/// deadline protocol: at least one of the round's block-pushes is lost
/// (independent losses at `push_loss` each). Zero on a single node —
/// the model has no inter-node push/pull there (matching `wire_s`). This
/// is the round-level quantity the degraded-round recipe in
/// EXPERIMENTS.md measures on a real cluster (`Σ degraded_iters / iters`
/// across shards, for rare faults).
pub fn degraded_round_rate(w: &Workload, c: &Cluster) -> f64 {
    if c.push_loss <= 0.0 || c.nodes <= 1 {
        return 0.0;
    }
    1.0 - (1.0 - c.push_loss.min(1.0)).powf(round_pushes(w, c))
}

/// Expected per-round stall from degraded rounds: a lossy round waits out
/// the server's iteration deadline before its pulls are served.
pub fn degraded_wait_s(w: &Workload, c: &Cluster) -> f64 {
    degraded_round_rate(w, c) * c.iter_deadline_s
}

/// Paper §5.1.2 ideal scaling efficiency:
/// `(T_FP + T_BP) / (T_FP + max(T_BP, T_COMM))` with
/// `T_COMM = 2·d_bytes / bandwidth` (full-precision PS push/pull).
pub fn ideal_scaling(w: &Workload, c: &Cluster) -> f64 {
    let t_comm = 2.0 * w.grad_bytes() as f64 * 8.0 / (c.net_gbps * 1e9);
    (w.tfp_s + w.tbp_s) / (w.tfp_s + w.tbp_s.max(t_comm))
}

/// One simulated training step under the BytePS-Compress two-stage scheme.
/// Returns the per-node breakdown; `step_time = tfp + max(tbp, comm)`
/// (communication overlapped with backward, as the paper assumes).
pub fn step_breakdown(w: &Workload, c: &Cluster, p: &CompressorProfile) -> Breakdown {
    let d = w.d_elems;
    let n = c.nodes;

    // Stage 1: intra-node all-reduce over gpus_per_node ranks in FP16
    // (§4.1.1): 2(g−1)/g · d · 2 bytes over NVLink.
    let intra_bytes =
        primitives::all_reduce(c.gpus_per_node) * d as f64 * 2.0;
    let intra_s = intra_bytes * 8.0 / (c.nvlink_gbps * 1e9);

    // Stage 2: inter-node two-way compressed push/pull.
    let wire_s = if n > 1 {
        let wire_per_dir = p.wire_bytes(d) as f64 * primitives::push_pull(n) / 2.0;
        2.0 * wire_per_dir * 8.0 / (c.net_gbps * 1e9) + 2.0 * c.latency_s
    } else {
        0.0
    };

    // CPU compression (projected): worker compress (push) + decompress
    // (pull) + this node's server share of (n pushes decompress + 1
    // compress) over its shard d / (nodes*servers_per_node).
    let cpu = |ns_per_elem: f64, elems: f64| ns_per_elem * elems / (1e9 * c.cpu_scale);
    let worker_compress_s = cpu(p.compress_ns_per_elem, d as f64);
    let worker_decompress_s = cpu(p.decompress_ns_per_elem, d as f64);
    let shard = d as f64 / (n * c.servers_per_node).max(1) as f64;
    let server_s = cpu(
        p.decompress_ns_per_elem * n as f64 + p.compress_ns_per_elem,
        shard,
    ) * c.servers_per_node as f64;

    let compress_s = worker_compress_s + server_s * 0.5;
    let decompress_s = worker_decompress_s + server_s * 0.5;
    // Per sync round: with the §4.2.1 block pipeline, per-block CPU
    // (de)compression overlaps the wire — the visible cost is the max of
    // the two plus one block's worth of fill/drain, not their sum. With
    // the pipeline off, compression serializes behind the network in full
    // (the Agarwal-et-al caution this subsystem exists to fix). The
    // server's share only joins the overlap when its shards are *staged*
    // (`server_pipeline`, modeling `server.compress_threads > 0`): a
    // 1-thread shard decodes/encodes on its I/O thread, after the wire.
    // NVLink stage added either way; gradient accumulation repeats the
    // sync.
    let cpu_s = compress_s + decompress_s;
    let overlapped_cpu = if c.server_pipeline { cpu_s } else { cpu_s - server_s };
    let serial_cpu = cpu_s - overlapped_cpu;
    let comm_per_round = if c.pipeline {
        let depth =
            (w.grad_bytes() as f64 / c.pipeline_block_bytes.max(1) as f64).ceil().max(1.0);
        wire_s.max(overlapped_cpu) + wire_s.min(overlapped_cpu) / depth + serial_cpu + intra_s
    } else {
        wire_s + cpu_s + intra_s
    };
    let comm_total = comm_per_round * w.sync_rounds;
    // Degraded rounds (lost pushes under the iteration deadline) stall
    // the *pull phase* for the deadline — after backprop has finished —
    // so unlike regular communication the stall can never hide behind
    // backprop. Added after the overlap subtraction; lands in `other_s`.
    let degraded_total = degraded_wait_s(w, c) * w.sync_rounds;

    // Overlap: what fraction of communication hides behind backprop.
    let hidden = (comm_total.min(w.tbp_s)) * w.overlap;
    Breakdown {
        compute_s: w.tfp_s + w.tbp_s,
        compress_s: compress_s * w.sync_rounds,
        decompress_s: decompress_s * w.sync_rounds,
        wire_s: (intra_s + wire_s) * w.sync_rounds,
        optimizer_s: 0.0,
        // `other_s` reconciles pipelining + overlap so total() = step time:
        // total = tfp + tbp + comm_total + degraded_total - hidden.
        other_s: comm_total + degraded_total
            - hidden
            - (cpu_s + intra_s + wire_s) * w.sync_rounds,
    }
}

/// Simulated step time in seconds.
pub fn step_time(w: &Workload, c: &Cluster, p: &CompressorProfile) -> f64 {
    let b = step_breakdown(w, c, p);
    // = tfp + tbp + comm_total − hidden
    b.total()
}

/// Cluster throughput in samples/s.
pub fn throughput(w: &Workload, c: &Cluster, p: &CompressorProfile) -> f64 {
    (w.batch_per_node * c.nodes) as f64 / step_time(w, c, p)
}

/// Measured scaling efficiency vs a single node (paper Fig. 3's y-axis).
pub fn scaling_efficiency(w: &Workload, c: &Cluster, p: &CompressorProfile) -> f64 {
    let mut one = c.clone();
    one.nodes = 1;
    let t1 = step_time(w, &one, p);
    let tn = step_time(w, c, p);
    t1 / tn
}

/// One aggregation tier's projected round time at fan-in `fan_in`, in
/// whole-gradient units over a **fixed pool** of aggregator CPU/NIC: the
/// tier serves `fan_in` peers, each delivering one compressed gradient,
/// so its round cost is the serialized ingress wire time (`fan_in`
/// compressed gradients through one NIC, one connection's latency — the
/// O(fan-in) term the hierarchical topology exists to cut) plus its CPU
/// share (decode × `fan_in` + one re-encode, projected by `cpu_scale`).
/// Both the flat PS tier (`fan_in = W`) and each level of the two-level
/// topology (`fan_in = m` at the leader, `G` at the shard) have this
/// shape — the asymmetry between the wire slope and the re-encode
/// constant is what creates the crossover (see
/// [`hier_crossover_nodes`]).
pub fn fan_in_round_s(d_elems: usize, fan_in: usize, c: &Cluster, p: &CompressorProfile) -> f64 {
    let wire_one = p.wire_bytes(d_elems) as f64 * 8.0 / (c.net_gbps * 1e9);
    let ingest_s = fan_in as f64 * wire_one + c.latency_s;
    let cpu_s = (p.decompress_ns_per_elem * fan_in as f64 + p.compress_ns_per_elem)
        * d_elems as f64
        / (1e9 * c.cpu_scale);
    ingest_s + cpu_s
}

/// Two-level round time for `nodes` workers in groups of `group_size`
/// (which must divide `nodes`): the leader tier aggregates `group_size`
/// member pushes, then the server tier aggregates `nodes / group_size`
/// group pushes. The levels are serialized — under BSP a leader forwards
/// its combined push only after its *last* member arrives — so the
/// two-level fleet pays the re-encode constant twice in exchange for
/// replacing the O(W) fan-in slope with O(m) + O(G).
pub fn hier_round_s(
    d_elems: usize,
    nodes: usize,
    group_size: usize,
    c: &Cluster,
    p: &CompressorProfile,
) -> f64 {
    let groups = nodes / group_size.max(1);
    fan_in_round_s(d_elems, group_size, c, p) + fan_in_round_s(d_elems, groups, c, p)
}

/// The best two-level split of `nodes` workers: the group size `m` (a
/// proper divisor with `2 <= m <= nodes/2`, so both levels aggregate at
/// least 2 peers) minimizing [`hier_round_s`]. `None` when `nodes < 4`
/// or prime — two-level needs at least 2 groups of at least 2.
pub fn best_group_size(
    d_elems: usize,
    nodes: usize,
    c: &Cluster,
    p: &CompressorProfile,
) -> Option<(usize, f64)> {
    let mut best: Option<(usize, f64)> = None;
    for m in 2..=nodes / 2 {
        if nodes % m != 0 {
            continue;
        }
        let t = hier_round_s(d_elems, nodes, m, c, p);
        if best.map_or(true, |(_, bt)| t < bt) {
            best = Some((m, t));
        }
    }
    best
}

/// Projected crossover: the smallest worker count (up to `max_nodes`)
/// where the best two-level split beats the flat topology's
/// [`fan_in_round_s`]. Wire-heavy profiles (identity) cross over at a
/// handful of workers — the serialized ingress dominates — while
/// CPU-heavy sparsifiers (top-k, whose re-encode constant the two-level
/// fleet pays twice) cross over only at large fleets. `None` if the flat
/// topology wins everywhere in range.
pub fn hier_crossover_nodes(
    d_elems: usize,
    c: &Cluster,
    p: &CompressorProfile,
    max_nodes: usize,
) -> Option<usize> {
    (4..=max_nodes).find(|&n| {
        best_group_size(d_elems, n, c, p)
            .is_some_and(|(_, t)| t < fan_in_round_s(d_elems, n, c, p))
    })
}

/// Geometric keep-ratio ramp from `lo` to `hi` over `steps` points — the
/// trajectory the adaptive per-key controller traces when measured gain sits
/// below `adaptive.target_gain` (its step rule is multiplicative, so the
/// ramp is geometric, not linear). `steps == 1` yields just `lo`; the last
/// point is always exactly `hi` otherwise. Endpoints outside `(0, 1]` are
/// the caller's bug and are clamped defensively.
pub fn ratio_trajectory(lo: f64, hi: f64, steps: usize) -> Vec<f64> {
    let lo = lo.clamp(1e-9, 1.0);
    let hi = hi.clamp(lo, 1.0);
    let steps = steps.max(1);
    if steps == 1 {
        return vec![lo];
    }
    let log_lo = lo.ln();
    let log_hi = hi.ln();
    (0..steps)
        .map(|i| {
            let t = i as f64 / (steps - 1) as f64;
            (log_lo + t * (log_hi - log_lo)).exp()
        })
        .collect()
}

/// Mean simulated step time over a keep-ratio trajectory: each point is a
/// static [`default_profile`] for `scheme` at that ratio, weighted equally.
/// This is the simnet projection of an *adaptive* run — the controller
/// spends early iterations at small `k` and ratchets toward the bound, so
/// its wall-clock sits between the static endpoints rather than at either.
pub fn trajectory_mean_step_time(
    w: &Workload,
    c: &Cluster,
    scheme: &str,
    trajectory: &[f64],
) -> f64 {
    assert!(!trajectory.is_empty(), "trajectory must have at least one ratio");
    let sum: f64 = trajectory
        .iter()
        .map(|&r| step_time(w, c, &default_profile(scheme, r)))
        .sum();
    sum / trajectory.len() as f64
}

/// Built-in (unmeasured) profiles with representative per-element costs —
/// used in unit tests and as a fallback when benches run without
/// calibration. Real benches overwrite these with `measure`d numbers.
pub fn default_profile(scheme: &str, param: f64) -> CompressorProfile {
    let (c_ns, d_ns, bpe) = match scheme {
        "identity" => (0.8, 0.8, 4.0),
        "fp16" => (2.0, 1.5, 2.0),
        "onebit" => (3.0, 2.0, 0.125),
        "topk" => (14.0, 0.05, 8.0 * param),
        "randomk" => (0.6, 0.05, 4.0 * param),
        "linear_dither" => (6.0, 3.0, param / 8.0),
        "natural_dither" => (9.0, 3.0, param / 8.0),
        _ => (4.0, 4.0, 4.0),
    };
    CompressorProfile {
        name: scheme.to_string(),
        compress_ns_per_elem: c_ns,
        decompress_ns_per_elem: d_ns,
        wire_bytes_fn: |n, bpe| (n as f64 * bpe).ceil() as usize,
        param: bpe,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_volume_classes() {
        // O(n) primitives grow linearly; O(1) primitives are bounded by 2.
        assert_eq!(primitives::all_gather(2), 1.0);
        assert_eq!(primitives::all_gather(8), 7.0);
        assert!(primitives::all_reduce(8) < 2.0);
        assert!(primitives::push_pull(8) < 2.0);
        assert!(primitives::all_reduce(64) < 2.0);
        // single node: no inter-node traffic
        assert_eq!(primitives::all_reduce(1), 0.0);
        assert_eq!(primitives::push_pull(1), 0.0);
    }

    #[test]
    fn paper_ideal_scaling_numbers() {
        // §5.1.2: ResNet50 ≈ 100%, VGG16 ≈ 40.4% at 25 Gb/s.
        let c = Cluster::default();
        let r = ideal_scaling(&Workload::resnet50(), &c);
        assert!(r > 0.99, "ResNet50 ideal scaling {r}");
        let v = ideal_scaling(&Workload::vgg16(), &c);
        assert!((v - 0.404).abs() < 0.03, "VGG16 ideal scaling {v} (paper: 0.404)");
    }

    #[test]
    fn compression_reduces_vgg16_step_time() {
        // Fig. 2's headline: VGG16 communication collapses under top-k.
        let c = Cluster::default();
        let w = Workload::vgg16();
        let full = step_time(&w, &c, &default_profile("identity", 0.0));
        let topk = step_time(&w, &c, &default_profile("topk", 0.001));
        assert!(topk < full * 0.6, "topk {topk} vs full {full}");
        // ResNet50: gain must be small (paper: 5%).
        let w = Workload::resnet50();
        let full = step_time(&w, &c, &default_profile("identity", 0.0));
        let topk = step_time(&w, &c, &default_profile("topk", 0.001));
        assert!(topk <= full + 1e-9 && topk > full * 0.85, "resnet topk {topk} vs full {full}");
    }

    #[test]
    fn single_node_has_no_internode_time() {
        let mut c = Cluster::default();
        c.nodes = 1;
        let w = Workload::resnet50();
        let p = default_profile("identity", 0.0);
        let b = step_breakdown(&w, &c, &p);
        // wire_s only contains the NVLink all-reduce now
        let intra = primitives::all_reduce(c.gpus_per_node) * w.d_elems as f64 * 2.0 * 8.0
            / (c.nvlink_gbps * 1e9);
        assert!((b.wire_s - intra).abs() < 1e-6);
    }

    #[test]
    fn scaling_efficiency_degrades_with_nodes_for_fat_models() {
        let p = default_profile("identity", 0.0);
        let w = Workload::vgg16();
        let mut effs = Vec::new();
        for nodes in [1usize, 2, 4, 8] {
            let mut c = Cluster::default();
            c.nodes = nodes;
            effs.push(scaling_efficiency(&w, &c, &p) / nodes as f64);
        }
        assert!((effs[0] - 1.0).abs() < 1e-9);
        // monotone decline
        for i in 1..effs.len() {
            assert!(effs[i] <= effs[i - 1] + 1e-9, "effs={effs:?}");
        }
        // and compression rescues it
        let pc = default_profile("topk", 0.001);
        let mut c = Cluster::default();
        c.nodes = 8;
        assert!(
            scaling_efficiency(&w, &c, &pc) > scaling_efficiency(&w, &c, &p),
            "compression should improve 8-node scaling"
        );
    }

    /// §4.2.1 acceptance shape: with the pipeline, compression wall-time is
    /// no longer additive with wire time; without it, it is. Uses a
    /// workload with no backprop overlap so step time isolates the comm
    /// path, and a profile whose CPU cost is comparable to its wire cost
    /// (where pipelining matters most).
    #[test]
    fn pipeline_overlaps_compression_with_wire() {
        let mut w = Workload::vgg16();
        w.overlap = 0.0; // no hiding behind backprop: comm is fully visible
        let p = CompressorProfile {
            name: "cpu-heavy".into(),
            compress_ns_per_elem: 20.0,
            decompress_ns_per_elem: 10.0,
            wire_bytes_fn: |n, bpe| (n as f64 * bpe).ceil() as usize,
            param: 2.0, // 2 B/elem on the wire
        };
        let mut on = Cluster::default();
        on.pipeline = true;
        let mut off = on.clone();
        off.pipeline = false;
        let t_on = step_breakdown(&w, &on, &p);
        let t_off = step_breakdown(&w, &off, &p);
        // Same component costs either way (the pipeline moves work in
        // time, it does not change how much work there is)...
        assert!((t_on.compress_s - t_off.compress_s).abs() < 1e-12);
        assert!((t_on.wire_s - t_off.wire_s).abs() < 1e-12);
        // ...but the serialized arm pays cpu + wire on the critical path.
        let cpu = t_on.compress_s + t_on.decompress_s;
        let intra = primitives::all_reduce(on.gpus_per_node) * w.d_elems as f64 * 2.0 * 8.0
            / (on.nvlink_gbps * 1e9);
        let wire_inter = t_on.wire_s - intra;
        let saving = t_off.total() - t_on.total();
        let expect = cpu.min(wire_inter);
        assert!(expect > 0.01, "test setup: cpu/wire should both be material, min={expect}");
        assert!(
            saving > 0.5 * expect,
            "pipeline saving {saving} too small vs min(cpu, wire) = {expect}"
        );
        // Deeper pipelines (smaller blocks) never cost more.
        let mut deep = on.clone();
        deep.pipeline_block_bytes = 1 << 20;
        let t_deep = step_breakdown(&w, &deep, &p);
        assert!(t_deep.total() <= t_on.total() + 1e-12);
    }

    /// Staged-server model: with `server_pipeline` off, the shard's CPU
    /// share serializes after the wire instead of overlapping it — step
    /// time can only grow, by exactly the server share that left the
    /// overlap (bounded by what the overlap was hiding). Component costs
    /// are identical either way (staging moves work in time).
    #[test]
    fn unstaged_server_serializes_its_cpu_share() {
        let mut w = Workload::vgg16();
        w.overlap = 0.0; // comm fully visible
        let p = CompressorProfile {
            name: "cpu-heavy".into(),
            compress_ns_per_elem: 20.0,
            decompress_ns_per_elem: 10.0,
            wire_bytes_fn: |n, bpe| (n as f64 * bpe).ceil() as usize,
            param: 2.0,
        };
        let staged = Cluster::default();
        let mut unstaged = staged.clone();
        unstaged.server_pipeline = false;
        let t_staged = step_breakdown(&w, &staged, &p);
        let t_unstaged = step_breakdown(&w, &unstaged, &p);
        assert!((t_staged.compress_s - t_unstaged.compress_s).abs() < 1e-12);
        assert!((t_staged.decompress_s - t_unstaged.decompress_s).abs() < 1e-12);
        assert!((t_staged.wire_s - t_unstaged.wire_s).abs() < 1e-12);
        let penalty = t_unstaged.total() - t_staged.total();
        assert!(penalty > 0.0, "unstaged shard must cost step time, got {penalty}");
        // With the block pipeline ALSO off everything serializes anyway:
        // the server knob changes nothing.
        let mut ser_a = staged.clone();
        ser_a.pipeline = false;
        let mut ser_b = unstaged.clone();
        ser_b.pipeline = false;
        assert!(
            (step_breakdown(&w, &ser_a, &p).total() - step_breakdown(&w, &ser_b, &p).total())
                .abs()
                < 1e-12
        );
    }

    /// Degraded-round model: zero loss is a strict no-op on the breakdown;
    /// with loss, the rate grows in loss and block count, is a proper
    /// probability, and the deadline stall shows up in step time.
    #[test]
    fn degraded_round_model_shapes() {
        let mut w = Workload::vgg16();
        // No backprop overlap: the deadline stall must be fully visible in
        // step time (with overlap it could hide behind tbp).
        w.overlap = 0.0;
        let clean = Cluster::default();
        assert_eq!(degraded_round_rate(&w, &clean), 0.0);
        let p = default_profile("topk", 0.001);
        let base = step_time(&w, &clean, &p);

        let mut lossy = clean.clone();
        lossy.push_loss = 1e-4;
        lossy.iter_deadline_s = 0.25;
        let rate = degraded_round_rate(&w, &lossy);
        assert!(rate > 0.0 && rate < 1.0, "rate {rate}");
        // More loss, more degraded rounds.
        let mut worse = lossy.clone();
        worse.push_loss = 1e-3;
        assert!(degraded_round_rate(&w, &worse) > rate);
        // Smaller blocks => more pushes per round => more exposure.
        let mut fine = lossy.clone();
        fine.pipeline_block_bytes = 1 << 20;
        assert!(degraded_round_rate(&w, &fine) > rate);
        // The deadline stall lands on the step time, and the breakdown
        // still reconciles (total = components).
        let t_lossy = step_time(&w, &lossy, &p);
        let expect = degraded_wait_s(&w, &lossy) * w.sync_rounds;
        assert!(
            (t_lossy - base - expect).abs() < 1e-9,
            "lossy {t_lossy} vs base {base} + stall {expect}"
        );
        // Certain loss degrades every round.
        let mut dead = lossy.clone();
        dead.push_loss = 1.0;
        assert!((degraded_round_rate(&w, &dead) - 1.0).abs() < 1e-12);
        // The stall is a pull-phase barrier after backprop: even with
        // full backprop overlap it lands on step time in full.
        let mut wo = Workload::vgg16();
        wo.overlap = 1.0;
        let mut lossy_o = lossy.clone();
        lossy_o.push_loss = 1e-4;
        let dt = step_time(&wo, &lossy_o, &p) - step_time(&wo, &clean, &p);
        let want = degraded_wait_s(&wo, &lossy_o) * wo.sync_rounds;
        assert!((dt - want).abs() < 1e-9, "overlap hid the deadline stall: {dt} vs {want}");
    }

    /// Adaptive-trajectory projection: a geometric ramp's mean step time is
    /// bracketed by the static endpoints (step time is monotone in the
    /// keep ratio — more kept elements, more wire bytes), and degenerate
    /// ramps collapse to the static model exactly.
    #[test]
    fn adaptive_trajectory_time_sits_between_static_endpoints() {
        let traj = ratio_trajectory(0.001, 0.05, 8);
        assert_eq!(traj.len(), 8);
        assert!((traj[0] - 0.001).abs() < 1e-12);
        assert!((traj[7] - 0.05).abs() < 1e-12);
        // geometric => strictly increasing
        for i in 1..traj.len() {
            assert!(traj[i] > traj[i - 1], "traj={traj:?}");
        }

        let mut w = Workload::vgg16();
        w.overlap = 0.0; // comm fully visible, so ratio changes show in time
        let c = Cluster::default();
        let t_lo = step_time(&w, &c, &default_profile("topk", 0.001));
        let t_hi = step_time(&w, &c, &default_profile("topk", 0.05));
        assert!(t_lo < t_hi, "test setup: step time must grow with ratio");
        let t_adaptive = trajectory_mean_step_time(&w, &c, "topk", &traj);
        assert!(
            t_adaptive > t_lo && t_adaptive < t_hi,
            "adaptive {t_adaptive} outside static bracket [{t_lo}, {t_hi}]"
        );

        // A flat trajectory IS the static model.
        let flat = ratio_trajectory(0.01, 0.01, 4);
        let t_flat = trajectory_mean_step_time(&w, &c, "topk", &flat);
        let t_static = step_time(&w, &c, &default_profile("topk", 0.01));
        assert!((t_flat - t_static).abs() < 1e-12);

        // Single-point trajectory is just the lower endpoint.
        assert_eq!(ratio_trajectory(0.02, 0.3, 1), vec![0.02]);
    }

    /// Hierarchical fan-in model: the two-level topology trades the O(W)
    /// serialized server ingress for O(m) + O(G) plus a second re-encode
    /// — so flat must win on tiny fleets, two-level on big ones, with a
    /// profile-dependent crossover in between.
    #[test]
    fn hierarchical_fan_in_crossover() {
        let c = Cluster::default();
        let d = Workload::vgg16().d_elems;
        let ident = default_profile("identity", 0.0);
        let topk = default_profile("topk", 0.001);

        // Tiny fleet: the extra tier costs more than the fan-in saves.
        for p in [&ident, &topk] {
            assert!(hier_round_s(d, 4, 2, &c, p) > fan_in_round_s(d, 4, &c, p));
        }
        // No valid split below 2 groups x 2 members, or for primes.
        assert!(best_group_size(d, 3, &c, &ident).is_none());
        assert!(best_group_size(d, 7, &c, &ident).is_none());

        // Wire-heavy identity crosses over almost immediately (serialized
        // ingress dominates); the CPU-heavy sparsifier — whose re-encode
        // constant the two-level fleet pays twice — needs a big fleet.
        let x_ident = hier_crossover_nodes(d, &c, &ident, 1 << 12).unwrap();
        let x_topk = hier_crossover_nodes(d, &c, &topk, 1 << 12).unwrap();
        assert!(x_ident <= 8, "identity crossover at {x_ident} workers");
        assert!((32..512).contains(&x_topk), "topk crossover at {x_topk} workers");
        assert!(x_ident < x_topk);

        // Past the crossover the two-level fleet keeps winning, and the
        // best split sits at sqrt(W) (m + W/m is minimized there).
        let (m, t) = best_group_size(d, 256, &c, &topk).unwrap();
        assert!(t < fan_in_round_s(d, 256, &c, &topk));
        assert_eq!(m, 16, "best split of 256 workers should be sqrt: got {m}");
    }

    #[test]
    fn measured_profile_is_sane() {
        let comp = crate::compress::by_name("onebit", 0.0).unwrap();
        let prof = CompressorProfile::measure("onebit", comp.as_ref(), 1 << 18, 0.0);
        assert!(prof.compress_ns_per_elem > 0.0 && prof.compress_ns_per_elem < 1e4);
        assert!(prof.decompress_ns_per_elem > 0.0);
        // ~0.125 bytes/elem + 4-byte scale
        let b = prof.wire_bytes(1 << 18) as f64 / (1 << 18) as f64;
        assert!(b < 0.2, "onebit bytes/elem {b}");
    }

    #[test]
    fn throughput_scales_with_nodes_for_thin_models() {
        let p = default_profile("topk", 0.001);
        let w = Workload::resnet50();
        let mut c = Cluster::default();
        c.nodes = 1;
        let t1 = throughput(&w, &c, &p);
        c.nodes = 8;
        let t8 = throughput(&w, &c, &p);
        assert!(t8 > t1 * 6.0, "t1={t1} t8={t8}");
    }
}
