//! In-tree property-testing mini-framework (proptest is unavailable
//! offline). Deterministic: every case derives from a root seed, and a
//! failure message reports the case index + seed so it can be replayed.

use crate::util::rng::Xoshiro256;

/// Per-case value generator.
pub struct Gen {
    rng: Xoshiro256,
    case_seed: u64,
}

impl Gen {
    fn new(case_seed: u64) -> Self {
        Gen { rng: Xoshiro256::seed_from_u64(case_seed), case_seed }
    }

    /// The seed identifying this case (for deriving auxiliary RNGs that
    /// must be stable per case).
    pub fn seed(&self) -> u64 {
        self.case_seed
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.next_f64()
    }

    /// Uniform f32 in `[-amp, amp)`.
    pub fn f32_amp(&mut self, amp: f32) -> f32 {
        self.rng.range_f32(-amp, amp)
    }

    /// Vector of `n` uniform f32 in `[-amp, amp)`, with occasional special
    /// structure mixed in (all-zero, single-spike, constant) to hit edge
    /// cases a plain uniform sampler would rarely produce.
    pub fn f32_vec(&mut self, n: usize, amp: f32) -> Vec<f32> {
        match self.rng.below(10) {
            0 => vec![0.0; n],
            1 => {
                let mut v = vec![0.0f32; n];
                if n > 0 {
                    let i = self.rng.below(n as u64) as usize;
                    v[i] = self.f32_amp(amp);
                }
                v
            }
            2 => vec![self.f32_amp(amp); n],
            _ => (0..n).map(|_| self.f32_amp(amp)).collect(),
        }
    }

    /// Vector of iid N(0, sigma²) samples.
    pub fn normal_vec(&mut self, n: usize, sigma: f32) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        self.rng.fill_normal(&mut v, sigma);
        v
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.below(items.len() as u64) as usize]
    }
}

/// Run `prop` on `cases` generated inputs. Panics with the case index and
/// seed on the first failure.
pub fn forall<F>(cases: usize, root_seed: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let mut seeder = Xoshiro256::seed_from_u64(root_seed);
    for case in 0..cases {
        let case_seed = seeder.next_u64();
        let mut g = Gen::new(case_seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property failed on case {case}/{cases} (case_seed={case_seed:#x}, \
                 root_seed={root_seed:#x}): {msg}"
            );
        }
    }
}

/// Assert two f32 slices are element-wise close.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for i in 0..a.len() {
        let tol = atol + rtol * b[i].abs();
        assert!(
            (a[i] - b[i]).abs() <= tol,
            "{what}: mismatch at {i}: {} vs {} (tol {tol})",
            a[i],
            b[i]
        );
    }
}

/// Relative L2 distance ‖a−b‖/max(‖b‖, eps) — scalar summary for
/// loss-curve and gradient comparisons.
pub fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        num += ((x - y) as f64).powi(2);
        den += (*y as f64).powi(2);
    }
    (num / den.max(1e-30)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_every_case() {
        let counter = std::cell::Cell::new(0usize);
        forall(37, 1, |_| {
            counter.set(counter.get() + 1);
            Ok(())
        });
        assert_eq!(counter.get(), 37);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failures() {
        forall(10, 2, |g| {
            if g.usize_in(0, 9) < 10 {
                Err("always fails".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut collected = Vec::new();
        forall(5, 99, |g| {
            collected.push(g.u64());
            Ok(())
        });
        let mut second = Vec::new();
        forall(5, 99, |g| {
            second.push(g.u64());
            Ok(())
        });
        assert_eq!(collected, second);
    }

    #[test]
    fn usize_in_is_inclusive() {
        forall(200, 3, |g| {
            let v = g.usize_in(5, 7);
            if (5..=7).contains(&v) {
                Ok(())
            } else {
                Err(format!("{v} out of [5,7]"))
            }
        });
    }

    #[test]
    fn rel_l2_zero_for_equal() {
        let a = vec![1.0f32, -2.0, 3.0];
        assert_eq!(rel_l2(&a, &a), 0.0);
    }

    #[test]
    #[should_panic(expected = "mismatch at 1")]
    fn allclose_catches_mismatch() {
        assert_allclose(&[1.0, 2.0], &[1.0, 2.1], 1e-3, 1e-3, "t");
    }
}
