//! Deterministic pseudo-random number generation.
//!
//! The `rand` crate is unavailable offline, so this module provides a
//! xoshiro256** generator (Blackman & Vigna) seeded through SplitMix64 —
//! the same construction `rand_xoshiro` uses. Every stochastic component
//! in the system (random-k sampling, dithering, synthetic data, property
//! tests) draws from this so runs are reproducible from a single seed.

/// SplitMix64 step — used for seeding and as a cheap standalone generator.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG. Fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 expansion, like `rand_xoshiro`.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Xoshiro256 { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` (n > 0), Lemire's method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal sample (Box–Muller; one value per call, unpaired).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fill `out` with iid N(0, sigma^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * sigma;
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm, then
    /// sorted for cache-friendly consumption). Requires `k <= n`.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<u32> {
        assert!(k <= n, "sample_indices: k={} > n={}", k, n);
        // Floyd's: for j in n-k..n, pick t in [0, j]; insert t or j.
        let mut set = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j as u64 + 1) as u32;
            if set.insert(t) {
                out.push(t);
            } else {
                set.insert(j as u32);
                out.push(j as u32);
            }
        }
        out.sort_unstable();
        out
    }

    /// Split into an independent stream (jump-free construction: reseed
    /// from the current stream; adequate for workload generation).
    pub fn fork(&mut self) -> Xoshiro256 {
        Xoshiro256::seed_from_u64(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Xoshiro256::seed_from_u64(42);
        let mut b = Xoshiro256::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_unit_interval() {
        let mut r = Xoshiro256::seed_from_u64(1);
        let mut sum = 0.0;
        const N: usize = 20_000;
        for _ in 0..N {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v));
            sum += v as f64;
        }
        let mean = sum / N as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Xoshiro256::seed_from_u64(2);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::seed_from_u64(3);
        const N: usize = 50_000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..N {
            let v = r.normal() as f64;
            s1 += v;
            s2 += v * v;
        }
        let mean = s1 / N as f64;
        let var = s2 / N as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Xoshiro256::seed_from_u64(4);
        for &(n, k) in &[(10usize, 10usize), (100, 7), (1000, 100), (5, 0)] {
            let idx = r.sample_indices(n, k);
            assert_eq!(idx.len(), k);
            for w in idx.windows(2) {
                assert!(w[0] < w[1], "not strictly sorted: {idx:?}");
            }
            for &i in &idx {
                assert!((i as usize) < n);
            }
        }
    }

    #[test]
    fn sample_indices_is_uniform_ish() {
        // Each index should appear with probability k/n.
        let mut r = Xoshiro256::seed_from_u64(5);
        let (n, k, trials) = (20usize, 5usize, 20_000usize);
        let mut counts = vec![0usize; n];
        for _ in 0..trials {
            for i in r.sample_indices(n, k) {
                counts[i as usize] += 1;
            }
        }
        let expect = trials * k / n; // 5000
        for &c in &counts {
            assert!(
                (c as f64 - expect as f64).abs() < expect as f64 * 0.1,
                "counts={counts:?}"
            );
        }
    }

    #[test]
    fn forks_are_decorrelated() {
        let mut a = Xoshiro256::seed_from_u64(42);
        let mut b = a.fork();
        let matches = (0..1000).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(matches, 0);
    }
}
