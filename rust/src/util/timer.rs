//! Wall-clock timing helpers for the custom benchmark harness (criterion is
//! unavailable offline; `cargo bench` runs `harness = false` binaries built
//! on these primitives).

use std::time::{Duration, Instant};

/// A simple stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Benchmark result: per-iteration timing summary.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    /// Throughput in items/s given items processed per iteration.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns / 1e9)
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} {:>10.3} ms/iter (±{:.3}, min {:.3}, max {:.3}, n={})",
            self.name,
            self.mean_ns / 1e6,
            self.std_ns / 1e6,
            self.min_ns / 1e6,
            self.max_ns / 1e6,
            self.iters
        )
    }
}

/// Run `f` for `warmup` + `iters` iterations and collect timing stats.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    let mut s = crate::util::stats::Summary::new();
    for &x in &samples {
        s.add(x);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: s.mean(),
        std_ns: s.std(),
        min_ns: s.min(),
        max_ns: s.max(),
    }
}

/// Run `f` repeatedly until `budget` elapses (at least once), returning stats.
pub fn bench_for<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // One calibration call, also serves as warmup.
    let t = Instant::now();
    f();
    let first = t.elapsed();
    let mut samples = vec![first.as_nanos() as f64];
    let deadline = Instant::now() + budget;
    while Instant::now() < deadline {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
        if samples.len() > 10_000 {
            break;
        }
    }
    let mut s = crate::util::stats::Summary::new();
    for &x in &samples {
        s.add(x);
    }
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_ns: s.mean(),
        std_ns: s.std(),
        min_ns: s.min(),
        max_ns: s.max(),
    }
}

/// Prevent the optimizer from discarding a value (std::hint::black_box is
/// stable; thin wrapper for call-site clarity).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop-spin", 2, 20, || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert_eq!(r.iters, 20);
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.mean_ns && r.mean_ns <= r.max_ns);
    }

    #[test]
    fn bench_for_respects_budget_loosely() {
        let r = bench_for("sleepless", Duration::from_millis(10), || {
            black_box(1 + 1);
        });
        assert!(r.iters >= 1);
    }
}
