//! Small self-contained utilities shared across the stack.
//!
//! Everything here is dependency-free by design: the offline build only
//! carries the `xla` crate's closure, so the PRNG, half-precision
//! conversion, and stats helpers that would normally come from `rand`,
//! `half`, and friends live in-tree.

pub mod f16;
pub mod rng;
pub mod stats;
pub mod timer;

/// Clamp `v` into `[lo, hi]`.
#[inline]
pub fn clamp(v: f32, lo: f32, hi: f32) -> f32 {
    v.max(lo).min(hi)
}

/// L2 norm of a slice.
#[inline]
pub fn l2_norm(x: &[f32]) -> f32 {
    x.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt() as f32
}

/// L1 norm of a slice.
#[inline]
pub fn l1_norm(x: &[f32]) -> f32 {
    x.iter().map(|v| v.abs() as f64).sum::<f64>() as f32
}

/// Maximum absolute value of a slice (0.0 for empty input).
#[inline]
pub fn max_abs(x: &[f32]) -> f32 {
    x.iter().fold(0.0f32, |m, v| m.max(v.abs()))
}

/// Human-readable byte count, e.g. `528.0 MiB`.
pub fn human_bytes(n: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", n, UNITS[0])
    } else {
        format!("{:.1} {}", v, UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norms() {
        assert_eq!(l2_norm(&[3.0, 4.0]), 5.0);
        assert_eq!(l1_norm(&[-3.0, 4.0]), 7.0);
        assert_eq!(max_abs(&[-3.0, 2.0]), 3.0);
        assert_eq!(max_abs(&[]), 0.0);
    }

    #[test]
    fn clamp_basic() {
        assert_eq!(clamp(5.0, 0.0, 1.0), 1.0);
        assert_eq!(clamp(-5.0, 0.0, 1.0), 0.0);
        assert_eq!(clamp(0.5, 0.0, 1.0), 0.5);
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.0 KiB");
        assert_eq!(human_bytes(528 * 1024 * 1024), "528.0 MiB");
    }
}
