//! Streaming statistics used by the benchmark harnesses and metrics.

/// Welford online mean/variance plus min/max.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator); 0 for fewer than 2 samples.
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

/// Percentile over a mutable sample buffer (nearest-rank, q in [0,1]).
pub fn percentile(samples: &mut [f64], q: f64) -> f64 {
    assert!(!samples.is_empty());
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((q * samples.len() as f64).ceil() as usize).clamp(1, samples.len());
    samples[rank - 1]
}

/// Ordinary least squares slope of y over x (for empirical rate fits, e.g.
/// verifying the O(1/sqrt(T)) convergence slope on log-log data).
pub fn ols_slope(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    let sx: f64 = x.iter().sum();
    let sy: f64 = y.iter().sum();
    let sxx: f64 = x.iter().map(|v| v * v).sum();
    let sxy: f64 = x.iter().zip(y).map(|(a, b)| a * b).sum();
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let data = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = Summary::new();
        for &d in &data {
            s.add(d);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // sample variance of this classic dataset = 32/7
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentiles() {
        let mut v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&mut v, 0.5), 50.0);
        assert_eq!(percentile(&mut v, 0.99), 99.0);
        assert_eq!(percentile(&mut v, 1.0), 100.0);
        assert_eq!(percentile(&mut v, 0.0), 1.0);
    }

    #[test]
    fn slope_of_line() {
        let x: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 * v + 1.0).collect();
        assert!((ols_slope(&x, &y) - 3.0).abs() < 1e-9);
    }
}
