//! IEEE-754 binary16 (and bfloat16) conversion, bit-exact, in-tree.
//!
//! The FP16 compressor (paper §4.1.1: intra-node conversion and the
//! "NAG (FP16)" baseline) needs f32↔f16 with round-to-nearest-even.
//! `half` is unavailable offline, so the conversion is implemented here
//! with the standard bit manipulation.

/// Convert f32 to IEEE binary16 bits with round-to-nearest-even.
/// Overflow saturates to ±inf; subnormals are produced where required.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf / NaN
        return if mant == 0 {
            sign | 0x7C00
        } else {
            sign | 0x7E00 // quiet NaN
        };
    }

    // Re-bias: f32 exp bias 127, f16 bias 15.
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7C00; // overflow -> inf
    }
    if unbiased >= -14 {
        // Normal f16. 10-bit mantissa; round to nearest even on bit 13.
        let mut m = mant >> 13;
        let rest = mant & 0x1FFF;
        if rest > 0x1000 || (rest == 0x1000 && (m & 1) == 1) {
            m += 1;
        }
        let mut e = (unbiased + 15) as u32;
        if m == 0x400 {
            // Mantissa rounding overflowed into the exponent.
            m = 0;
            e += 1;
            if e >= 31 {
                return sign | 0x7C00;
            }
        }
        return sign | ((e as u16) << 10) | (m as u16);
    }
    if unbiased >= -25 {
        // Subnormal f16 (−25 covers values that round up into the smallest
        // subnormal, e.g. 0.9999·2^-24).
        let full = mant | 0x0080_0000; // implicit leading 1
        let shift = (-14 - unbiased) as u32 + 13;
        let m = full >> shift;
        let rest = full & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let m = if rest > half || (rest == half && (m & 1) == 1) {
            m + 1
        } else {
            m
        };
        return sign | (m as u16);
    }
    sign // underflow -> signed zero
}

/// Convert IEEE binary16 bits to f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;
    let bits = match (exp, mant) {
        (0, 0) => sign,
        (0, m) => {
            // Subnormal: normalize.
            let lead = m.leading_zeros() - 21; // zeros within the 10-bit field
            let m = (m << (lead + 1)) & 0x03FF;
            let e = 127 - 15 - lead;
            sign | (e << 23) | (m << 13)
        }
        (0x1F, 0) => sign | 0x7F80_0000,
        (0x1F, m) => sign | 0x7F80_0000 | (m << 13),
        (e, m) => sign | ((e + 127 - 15) << 23) | (m << 13),
    };
    f32::from_bits(bits)
}

/// Round-trip f32 through f16 (the FP16 compressor's value transform).
#[inline]
pub fn f16_round(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// Convert f32 to bfloat16 bits with round-to-nearest-even.
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040; // keep sign, force quiet
    }
    let round_bit = 0x0000_8000u32;
    let lsb = (bits >> 16) & 1;
    let rest = bits & 0x0000_FFFF;
    let mut hi = (bits >> 16) as u16;
    if rest > round_bit || (rest == round_bit && lsb == 1) {
        hi = hi.wrapping_add(1);
    }
    hi
}

/// Convert bfloat16 bits to f32 (exact).
#[inline]
pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values() {
        // Values exactly representable in f16 must round-trip bit-exact.
        for &v in &[0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 0.25] {
            assert_eq!(f16_round(v), v, "v={v}");
        }
        assert_eq!(f32_to_f16_bits(1.0), 0x3C00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xC000);
        assert_eq!(f16_bits_to_f32(0x7C00), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(0xFC00), f32::NEG_INFINITY);
    }

    #[test]
    fn overflow_saturates_to_inf() {
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e6)), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(-1e6)), f32::NEG_INFINITY);
    }

    #[test]
    fn nan_stays_nan() {
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn subnormals() {
        let tiny = 5.96e-8f32; // smallest positive f16 subnormal ~5.96e-8
        let rt = f16_round(tiny);
        assert!(rt > 0.0 && (rt - tiny).abs() / tiny < 0.5);
        // Deep underflow flushes to zero with preserved sign.
        assert_eq!(f16_round(1e-10), 0.0);
        assert_eq!(f16_round(-1e-10).to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn relative_error_bound_for_normals() {
        // f16 has 11 bits of significand => rel err <= 2^-11 for normals.
        let mut state = 123u64;
        for _ in 0..10_000 {
            let r = crate::util::rng::splitmix64(&mut state);
            let v = ((r as f64 / u64::MAX as f64) * 2.0 - 1.0) as f32 * 100.0;
            if v.abs() < 6.2e-5 {
                continue; // skip subnormal range
            }
            let rt = f16_round(v);
            let rel = ((rt - v) / v).abs();
            assert!(rel <= 1.0 / 2048.0 + 1e-7, "v={v} rt={rt} rel={rel}");
        }
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and 1+2^-10:
        // must round to even mantissa (1.0).
        let halfway = 1.0 + 2f32.powi(-11);
        assert_eq!(f16_round(halfway), 1.0);
        // 1 + 3*2^-11 is halfway between 1+2^-10 and 1+2^-9: rounds up to even.
        let halfway2 = 1.0 + 3.0 * 2f32.powi(-11);
        assert_eq!(f16_round(halfway2), 1.0 + 2.0 * 2f32.powi(-10));
    }

    #[test]
    fn bf16_roundtrip() {
        for &v in &[0.0f32, 1.0, -1.0, 3.140625, 1e30, -1e-30] {
            let rt = bf16_bits_to_f32(f32_to_bf16_bits(v));
            if v == 0.0 {
                assert_eq!(rt, 0.0);
            } else {
                assert!(((rt - v) / v).abs() <= 1.0 / 256.0, "v={v} rt={rt}");
            }
        }
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::NAN)).is_nan());
    }
}
