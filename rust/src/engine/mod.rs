//! Training engine: wires the PJRT runtime (L2 artifacts), the data
//! generators, the comm fabric (workers + parameter servers), and the
//! optimizer into the full CLAN training loop (Alg. 5).
//!
//! The comm fabric is reusable without a model ([`CommFabric`]): benches
//! drive it with synthetic gradients to measure the pure system cost,
//! which is how the Table-6 ablation rows are produced.

use crate::comm::Endpoint;
use crate::compress::threshold::SizeThreshold;
use crate::compress::Compressor;
use crate::configx::{SyncMode, TrainConfig};
use crate::data::Corpus;
use crate::metrics::Breakdown;
use crate::optim::{blocks::Block, WarmupSchedule};
use crate::parallel::ThreadPool;
use crate::ps::{Server, ServerOptions, ServerStats, ShardPlan};
use crate::runtime::{self, Manifest, Runtime};
use crate::worker::pipeline::Partition;
use crate::worker::WorkerComm;
use anyhow::{Context, Result};
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

/// Per-exchange timing/volume stats (summed over workers).
#[derive(Clone, Copy, Debug, Default)]
pub struct CommStats {
    pub compress_s: f64,
    pub decompress_s: f64,
    pub wire_bytes: u64,
}

/// Transport-agnostic fabric derivation: everything both sides of the wire
/// must agree on — compressor, sync mode, fusion, block partition, shard
/// plan, cluster shape — computed once from config + model blocks. The
/// single-process [`CommFabric`] and the multi-process cluster launchers
/// ([`crate::cluster`]) both build from this, so the two paths cannot
/// drift: same config in, same plan and seeds out.
pub struct FabricSpec {
    pub comp: Arc<dyn Compressor>,
    pub sync: SyncMode,
    pub fused: bool,
    pub n_workers: usize,
    pub n_servers: usize,
    /// Hierarchical two-level aggregation (`cluster.groups`): number of
    /// worker groups, `0` = flat. With groups, each server shard talks to
    /// `groups` leader relays instead of `n_workers` workers — fan-in
    /// drops from O(W) to O(G) — while `n_workers` keeps its flat meaning
    /// (the averaging divisor and the `served_with` unit).
    pub groups: usize,
    /// Block partition (§4.2.1/§4.2.3): the pipeline's wire unit.
    pub partition: Arc<Partition>,
    /// Key → server-shard assignment (§4.2.4).
    pub plan: Arc<ShardPlan>,
}

impl FabricSpec {
    /// Derive the spec from a config (scheme, sync mode, threshold,
    /// fusion, shard balance, servers, pipeline partitioning).
    pub fn from_config(cfg: &TrainConfig, blocks: &[Block]) -> Result<FabricSpec> {
        let n_workers = cfg.cluster.nodes;
        // Cluster mode pins the shard count to the address list; the
        // single-process default keeps the §4.2.5 more-servers derivation.
        let n_servers = if !cfg.cluster.addresses.is_empty() {
            cfg.cluster.addresses.len()
        } else if cfg.system.more_servers {
            cfg.cluster.servers.max(2)
        } else {
            1
        };
        let inner = crate::compress::by_name(&cfg.compression.scheme, cfg.compression.param)
            .map_err(anyhow::Error::msg)?;
        let comp: Arc<dyn Compressor> = if cfg.system.size_threshold_on {
            Arc::new(SizeThreshold::new(inner, cfg.compression.size_threshold))
        } else {
            inner
        };
        let sync =
            if comp.name() == "identity" { SyncMode::Full } else { cfg.compression.sync };
        let fused = cfg.system.operator_fusion && cfg.compression.fused_residual;

        // With the pipeline off every tensor is one block and the keyspace
        // is bit-compatible with the pre-pipeline fabric.
        let partition =
            Arc::new(Partition::new(blocks, cfg.pipeline.block_bytes, cfg.pipeline.enabled));

        // Shard plan (§4.2.4), balancing *blocks*: compressed blocks cost
        // ~4x their size in server CPU (decompress xN + compress);
        // bypassed blocks are memcpy-cheap. Splitting big tensors first
        // means their server-side work spreads across shards too.
        let items: Vec<(crate::comm::Key, f64)> = partition
            .subs()
            .iter()
            .map(|sb| {
                let bypass =
                    cfg.system.size_threshold_on && 4 * sb.len() < cfg.compression.size_threshold;
                (sb.key, sb.len() as f64 * if bypass { 1.0 } else { 4.0 })
            })
            .collect();
        let plan = Arc::new(if cfg.system.workload_balance {
            ShardPlan::balanced_keyed(&items, n_servers)
        } else {
            ShardPlan::round_robin_keyed(
                &items.iter().map(|(k, _)| *k).collect::<Vec<_>>(),
                n_servers,
            )
        });

        let groups = cfg.cluster.groups;
        if groups > 0 && n_workers % groups != 0 {
            // validate() catches this for loaded configs; guard the
            // programmatic path too so a bad spec fails here, not as a
            // wedged relay.
            anyhow::bail!("cluster.groups ({groups}) must evenly divide nodes ({n_workers})");
        }

        Ok(FabricSpec { comp, sync, fused, n_workers, n_servers, groups, partition, plan })
    }

    /// How many peers each server shard registers and reads from: the
    /// group leaders in hierarchical mode, every worker when flat.
    pub fn registrants(&self) -> usize {
        if self.groups > 0 {
            self.groups
        } else {
            self.n_workers
        }
    }

    /// Workers per group (hierarchical mode only; panics on `groups = 0`
    /// via division semantics — callers check `groups > 0` first).
    pub fn group_size(&self) -> usize {
        self.n_workers / self.groups.max(1)
    }

    /// The shard plan a group *member* routes by: its single endpoint is
    /// the leader, so every key maps to endpoint 0. The leader routes by
    /// the real [`plan`](FabricSpec::plan).
    pub fn member_plan(&self) -> Arc<ShardPlan> {
        let keys: Vec<crate::comm::Key> =
            self.partition.subs().iter().map(|sb| sb.key).collect();
        Arc::new(ShardPlan::round_robin_keyed(&keys, 1))
    }

    /// Relay options for group `group_idx` (shared by the inproc fabric
    /// and the cluster `leader` subcommand — one derivation, no drift).
    pub fn relay_options(
        &self,
        group_idx: u32,
        run_seed: u64,
    ) -> crate::worker::group::RelayOptions {
        let m = self.group_size();
        let base = group_idx as usize * m;
        crate::worker::group::RelayOptions {
            group_idx,
            member_ranks: (base..base + m).map(|r| r as u32).collect(),
            comp: Arc::clone(&self.comp),
            sync: self.sync,
            fused: self.fused,
            seed: run_seed,
            plan: Arc::clone(&self.plan),
        }
    }

    /// Per-shard server RNG seed. One derivation shared by the inproc
    /// fabric and the cluster `server` subcommand — second-way stochastic
    /// compression must not depend on how the shard was launched.
    pub fn server_seed(run_seed: u64, shard: usize) -> u64 {
        run_seed ^ (shard as u64).wrapping_mul(0xD1B54A32D192ED03)
    }

    /// Options for server shard `shard` under run seed `run_seed`.
    pub fn server_options(&self, cfg: &TrainConfig, shard: usize, run_seed: u64) -> ServerOptions {
        ServerOptions {
            comp: Arc::clone(&self.comp),
            sync: self.sync,
            fused: self.fused,
            n_workers: self.n_workers,
            intra_threads: cfg.system.intra_threads,
            seed: Self::server_seed(run_seed, shard),
            // A shard serves a subset of the partition; its key count can
            // never legitimately exceed the whole partition.
            max_keys: self.partition.len(),
            iter_deadline: cfg.server.iter_deadline(),
            compress_threads: cfg.server.compress_threads,
            deadline_auto_margin: cfg.server.iter_deadline_auto_margin,
            // Single-process runs derive the envelope from the shared
            // config (the grant the TCP handshake would negotiate against
            // itself); cluster servers do the same in `cluster::serve`.
            adaptive_bounds: {
                let b = crate::compress::controller::requested_bounds(cfg);
                (b != (0, 0)).then_some(b)
            },
        }
    }

    /// Build one worker's comm client over an endpoint row (`endpoints[s]`
    /// talks to server shard `s`). `run_seed`, `plan`, and the granted
    /// `adaptive` controller are explicit because cluster workers adopt
    /// all three from the servers' `Welcome` rather than their local
    /// config (`None` = static compression).
    pub fn worker_comm(
        &self,
        cfg: &TrainConfig,
        rank: u32,
        run_seed: u64,
        endpoints: Vec<Box<dyn Endpoint>>,
        plan: Arc<ShardPlan>,
        adaptive: Option<Arc<crate::compress::controller::GainController>>,
    ) -> WorkerComm {
        WorkerComm::new(
            rank,
            Arc::clone(&self.comp),
            self.sync,
            self.fused,
            cfg.system.intra_threads,
            run_seed,
            endpoints,
            plan,
            cfg.system.compress_threads,
            cfg.pipeline.inflight,
            cfg.pipeline.ack_window,
            self.n_workers,
            adaptive,
        )
    }
}

/// A fully-wired endpoint mesh: `worker_rows[w][s]` is worker `w`'s
/// endpoint to server `s`, `server_rows[s][w]` the matching server side.
/// [`inproc`](EndpointMesh::inproc) builds the single-process mesh;
/// cluster mode builds one row per OS process over TCP instead and never
/// holds the whole mesh in one place.
pub struct EndpointMesh {
    pub worker_rows: Vec<Vec<Box<dyn Endpoint>>>,
    pub server_rows: Vec<Vec<Box<dyn Endpoint>>>,
}

impl EndpointMesh {
    /// In-process channel mesh: one `inproc::pair` per (worker, server).
    pub fn inproc(n_workers: usize, n_servers: usize) -> EndpointMesh {
        let mut worker_rows: Vec<Vec<Box<dyn Endpoint>>> =
            (0..n_workers).map(|_| Vec::with_capacity(n_servers)).collect();
        let mut server_rows: Vec<Vec<Box<dyn Endpoint>>> = Vec::with_capacity(n_servers);
        for _ in 0..n_servers {
            let mut server_side: Vec<Box<dyn Endpoint>> = Vec::with_capacity(n_workers);
            for row in worker_rows.iter_mut() {
                let (wep, sep) = crate::comm::inproc::pair();
                row.push(Box::new(wep) as Box<dyn Endpoint>);
                server_side.push(Box::new(sep) as Box<dyn Endpoint>);
            }
            server_rows.push(server_side);
        }
        EndpointMesh { worker_rows, server_rows }
    }
}

/// The hierarchical (two-level) endpoint mesh: workers talk only to their
/// group's relay, relays talk to every server shard. `worker_rows[w]` is
/// one endpoint (worker `w` → its leader); `member_rows[g]` the relay
/// side of group `g`'s member links in global-rank order;
/// `upstream_rows[g][s]` relay `g`'s endpoint to shard `s`;
/// `server_rows[s][g]` the matching shard side (index == group index, so
/// the server's connection-ordered reduce is group-ordered).
pub struct HierMesh {
    pub worker_rows: Vec<Vec<Box<dyn Endpoint>>>,
    pub member_rows: Vec<Vec<Box<dyn Endpoint>>>,
    pub upstream_rows: Vec<Vec<Box<dyn Endpoint>>>,
    pub server_rows: Vec<Vec<Box<dyn Endpoint>>>,
}

impl HierMesh {
    /// In-process two-level mesh for `n_workers` workers in `groups`
    /// equal groups over `n_servers` shards.
    pub fn inproc(n_workers: usize, groups: usize, n_servers: usize) -> HierMesh {
        assert!(groups > 0 && n_workers % groups == 0);
        let m = n_workers / groups;
        let mut worker_rows: Vec<Vec<Box<dyn Endpoint>>> = Vec::with_capacity(n_workers);
        let mut member_rows: Vec<Vec<Box<dyn Endpoint>>> = Vec::with_capacity(groups);
        for _g in 0..groups {
            let mut members: Vec<Box<dyn Endpoint>> = Vec::with_capacity(m);
            for _ in 0..m {
                let (wep, rep) = crate::comm::inproc::pair();
                worker_rows.push(vec![Box::new(wep) as Box<dyn Endpoint>]);
                members.push(Box::new(rep) as Box<dyn Endpoint>);
            }
            member_rows.push(members);
        }
        let mut upstream_rows: Vec<Vec<Box<dyn Endpoint>>> =
            (0..groups).map(|_| Vec::with_capacity(n_servers)).collect();
        let mut server_rows: Vec<Vec<Box<dyn Endpoint>>> = Vec::with_capacity(n_servers);
        for _s in 0..n_servers {
            let mut server_side: Vec<Box<dyn Endpoint>> = Vec::with_capacity(groups);
            for row in upstream_rows.iter_mut() {
                let (uep, sep) = crate::comm::inproc::pair();
                row.push(Box::new(uep) as Box<dyn Endpoint>);
                server_side.push(Box::new(sep) as Box<dyn Endpoint>);
            }
            server_rows.push(server_side);
        }
        HierMesh { worker_rows, member_rows, upstream_rows, server_rows }
    }
}

/// Workers + servers wired over an endpoint mesh (in-process by default).
/// With `cluster.groups > 0` a tier of group-leader relays
/// ([`crate::worker::group`]) sits between them.
pub struct CommFabric {
    workers: Vec<WorkerComm>,
    relays: Vec<crate::worker::group::RelayHandle>,
    servers: Vec<Server>,
    blocks: Vec<Block>,
    partition: Arc<Partition>,
    pipelined: bool,
    dim: usize,
    iter: u64,
}

impl CommFabric {
    /// Build a fabric for `blocks` over a flat `dim`-vector, as configured,
    /// over in-process channels. `cluster.groups > 0` builds the two-level
    /// topology (workers → group relays → shards) instead of the flat mesh.
    pub fn new(cfg: &TrainConfig, blocks: Vec<Block>, dim: usize) -> Result<CommFabric> {
        let spec = FabricSpec::from_config(cfg, &blocks)?;
        if spec.groups > 0 {
            let mesh = HierMesh::inproc(spec.n_workers, spec.groups, spec.n_servers);
            return Self::with_hier_mesh(cfg, spec, blocks, dim, mesh);
        }
        let mesh = EndpointMesh::inproc(spec.n_workers, spec.n_servers);
        Self::with_mesh(cfg, spec, blocks, dim, mesh)
    }

    /// Build the two-level fabric over an explicit hierarchical mesh:
    /// each server shard reads `groups` connections (one per relay), each
    /// relay locally combines its `n_workers / groups` members' pushes.
    pub fn with_hier_mesh(
        cfg: &TrainConfig,
        spec: FabricSpec,
        blocks: Vec<Block>,
        dim: usize,
        mesh: HierMesh,
    ) -> Result<CommFabric> {
        if mesh.worker_rows.len() != spec.n_workers
            || mesh.member_rows.len() != spec.groups
            || mesh.upstream_rows.len() != spec.groups
            || mesh.server_rows.len() != spec.n_servers
        {
            anyhow::bail!(
                "hierarchical mesh shape mismatch: {} workers / {} member rows / \
                 {} upstream rows / {} server rows vs spec {}w x {}g x {}s",
                mesh.worker_rows.len(),
                mesh.member_rows.len(),
                mesh.upstream_rows.len(),
                mesh.server_rows.len(),
                spec.n_workers,
                spec.groups,
                spec.n_servers
            );
        }
        let shared_pool: Option<Arc<ThreadPool>> = (cfg.server.compress_threads > 0)
            .then(|| Arc::new(ThreadPool::new(cfg.server.compress_threads)));
        let mut servers = Vec::with_capacity(spec.n_servers);
        for (s, server_side) in mesh.server_rows.into_iter().enumerate() {
            // n_workers stays W in the options: G weighted group pushes
            // must average exactly like W flat ones.
            servers.push(Server::spawn_with_pool(
                spec.server_options(cfg, s, cfg.seed),
                server_side,
                shared_pool.clone(),
            ));
        }
        let relays: Vec<crate::worker::group::RelayHandle> = mesh
            .member_rows
            .into_iter()
            .zip(mesh.upstream_rows)
            .enumerate()
            .map(|(g, (members, upstream))| {
                crate::worker::group::spawn_relay(
                    spec.relay_options(g as u32, cfg.seed),
                    members,
                    upstream,
                )
            })
            .collect();
        // Every worker routes all keys to its single leader endpoint; its
        // rank, seeds, and EF state keep their flat-W meaning.
        let member_plan = spec.member_plan();
        let workers = mesh
            .worker_rows
            .into_iter()
            .enumerate()
            .map(|(w, eps)| {
                spec.worker_comm(cfg, w as u32, cfg.seed, eps, Arc::clone(&member_plan), None)
            })
            .collect();
        Ok(CommFabric {
            workers,
            relays,
            servers,
            blocks,
            partition: Arc::clone(&spec.partition),
            pipelined: cfg.pipeline.enabled,
            dim,
            iter: 0,
        })
    }

    /// Build a fabric over an explicit endpoint mesh. The mesh shape must
    /// match the spec (`n_workers` x `n_servers`).
    pub fn with_mesh(
        cfg: &TrainConfig,
        spec: FabricSpec,
        blocks: Vec<Block>,
        dim: usize,
        mesh: EndpointMesh,
    ) -> Result<CommFabric> {
        if mesh.worker_rows.len() != spec.n_workers || mesh.server_rows.len() != spec.n_servers {
            anyhow::bail!(
                "mesh is {}x{} but the spec needs {}x{} (workers x servers)",
                mesh.worker_rows.len(),
                mesh.server_rows.len(),
                spec.n_workers,
                spec.n_servers
            );
        }
        // Staged shards (§4.2.1 server side): in-process, every co-located
        // shard shares ONE decode/encode pool — they model one machine's
        // compression CPUs, and per-shard pools would oversubscribe it.
        // Cluster mode gives each shard its own pool instead (one shard
        // per OS process owns its CPUs; see `cluster::serve`).
        let shared_pool: Option<Arc<ThreadPool>> = (cfg.server.compress_threads > 0)
            .then(|| Arc::new(ThreadPool::new(cfg.server.compress_threads)));
        let mut servers = Vec::with_capacity(spec.n_servers);
        for (s, server_side) in mesh.server_rows.into_iter().enumerate() {
            servers.push(Server::spawn_with_pool(
                spec.server_options(cfg, s, cfg.seed),
                server_side,
                shared_pool.clone(),
            ));
        }
        let workers = mesh
            .worker_rows
            .into_iter()
            .enumerate()
            .map(|(w, eps)| {
                // In-process: the worker self-grants its own request (the
                // exact pair the TCP handshake would echo back), so inproc
                // and cluster adaptive runs see identical bounds.
                let adaptive = crate::compress::controller::from_negotiated(
                    cfg,
                    crate::compress::controller::requested_bounds(cfg),
                );
                spec.worker_comm(cfg, w as u32, cfg.seed, eps, Arc::clone(&spec.plan), adaptive)
            })
            .collect();

        Ok(CommFabric {
            workers,
            relays: Vec::new(),
            servers,
            blocks,
            partition: Arc::clone(&spec.partition),
            pipelined: cfg.pipeline.enabled,
            dim,
            iter: 0,
        })
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    pub fn blocks(&self) -> &[Block] {
        &self.blocks
    }

    /// The wire partition (tensor blocks) this fabric exchanges.
    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    /// One BSP exchange (Alg. 3/4 end to end over the message fabric):
    /// every worker pushes all its blocks, then pulls all aggregates.
    /// With the pipeline enabled, per-block compress→push and
    /// pull→decompress jobs run through each worker's thread pool
    /// (§4.2.1); otherwise the serial reference path runs inline.
    /// Returns worker 0's aggregated gradient (all workers receive the
    /// same bytes) plus summed stats.
    pub fn exchange(&mut self, per_worker_grads: &[Vec<f32>]) -> (Vec<f32>, CommStats) {
        assert_eq!(per_worker_grads.len(), self.workers.len());
        for g in per_worker_grads {
            assert_eq!(g.len(), self.dim);
        }
        let iter = self.iter;
        self.iter += 1;
        let partition = &self.partition;
        let pipelined = self.pipelined;
        let dim = self.dim;
        let results: Vec<(Vec<f32>, CommStats)> = std::thread::scope(|s| {
            let handles: Vec<_> = self
                .workers
                .iter_mut()
                .zip(per_worker_grads)
                .map(|(wc, grad)| {
                    s.spawn(move || {
                        let mut stats = CommStats::default();
                        let before = wc.bytes_sent();
                        let mut agg = vec![0.0f32; dim];
                        if pipelined {
                            stats.compress_s += wc.push_all(iter, grad, partition);
                            let (rx_bytes, dt) = wc.pull_all(iter, &mut agg, partition);
                            stats.wire_bytes += rx_bytes;
                            stats.decompress_s += dt;
                        } else {
                            for sb in partition.subs() {
                                let (_, dt) = wc.push(sb.key, iter, &grad[sb.range.clone()]);
                                stats.compress_s += dt;
                            }
                            for sb in partition.subs() {
                                let (rx_bytes, dt) =
                                    wc.pull(sb.key, iter, &mut agg[sb.range.clone()]);
                                stats.wire_bytes += rx_bytes as u64;
                                stats.decompress_s += dt;
                            }
                        }
                        stats.wire_bytes += wc.bytes_sent() - before;
                        (agg, stats)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("worker thread panicked")).collect()
        });
        let mut total = CommStats::default();
        for (_, st) in &results {
            total.compress_s += st.compress_s;
            total.decompress_s += st.decompress_s;
            total.wire_bytes += st.wire_bytes;
        }
        (results.into_iter().next().unwrap().0, total)
    }

    /// Shut everything down; returns per-server stats. In the two-level
    /// topology the member shutdowns drain the relays first (each relay
    /// forwards one `Shutdown` per shard once all its members are done),
    /// then the shards exit.
    pub fn shutdown(self) -> Vec<ServerStats> {
        for w in &self.workers {
            w.shutdown();
        }
        drop(self.workers);
        for r in self.relays {
            let stats = r.join();
            if stats.rejected + stats.unexpected > 0 {
                eprintln!("relay: {stats}");
            }
        }
        self.servers.into_iter().map(|s| s.join()).collect()
    }
}

/// Full training-run report.
#[derive(Debug, Default)]
pub struct EngineReport {
    /// (step, mean training loss over workers)
    pub losses: Vec<(usize, f64)>,
    /// (step, eval loss) — held-out corpus.
    pub eval_losses: Vec<(usize, f64)>,
    pub breakdown: Breakdown,
    pub wire_bytes: u64,
    pub elapsed_s: f64,
    pub steps: usize,
    /// Total f32s a full-precision run would have moved (for rate reports).
    pub full_precision_bytes: u64,
    /// Final flat parameter vector (for downstream eval / finetuning).
    pub final_params: Vec<f32>,
}

impl EngineReport {
    pub fn compression_rate(&self) -> f64 {
        self.full_precision_bytes as f64 / self.wire_bytes.max(1) as f64
    }

    pub fn final_loss(&self) -> f64 {
        self.losses.last().map(|(_, l)| *l).unwrap_or(f64::NAN)
    }
}

/// Train a model end to end per the config. This is the paper's Alg. 5
/// running over real message passing with the PJRT-compiled model.
pub fn train(cfg: &TrainConfig, art_dir: &Path) -> Result<EngineReport> {
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(art_dir)?;
    let entry = manifest.model(&cfg.model)?.clone();
    let train_exe = rt
        .load_hlo(&manifest.dir.join(&entry.train_hlo))
        .context("compile train artifact")?;
    let eval_exe = rt.load_hlo(&manifest.dir.join(&entry.eval_hlo)).context("compile eval artifact")?;

    let mut params = manifest.load_init_params(&entry)?;
    let blocks = manifest.blocks(&entry);
    let dim = entry.total_params;
    let mut opt = crate::optim::build(&cfg.optimizer, blocks.clone(), dim)
        .map_err(anyhow::Error::msg)?;
    let schedule = WarmupSchedule {
        base_lr: cfg.optimizer.lr,
        warmup_steps: cfg.optimizer.warmup_steps,
        total_steps: 0,
    };

    let mut fabric = CommFabric::new(cfg, blocks, dim)?;
    let n_workers = fabric.n_workers();
    let mut corpora: Vec<Corpus> =
        (0..n_workers).map(|w| Corpus::new(entry.vocab, cfg.seed ^ (w as u64) << 17)).collect();
    let mut heldout = Corpus::new(entry.vocab, cfg.seed ^ 0xE7A1);
    let mut tasks: Vec<crate::data::ClassifyTask> = (0..n_workers)
        .map(|w| crate::data::ClassifyTask::new("train", entry.vocab, entry.num_classes.max(2), 0.55, cfg.seed ^ (w as u64) << 9))
        .collect();

    let mut report = EngineReport::default();
    let run_start = Instant::now();

    for step in 0..cfg.steps {
        opt.set_lr(schedule.lr_at(step) as f32);

        // 1. Per-worker forward/backward through PJRT.
        let t = Instant::now();
        let mut grads = Vec::with_capacity(n_workers);
        let mut loss_sum = 0.0f64;
        for w in 0..n_workers {
            let mut inputs = runtime::param_literals(&entry, &params)?;
            if entry.num_classes > 0 {
                let (tokens, labels) = tasks[w].batch(entry.batch, entry.seq);
                inputs.push(runtime::i32_literal(&tokens, &[entry.batch, entry.seq])?);
                inputs.push(runtime::i32_literal(&labels, &[entry.batch])?);
            } else {
                let b = corpora[w].mlm_batch(entry.batch, entry.seq, 0.15);
                inputs.push(runtime::i32_literal(&b.tokens, &[entry.batch, entry.seq])?);
                inputs.push(runtime::i32_literal(&b.targets, &[entry.batch, entry.seq])?);
                inputs.push(runtime::f32_literal(&b.mask, &[entry.batch, entry.seq])?);
            }
            let outputs = train_exe.run(&inputs)?;
            let (loss, flat) = runtime::collect_grads(&entry, &outputs)?;
            loss_sum += loss as f64;
            grads.push(flat);
        }
        report.breakdown.compute_s += t.elapsed().as_secs_f64();

        // 2. Compressed push/pull over the fabric.
        let t = Instant::now();
        let (agg, stats) = fabric.exchange(&grads);
        let wall = t.elapsed().as_secs_f64();
        report.breakdown.compress_s += stats.compress_s;
        report.breakdown.decompress_s += stats.decompress_s;
        report.breakdown.wire_s += (wall - stats.compress_s - stats.decompress_s).max(0.0);
        report.wire_bytes += stats.wire_bytes;
        report.full_precision_bytes += (n_workers * 2 * 4 * dim) as u64;

        // 3. Optimizer update (identical on every worker; applied once to
        // the replicated parameter vector).
        let t = Instant::now();
        opt.step(&mut params, &agg);
        report.breakdown.optimizer_s += t.elapsed().as_secs_f64();

        let mean_loss = loss_sum / n_workers as f64;
        report.losses.push((step, mean_loss));

        if cfg.log_every > 0 && (step % cfg.log_every == 0 || step + 1 == cfg.steps) {
            // Held-out eval (MLM models only; classifier eval needs labels
            // from its task, handled by the examples directly).
            if entry.num_classes == 0 {
                let b = heldout.mlm_batch(entry.batch, entry.seq, 0.15);
                let mut inputs = runtime::param_literals(&entry, &params)?;
                inputs.push(runtime::i32_literal(&b.tokens, &[entry.batch, entry.seq])?);
                inputs.push(runtime::i32_literal(&b.targets, &[entry.batch, entry.seq])?);
                inputs.push(runtime::f32_literal(&b.mask, &[entry.batch, entry.seq])?);
                let out = eval_exe.run(&inputs)?;
                let eval_loss = out[0].to_vec::<f32>()?[0] as f64;
                report.eval_losses.push((step, eval_loss));
            }
        }
    }

    report.steps = cfg.steps;
    report.elapsed_s = run_start.elapsed().as_secs_f64();
    report.final_params = params;
    fabric.shutdown();
    Ok(report)
}

/// Evaluate a classifier checkpoint on `n_batches` held-out batches of the
/// given task; returns (mean loss, mean accuracy).
pub fn eval_classifier(
    model: &str,
    art_dir: &Path,
    params: &[f32],
    task: &mut crate::data::ClassifyTask,
    n_batches: usize,
) -> Result<(f64, f64)> {
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(art_dir)?;
    let entry = manifest.model(model)?.clone();
    let exe = rt.load_hlo(&manifest.dir.join(&entry.eval_hlo))?;
    let mut loss_sum = 0.0;
    let mut acc_sum = 0.0;
    for _ in 0..n_batches {
        let (tokens, labels) = task.batch(entry.batch, entry.seq);
        let mut inputs = runtime::param_literals(&entry, params)?;
        inputs.push(runtime::i32_literal(&tokens, &[entry.batch, entry.seq])?);
        inputs.push(runtime::i32_literal(&labels, &[entry.batch])?);
        let out = exe.run(&inputs)?;
        loss_sum += out[0].to_vec::<f32>()?[0] as f64;
        acc_sum += out[1].to_vec::<f32>()?[0] as f64;
    }
    Ok((loss_sum / n_batches as f64, acc_sum / n_batches as f64))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::by_name;
    use crate::optim::sync::CompressEfPushPull;
    use crate::testutil::assert_allclose;
    use crate::util::rng::Xoshiro256;

    fn cfg_with(scheme: &str, param: f64, sync: SyncMode, nodes: usize) -> TrainConfig {
        let mut cfg = TrainConfig::default();
        cfg.cluster.nodes = nodes;
        cfg.cluster.servers = 2;
        cfg.compression.scheme = scheme.into();
        cfg.compression.param = param;
        cfg.compression.sync = sync;
        cfg.compression.size_threshold = 0; // compress everything
        cfg.system.size_threshold_on = false;
        cfg
    }

    /// The distributed fabric must be bit-identical to the in-memory
    /// reference (Alg. 4) for deterministic compressors.
    #[test]
    fn fabric_matches_reference_alg4_topk() {
        let dim = 300;
        let nodes = 3;
        let cfg = cfg_with("topk", 0.1, SyncMode::CompressedEf, nodes);
        let blocks = crate::optim::blocks::from_shapes(&[
            ("a".into(), 100),
            ("b".into(), 150),
            ("c".into(), 50),
        ]);
        let mut fabric = CommFabric::new(&cfg, blocks.clone(), dim).unwrap();

        // Reference: one EF push/pull per block per round.
        let comp = by_name("topk", 0.1).unwrap();
        let mut refs: Vec<CompressEfPushPull> = (0..blocks.len())
            .map(|_| CompressEfPushPull::new(comp.clone(), nodes, 1, true))
            .collect();

        let mut data_rng = Xoshiro256::seed_from_u64(5);
        for _round in 0..4 {
            let grads: Vec<Vec<f32>> = (0..nodes)
                .map(|_| {
                    let mut g = vec![0.0f32; dim];
                    data_rng.fill_normal(&mut g, 1.0);
                    g
                })
                .collect();
            let (got, stats) = fabric.exchange(&grads);
            assert!(stats.wire_bytes > 0);
            let mut want = vec![0.0f32; dim];
            for (k, b) in blocks.iter().enumerate() {
                let per_block: Vec<Vec<f32>> =
                    grads.iter().map(|g| g[b.range()].to_vec()).collect();
                let p = refs[k].round(k as u64, &per_block);
                want[b.range()].copy_from_slice(&p);
            }
            assert_allclose(&got, &want, 1e-6, 1e-5, "fabric vs reference Alg.4");
        }
        fabric.shutdown();
    }

    #[test]
    fn fabric_full_precision_is_exact_mean() {
        let dim = 128;
        let nodes = 4;
        let cfg = cfg_with("identity", 0.0, SyncMode::Full, nodes);
        let blocks = crate::optim::blocks::single(dim);
        let mut fabric = CommFabric::new(&cfg, blocks, dim).unwrap();
        let grads: Vec<Vec<f32>> =
            (0..nodes).map(|w| (0..dim).map(|i| (w * dim + i) as f32).collect()).collect();
        let (got, _) = fabric.exchange(&grads);
        for i in 0..dim {
            let want: f32 = (0..nodes).map(|w| (w * dim + i) as f32).sum::<f32>() / nodes as f32;
            assert!((got[i] - want).abs() < 1e-4);
        }
        let stats = fabric.shutdown();
        assert_eq!(stats.iter().map(|s| s.pushes).sum::<u64>(), nodes as u64);
    }

    #[test]
    fn fabric_compression_reduces_wire_bytes() {
        let dim = 100_000;
        let nodes = 2;
        let blocks = crate::optim::blocks::single(dim);
        let run = |scheme: &str, param: f64, sync: SyncMode| -> u64 {
            let cfg = cfg_with(scheme, param, sync, nodes);
            let mut fabric = CommFabric::new(&cfg, blocks.clone(), dim).unwrap();
            let grads: Vec<Vec<f32>> = (0..nodes)
                .map(|w| (0..dim).map(|i| ((w + i) as f32 * 0.001).sin()).collect())
                .collect();
            let (_, stats) = fabric.exchange(&grads);
            fabric.shutdown();
            stats.wire_bytes
        };
        let full = run("identity", 0.0, SyncMode::Full);
        let topk = run("topk", 0.001, SyncMode::CompressedEf);
        let onebit = run("onebit", 0.0, SyncMode::CompressedEf);
        assert!(topk < full / 100, "topk {topk} vs full {full}");
        assert!(onebit < full / 20, "onebit {onebit} vs full {full}");
    }

    /// Windowed pushes (`pipeline.ack_window`, acks drained during the
    /// push phase) must be bit-identical to the legacy phase barrier:
    /// per-block job seeds make the wire bytes independent of job
    /// scheduling for deterministic compressors, and the window only
    /// changes *when* acks are read, not what is sent.
    #[test]
    fn ack_window_matches_phase_barrier() {
        let dim = 1500;
        let nodes = 2;
        let blocks =
            crate::optim::blocks::from_shapes(&[("a".into(), 1000), ("b".into(), 500)]);
        for (scheme, param, sync) in
            [("identity", 0.0, SyncMode::Full), ("topk", 0.1, SyncMode::CompressedEf)]
        {
            let run = |ack_window: bool| -> Vec<Vec<f32>> {
                let mut cfg = cfg_with(scheme, param, sync, nodes);
                cfg.pipeline.enabled = true;
                cfg.pipeline.block_bytes = 256 * 4;
                // A window smaller than the block count forces real
                // sliding (acks must drain for the phase to finish).
                cfg.pipeline.inflight = 2;
                cfg.pipeline.ack_window = ack_window;
                let mut fabric = CommFabric::new(&cfg, blocks.clone(), dim).unwrap();
                let mut rng = Xoshiro256::seed_from_u64(11);
                let mut out = Vec::new();
                for _ in 0..3 {
                    let grads: Vec<Vec<f32>> = (0..nodes)
                        .map(|_| {
                            let mut g = vec![0.0f32; dim];
                            rng.fill_normal(&mut g, 1.0);
                            g
                        })
                        .collect();
                    let (agg, _) = fabric.exchange(&grads);
                    out.push(agg);
                }
                fabric.shutdown();
                out
            };
            let windowed = run(true);
            let barrier = run(false);
            assert_eq!(windowed, barrier, "{scheme}: windowed pushes diverged from barrier");
        }
    }

    /// Acceptance at the fabric level: staged server shards
    /// (`server.compress_threads > 0`, one pool shared across the
    /// in-process shards) produce bit-identical aggregates to the
    /// synchronous reference — the §4.2.1 server pipeline moves work in
    /// time, never changes the bytes. The new reduce is summed in
    /// worker-index order, so this holds regardless of message arrival
    /// order across the two runs.
    #[test]
    fn staged_server_fabric_is_bit_identical_to_sync() {
        let dim = 1200;
        let nodes = 3;
        let blocks =
            crate::optim::blocks::from_shapes(&[("a".into(), 800), ("b".into(), 400)]);
        let run = |threads: usize| -> Vec<Vec<f32>> {
            let mut cfg = cfg_with("topk", 0.1, SyncMode::CompressedEf, nodes);
            cfg.pipeline.block_bytes = 256 * 4; // real block partitioning
            cfg.server.compress_threads = threads;
            let mut fabric = CommFabric::new(&cfg, blocks.clone(), dim).unwrap();
            let mut rng = Xoshiro256::seed_from_u64(9);
            let mut out = Vec::new();
            for _ in 0..4 {
                let grads: Vec<Vec<f32>> = (0..nodes)
                    .map(|_| {
                        let mut g = vec![0.0f32; dim];
                        rng.fill_normal(&mut g, 1.0);
                        g
                    })
                    .collect();
                let (agg, _) = fabric.exchange(&grads);
                out.push(agg);
            }
            fabric.shutdown();
            out
        };
        assert_eq!(run(0), run(4), "staged shards diverged from the synchronous reference");
    }

    /// Tentpole acceptance at the fabric level: the two-level topology
    /// (`cluster.groups = 2`, 4 workers) must produce bit-identical
    /// aggregates to the flat 4-worker fabric on the integer-valued
    /// synthetic workload — for identity (lossless pass-through at the
    /// leader) AND top-k + EF (exact-sparse union re-encode) — while each
    /// server shard ingests G pushes per key per round instead of W.
    #[test]
    fn hierarchical_fabric_is_bit_identical_to_flat_and_cuts_fan_in() {
        let dim = 600;
        let nodes = 4;
        let groups = 2;
        let iters = 4usize;
        let blocks =
            crate::optim::blocks::from_shapes(&[("a".into(), 400), ("b".into(), 200)]);
        for (scheme, param, sync) in
            [("identity", 0.0, SyncMode::Full), ("topk", 0.1, SyncMode::CompressedEf)]
        {
            let run = |groups: usize| -> (Vec<Vec<f32>>, u64, usize) {
                let mut cfg = cfg_with(scheme, param, sync, nodes);
                cfg.cluster.groups = groups;
                cfg.pipeline.block_bytes = 256 * 4; // real block partitioning
                let mut fabric = CommFabric::new(&cfg, blocks.clone(), dim).unwrap();
                let n_keys = fabric.partition().subs().len();
                let mut out = Vec::new();
                for it in 0..iters as u64 {
                    // Integer-valued gradients: every partial sum is exact
                    // in f32, so group-order association cannot move bits.
                    let grads: Vec<Vec<f32>> = (0..nodes as u32)
                        .map(|w| crate::cluster::synthetic_grad(7, w, it, dim))
                        .collect();
                    let (agg, _) = fabric.exchange(&grads);
                    out.push(agg);
                }
                let stats = fabric.shutdown();
                (out, stats.iter().map(|s| s.pushes).sum::<u64>(), n_keys)
            };
            let (flat, flat_pushes, n_keys) = run(0);
            let (hier, hier_pushes, _) = run(groups);
            assert_eq!(flat, hier, "{scheme}: hierarchical aggregates diverged from flat");
            // Fan-in scaling: per round each shard tier decodes G combined
            // pushes instead of W member pushes.
            assert_eq!(flat_pushes, (nodes * n_keys * iters) as u64);
            assert_eq!(
                hier_pushes,
                (groups * n_keys * iters) as u64,
                "{scheme}: server fan-in must scale with groups, not workers"
            );
        }
    }

    /// Staged server shards under the two-level topology: the shard-side
    /// decode/encode pool must not change the bytes when its peers are
    /// relays either.
    #[test]
    fn hierarchical_fabric_with_staged_servers_matches_sync() {
        let dim = 500;
        let nodes = 4;
        let blocks = crate::optim::blocks::single(dim);
        let run = |threads: usize| -> Vec<Vec<f32>> {
            let mut cfg = cfg_with("topk", 0.1, SyncMode::CompressedEf, nodes);
            cfg.cluster.groups = 2;
            cfg.pipeline.block_bytes = 128 * 4;
            cfg.server.compress_threads = threads;
            let mut fabric = CommFabric::new(&cfg, blocks.clone(), dim).unwrap();
            let mut out = Vec::new();
            for it in 0..3u64 {
                let grads: Vec<Vec<f32>> = (0..nodes as u32)
                    .map(|w| crate::cluster::synthetic_grad(11, w, it, dim))
                    .collect();
                let (agg, _) = fabric.exchange(&grads);
                out.push(agg);
            }
            fabric.shutdown();
            out
        };
        assert_eq!(run(0), run(4), "staged hierarchical shards diverged from synchronous");
    }

    #[test]
    fn size_threshold_bypasses_small_blocks() {
        let dim = 1000;
        let nodes = 2;
        let mut cfg = cfg_with("topk", 0.01, SyncMode::CompressedEf, nodes);
        cfg.system.size_threshold_on = true;
        cfg.compression.size_threshold = 10_000; // 4*1000 < 10k -> bypass
        let blocks = crate::optim::blocks::single(dim);
        let mut fabric = CommFabric::new(&cfg, blocks, dim).unwrap();
        let grads: Vec<Vec<f32>> = (0..nodes).map(|_| vec![1.0f32; dim]).collect();
        let (got, _) = fabric.exchange(&grads);
        // bypassed => exact mean, not top-k sparsified
        assert!(got.iter().all(|&v| (v - 1.0).abs() < 1e-6));
        fabric.shutdown();
    }
}
