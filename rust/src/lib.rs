//! # BytePS-Compress
//!
//! A reproduction of *"Compressed Communication for Distributed Training:
//! Adaptive Methods and System"* (CS.DC 2021) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: parameter servers, workers,
//!   CPU-side gradient compressors, the CLAN/LANS optimizer family, and the
//!   training engine. Python is never on the step path.
//! * **L2** — the JAX model (`python/compile/model.py`), AOT-lowered to HLO
//!   text and executed here through the PJRT CPU client ([`runtime`]).
//! * **L1** — Pallas kernels (`python/compile/kernels/`) that lower into the
//!   same HLO artifacts (fused LANS update, fused attention, dithering
//!   quantizer).
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index, and `EXPERIMENTS.md` for paper-vs-measured results.
//!
//! ## Quick tour
//!
//! ```no_run
//! use byteps_compress::compress::{self, Compressor, Ctx};
//! use byteps_compress::util::rng::Xoshiro256;
//!
//! let topk = compress::by_name("topk", 0.001).unwrap();
//! let mut rng = Xoshiro256::seed_from_u64(7);
//! let grad = vec![0.5f32; 1 << 20];
//! let wire = topk.compress(&grad, &mut Ctx::new(&mut rng));
//! let mut out = vec![0.0; grad.len()];
//! topk.decompress(&wire, &mut out);
//! assert!(wire.nbytes() < 4 * grad.len() / 100); // >100x smaller
//! ```

pub mod cli;
pub mod cluster;
pub mod comm;
pub mod compress;
pub mod configx;
pub mod data;
pub mod engine;
pub mod lint;
pub mod metrics;
pub mod optim;
pub mod parallel;
pub mod ps;
pub mod runtime;
pub mod simnet;
pub mod testutil;
pub mod util;
pub mod worker;
