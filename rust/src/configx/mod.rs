//! Typed run configuration, loaded from JSON files or CLI overrides.
//!
//! The config system mirrors what a user of BytePS would set through
//! environment variables and launcher flags: cluster shape, compression
//! scheme + parameters, optimizer hyper-parameters, model/artifact choice,
//! and the system-optimization toggles ablated in Table 6.
//!
//! ## Knob inventory
//!
//! Every accepted knob, by section (the machine-checked copy of this table
//! lives in DESIGN.md §Config knobs — the `docs-freshness` lint fails the
//! build if that table and this module's structs drift apart):
//!
//! | knob | meaning |
//! |---|---|
//! | `model` | artifact name (see artifacts/manifest.json) |
//! | `steps` | training steps |
//! | `batch_per_worker` | per-worker batch size |
//! | `seed` | run seed (job seeds derive from it) |
//! | `log_every` | log cadence in steps |
//! | `task_difficulty` | synthetic classification task difficulty |
//! | `optimizer.name` | "lans" \| "clan" \| "nag" \| "adam" \| "sgd" |
//! | `optimizer.lr` | learning rate |
//! | `optimizer.beta1`, `optimizer.beta2`, `optimizer.eps` | moment hyper-params |
//! | `optimizer.weight_decay` | weight decay λ |
//! | `optimizer.momentum` | NAG/SGD momentum |
//! | `optimizer.phi_lo`, `optimizer.phi_hi` | φ clamp bounds (Assumption 4) |
//! | `optimizer.warmup_steps` | linear LR warmup steps |
//! | `compression.scheme` | one of the seven paper compressors |
//! | `compression.param` | keep ratio (sparsifiers) or bit width (dither) |
//! | `compression.size_threshold` | bytes below which compression is bypassed (§4.2.3) |
//! | `compression.fused_residual` | fused EF residual update (§4.2.2) |
//! | `compression.sync` | "full" \| "compressed" \| "compressed_ef" |
//! | `adaptive.enabled` | per-key online controller on/off (default off = static ratios) |
//! | `adaptive.k_min`, `adaptive.k_max` | requested keep-ratio bounds, negotiated at `Hello`/`Welcome` |
//! | `adaptive.ema` | gain-EMA smoothing factor in (0, 1] |
//! | `adaptive.target_gain` | target compression gain in (0, 1) |
//! | `cluster.nodes`, `cluster.gpus_per_node`, `cluster.servers` | topology |
//! | `cluster.net_gbps`, `cluster.latency_us` | simulated wire |
//! | `cluster.addresses` | TCP shard listen addresses (empty = inproc fabric) |
//! | `cluster.groups` | hierarchical two-level aggregation: worker groups (0 = flat) |
//! | `cluster.group_addresses` | cluster-mode group-leader listen addresses, one per group |
//! | `system.compress_threads` | worker compression pool threads |
//! | `system.intra_threads` | intra-task chunked parallelism |
//! | `system.operator_fusion` | §4.2.2 toggle |
//! | `system.size_threshold_on` | §4.2.3 toggle |
//! | `system.workload_balance` | §4.2.4 toggle |
//! | `system.more_servers` | §4.2.5 toggle |
//! | `system.numa_tuning` | §4.2.6 toggle |
//! | `pipeline.enabled` | block-partitioned push/pull pipeline (§4.2.1) |
//! | `pipeline.block_bytes` | partition block size in bytes |
//! | `pipeline.inflight` | max in-flight compress/push jobs |
//! | `pipeline.ack_window` | sliding ack window vs phase barrier |
//! | `server.iter_deadline_ms` | degraded-round deadline (0 = strict BSP) |
//! | `server.compress_threads` | staged shard pool (0 = synchronous reference path) |
//! | `server.iter_deadline_auto_margin` | p99-derived auto deadline (0 = off) |

pub mod json;

use self::json::{Json, JsonError};
use std::fmt;
use std::path::Path;

/// Which gradient synchronization path to use (paper Alg. 1/3/4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyncMode {
    /// Alg. 1: full-precision push/pull.
    Full,
    /// Alg. 3: two-way compression, no error feedback (unbiased compressors).
    Compressed,
    /// Alg. 4: two-way compression with worker + server error feedback.
    CompressedEf,
}

impl SyncMode {
    pub fn parse(s: &str) -> Result<Self, ConfigError> {
        match s {
            "full" => Ok(SyncMode::Full),
            "compressed" => Ok(SyncMode::Compressed),
            "compressed_ef" | "ef" => Ok(SyncMode::CompressedEf),
            _ => Err(ConfigError(format!("unknown sync mode '{s}'"))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SyncMode::Full => "full",
            SyncMode::Compressed => "compressed",
            SyncMode::CompressedEf => "compressed_ef",
        }
    }
}

/// Compression scheme selection + parameters (paper §5.1 method list).
#[derive(Clone, Debug, PartialEq)]
pub struct CompressionConfig {
    /// "identity" | "fp16" | "onebit" | "topk" | "randomk" |
    /// "linear_dither" | "natural_dither"
    pub scheme: String,
    /// top-k/random-k ratio (fraction of elements kept) or dithering bit
    /// count, depending on scheme. top-k: 0.001 = paper's k=0.1%;
    /// random-k: 1/32; linear dithering: 5 or 7 (bits); natural: 3 (bits).
    pub param: f64,
    /// Tensors smaller than this many BYTES bypass compression (§4.2.3).
    pub size_threshold: usize,
    /// Use the fused EF residual update (§4.2.2). Ablation toggle.
    pub fused_residual: bool,
    /// Sync algorithm to drive with this compressor.
    pub sync: SyncMode,
}

impl Default for CompressionConfig {
    fn default() -> Self {
        CompressionConfig {
            scheme: "topk".into(),
            param: 0.001,
            size_threshold: 1 << 20, // 1 MiB, the paper's default
            fused_residual: true,
            sync: SyncMode::CompressedEf,
        }
    }
}

/// Per-key adaptive compression controller (`compress::controller`): the
/// worker measures each block's compression gain from the EF residual and
/// steers the sparsifier keep ratio toward `target_gain` inside
/// `[k_min, k_max]`. The bounds here are what the worker *requests* at
/// registration; the server clamps them into its own envelope and the
/// `Welcome` reply carries the granted pair. Off by default — the static
/// path is bit-identical to a build without the controller.
#[derive(Clone, Debug, PartialEq)]
pub struct AdaptiveConfig {
    /// Enable the controller. Requires a sparsifier scheme ("topk" /
    /// "randomk" / "randomk_unbiased") and `sync = compressed_ef` (the
    /// gain signal lives in the EF residual); other combinations simply
    /// run static.
    pub enabled: bool,
    /// Lower keep-ratio bound the worker requests, in (0, 1].
    pub k_min: f64,
    /// Upper keep-ratio bound the worker requests, in (0, 1].
    pub k_max: f64,
    /// EMA smoothing factor for the per-key gain signal, in (0, 1]
    /// (1 = no smoothing).
    pub ema: f64,
    /// Target compression gain in (0, 1): the controller raises k while
    /// the smoothed gain sits below `target_gain - DEAD_BAND` and lowers
    /// it above `target_gain + DEAD_BAND`.
    pub target_gain: f64,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig { enabled: false, k_min: 0.0005, k_max: 0.05, ema: 0.3, target_gain: 0.7 }
    }
}

/// Optimizer selection + hyper-parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct OptimizerConfig {
    /// "lans" | "clan" | "nag" | "adam" | "sgd"
    pub name: String,
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    /// Weight decay λ (LANS step 13).
    pub weight_decay: f64,
    /// Momentum for NAG/SGD.
    pub momentum: f64,
    /// φ clamp bounds (Assumption 4): φ(z) = clamp(z, phi_lo, phi_hi).
    pub phi_lo: f64,
    pub phi_hi: f64,
    /// Linear warmup steps then constant (paper uses warmup for e2e runs).
    pub warmup_steps: usize,
}

impl Default for OptimizerConfig {
    fn default() -> Self {
        OptimizerConfig {
            name: "clan".into(),
            lr: 1e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-6,
            weight_decay: 0.01,
            momentum: 0.9,
            phi_lo: 0.01,
            phi_hi: 10.0,
            warmup_steps: 0,
        }
    }
}

/// Cluster topology (real in-process nodes + simulated wire).
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterConfig {
    /// Number of worker nodes (paper: 1–8 P3.16xlarge).
    pub nodes: usize,
    /// Simulated GPU ranks per node (paper: 8× V100).
    pub gpus_per_node: usize,
    /// Parameter-server instances. Paper §4.2.5 co-locates 2 per node.
    pub servers: usize,
    /// Inter-node bandwidth in Gbit/s for the simulated wire (paper: 25).
    pub net_gbps: f64,
    /// Per-message one-way latency in microseconds.
    pub latency_us: f64,
    /// Cluster-mode server listen addresses, indexed by shard
    /// (`bytepsc server --shard I` binds `addresses[I]`; workers dial the
    /// whole list). Non-empty ⇒ the shard count is `addresses.len()`,
    /// overriding `servers`/`more_servers`. Empty (the default) keeps the
    /// single-process in-proc fabric.
    pub addresses: Vec<String>,
    /// Hierarchical two-level aggregation: number of worker groups. `0`
    /// (the default) keeps the flat topology — every worker pushes to
    /// every shard directly and every existing run is bit-identical.
    /// `> 0` partitions the `nodes` workers into `groups` equal groups;
    /// each group's leader locally combines its members' compressed
    /// pushes and forwards one weighted `GroupPush` per key, cutting
    /// server fan-in from O(nodes) to O(groups). Requires
    /// `nodes % groups == 0` and is mutually exclusive with
    /// `adaptive.enabled` (per-key ratio drift would break the leader's
    /// exact-sparse recombination).
    pub groups: usize,
    /// Cluster-mode group-leader listen addresses, indexed by group
    /// (`bytepsc leader --group I` binds `group_addresses[I]`; the
    /// group's members dial it instead of the server shards). Must be
    /// empty (single-process fabric) or have exactly `groups` entries.
    pub group_addresses: Vec<String>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            nodes: 4,
            gpus_per_node: 8,
            servers: 8,
            net_gbps: 25.0,
            latency_us: 25.0,
            addresses: Vec::new(),
            groups: 0,
            group_addresses: Vec::new(),
        }
    }
}

/// System-optimization toggles — the Table 6 ablation axes.
#[derive(Clone, Debug, PartialEq)]
pub struct SystemConfig {
    /// CPU threads for the compression pool (inter-task parallelism).
    pub compress_threads: usize,
    /// Intra-task chunked parallelism within one compression job.
    pub intra_threads: usize,
    /// §4.2.2 fused residual (mirrors CompressionConfig.fused_residual).
    pub operator_fusion: bool,
    /// §4.2.3 size threshold active.
    pub size_threshold_on: bool,
    /// §4.2.4 workload-balanced shard assignment (compressed tensors get
    /// more server shards).
    pub workload_balance: bool,
    /// §4.2.5 extra co-located servers (2 per node instead of 1).
    pub more_servers: bool,
    /// §4.2.6 NUMA/affinity tuning.
    pub numa_tuning: bool,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            compress_threads: 4,
            intra_threads: 2,
            operator_fusion: true,
            size_threshold_on: true,
            workload_balance: true,
            more_servers: true,
            numa_tuning: true,
        }
    }
}

/// Parameter-server behavior knobs (per-process; never part of the wire
/// fingerprint — workers don't need to agree on them).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServerConfig {
    /// Iteration deadline in milliseconds for degraded rounds: once a
    /// shard has at least one push for an iteration and this long
    /// elapses without the round completing, it serves the partial
    /// aggregate with `served_with < n_workers` on the wire instead of
    /// stalling every worker's pull on a lost/rejected push. `0` (the
    /// default) keeps strict BSP — bit-identical to the pre-deadline
    /// server.
    pub iter_deadline_ms: u64,
    /// CPU threads for the server shard's staged decode/encode pool
    /// (`bytepsc server --compress-threads`). `0` (the default) keeps the
    /// synchronous reference path: every stage runs inline on the shard's
    /// I/O thread. Any value `> 0` turns the shard into the staged
    /// ingress → decode → reduce → seal → encode pipeline, bit-identical
    /// to `0` for every compressor in `compress::paper_suite()`.
    pub compress_threads: usize,
    /// Deadline auto-tuning (`--deadline-auto-margin`): with
    /// `iter_deadline_ms = 0` and this margin `> 0`, each shard derives
    /// its own deadline as observed p99 full-round latency × margin,
    /// re-evaluated at every sealed full round. `0` (the default) = off.
    /// Setting both this and `iter_deadline_ms` is a config error — the
    /// static knob would silently win.
    pub iter_deadline_auto_margin: f64,
}

impl ServerConfig {
    /// The deadline as an `Option<Duration>` (`0` = unset/strict BSP).
    pub fn iter_deadline(&self) -> Option<std::time::Duration> {
        (self.iter_deadline_ms > 0)
            .then(|| std::time::Duration::from_millis(self.iter_deadline_ms))
    }
}

/// Block-partitioned push/pull pipeline knobs (§4.2.1/§4.2.3): tensors
/// above `block_bytes` are split into fixed-size blocks, each with its own
/// wire key, so CPU compression of block i+1 overlaps the in-flight send
/// of block i.
#[derive(Clone, Debug, PartialEq)]
pub struct PipelineConfig {
    /// Drive per-block compress->push / pull->decompress through the
    /// worker's thread pool. Off = the serial reference path (the
    /// "compression w/o pipelining" ablation arm).
    pub enabled: bool,
    /// Partition block size in BYTES of f32 data (paper default 4 MiB).
    /// Tensors at or below this size stay whole.
    pub block_bytes: usize,
    /// Max compress/push jobs in flight per worker (bounds the memory held
    /// by per-block gradient staging copies; with `ack_window` on, also
    /// bounds sent-but-unacked pushes).
    pub inflight: usize,
    /// Drain server acks concurrently with the push phase, making
    /// `inflight` a true sliding window over unacked pushes instead of a
    /// phase barrier that parks every ack in the socket buffer until the
    /// pull phase. Wire traffic is identical either way (per-block job
    /// seeds); off = the legacy barrier for ablation.
    pub ack_window: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig { enabled: true, block_bytes: 4 << 20, inflight: 16, ack_window: true }
    }
}

/// Training-run config: model/artifact + schedule.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    /// Artifact name, e.g. "transformer_mini" (see artifacts/manifest.json).
    pub model: String,
    pub steps: usize,
    pub batch_per_worker: usize,
    pub seed: u64,
    /// Log every N steps.
    pub log_every: usize,
    /// Difficulty of the synthetic classification task (classifier models
    /// only; see `data::ClassifyTask`).
    pub task_difficulty: f64,
    pub optimizer: OptimizerConfig,
    pub compression: CompressionConfig,
    pub adaptive: AdaptiveConfig,
    pub cluster: ClusterConfig,
    pub system: SystemConfig,
    pub pipeline: PipelineConfig,
    pub server: ServerConfig,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "transformer_tiny".into(),
            steps: 100,
            batch_per_worker: 8,
            seed: 42,
            log_every: 10,
            task_difficulty: 0.55,
            optimizer: OptimizerConfig::default(),
            compression: CompressionConfig::default(),
            adaptive: AdaptiveConfig::default(),
            cluster: ClusterConfig::default(),
            system: SystemConfig::default(),
            pipeline: PipelineConfig::default(),
            server: ServerConfig::default(),
        }
    }
}

/// Config load/parse error.
#[derive(Debug, Clone)]
pub struct ConfigError(pub String);

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

impl From<JsonError> for ConfigError {
    fn from(e: JsonError) -> Self {
        ConfigError(e.to_string())
    }
}

fn f(v: &Json, key: &str, default: f64) -> f64 {
    v.get(key).and_then(Json::as_f64).unwrap_or(default)
}

fn u(v: &Json, key: &str, default: usize) -> usize {
    v.get(key).and_then(Json::as_usize).unwrap_or(default)
}

fn b(v: &Json, key: &str, default: bool) -> bool {
    v.get(key).and_then(Json::as_bool).unwrap_or(default)
}

fn s(v: &Json, key: &str, default: &str) -> String {
    v.get(key).and_then(Json::as_str).unwrap_or(default).to_string()
}

impl TrainConfig {
    /// Parse from a JSON document. Missing fields fall back to defaults, so
    /// configs stay terse; unknown fields are rejected to catch typos.
    pub fn from_json(v: &Json) -> Result<Self, ConfigError> {
        let d = TrainConfig::default();
        let obj = v.as_obj().ok_or_else(|| ConfigError("top level must be an object".into()))?;
        const KNOWN: [&str; 14] = [
            "model", "steps", "batch_per_worker", "seed", "log_every", "task_difficulty",
            "optimizer", "compression", "adaptive", "cluster", "system", "pipeline", "server",
            "comment",
        ];
        for k in obj.keys() {
            if !KNOWN.contains(&k.as_str()) {
                return Err(ConfigError(format!("unknown config field '{k}'")));
            }
        }
        let od = OptimizerConfig::default();
        let o = v.get("optimizer").cloned().unwrap_or(Json::Obj(Default::default()));
        let optimizer = OptimizerConfig {
            name: s(&o, "name", &od.name),
            lr: f(&o, "lr", od.lr),
            beta1: f(&o, "beta1", od.beta1),
            beta2: f(&o, "beta2", od.beta2),
            eps: f(&o, "eps", od.eps),
            weight_decay: f(&o, "weight_decay", od.weight_decay),
            momentum: f(&o, "momentum", od.momentum),
            phi_lo: f(&o, "phi_lo", od.phi_lo),
            phi_hi: f(&o, "phi_hi", od.phi_hi),
            warmup_steps: u(&o, "warmup_steps", od.warmup_steps),
        };
        let cd = CompressionConfig::default();
        let c = v.get("compression").cloned().unwrap_or(Json::Obj(Default::default()));
        let compression = CompressionConfig {
            scheme: s(&c, "scheme", &cd.scheme),
            param: f(&c, "param", cd.param),
            size_threshold: u(&c, "size_threshold", cd.size_threshold),
            fused_residual: b(&c, "fused_residual", cd.fused_residual),
            sync: SyncMode::parse(&s(&c, "sync", cd.sync.name()))?,
        };
        let ad = AdaptiveConfig::default();
        let a = v.get("adaptive").cloned().unwrap_or(Json::Obj(Default::default()));
        let adaptive = AdaptiveConfig {
            enabled: b(&a, "enabled", ad.enabled),
            k_min: f(&a, "k_min", ad.k_min),
            k_max: f(&a, "k_max", ad.k_max),
            ema: f(&a, "ema", ad.ema),
            target_gain: f(&a, "target_gain", ad.target_gain),
        };
        let kd = ClusterConfig::default();
        let k = v.get("cluster").cloned().unwrap_or(Json::Obj(Default::default()));
        let addresses = match k.get("addresses") {
            None => kd.addresses.clone(),
            Some(a) => a
                .as_arr()
                .ok_or_else(|| ConfigError("cluster.addresses must be an array".into()))?
                .iter()
                .map(|e| {
                    e.as_str().map(str::to_string).ok_or_else(|| {
                        ConfigError("cluster.addresses entries must be strings".into())
                    })
                })
                .collect::<Result<Vec<String>, ConfigError>>()?,
        };
        let group_addresses = match k.get("group_addresses") {
            None => kd.group_addresses.clone(),
            Some(a) => a
                .as_arr()
                .ok_or_else(|| ConfigError("cluster.group_addresses must be an array".into()))?
                .iter()
                .map(|e| {
                    e.as_str().map(str::to_string).ok_or_else(|| {
                        ConfigError("cluster.group_addresses entries must be strings".into())
                    })
                })
                .collect::<Result<Vec<String>, ConfigError>>()?,
        };
        let cluster = ClusterConfig {
            nodes: u(&k, "nodes", kd.nodes),
            gpus_per_node: u(&k, "gpus_per_node", kd.gpus_per_node),
            servers: u(&k, "servers", kd.servers),
            net_gbps: f(&k, "net_gbps", kd.net_gbps),
            latency_us: f(&k, "latency_us", kd.latency_us),
            addresses,
            groups: u(&k, "groups", kd.groups),
            group_addresses,
        };
        let sd = SystemConfig::default();
        let y = v.get("system").cloned().unwrap_or(Json::Obj(Default::default()));
        let system = SystemConfig {
            compress_threads: u(&y, "compress_threads", sd.compress_threads),
            intra_threads: u(&y, "intra_threads", sd.intra_threads),
            operator_fusion: b(&y, "operator_fusion", sd.operator_fusion),
            size_threshold_on: b(&y, "size_threshold_on", sd.size_threshold_on),
            workload_balance: b(&y, "workload_balance", sd.workload_balance),
            more_servers: b(&y, "more_servers", sd.more_servers),
            numa_tuning: b(&y, "numa_tuning", sd.numa_tuning),
        };
        let pd = PipelineConfig::default();
        let p = v.get("pipeline").cloned().unwrap_or(Json::Obj(Default::default()));
        let pipeline = PipelineConfig {
            enabled: b(&p, "enabled", pd.enabled),
            block_bytes: u(&p, "block_bytes", pd.block_bytes),
            inflight: u(&p, "inflight", pd.inflight),
            ack_window: b(&p, "ack_window", pd.ack_window),
        };
        let vd = ServerConfig::default();
        let sv = v.get("server").cloned().unwrap_or(Json::Obj(Default::default()));
        let server = ServerConfig {
            iter_deadline_ms: u(&sv, "iter_deadline_ms", vd.iter_deadline_ms as usize) as u64,
            compress_threads: u(&sv, "compress_threads", vd.compress_threads),
            iter_deadline_auto_margin: f(
                &sv,
                "iter_deadline_auto_margin",
                vd.iter_deadline_auto_margin,
            ),
        };
        let cfg = TrainConfig {
            model: s(v, "model", &d.model),
            steps: u(v, "steps", d.steps),
            batch_per_worker: u(v, "batch_per_worker", d.batch_per_worker),
            seed: u(v, "seed", d.seed as usize) as u64,
            log_every: u(v, "log_every", d.log_every),
            task_difficulty: f(v, "task_difficulty", d.task_difficulty),
            optimizer,
            compression,
            adaptive,
            cluster,
            system,
            pipeline,
            server,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_str(src: &str) -> Result<Self, ConfigError> {
        Self::from_json(&Json::parse(src)?)
    }

    pub fn from_file(path: &Path) -> Result<Self, ConfigError> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| ConfigError(format!("read {}: {e}", path.display())))?;
        Self::from_str(&src)
    }

    /// Sanity checks that would otherwise surface as confusing panics deep
    /// in the engine.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.cluster.nodes == 0 {
            return Err(ConfigError("cluster.nodes must be >= 1".into()));
        }
        if self.cluster.servers == 0 {
            return Err(ConfigError("cluster.servers must be >= 1".into()));
        }
        if self.cluster.addresses.iter().any(|a| a.is_empty()) {
            return Err(ConfigError("cluster.addresses entries must be non-empty".into()));
        }
        if self.cluster.groups > 0 {
            if self.cluster.nodes % self.cluster.groups != 0 {
                return Err(ConfigError(format!(
                    "cluster.groups ({}) must evenly divide cluster.nodes ({})",
                    self.cluster.groups, self.cluster.nodes
                )));
            }
            // The server weighs each group push by a u16 member count; a
            // group larger than that cannot be represented on the wire.
            if self.cluster.nodes / self.cluster.groups > usize::from(u16::MAX) {
                return Err(ConfigError("group size exceeds the u16 members weight".into()));
            }
            if self.adaptive.enabled {
                return Err(ConfigError(
                    "cluster.groups > 0 is incompatible with adaptive.enabled — per-key \
                     keep-ratio drift would break the leader's exact recombination"
                        .into(),
                ));
            }
        }
        if !self.cluster.group_addresses.is_empty() {
            if self.cluster.groups == 0 {
                return Err(ConfigError(
                    "cluster.group_addresses requires cluster.groups > 0".into(),
                ));
            }
            if self.cluster.group_addresses.len() != self.cluster.groups {
                return Err(ConfigError(format!(
                    "cluster.group_addresses has {} entries but cluster.groups is {}",
                    self.cluster.group_addresses.len(),
                    self.cluster.groups
                )));
            }
            if self.cluster.group_addresses.iter().any(|a| a.is_empty()) {
                return Err(ConfigError(
                    "cluster.group_addresses entries must be non-empty".into(),
                ));
            }
        }
        if self.optimizer.lr <= 0.0 {
            return Err(ConfigError("optimizer.lr must be > 0".into()));
        }
        if !(0.0..1.0).contains(&self.optimizer.beta1)
            || !(0.0..1.0).contains(&self.optimizer.beta2)
        {
            return Err(ConfigError("beta1/beta2 must be in [0, 1)".into()));
        }
        match self.compression.scheme.as_str() {
            "topk" | "randomk" => {
                if !(0.0 < self.compression.param && self.compression.param <= 1.0) {
                    return Err(ConfigError("top-k/random-k param must be in (0, 1]".into()));
                }
            }
            "linear_dither" | "natural_dither" => {
                if !(1.0..=16.0).contains(&self.compression.param) {
                    return Err(ConfigError("dithering bits must be in [1, 16]".into()));
                }
            }
            "identity" | "fp16" | "onebit" => {}
            other => return Err(ConfigError(format!("unknown compression scheme '{other}'"))),
        }
        // Adaptive-controller bounds must be a well-formed sub-range of
        // (0, 1] even when the controller is off — they are what `Hello`
        // would request, and a degenerate request must fail here, not at
        // registration. (NaN fails every comparison and lands here too.)
        if !(self.adaptive.k_min > 0.0
            && self.adaptive.k_min <= self.adaptive.k_max
            && self.adaptive.k_max <= 1.0)
        {
            return Err(ConfigError(
                "adaptive.k_min/k_max must satisfy 0 < k_min <= k_max <= 1".into(),
            ));
        }
        if !(self.adaptive.ema > 0.0 && self.adaptive.ema <= 1.0) {
            return Err(ConfigError("adaptive.ema must be in (0, 1]".into()));
        }
        if !(self.adaptive.target_gain > 0.0 && self.adaptive.target_gain < 1.0) {
            return Err(ConfigError("adaptive.target_gain must be in (0, 1)".into()));
        }
        if self.pipeline.block_bytes < 64 {
            return Err(ConfigError("pipeline.block_bytes must be >= 64".into()));
        }
        if self.pipeline.inflight == 0 {
            return Err(ConfigError("pipeline.inflight must be >= 1".into()));
        }
        if self.server.iter_deadline_auto_margin < 0.0
            || !self.server.iter_deadline_auto_margin.is_finite()
        {
            return Err(ConfigError(
                "server.iter_deadline_auto_margin must be a finite value >= 0".into(),
            ));
        }
        if self.server.iter_deadline_auto_margin > 0.0 && self.server.iter_deadline_ms > 0 {
            return Err(ConfigError(
                "server.iter_deadline_auto_margin requires iter_deadline_ms = 0 \
                 (the static deadline would silently win)"
                    .into(),
            ));
        }
        if self.compression.sync == SyncMode::Compressed
            && matches!(self.compression.scheme.as_str(), "topk" | "onebit")
        {
            // Biased compressors without EF diverge (paper §3.1) — allow it
            // only behind the explicit scheme name for ablation studies.
            // We warn rather than reject.
        }
        Ok(())
    }

    /// Serialize back to JSON (for run provenance in metrics dumps).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(self.model.clone())),
            ("steps", Json::num(self.steps as f64)),
            ("batch_per_worker", Json::num(self.batch_per_worker as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("log_every", Json::num(self.log_every as f64)),
            ("task_difficulty", Json::num(self.task_difficulty)),
            (
                "optimizer",
                Json::obj(vec![
                    ("name", Json::str(self.optimizer.name.clone())),
                    ("lr", Json::num(self.optimizer.lr)),
                    ("beta1", Json::num(self.optimizer.beta1)),
                    ("beta2", Json::num(self.optimizer.beta2)),
                    ("eps", Json::num(self.optimizer.eps)),
                    ("weight_decay", Json::num(self.optimizer.weight_decay)),
                    ("momentum", Json::num(self.optimizer.momentum)),
                    ("phi_lo", Json::num(self.optimizer.phi_lo)),
                    ("phi_hi", Json::num(self.optimizer.phi_hi)),
                    ("warmup_steps", Json::num(self.optimizer.warmup_steps as f64)),
                ]),
            ),
            (
                "compression",
                Json::obj(vec![
                    ("scheme", Json::str(self.compression.scheme.clone())),
                    ("param", Json::num(self.compression.param)),
                    ("size_threshold", Json::num(self.compression.size_threshold as f64)),
                    ("fused_residual", Json::Bool(self.compression.fused_residual)),
                    ("sync", Json::str(self.compression.sync.name())),
                ]),
            ),
            (
                "adaptive",
                Json::obj(vec![
                    ("enabled", Json::Bool(self.adaptive.enabled)),
                    ("k_min", Json::num(self.adaptive.k_min)),
                    ("k_max", Json::num(self.adaptive.k_max)),
                    ("ema", Json::num(self.adaptive.ema)),
                    ("target_gain", Json::num(self.adaptive.target_gain)),
                ]),
            ),
            (
                "cluster",
                Json::obj(vec![
                    ("nodes", Json::num(self.cluster.nodes as f64)),
                    ("gpus_per_node", Json::num(self.cluster.gpus_per_node as f64)),
                    ("servers", Json::num(self.cluster.servers as f64)),
                    ("net_gbps", Json::num(self.cluster.net_gbps)),
                    ("latency_us", Json::num(self.cluster.latency_us)),
                    (
                        "addresses",
                        Json::Arr(
                            self.cluster
                                .addresses
                                .iter()
                                .map(|a| Json::str(a.clone()))
                                .collect(),
                        ),
                    ),
                    ("groups", Json::num(self.cluster.groups as f64)),
                    (
                        "group_addresses",
                        Json::Arr(
                            self.cluster
                                .group_addresses
                                .iter()
                                .map(|a| Json::str(a.clone()))
                                .collect(),
                        ),
                    ),
                ]),
            ),
            (
                "system",
                Json::obj(vec![
                    ("compress_threads", Json::num(self.system.compress_threads as f64)),
                    ("intra_threads", Json::num(self.system.intra_threads as f64)),
                    ("operator_fusion", Json::Bool(self.system.operator_fusion)),
                    ("size_threshold_on", Json::Bool(self.system.size_threshold_on)),
                    ("workload_balance", Json::Bool(self.system.workload_balance)),
                    ("more_servers", Json::Bool(self.system.more_servers)),
                    ("numa_tuning", Json::Bool(self.system.numa_tuning)),
                ]),
            ),
            (
                "pipeline",
                Json::obj(vec![
                    ("enabled", Json::Bool(self.pipeline.enabled)),
                    ("block_bytes", Json::num(self.pipeline.block_bytes as f64)),
                    ("inflight", Json::num(self.pipeline.inflight as f64)),
                    ("ack_window", Json::Bool(self.pipeline.ack_window)),
                ]),
            ),
            (
                "server",
                Json::obj(vec![
                    ("iter_deadline_ms", Json::num(self.server.iter_deadline_ms as f64)),
                    ("compress_threads", Json::num(self.server.compress_threads as f64)),
                    (
                        "iter_deadline_auto_margin",
                        Json::num(self.server.iter_deadline_auto_margin),
                    ),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn parse_partial_config_uses_defaults() {
        let cfg = TrainConfig::from_str(
            r#"{"model": "transformer_mini", "compression": {"scheme": "onebit"}}"#,
        )
        .unwrap();
        assert_eq!(cfg.model, "transformer_mini");
        assert_eq!(cfg.compression.scheme, "onebit");
        assert_eq!(cfg.steps, TrainConfig::default().steps);
        assert_eq!(cfg.cluster.net_gbps, 25.0);
    }

    #[test]
    fn unknown_top_level_field_rejected() {
        let err = TrainConfig::from_str(r#"{"modle": "typo"}"#).unwrap_err();
        assert!(err.0.contains("modle"));
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(TrainConfig::from_str(r#"{"optimizer": {"lr": -1}}"#).is_err());
        assert!(TrainConfig::from_str(r#"{"compression": {"scheme": "topk", "param": 0}}"#)
            .is_err());
        assert!(TrainConfig::from_str(r#"{"compression": {"scheme": "nope"}}"#).is_err());
        assert!(TrainConfig::from_str(r#"{"cluster": {"nodes": 0}}"#).is_err());
        assert!(TrainConfig::from_str(
            r#"{"compression": {"scheme": "linear_dither", "param": 40}}"#
        )
        .is_err());
    }

    #[test]
    fn json_roundtrip_preserves_config() {
        let mut cfg = TrainConfig::default();
        cfg.model = "transformer_base100m".into();
        cfg.compression.scheme = "linear_dither".into();
        cfg.compression.param = 7.0;
        cfg.compression.sync = SyncMode::Compressed;
        cfg.system.numa_tuning = false;
        cfg.pipeline.enabled = false;
        cfg.pipeline.block_bytes = 1 << 20;
        cfg.pipeline.inflight = 8;
        cfg.pipeline.ack_window = false;
        cfg.server.iter_deadline_ms = 250;
        cfg.server.compress_threads = 3;
        let rt = TrainConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(rt, cfg);
        // Auto-margin roundtrips too (only valid with the static knob 0).
        cfg.server.iter_deadline_ms = 0;
        cfg.server.iter_deadline_auto_margin = 2.5;
        let rt = TrainConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(rt, cfg);
    }

    #[test]
    fn server_section_parses_and_defaults_to_strict_bsp() {
        // Absent section = strict BSP (no deadline).
        let cfg = TrainConfig::from_str("{}").unwrap();
        assert_eq!(cfg.server.iter_deadline_ms, 0);
        assert_eq!(cfg.server.iter_deadline(), None);
        // Explicit deadline parses and converts.
        let cfg =
            TrainConfig::from_str(r#"{"server": {"iter_deadline_ms": 150}}"#).unwrap();
        assert_eq!(cfg.server.iter_deadline_ms, 150);
        assert_eq!(
            cfg.server.iter_deadline(),
            Some(std::time::Duration::from_millis(150))
        );
        // ack_window knob parses; defaults on.
        assert!(TrainConfig::from_str("{}").unwrap().pipeline.ack_window);
        let cfg =
            TrainConfig::from_str(r#"{"pipeline": {"ack_window": false}}"#).unwrap();
        assert!(!cfg.pipeline.ack_window);
    }

    #[test]
    fn server_staged_and_auto_deadline_knobs_parse_and_validate() {
        // Defaults: synchronous reference path, no auto-tuning.
        let cfg = TrainConfig::from_str("{}").unwrap();
        assert_eq!(cfg.server.compress_threads, 0);
        assert_eq!(cfg.server.iter_deadline_auto_margin, 0.0);
        // Explicit staged shard + auto margin.
        let cfg = TrainConfig::from_str(
            r#"{"server": {"compress_threads": 4, "iter_deadline_auto_margin": 3.0}}"#,
        )
        .unwrap();
        assert_eq!(cfg.server.compress_threads, 4);
        assert_eq!(cfg.server.iter_deadline_auto_margin, 3.0);
        // Auto margin alongside a static deadline is ambiguous: rejected.
        assert!(TrainConfig::from_str(
            r#"{"server": {"iter_deadline_ms": 100, "iter_deadline_auto_margin": 3.0}}"#
        )
        .is_err());
        // Negative margin rejected.
        assert!(TrainConfig::from_str(
            r#"{"server": {"iter_deadline_auto_margin": -1.0}}"#
        )
        .is_err());
    }

    #[test]
    fn pipeline_section_parses_and_validates() {
        let cfg = TrainConfig::from_str(
            r#"{"pipeline": {"enabled": false, "block_bytes": 65536, "inflight": 4}}"#,
        )
        .unwrap();
        assert!(!cfg.pipeline.enabled);
        assert_eq!(cfg.pipeline.block_bytes, 65536);
        assert_eq!(cfg.pipeline.inflight, 4);
        // Defaults apply when the section is absent.
        let cfg = TrainConfig::from_str("{}").unwrap();
        assert!(cfg.pipeline.enabled);
        assert_eq!(cfg.pipeline.block_bytes, 4 << 20);
        // Degenerate knobs rejected.
        assert!(TrainConfig::from_str(r#"{"pipeline": {"block_bytes": 1}}"#).is_err());
        assert!(TrainConfig::from_str(r#"{"pipeline": {"inflight": 0}}"#).is_err());
    }

    #[test]
    fn cluster_addresses_parse_and_roundtrip() {
        let cfg = TrainConfig::from_str(
            r#"{"cluster": {"addresses": ["127.0.0.1:4000", "127.0.0.1:4001"]}}"#,
        )
        .unwrap();
        assert_eq!(cfg.cluster.addresses, vec!["127.0.0.1:4000", "127.0.0.1:4001"]);
        let rt = TrainConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(rt, cfg);
        // Defaults to empty (single-process fabric).
        assert!(TrainConfig::from_str("{}").unwrap().cluster.addresses.is_empty());
        // Malformed sections rejected.
        assert!(TrainConfig::from_str(r#"{"cluster": {"addresses": "nope"}}"#).is_err());
        assert!(TrainConfig::from_str(r#"{"cluster": {"addresses": [7]}}"#).is_err());
        assert!(TrainConfig::from_str(r#"{"cluster": {"addresses": [""]}}"#).is_err());
    }

    #[test]
    fn adaptive_section_parses_validates_and_roundtrips() {
        // Default: controller off, bounds well-formed.
        let cfg = TrainConfig::from_str("{}").unwrap();
        assert!(!cfg.adaptive.enabled);
        assert!(cfg.adaptive.k_min > 0.0 && cfg.adaptive.k_min <= cfg.adaptive.k_max);
        // Explicit section parses.
        let cfg = TrainConfig::from_str(
            r#"{"adaptive": {"enabled": true, "k_min": 0.001, "k_max": 0.2,
                "ema": 0.5, "target_gain": 0.8}}"#,
        )
        .unwrap();
        assert!(cfg.adaptive.enabled);
        assert_eq!(cfg.adaptive.k_min, 0.001);
        assert_eq!(cfg.adaptive.k_max, 0.2);
        assert_eq!(cfg.adaptive.ema, 0.5);
        assert_eq!(cfg.adaptive.target_gain, 0.8);
        // Roundtrips through to_json.
        let rt = TrainConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(rt, cfg);
        // Degenerate knobs rejected even with the controller off.
        assert!(TrainConfig::from_str(r#"{"adaptive": {"k_min": 0}}"#).is_err());
        assert!(TrainConfig::from_str(r#"{"adaptive": {"k_min": 0.5, "k_max": 0.1}}"#).is_err());
        assert!(TrainConfig::from_str(r#"{"adaptive": {"k_max": 1.5}}"#).is_err());
        assert!(TrainConfig::from_str(r#"{"adaptive": {"ema": 0}}"#).is_err());
        assert!(TrainConfig::from_str(r#"{"adaptive": {"ema": 1.5}}"#).is_err());
        assert!(TrainConfig::from_str(r#"{"adaptive": {"target_gain": 1.0}}"#).is_err());
    }

    #[test]
    fn hierarchical_groups_parse_validate_and_roundtrip() {
        // Default: flat topology.
        let cfg = TrainConfig::from_str("{}").unwrap();
        assert_eq!(cfg.cluster.groups, 0);
        assert!(cfg.cluster.group_addresses.is_empty());
        // 4 nodes in 2 groups parses and roundtrips.
        let cfg = TrainConfig::from_str(r#"{"cluster": {"nodes": 4, "groups": 2}}"#).unwrap();
        assert_eq!(cfg.cluster.groups, 2);
        let rt = TrainConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(rt, cfg);
        // Leader addresses must match the group count, one per group.
        let cfg = TrainConfig::from_str(
            r#"{"cluster": {"nodes": 4, "groups": 2,
                "group_addresses": ["127.0.0.1:5000", "127.0.0.1:5001"]}}"#,
        )
        .unwrap();
        assert_eq!(cfg.cluster.group_addresses.len(), 2);
        assert_eq!(TrainConfig::from_json(&cfg.to_json()).unwrap(), cfg);
        // Uneven partition rejected.
        assert!(TrainConfig::from_str(r#"{"cluster": {"nodes": 5, "groups": 2}}"#).is_err());
        // Leader addresses without groups, or with the wrong count, rejected.
        assert!(TrainConfig::from_str(
            r#"{"cluster": {"group_addresses": ["127.0.0.1:5000"]}}"#
        )
        .is_err());
        assert!(TrainConfig::from_str(
            r#"{"cluster": {"nodes": 4, "groups": 2, "group_addresses": ["127.0.0.1:5000"]}}"#
        )
        .is_err());
        // Hierarchical × adaptive is a config error, not a silent fallback.
        assert!(TrainConfig::from_str(
            r#"{"cluster": {"nodes": 4, "groups": 2}, "adaptive": {"enabled": true}}"#
        )
        .is_err());
    }

    #[test]
    fn sync_mode_names_roundtrip() {
        for m in [SyncMode::Full, SyncMode::Compressed, SyncMode::CompressedEf] {
            assert_eq!(SyncMode::parse(m.name()).unwrap(), m);
        }
        assert!(SyncMode::parse("bogus").is_err());
    }
}
