//! Minimal JSON parser / serializer (serde is unavailable offline).
//!
//! Supports the full JSON grammar minus exotic number forms; numbers are
//! stored as f64 (adequate for config + artifact manifests). Used for run
//! configs, the artifact manifest emitted by `python/compile/aot.py`, and
//! metrics dumps.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= usize::MAX as f64 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `get` that errors with the key name — for required config fields.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError { msg: format!("missing field '{key}'"), pos: 0 })
    }

    // -- builders -----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

/// Parse / access error.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn keyword(&mut self, kw: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{kw}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("unpaired low surrogate"));
                        } else {
                            out.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("raw control character in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let start = self.pos - 1;
                        let end = start + len;
                        if end > self.src.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.src[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
                None => return Err(self.err("unterminated string")),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = (v << 4) | d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

// --- serialization ----------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt_value(self, f, false, 0)
    }
}

impl Json {
    /// Pretty-print with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        fmt_value(self, &mut s, true, 0).expect("fmt to String cannot fail");
        s
    }
}

fn fmt_value<W: fmt::Write>(v: &Json, w: &mut W, pretty: bool, indent: usize) -> fmt::Result {
    match v {
        Json::Null => w.write_str("null"),
        Json::Bool(b) => w.write_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                write!(w, "{}", *n as i64)
            } else {
                write!(w, "{}", n)
            }
        }
        Json::Str(s) => fmt_string(s, w),
        Json::Arr(a) => {
            w.write_char('[')?;
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    w.write_char(',')?;
                }
                if pretty {
                    w.write_char('\n')?;
                    for _ in 0..indent + 2 {
                        w.write_char(' ')?;
                    }
                }
                fmt_value(item, w, pretty, indent + 2)?;
            }
            if pretty && !a.is_empty() {
                w.write_char('\n')?;
                for _ in 0..indent {
                    w.write_char(' ')?;
                }
            }
            w.write_char(']')
        }
        Json::Obj(o) => {
            w.write_char('{')?;
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    w.write_char(',')?;
                }
                if pretty {
                    w.write_char('\n')?;
                    for _ in 0..indent + 2 {
                        w.write_char(' ')?;
                    }
                }
                fmt_string(k, w)?;
                w.write_char(':')?;
                if pretty {
                    w.write_char(' ')?;
                }
                fmt_value(val, w, pretty, indent + 2)?;
            }
            if pretty && !o.is_empty() {
                w.write_char('\n')?;
                for _ in 0..indent {
                    w.write_char(' ')?;
                }
            }
            w.write_char('}')
        }
    }
}

fn fmt_string<W: fmt::Write>(s: &str, w: &mut W) -> fmt::Result {
    w.write_char('"')?;
    for c in s.chars() {
        match c {
            '"' => w.write_str("\\\"")?,
            '\\' => w.write_str("\\\\")?,
            '\n' => w.write_str("\\n")?,
            '\r' => w.write_str("\\r")?,
            '\t' => w.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(w, "\\u{:04x}", c as u32)?,
            c => w.write_char(c)?,
        }
    }
    w.write_char('"')
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_unicode_escapes() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        // surrogate pair: U+1F600
        assert_eq!(Json::parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
        // raw multibyte utf-8 passthrough
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,]", "{\"a\":}", "tru", "\"abc", "01x", "{\"a\":1,}", "[1 2]"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn roundtrip_compact() {
        let src = r#"{"arr":[1,2.5,null,true],"name":"x\"y","nested":{"k":-7}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::obj(vec![
            ("model", Json::str("bert-mini")),
            ("lr", Json::num(1e-3)),
            ("layers", Json::Arr(vec![Json::num(1.0), Json::num(2.0)])),
        ]);
        let p = v.pretty();
        assert!(p.contains('\n'));
        assert_eq!(Json::parse(&p).unwrap(), v);
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 3, "f": 1.5, "s": "a", "b": true}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("f").unwrap().as_usize(), None);
        assert_eq!(v.get("f").unwrap().as_f64(), Some(1.5));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert!(v.req("missing").is_err());
    }

    #[test]
    fn control_chars_escaped_on_output() {
        let v = Json::Str("a\u{0001}b".into());
        assert_eq!(v.to_string(), "\"a\\u0001b\"");
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }
}
