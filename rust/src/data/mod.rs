//! Synthetic data generation: Zipf-distributed token streams with Markov
//! structure (so an LM has something to learn), MLM masking, and the
//! synthetic classification tasks used as the GLUE substitute (Table 4).
//!
//! Everything is seed-deterministic so runs are reproducible and all
//! workers/methods see identical data order at equal seeds.

use crate::util::rng::Xoshiro256;

/// Reserved token ids.
pub const PAD: i32 = 0;
pub const MASK: i32 = 1;
pub const FIRST_REGULAR: i32 = 2;

/// An MLM training batch (flat row-major buffers + shapes).
#[derive(Clone, Debug)]
pub struct MlmBatch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub mask: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
}

/// Zipf + first-order-Markov token source: token t+1 is, with probability
/// `coherence`, a deterministic function of token t (learnable structure);
/// otherwise a fresh Zipf draw (noise floor). This gives loss curves the
/// same "fast drop, long tail" shape as real-corpus MLM.
pub struct Corpus {
    rng: Xoshiro256,
    vocab: usize,
    /// CDF for Zipf(1.0) over the regular tokens.
    cdf: Vec<f64>,
    coherence: f64,
    prev: i32,
}

impl Corpus {
    pub fn new(vocab: usize, seed: u64) -> Self {
        assert!(vocab as i32 > FIRST_REGULAR + 1);
        let n = vocab - FIRST_REGULAR as usize;
        let mut weights: Vec<f64> = (1..=n).map(|r| 1.0 / r as f64).collect();
        let total: f64 = weights.iter().sum();
        let mut acc = 0.0;
        for w in &mut weights {
            acc += *w / total;
            *w = acc;
        }
        Corpus {
            rng: Xoshiro256::seed_from_u64(seed),
            vocab,
            cdf: weights,
            coherence: 0.5,
            prev: FIRST_REGULAR,
        }
    }

    fn zipf(&mut self) -> i32 {
        let u = self.rng.next_f64();
        // binary search the CDF
        let idx = self.cdf.partition_point(|&c| c < u);
        FIRST_REGULAR + idx.min(self.cdf.len() - 1) as i32
    }

    /// Next token in the stream.
    pub fn next_token(&mut self) -> i32 {
        let t = if self.rng.next_f64() < self.coherence {
            // Deterministic successor: affine map in the regular range.
            let n = self.vocab as i64 - FIRST_REGULAR as i64;
            let x = self.prev as i64 - FIRST_REGULAR as i64;
            FIRST_REGULAR + ((x * 31 + 7) % n) as i32
        } else {
            self.zipf()
        };
        self.prev = t;
        t
    }

    /// Sample an MLM batch: `mask_frac` of positions are replaced with
    /// [MASK] and contribute to the loss (BERT's 15% default).
    pub fn mlm_batch(&mut self, batch: usize, seq: usize, mask_frac: f64) -> MlmBatch {
        let n = batch * seq;
        let mut tokens = Vec::with_capacity(n);
        for _ in 0..n {
            tokens.push(self.next_token());
        }
        let targets = tokens.clone();
        let mut mask = vec![0.0f32; n];
        for i in 0..n {
            if self.rng.next_f64() < mask_frac {
                tokens[i] = MASK;
                mask[i] = 1.0;
            }
        }
        // Guarantee at least one masked position (loss must be defined).
        if mask.iter().all(|&m| m == 0.0) {
            let i = self.rng.below(n as u64) as usize;
            tokens[i] = MASK;
            mask[i] = 1.0;
        }
        MlmBatch { tokens, targets, mask, batch, seq }
    }
}

/// A synthetic classification task (GLUE substitute): each class is a
/// distinct token distribution; `difficulty` ∈ (0, 1] scales class
/// separation (1 = trivially separable, → 0 = chance).
pub struct ClassifyTask {
    rng: Xoshiro256,
    vocab: usize,
    classes: usize,
    difficulty: f64,
    pub name: &'static str,
}

impl ClassifyTask {
    pub fn new(name: &'static str, vocab: usize, classes: usize, difficulty: f64, seed: u64) -> Self {
        assert!(classes >= 2 && (0.0..=1.0).contains(&difficulty));
        ClassifyTask { rng: Xoshiro256::seed_from_u64(seed), vocab, classes, difficulty, name }
    }

    /// The paper's four GLUE tasks mapped to four difficulties (MNLI-m is
    /// hardest, SST-2 easiest — mirroring the paper's accuracy ordering).
    pub fn glue_suite(vocab: usize, seed: u64) -> Vec<ClassifyTask> {
        vec![
            ClassifyTask::new("MNLI-m*", vocab, 4, 0.35, seed ^ 1),
            ClassifyTask::new("QNLI*", vocab, 4, 0.55, seed ^ 2),
            ClassifyTask::new("SST-2*", vocab, 4, 0.75, seed ^ 3),
            ClassifyTask::new("MRPC*", vocab, 4, 0.45, seed ^ 4),
        ]
    }

    /// Sample (tokens, labels): class c biases tokens toward the band
    /// `[c·V/C, (c+1)·V/C)` with probability `difficulty`.
    pub fn batch(&mut self, batch: usize, seq: usize) -> (Vec<i32>, Vec<i32>) {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut labels = Vec::with_capacity(batch);
        let band = (self.vocab - FIRST_REGULAR as usize) / self.classes;
        for _ in 0..batch {
            let label = self.rng.below(self.classes as u64) as i32;
            labels.push(label);
            for _ in 0..seq {
                let t = if self.rng.next_f64() < self.difficulty {
                    FIRST_REGULAR
                        + (label as usize * band) as i32
                        + self.rng.below(band as u64) as i32
                } else {
                    FIRST_REGULAR + self.rng.below((self.vocab - FIRST_REGULAR as usize) as u64) as i32
                };
                tokens.push(t);
            }
        }
        (tokens, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab_range() {
        let mut c = Corpus::new(256, 1);
        for _ in 0..10_000 {
            let t = c.next_token();
            assert!((FIRST_REGULAR..256).contains(&t));
        }
    }

    #[test]
    fn zipf_head_is_heavy() {
        let mut c = Corpus::new(1024, 2);
        c.coherence = 0.0; // pure Zipf
        let mut head = 0usize;
        let n = 20_000;
        for _ in 0..n {
            if c.next_token() < FIRST_REGULAR + 10 {
                head += 1;
            }
        }
        // Zipf(1.0) over ~1k tokens: top-10 mass ≈ H(10)/H(1022) ≈ 0.39
        assert!(head as f64 / n as f64 > 0.25, "head mass {}", head as f64 / n as f64);
    }

    #[test]
    fn mlm_batch_invariants() {
        let mut c = Corpus::new(512, 3);
        let b = c.mlm_batch(4, 32, 0.15);
        assert_eq!(b.tokens.len(), 128);
        assert_eq!(b.targets.len(), 128);
        assert_eq!(b.mask.len(), 128);
        let masked = b.mask.iter().filter(|&&m| m == 1.0).count();
        assert!(masked >= 1);
        for i in 0..128 {
            if b.mask[i] == 1.0 {
                assert_eq!(b.tokens[i], MASK);
                assert_ne!(b.targets[i], MASK);
            } else {
                assert_eq!(b.tokens[i], b.targets[i]);
            }
        }
        // masking rate near 15%
        assert!((masked as f64 / 128.0 - 0.15).abs() < 0.15);
    }

    #[test]
    fn mlm_batch_always_has_a_masked_position() {
        let mut c = Corpus::new(64, 4);
        for _ in 0..50 {
            let b = c.mlm_batch(1, 4, 0.0); // 0% would otherwise mask nothing
            assert!(b.mask.iter().any(|&m| m == 1.0));
        }
    }

    #[test]
    fn classify_task_is_learnable_and_difficulty_ordered() {
        // A trivial band classifier should reach high accuracy on easy
        // tasks and lower on hard ones.
        let eval = |difficulty: f64| -> f64 {
            let mut t = ClassifyTask::new("t", 1024, 4, difficulty, 9);
            let band = (1024 - FIRST_REGULAR as usize) / 4;
            let (tokens, labels) = t.batch(400, 16);
            let mut correct = 0;
            for (i, &label) in labels.iter().enumerate() {
                // majority-band vote
                let mut counts = [0usize; 4];
                for &tok in &tokens[i * 16..(i + 1) * 16] {
                    let c = ((tok - FIRST_REGULAR) as usize / band).min(3);
                    counts[c] += 1;
                }
                let pred = counts.iter().enumerate().max_by_key(|(_, c)| **c).unwrap().0;
                if pred == label as usize {
                    correct += 1;
                }
            }
            correct as f64 / labels.len() as f64
        };
        let easy = eval(0.75);
        let hard = eval(0.2);
        assert!(easy > 0.9, "easy task acc {easy}");
        assert!(hard < easy, "hard {hard} !< easy {easy}");
    }

    #[test]
    fn glue_suite_has_four_named_tasks() {
        let suite = ClassifyTask::glue_suite(2048, 1);
        assert_eq!(suite.len(), 4);
        assert_eq!(suite[0].name, "MNLI-m*");
    }

    #[test]
    fn determinism_per_seed() {
        let mut a = Corpus::new(128, 42);
        let mut b = Corpus::new(128, 42);
        let ba = a.mlm_batch(2, 8, 0.15);
        let bb = b.mlm_batch(2, 8, 0.15);
        assert_eq!(ba.tokens, bb.tokens);
        assert_eq!(ba.mask, bb.mask);
    }
}
