//! Binary wire format for [`Message`] — length-prefixed frames with a
//! fixed header, used verbatim by the TCP transport and for byte
//! accounting by the in-process transport.
//!
//! ```text
//! frame := [len: u32le] [tag: u8] body
//! Push      body := [key u64][iter u64][worker u32][block]
//! GroupPush body := [key u64][iter u64][worker u32][members u16][block]
//! Pull      body := [key u64][iter u64][worker u32]
//! PullResp  body := [key u64][iter u64][served u16][block]
//! Ack       body := [key u64][iter u64]
//! Hello     body := [worker u32][n_keys u64][config u64]
//!                   [k_min_ppm u32][k_max_ppm u32]
//! Welcome   body := [n_workers u32][shard u32][seed u64]
//!                   [k_min_ppm u32][k_max_ppm u32][count u32]
//!                   ([key u64][server u32]) * count
//! Shutdown  body := (empty)
//! block := [scheme u8][n u64][payload_len u32][payload …]
//! key   := [block_idx : 24 bits][tensor_id : 40 bits]   (see comm::BlockKey)
//! ```
//!
//! The `key` field carries the pipeline's block sub-key (§4.2.1): tensor id
//! in the low 40 bits, block index in the high 24. A whole tensor is block
//! 0, so pre-pipeline keys decode unchanged. `Hello`/`Welcome` are the
//! cluster-mode registration handshake (see `crate::cluster`); their
//! `k_min_ppm`/`k_max_ppm` pair carries the adaptive-compression bounds
//! negotiation — requested on `Hello`, granted (server-clamped) on
//! `Welcome`, `(0, 0)` meaning a static run. The `served` count on
//! `PullResp` is the number of worker contributions in the aggregate —
//! smaller than the run's worker count when the server's iteration
//! deadline completed the round degraded (see `crate::ps`).
//!
//! Decoding validates the block payload against its scheme
//! ([`crate::compress::validate_wire`]): a corrupt or malicious frame —
//! truncated payload, inconsistent `k`, out-of-range top-k index — is
//! rejected as [`CommError::Protocol`] at the wire boundary instead of
//! panicking inside the server's decompressor.
//!
//! The [`MAX_FRAME_LEN`] cap is enforced *symmetrically*: `recv` rejects
//! oversized length prefixes, and [`encode`] refuses to serialize a body
//! that the peer would reject — an oversized tensor surfaces as a
//! [`CommError`] at the sender instead of a fully-serialized frame that
//! severs the peer's connection.
// Wire-facing module: the static-invariants lint (rust/src/lint) keeps
// this file panic-free outside tests, and clippy enforces the same at
// the `unwrap`/`expect` level.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use super::{CommError, Message};
use crate::compress::{Compressed, SchemeId};

/// Maximum frame body size in bytes (the u32 length prefix is excluded).
/// Enforced on both encode ([`encode`]) and receive (both transports).
pub const MAX_FRAME_LEN: usize = 1 << 30;

/// Wire-format version, bumped whenever a frame layout changes
/// incompatibly (v2: `PullResp` gained the `served_with: u16` field;
/// v3: `Hello`/`Welcome` gained the `k_min_ppm`/`k_max_ppm`
/// adaptive-bounds negotiation fields; v4: `GroupPush` — a group
/// leader's weighted combined push for hierarchical two-level
/// aggregation). Folded into the cluster registration fingerprint
/// (`cluster::config_fingerprint`) so mixed-version binaries fail
/// loudly at the handshake instead of misparsing each other's frames
/// mid-run.
pub const WIRE_VERSION: u32 = 4;

const TAG_PUSH: u8 = 1;
const TAG_PULL: u8 = 2;
const TAG_PULL_RESP: u8 = 3;
const TAG_ACK: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;
const TAG_HELLO: u8 = 6;
const TAG_WELCOME: u8 = 7;
const TAG_GROUP_PUSH: u8 = 8;

fn put_u16(b: &mut Vec<u8>, v: u16) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn u8(&mut self) -> Result<u8, CommError> {
        let v = *self.buf.get(self.pos).ok_or_else(|| CommError::Protocol("truncated".into()))?;
        self.pos += 1;
        Ok(v)
    }

    /// Read exactly `N` bytes as a fixed array. The copy (instead of
    /// `try_into().unwrap()` on the checked slice) keeps the reader
    /// panic-free end to end: `bytes()` already guarantees the length,
    /// so no unreachable error arm is needed.
    fn array<const N: usize>(&mut self, what: &'static str) -> Result<[u8; N], CommError> {
        let s = self
            .buf
            .get(self.pos..self.pos + N)
            .ok_or_else(|| CommError::Protocol(format!("truncated {what}")))?;
        self.pos += N;
        let mut a = [0u8; N];
        a.copy_from_slice(s);
        Ok(a)
    }

    fn u16(&mut self) -> Result<u16, CommError> {
        Ok(u16::from_le_bytes(self.array("u16")?))
    }

    fn u32(&mut self) -> Result<u32, CommError> {
        Ok(u32::from_le_bytes(self.array("u32")?))
    }

    fn u64(&mut self) -> Result<u64, CommError> {
        Ok(u64::from_le_bytes(self.array("u64")?))
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8], CommError> {
        let end = self.pos + n;
        let s = self
            .buf
            .get(self.pos..end)
            .ok_or_else(|| CommError::Protocol("truncated payload".into()))?;
        self.pos = end;
        Ok(s)
    }
}

/// Serialize a block's header (scheme, element count, payload length)
/// without the payload bytes themselves — the payload is always the
/// trailing chunk of the frame, which lets the TCP transport send it as
/// a second `IoSlice` straight from the message ([`encode_split_into`]).
fn put_block_header(b: &mut Vec<u8>, c: &Compressed) -> Result<(), CommError> {
    b.push(c.scheme.wire_id());
    // lint: allow(cast: usize -> u64) — widening on every supported (64-bit) target
    put_u64(b, c.n as u64);
    let plen = u32::try_from(c.payload.len()).map_err(|_| {
        CommError::Protocol(format!("block payload {} bytes exceeds u32", c.payload.len()))
    })?;
    put_u32(b, plen);
    Ok(())
}

fn put_block(b: &mut Vec<u8>, c: &Compressed) -> Result<(), CommError> {
    put_block_header(b, c)?;
    b.extend_from_slice(&c.payload);
    Ok(())
}

fn get_block(r: &mut Reader) -> Result<Compressed, CommError> {
    let scheme = SchemeId::from_u8(r.u8()?)
        .ok_or_else(|| CommError::Protocol("bad scheme id".into()))?;
    // try_from instead of `as`: a 2^32+ element count in the header must
    // be a protocol error on every target, never a silent truncation.
    let n = usize::try_from(r.u64()?)
        .map_err(|_| CommError::Protocol("block element count exceeds usize".into()))?;
    // lint: allow(cast: u32 -> usize) — widening on every supported (64-bit) target
    let plen = r.u32()? as usize;
    // The decoded payload is the dominant per-frame allocation on the
    // server's steady-state recv path; rent it from the pool so consumers
    // that `give_bytes` it back after use close the recycling loop.
    // lint: transfers(decode)
    let mut payload = super::BufPool::global().rent_bytes_empty();
    payload.extend_from_slice(r.bytes(plen)?);
    let c = Compressed { scheme, n, payload };
    crate::compress::validate_wire(&c).map_err(CommError::Protocol)?;
    Ok(c)
}

/// Exact encoded body length of a message, computed without serializing.
/// Keeps [`frame_bytes`] allocation-free and lets [`encode`] reject an
/// oversized message *before* buffering a gigabyte of doomed bytes.
pub fn body_len(msg: &Message) -> usize {
    let block_len = |c: &Compressed| 1 + 8 + 4 + c.payload.len();
    match msg {
        Message::Push { data, .. } => 1 + 8 + 8 + 4 + block_len(data),
        Message::GroupPush { data, .. } => 1 + 8 + 8 + 4 + 2 + block_len(data),
        Message::Pull { .. } => 1 + 8 + 8 + 4,
        Message::PullResp { data, .. } => 1 + 8 + 8 + 2 + block_len(data),
        Message::Ack { .. } => 1 + 8 + 8,
        Message::Hello { .. } => 1 + 4 + 8 + 8 + 4 + 4,
        Message::Welcome { plan, .. } => 1 + 4 + 4 + 8 + 4 + 4 + 4 + 12 * plan.len(),
        Message::Shutdown => 1,
    }
}

/// Check a message against [`MAX_FRAME_LEN`]; returns its body length.
pub fn check_len(msg: &Message) -> Result<usize, CommError> {
    let len = body_len(msg);
    if len > MAX_FRAME_LEN {
        return Err(CommError::Protocol(format!(
            "frame too large to send: {len} bytes (cap {MAX_FRAME_LEN})"
        )));
    }
    Ok(len)
}

/// Encode a message body (without the length prefix). Fails when a
/// length field (block payload, Welcome plan) exceeds its wire width.
pub fn encode_body(msg: &Message) -> Result<Vec<u8>, CommError> {
    let mut b = Vec::with_capacity(body_len(msg));
    encode_body_into(msg, &mut b)?;
    Ok(b)
}

/// Serialize a message body by appending to `b` (no clearing, no length
/// prefix) — the shared core of [`encode_body`] and [`encode_into`].
fn encode_body_into(msg: &Message, b: &mut Vec<u8>) -> Result<(), CommError> {
    let start = b.len();
    match msg {
        Message::Push { key, iter, worker, data } => {
            b.push(TAG_PUSH);
            put_u64(b, *key);
            put_u64(b, *iter);
            put_u32(b, *worker);
            put_block(b, data)?;
        }
        Message::GroupPush { key, iter, worker, members, data } => {
            b.push(TAG_GROUP_PUSH);
            put_u64(b, *key);
            put_u64(b, *iter);
            put_u32(b, *worker);
            put_u16(b, *members);
            put_block(b, data)?;
        }
        Message::Pull { key, iter, worker } => {
            b.push(TAG_PULL);
            put_u64(b, *key);
            put_u64(b, *iter);
            put_u32(b, *worker);
        }
        Message::PullResp { key, iter, served_with, data } => {
            b.push(TAG_PULL_RESP);
            put_u64(b, *key);
            put_u64(b, *iter);
            put_u16(b, *served_with);
            put_block(b, data)?;
        }
        Message::Ack { key, iter } => {
            b.push(TAG_ACK);
            put_u64(b, *key);
            put_u64(b, *iter);
        }
        Message::Hello { worker, n_keys, config, k_min_ppm, k_max_ppm } => {
            b.push(TAG_HELLO);
            put_u32(b, *worker);
            put_u64(b, *n_keys);
            put_u64(b, *config);
            put_u32(b, *k_min_ppm);
            put_u32(b, *k_max_ppm);
        }
        Message::Welcome { n_workers, shard, seed, k_min_ppm, k_max_ppm, plan } => {
            b.push(TAG_WELCOME);
            put_u32(b, *n_workers);
            put_u32(b, *shard);
            put_u64(b, *seed);
            put_u32(b, *k_min_ppm);
            put_u32(b, *k_max_ppm);
            let count = u32::try_from(plan.len()).map_err(|_| {
                CommError::Protocol(format!("welcome plan {} entries exceeds u32", plan.len()))
            })?;
            put_u32(b, count);
            for &(key, server) in plan {
                put_u64(b, key);
                put_u32(b, server);
            }
        }
        Message::Shutdown => b.push(TAG_SHUTDOWN),
    }
    debug_assert_eq!(b.len() - start, body_len(msg));
    Ok(())
}

/// Encode a full frame (length prefix + body). Fails — before serializing
/// anything — if the body would exceed [`MAX_FRAME_LEN`], the same cap the
/// receive path enforces.
pub fn encode(msg: &Message) -> Result<Vec<u8>, CommError> {
    let mut out = Vec::new();
    encode_into(msg, &mut out)?;
    Ok(out)
}

/// Like [`encode`], but serializes into a caller-provided buffer (cleared
/// first, capacity retained) — the per-connection send scratch of the TCP
/// transport reuses one buffer across frames instead of allocating each.
pub fn encode_into(msg: &Message, out: &mut Vec<u8>) -> Result<(), CommError> {
    let len = check_len(msg)?;
    // check_len capped `len` at MAX_FRAME_LEN (2^30), so this never fails;
    // try_from keeps the no-bare-`as` discipline without a panic path.
    let len32 = u32::try_from(len)
        .map_err(|_| CommError::Protocol(format!("frame too large to send: {len} bytes")))?;
    out.clear();
    out.reserve(4 + len);
    put_u32(out, len32);
    encode_body_into(msg, out)?;
    Ok(())
}

/// Like [`encode_into`], but for block-carrying messages (`Push`,
/// `GroupPush`, `PullResp`) the trailing block payload is *not* copied
/// into `out` — the length prefix still covers the full body, and the
/// caller sends the payload as a second slice straight from the message
/// (the TCP transport's vectored send). Returns `true` when the payload
/// was split off, `false` when `out` holds the complete frame.
pub fn encode_split_into(msg: &Message, out: &mut Vec<u8>) -> Result<bool, CommError> {
    let len = check_len(msg)?;
    let len32 = u32::try_from(len)
        .map_err(|_| CommError::Protocol(format!("frame too large to send: {len} bytes")))?;
    out.clear();
    let split = match msg {
        Message::Push { key, iter, worker, data } => {
            out.reserve(4 + len - data.payload.len());
            put_u32(out, len32);
            out.push(TAG_PUSH);
            put_u64(out, *key);
            put_u64(out, *iter);
            put_u32(out, *worker);
            put_block_header(out, data)?;
            true
        }
        Message::GroupPush { key, iter, worker, members, data } => {
            out.reserve(4 + len - data.payload.len());
            put_u32(out, len32);
            out.push(TAG_GROUP_PUSH);
            put_u64(out, *key);
            put_u64(out, *iter);
            put_u32(out, *worker);
            put_u16(out, *members);
            put_block_header(out, data)?;
            true
        }
        Message::PullResp { key, iter, served_with, data } => {
            out.reserve(4 + len - data.payload.len());
            put_u32(out, len32);
            out.push(TAG_PULL_RESP);
            put_u64(out, *key);
            put_u64(out, *iter);
            put_u16(out, *served_with);
            put_block_header(out, data)?;
            true
        }
        _ => {
            out.reserve(4 + len);
            put_u32(out, len32);
            encode_body_into(msg, out)?;
            false
        }
    };
    Ok(split)
}

/// Decode a message body (frame already stripped of its length prefix).
pub fn decode_body(buf: &[u8]) -> Result<Message, CommError> {
    let mut r = Reader { buf, pos: 0 };
    let tag = r.u8()?;
    let msg = match tag {
        TAG_PUSH => Message::Push {
            key: r.u64()?,
            iter: r.u64()?,
            worker: r.u32()?,
            data: get_block(&mut r)?,
        },
        TAG_GROUP_PUSH => Message::GroupPush {
            key: r.u64()?,
            iter: r.u64()?,
            worker: r.u32()?,
            members: r.u16()?,
            data: get_block(&mut r)?,
        },
        TAG_PULL => Message::Pull { key: r.u64()?, iter: r.u64()?, worker: r.u32()? },
        TAG_PULL_RESP => Message::PullResp {
            key: r.u64()?,
            iter: r.u64()?,
            served_with: r.u16()?,
            data: get_block(&mut r)?,
        },
        TAG_ACK => Message::Ack { key: r.u64()?, iter: r.u64()? },
        TAG_HELLO => Message::Hello {
            worker: r.u32()?,
            n_keys: r.u64()?,
            config: r.u64()?,
            k_min_ppm: r.u32()?,
            k_max_ppm: r.u32()?,
        },
        TAG_WELCOME => {
            let n_workers = r.u32()?;
            let shard = r.u32()?;
            let seed = r.u64()?;
            let k_min_ppm = r.u32()?;
            let k_max_ppm = r.u32()?;
            // lint: allow(cast: u32 -> usize) — widening on every supported (64-bit) target
            let count = r.u32()? as usize;
            // Untrusted input: bound the allocation by the bytes actually
            // present (12 per entry) before reserving `count` slots.
            if count > (buf.len() - r.pos) / 12 {
                return Err(CommError::Protocol(format!(
                    "welcome plan claims {count} entries, frame too short"
                )));
            }
            let mut plan = Vec::with_capacity(count);
            for _ in 0..count {
                plan.push((r.u64()?, r.u32()?));
            }
            Message::Welcome { n_workers, shard, seed, k_min_ppm, k_max_ppm, plan }
        }
        TAG_SHUTDOWN => Message::Shutdown,
        t => return Err(CommError::Protocol(format!("unknown tag {t}"))),
    };
    if r.pos != buf.len() {
        return Err(CommError::Protocol(format!("{} trailing bytes", buf.len() - r.pos)));
    }
    Ok(msg)
}

/// Wire size of a message, including the 4-byte length prefix.
pub fn frame_bytes(msg: &Message) -> usize {
    4 + body_len(msg)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::testutil::forall;

    /// A structurally valid wire block (decode now validates payloads, so
    /// random bytes no longer roundtrip).
    fn sample_block(g: &mut crate::testutil::Gen) -> Compressed {
        let rand_bytes = |g: &mut crate::testutil::Gen, len: usize| -> Vec<u8> {
            (0..len).map(|_| (g.u64() & 0xFF) as u8).collect()
        };
        match g.usize_in(0, 6) {
            0 => {
                let n = g.usize_in(0, 32);
                Compressed { scheme: SchemeId::Identity, n, payload: rand_bytes(g, 4 * n) }
            }
            5 | 6 => {
                // Dither blocks: any payload inside the validation envelope
                // spanned by 2..=16 bits per element (plus the f32 scale).
                let scheme =
                    if g.bool() { SchemeId::LinearDither } else { SchemeId::NaturalDither };
                let n = g.usize_in(0, 32);
                let lo = 4 + (2 * n).div_ceil(8);
                let hi = 4 + 2 * n;
                let len = g.usize_in(lo, hi);
                Compressed { scheme, n, payload: rand_bytes(g, len) }
            }
            1 => {
                let n = g.usize_in(0, 32);
                Compressed { scheme: SchemeId::Fp16, n, payload: rand_bytes(g, 2 * n) }
            }
            2 => {
                let n = g.usize_in(0, 32);
                Compressed { scheme: SchemeId::OneBit, n, payload: rand_bytes(g, 4 + n.div_ceil(8)) }
            }
            3 => {
                let n = g.usize_in(1, 32);
                let k = g.usize_in(1, n);
                let mut payload = Vec::new();
                payload.extend_from_slice(&(k as u32).to_le_bytes());
                for _ in 0..k {
                    payload.extend_from_slice(&(g.usize_in(0, n - 1) as u32).to_le_bytes());
                }
                payload.extend_from_slice(&rand_bytes(g, 4 * k));
                Compressed { scheme: SchemeId::TopK, n, payload }
            }
            _ => {
                let n = g.usize_in(1, 32);
                let k = g.usize_in(1, n);
                let mut payload = Vec::new();
                payload.extend_from_slice(&(k as u32).to_le_bytes());
                payload.extend_from_slice(&g.u64().to_le_bytes()); // seed
                payload.extend_from_slice(&rand_bytes(g, 4 * k));
                Compressed { scheme: SchemeId::RandomK, n, payload }
            }
        }
    }

    #[test]
    fn roundtrip_all_message_kinds() {
        forall(200, 0xf4a3e, |g| {
            let msg = match g.usize_in(0, 7) {
                0 => Message::Push {
                    key: g.u64(),
                    iter: g.u64(),
                    worker: (g.u64() & 0xFFFF) as u32,
                    data: sample_block(g),
                },
                7 => Message::GroupPush {
                    key: g.u64(),
                    iter: g.u64(),
                    worker: (g.u64() & 0xFFFF) as u32,
                    members: (g.u64() & 0xFFFF) as u16,
                    data: sample_block(g),
                },
                1 => Message::Pull { key: g.u64(), iter: g.u64(), worker: 3 },
                2 => Message::PullResp {
                    key: g.u64(),
                    iter: g.u64(),
                    served_with: (g.u64() & 0xFFFF) as u16,
                    data: sample_block(g),
                },
                3 => Message::Ack { key: g.u64(), iter: g.u64() },
                4 => Message::Hello {
                    worker: (g.u64() & 0xFFFF) as u32,
                    n_keys: g.u64(),
                    config: g.u64(),
                    k_min_ppm: (g.u64() % 1_000_001) as u32,
                    k_max_ppm: (g.u64() % 1_000_001) as u32,
                },
                5 => {
                    let n = g.usize_in(0, 12);
                    Message::Welcome {
                        n_workers: (g.u64() & 0xFF) as u32,
                        shard: (g.u64() & 0xF) as u32,
                        seed: g.u64(),
                        k_min_ppm: (g.u64() % 1_000_001) as u32,
                        k_max_ppm: (g.u64() % 1_000_001) as u32,
                        plan: (0..n).map(|_| (g.u64(), (g.u64() & 0x7) as u32)).collect(),
                    }
                }
                _ => Message::Shutdown,
            };
            let enc = encode(&msg).map_err(|e| e.to_string())?;
            let len = u32::from_le_bytes(enc[..4].try_into().unwrap()) as usize;
            if len != enc.len() - 4 {
                return Err("length prefix wrong".into());
            }
            let dec = decode_body(&enc[4..]).map_err(|e| e.to_string())?;
            if dec != msg {
                return Err(format!("roundtrip mismatch: {msg:?} vs {dec:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn rejects_malformed() {
        assert!(decode_body(&[]).is_err());
        assert!(decode_body(&[99]).is_err());
        assert!(decode_body(&[TAG_ACK, 1, 2]).is_err()); // truncated
        // trailing garbage
        let mut enc = encode_body(&Message::Shutdown).unwrap();
        enc.push(0);
        assert!(decode_body(&enc).is_err());
        // bad scheme id inside a block
        let msg = Message::PullResp {
            key: 1,
            iter: 1,
            served_with: 1,
            data: Compressed { scheme: SchemeId::TopK, n: 4, payload: vec![1, 2, 3] },
        };
        let mut enc = encode_body(&msg).unwrap();
        enc[19] = 0xEE; // scheme byte (1 tag + 8 key + 8 iter + 2 served)
        assert!(decode_body(&enc).is_err());
    }

    #[test]
    fn frame_bytes_matches_encoding() {
        for msg in one_of_each_tag() {
            assert_eq!(frame_bytes(&msg), encode(&msg).unwrap().len(), "{msg:?}");
            assert_eq!(body_len(&msg), encode_body(&msg).unwrap().len(), "{msg:?}");
        }
    }

    /// Encode enforces the same 1 GiB cap the receive path does: an
    /// oversized tensor fails at the sender with a protocol error instead
    /// of being serialized, sent, and severing the peer's connection.
    #[test]
    fn oversized_frame_rejected_at_encode() {
        let n = MAX_FRAME_LEN + 8;
        // vec![0u8; n] is alloc_zeroed: the kernel hands back lazy zero
        // pages and nothing below ever touches them (check_len/body_len
        // only read `payload.len()`), so this costs address space, not
        // >1 GiB of resident memory.
        let msg = Message::PullResp {
            key: 0,
            iter: 0,
            served_with: 1,
            data: Compressed { scheme: SchemeId::Identity, n: n / 4, payload: vec![0u8; n] },
        };
        let err = encode(&msg).unwrap_err();
        assert!(
            matches!(err, CommError::Protocol(ref m) if m.contains("too large")),
            "got {err:?}"
        );
        // check_len agrees without allocating anything.
        assert!(check_len(&msg).is_err());
        // Just-under-cap messages still size correctly (frame_bytes is
        // allocation-free either way).
        assert_eq!(frame_bytes(&msg), 4 + 1 + 8 + 8 + 2 + 1 + 8 + 4 + n);
    }

    /// A hostile Welcome claiming billions of plan entries must fail fast
    /// on the length check, not attempt the allocation.
    #[test]
    fn welcome_with_inflated_count_rejected() {
        let msg = Message::Welcome {
            n_workers: 2,
            shard: 0,
            seed: 1,
            k_min_ppm: 500,
            k_max_ppm: 50_000,
            plan: vec![(5, 1), (9, 0)],
        };
        let mut body = encode_body(&msg).unwrap();
        // count field sits after tag(1) + n_workers(4) + shard(4) + seed(8)
        // + k_min_ppm(4) + k_max_ppm(4).
        let count_at = 1 + 4 + 4 + 8 + 4 + 4;
        body[count_at..count_at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = decode_body(&body).unwrap_err();
        assert!(matches!(err, CommError::Protocol(_)), "got {err:?}");
    }

    /// One representative message per tag, each with a data block where the
    /// format carries one.
    fn one_of_each_tag() -> Vec<Message> {
        let block = Compressed {
            scheme: SchemeId::TopK,
            n: 8,
            payload: {
                let mut p = Vec::new();
                p.extend_from_slice(&2u32.to_le_bytes());
                p.extend_from_slice(&1u32.to_le_bytes());
                p.extend_from_slice(&5u32.to_le_bytes());
                p.extend_from_slice(&1.5f32.to_le_bytes());
                p.extend_from_slice(&(-2.5f32).to_le_bytes());
                p
            },
        };
        vec![
            Message::Push { key: 0x0000_0A00_0000_0003, iter: 7, worker: 2, data: block.clone() },
            Message::GroupPush {
                key: 0x0000_0A00_0000_0003,
                iter: 7,
                worker: 1,
                members: 2,
                data: block.clone(),
            },
            Message::Pull { key: 11, iter: 7, worker: 2 },
            Message::PullResp { key: 11, iter: 7, served_with: 3, data: block },
            Message::Ack { key: 11, iter: 7 },
            Message::Hello { worker: 2, n_keys: 9, config: 0xABCD, k_min_ppm: 500, k_max_ppm: 50_000 },
            Message::Welcome {
                n_workers: 3,
                shard: 1,
                seed: 42,
                k_min_ppm: 1000,
                k_max_ppm: 40_000,
                plan: vec![(11, 0), (12, 1)],
            },
            Message::Shutdown,
        ]
    }

    /// Every proper prefix of every message body must fail to decode —
    /// truncation at any field boundary (and inside any field) is an error,
    /// never a silently shorter message.
    #[test]
    fn every_truncation_of_every_tag_is_rejected() {
        for msg in one_of_each_tag() {
            let body = encode_body(&msg).unwrap();
            // Sanity: the full body decodes back.
            assert_eq!(decode_body(&body).unwrap(), msg);
            for cut in 0..body.len() {
                assert!(
                    decode_body(&body[..cut]).is_err(),
                    "truncation to {cut}/{} bytes of {msg:?} decoded",
                    body.len()
                );
            }
        }
    }

    /// Appending trailing garbage to any message is rejected too.
    #[test]
    fn trailing_bytes_rejected_for_every_tag() {
        for msg in one_of_each_tag() {
            let mut body = encode_body(&msg).unwrap();
            body.push(0);
            assert!(decode_body(&body).is_err(), "{msg:?} accepted trailing byte");
        }
    }

    /// Corrupt block payloads inside Push/PullResp are rejected at decode
    /// (the server-crash class: out-of-range top-k indices, bad k).
    #[test]
    fn corrupt_block_payload_rejected_at_decode() {
        let msgs = one_of_each_tag();
        // msgs[0] is the Push with a 2-entry top-k block on n = 8.
        let body = encode_body(&msgs[0]).unwrap();
        // Body layout: tag(1) key(8) iter(8) worker(4) scheme(1) n(8) plen(4) payload.
        let payload_at = 1 + 8 + 8 + 4 + 1 + 8 + 4;
        // First index (little-endian u32 after the k header) -> 0xFFFF_FFFF.
        let mut bad = body.clone();
        for b in &mut bad[payload_at + 4..payload_at + 8] {
            *b = 0xFF;
        }
        let err = decode_body(&bad).unwrap_err();
        assert!(matches!(err, CommError::Protocol(_)), "got {err:?}");
        // k header inflated beyond n.
        let mut bad = body.clone();
        bad[payload_at] = 200;
        assert!(decode_body(&bad).is_err());
        // Declared payload length larger than the remaining bytes.
        let mut bad = body;
        let plen_at = 1 + 8 + 8 + 4 + 1 + 8;
        bad[plen_at] = 0xFF;
        assert!(decode_body(&bad).is_err());
    }

    /// The split (vectored-send) encoding must be byte-identical to the
    /// plain encoding once the payload is appended, for every tag — and
    /// report the split flag exactly for the block-carrying messages.
    #[test]
    fn split_encoding_matches_full_encoding() {
        for msg in one_of_each_tag() {
            let full = encode(&msg).unwrap();
            let mut head = Vec::new();
            let split = encode_split_into(&msg, &mut head).unwrap();
            let payload: &[u8] = match &msg {
                Message::Push { data, .. }
                | Message::GroupPush { data, .. }
                | Message::PullResp { data, .. } => {
                    assert!(split, "{msg:?} should split");
                    &data.payload
                }
                _ => {
                    assert!(!split, "{msg:?} should not split");
                    &[]
                }
            };
            let mut rejoined = head;
            rejoined.extend_from_slice(payload);
            assert_eq!(rejoined, full, "{msg:?}");
        }
    }

    /// Corrupt group-push frames: per-field byte corruption of the block
    /// header and payload must surface as protocol errors, never a panic
    /// (same sweep the flat Push gets above, shifted by the `members`
    /// field).
    #[test]
    fn corrupt_group_push_rejected_at_decode() {
        let msgs = one_of_each_tag();
        // msgs[1] is the GroupPush with a 2-entry top-k block on n = 8.
        let Message::GroupPush { .. } = &msgs[1] else { panic!("tag order changed") };
        let body = encode_body(&msgs[1]).unwrap();
        assert!(decode_body(&body).is_ok());
        // Body layout: tag(1) key(8) iter(8) worker(4) members(2)
        //              scheme(1) n(8) plen(4) payload.
        let payload_at = 1 + 8 + 8 + 4 + 2 + 1 + 8 + 4;
        // First top-k index -> out of range.
        let mut bad = body.clone();
        for b in &mut bad[payload_at + 4..payload_at + 8] {
            *b = 0xFF;
        }
        assert!(matches!(decode_body(&bad).unwrap_err(), CommError::Protocol(_)));
        // k header inflated beyond n.
        let mut bad = body.clone();
        bad[payload_at] = 200;
        assert!(decode_body(&bad).is_err());
        // Bad scheme id.
        let mut bad = body.clone();
        bad[1 + 8 + 8 + 4 + 2] = 0xEE;
        assert!(decode_body(&bad).is_err());
        // Declared payload length larger than the remaining bytes.
        let mut bad = body;
        let plen_at = 1 + 8 + 8 + 4 + 2 + 1 + 8;
        bad[plen_at] = 0xFF;
        assert!(decode_body(&bad).is_err());
    }

    #[test]
    fn key_sub_key_survives_the_wire() {
        use crate::comm::BlockKey;
        let key = BlockKey::new(123, 45).pack();
        let msg = Message::Ack { key, iter: 0 };
        let enc = encode_body(&msg).unwrap();
        let Message::Ack { key: k, .. } = decode_body(&enc).unwrap() else { panic!() };
        assert_eq!(BlockKey::unpack(k), BlockKey::new(123, 45));
    }
}
