//! Binary wire format for [`Message`] — length-prefixed frames with a
//! fixed header, used verbatim by the TCP transport and for byte
//! accounting by the in-process transport.
//!
//! ```text
//! frame := [len: u32le] [tag: u8] body
//! Push      body := [key u64][iter u64][worker u32][block]
//! Pull      body := [key u64][iter u64][worker u32]
//! PullResp  body := [key u64][iter u64][block]
//! Ack       body := [key u64][iter u64]
//! Shutdown  body := (empty)
//! block := [scheme u8][n u64][payload_len u32][payload …]
//! ```

use super::{CommError, Message};
use crate::compress::{Compressed, SchemeId};

const TAG_PUSH: u8 = 1;
const TAG_PULL: u8 = 2;
const TAG_PULL_RESP: u8 = 3;
const TAG_ACK: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;

fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn u8(&mut self) -> Result<u8, CommError> {
        let v = *self.buf.get(self.pos).ok_or_else(|| CommError::Protocol("truncated".into()))?;
        self.pos += 1;
        Ok(v)
    }

    fn u32(&mut self) -> Result<u32, CommError> {
        let end = self.pos + 4;
        let s = self
            .buf
            .get(self.pos..end)
            .ok_or_else(|| CommError::Protocol("truncated u32".into()))?;
        self.pos = end;
        Ok(u32::from_le_bytes(s.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, CommError> {
        let end = self.pos + 8;
        let s = self
            .buf
            .get(self.pos..end)
            .ok_or_else(|| CommError::Protocol("truncated u64".into()))?;
        self.pos = end;
        Ok(u64::from_le_bytes(s.try_into().unwrap()))
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8], CommError> {
        let end = self.pos + n;
        let s = self
            .buf
            .get(self.pos..end)
            .ok_or_else(|| CommError::Protocol("truncated payload".into()))?;
        self.pos = end;
        Ok(s)
    }
}

fn put_block(b: &mut Vec<u8>, c: &Compressed) {
    b.push(c.scheme as u8);
    put_u64(b, c.n as u64);
    put_u32(b, c.payload.len() as u32);
    b.extend_from_slice(&c.payload);
}

fn get_block(r: &mut Reader) -> Result<Compressed, CommError> {
    let scheme = SchemeId::from_u8(r.u8()?)
        .ok_or_else(|| CommError::Protocol("bad scheme id".into()))?;
    let n = r.u64()? as usize;
    let plen = r.u32()? as usize;
    let payload = r.bytes(plen)?.to_vec();
    Ok(Compressed { scheme, n, payload })
}

/// Encode a message body (without the length prefix).
pub fn encode_body(msg: &Message) -> Vec<u8> {
    let mut b = Vec::with_capacity(32 + msg.payload_bytes());
    match msg {
        Message::Push { key, iter, worker, data } => {
            b.push(TAG_PUSH);
            put_u64(&mut b, *key);
            put_u64(&mut b, *iter);
            put_u32(&mut b, *worker);
            put_block(&mut b, data);
        }
        Message::Pull { key, iter, worker } => {
            b.push(TAG_PULL);
            put_u64(&mut b, *key);
            put_u64(&mut b, *iter);
            put_u32(&mut b, *worker);
        }
        Message::PullResp { key, iter, data } => {
            b.push(TAG_PULL_RESP);
            put_u64(&mut b, *key);
            put_u64(&mut b, *iter);
            put_block(&mut b, data);
        }
        Message::Ack { key, iter } => {
            b.push(TAG_ACK);
            put_u64(&mut b, *key);
            put_u64(&mut b, *iter);
        }
        Message::Shutdown => b.push(TAG_SHUTDOWN),
    }
    b
}

/// Encode a full frame (length prefix + body).
pub fn encode(msg: &Message) -> Vec<u8> {
    let body = encode_body(msg);
    let mut out = Vec::with_capacity(4 + body.len());
    put_u32(&mut out, body.len() as u32);
    out.extend_from_slice(&body);
    out
}

/// Decode a message body (frame already stripped of its length prefix).
pub fn decode_body(buf: &[u8]) -> Result<Message, CommError> {
    let mut r = Reader { buf, pos: 0 };
    let tag = r.u8()?;
    let msg = match tag {
        TAG_PUSH => Message::Push {
            key: r.u64()?,
            iter: r.u64()?,
            worker: r.u32()?,
            data: get_block(&mut r)?,
        },
        TAG_PULL => Message::Pull { key: r.u64()?, iter: r.u64()?, worker: r.u32()? },
        TAG_PULL_RESP => Message::PullResp { key: r.u64()?, iter: r.u64()?, data: get_block(&mut r)? },
        TAG_ACK => Message::Ack { key: r.u64()?, iter: r.u64()? },
        TAG_SHUTDOWN => Message::Shutdown,
        t => return Err(CommError::Protocol(format!("unknown tag {t}"))),
    };
    if r.pos != buf.len() {
        return Err(CommError::Protocol(format!("{} trailing bytes", buf.len() - r.pos)));
    }
    Ok(msg)
}

/// Wire size of a message, including the 4-byte length prefix.
pub fn frame_bytes(msg: &Message) -> usize {
    4 + encode_body(msg).len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;

    fn sample_block(g: &mut crate::testutil::Gen) -> Compressed {
        let scheme = *g.choose(&[
            SchemeId::Identity,
            SchemeId::Fp16,
            SchemeId::OneBit,
            SchemeId::TopK,
            SchemeId::RandomK,
            SchemeId::LinearDither,
            SchemeId::NaturalDither,
        ]);
        let plen = g.usize_in(0, 64);
        let payload = (0..plen).map(|_| (g.u64() & 0xFF) as u8).collect();
        Compressed { scheme, n: g.usize_in(0, 1000), payload }
    }

    #[test]
    fn roundtrip_all_message_kinds() {
        forall(200, 0xf4a3e, |g| {
            let msg = match g.usize_in(0, 4) {
                0 => Message::Push {
                    key: g.u64(),
                    iter: g.u64(),
                    worker: (g.u64() & 0xFFFF) as u32,
                    data: sample_block(g),
                },
                1 => Message::Pull { key: g.u64(), iter: g.u64(), worker: 3 },
                2 => Message::PullResp { key: g.u64(), iter: g.u64(), data: sample_block(g) },
                3 => Message::Ack { key: g.u64(), iter: g.u64() },
                _ => Message::Shutdown,
            };
            let enc = encode(&msg);
            let len = u32::from_le_bytes(enc[..4].try_into().unwrap()) as usize;
            if len != enc.len() - 4 {
                return Err("length prefix wrong".into());
            }
            let dec = decode_body(&enc[4..]).map_err(|e| e.to_string())?;
            if dec != msg {
                return Err(format!("roundtrip mismatch: {msg:?} vs {dec:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn rejects_malformed() {
        assert!(decode_body(&[]).is_err());
        assert!(decode_body(&[99]).is_err());
        assert!(decode_body(&[TAG_ACK, 1, 2]).is_err()); // truncated
        // trailing garbage
        let mut enc = encode_body(&Message::Shutdown);
        enc.push(0);
        assert!(decode_body(&enc).is_err());
        // bad scheme id inside a block
        let msg = Message::PullResp {
            key: 1,
            iter: 1,
            data: Compressed { scheme: SchemeId::TopK, n: 4, payload: vec![1, 2, 3] },
        };
        let mut enc = encode_body(&msg);
        enc[17] = 0xEE; // scheme byte (1 tag + 8 key + 8 iter)
        assert!(decode_body(&enc).is_err());
    }

    #[test]
    fn frame_bytes_matches_encoding() {
        let msg = Message::Ack { key: 7, iter: 9 };
        assert_eq!(frame_bytes(&msg), encode(&msg).len());
    }
}
