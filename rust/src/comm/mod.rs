//! Communication substrate: wire framing for compressed gradient blocks,
//! the push/pull RPC message set, and two interchangeable transports
//! (in-process channels and TCP over localhost).
//!
//! The paper's system uses BytePS's ZeroMQ/RDMA stack; here the same
//! message flow runs over [`inproc`] for single-process experiments and
//! [`tcp`] for true multi-process runs. The byte counters the benchmarks
//! report come from this layer, so wire volume is measured, not assumed.

pub mod frame;
pub mod inproc;
pub mod pool;
pub mod tcp;

pub use pool::BufPool;

use crate::compress::Compressed;

/// Key identifying one gradient *block* in the PS keyspace.
///
/// Since the §4.2.1 pipeline, a key is a packed [`BlockKey`]: the low
/// [`BLOCK_SHIFT`] bits carry the tensor id and the high bits the block
/// index within that tensor. Whole-tensor keys are simply block 0, so a
/// plain tensor id is a valid `Key` unchanged (`pack(t, 0) == t`).
pub type Key = u64;

/// Bit position where the block-index sub-key starts inside a [`Key`].
pub const BLOCK_SHIFT: u32 = 40;

/// Maximum number of blocks a single tensor may be partitioned into.
pub const MAX_BLOCKS_PER_TENSOR: u64 = 1 << (64 - BLOCK_SHIFT);

/// Structured form of a wire [`Key`]: `(tensor id, block index)`.
///
/// The pipeline (worker::pipeline, §4.2.1/§4.2.3) partitions large tensors
/// into fixed-size blocks and gives each block its own key so that blocks
/// ship, aggregate, and re-compress independently — including on different
/// server shards (§4.2.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BlockKey {
    /// Tensor id (the pre-pipeline key), < 2^40.
    pub tensor: u64,
    /// Block index within the tensor's partition.
    pub block: u32,
}

impl BlockKey {
    pub fn new(tensor: u64, block: u32) -> BlockKey {
        assert!(tensor < 1 << BLOCK_SHIFT, "tensor id {tensor} exceeds {BLOCK_SHIFT} bits");
        assert!(u64::from(block) < MAX_BLOCKS_PER_TENSOR, "block index {block} too large");
        BlockKey { tensor, block }
    }

    /// Pack into the wire key. Block 0 packs to the bare tensor id.
    pub fn pack(self) -> Key {
        u64::from(self.block) << BLOCK_SHIFT | self.tensor
    }

    /// Recover the structured key from a wire key.
    pub fn unpack(key: Key) -> BlockKey {
        // lint: allow(cast: u64 -> u32, trunc) — after the 40-bit shift only 24 bits remain, always < 2^32
        BlockKey { tensor: key & ((1u64 << BLOCK_SHIFT) - 1), block: (key >> BLOCK_SHIFT) as u32 }
    }
}

/// A push/pull RPC message. `iter` tags the training step so servers can
/// detect stragglers/duplicates (BSP semantics: one push per worker per
/// key per iteration).
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Worker → server: compressed gradient for `key` at step `iter`.
    Push { key: Key, iter: u64, worker: u32, data: Compressed },
    /// Group leader → server: a *combined* compressed push carrying the
    /// locally-reduced gradient **sum** (not average) of `members` workers
    /// (the leader itself included) in the hierarchical two-level
    /// topology. The server weighs this contribution `members`-fold when
    /// deciding round completion and the averaging divisor, so a round of
    /// G group pushes averages exactly like W flat pushes. `worker` is
    /// the *group* index (the leader's registered rank in the server's
    /// G-wide fan-in). A hostile `members` claim is clamped to the
    /// round's remaining capacity at ingress and counted
    /// (`ServerStats.members_clamped`), never trusted.
    GroupPush { key: Key, iter: u64, worker: u32, members: u16, data: Compressed },
    /// Worker → server: request the aggregated gradient once ready.
    Pull { key: Key, iter: u64, worker: u32 },
    /// Server → worker: aggregated (re-compressed) gradient. `served_with`
    /// is the number of worker contributions in the aggregate: equal to
    /// the run's worker count for a full BSP round, smaller when the
    /// server's iteration deadline completed the round *degraded* (a push
    /// was lost or rejected and the deadline elapsed). Workers use it to
    /// tell a degraded round from a full one — the lost contribution
    /// becomes an observable, counted event instead of a silent one —
    /// without a separate NACK message (see DESIGN.md §Cluster mode for
    /// the precise convergence semantics).
    PullResp { key: Key, iter: u64, served_with: u16, data: Compressed },
    /// Server → worker: push acknowledged.
    Ack { key: Key, iter: u64 },
    /// Worker → server: cluster-mode registration, the first frame on a
    /// fresh connection. `n_keys` is the worker's partition size and
    /// `config` a fingerprint of everything both sides must agree on
    /// (scheme/param/sync/fusion/threshold/pipeline/adaptive-enable — see
    /// `cluster::config_fingerprint`), so a mismatched launch config is
    /// rejected at registration instead of silently corrupting training.
    /// `k_min_ppm`/`k_max_ppm` are the keep-ratio bounds the worker's
    /// adaptive controller *requests*, in parts-per-million of elements
    /// kept; `(0, 0)` is the static sentinel (controller off). A request
    /// with `k_min_ppm > k_max_ppm` or a lone zero is malformed and
    /// rejected at registration.
    Hello { worker: u32, n_keys: u64, config: u64, k_min_ppm: u32, k_max_ppm: u32 },
    /// Server → worker: handshake reply. The worker adopts `seed` and the
    /// shard `plan` (`(key, server index)` pairs) from the server instead
    /// of assuming co-located construction; `shard` is the responding
    /// server's own index so the worker can verify its `--servers`
    /// ordering matches the plan. `k_min_ppm`/`k_max_ppm` are the
    /// **granted** adaptive bounds: the worker's requested pair clamped
    /// into the server's configured envelope (`(0, 0)` = static run). The
    /// worker's controller must stay inside them — the server's ingress
    /// counts any per-block `k` outside the granted envelope as
    /// `bounds_rejected` and drops the push.
    Welcome {
        n_workers: u32,
        shard: u32,
        seed: u64,
        k_min_ppm: u32,
        k_max_ppm: u32,
        plan: Vec<(Key, u32)>,
    },
    /// Graceful shutdown.
    Shutdown,
}

impl Message {
    /// Payload bytes this message contributes to wire traffic (headers are
    /// accounted by the frame encoder).
    pub fn payload_bytes(&self) -> usize {
        match self {
            Message::Push { data, .. }
            | Message::GroupPush { data, .. }
            | Message::PullResp { data, .. } => data.nbytes(),
            _ => 0,
        }
    }
}

/// A bidirectional, message-oriented channel endpoint.
///
/// `Sync` is required: the push/pull pipeline sends from many compression
/// jobs concurrently through one shared endpoint (both transports take
/// `&self` and lock internally).
pub trait Endpoint: Send + Sync {
    fn send(&self, msg: Message) -> Result<(), CommError>;
    fn recv(&self) -> Result<Message, CommError>;
    /// Non-blocking receive.
    fn try_recv(&self) -> Result<Option<Message>, CommError>;
    /// Total bytes sent through this endpoint (frame-encoded size).
    fn bytes_sent(&self) -> u64;
}

/// Boxed endpoints are endpoints too, so meshes can mix transports
/// (`engine::EndpointMesh` rows are `Vec<Box<dyn Endpoint>>` and feed
/// `Server::spawn` / `WorkerComm` unchanged).
impl Endpoint for Box<dyn Endpoint> {
    fn send(&self, msg: Message) -> Result<(), CommError> {
        (**self).send(msg)
    }

    fn recv(&self) -> Result<Message, CommError> {
        (**self).recv()
    }

    fn try_recv(&self) -> Result<Option<Message>, CommError> {
        (**self).try_recv()
    }

    fn bytes_sent(&self) -> u64 {
        (**self).bytes_sent()
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CommError {
    Closed,
    Protocol(String),
    Io(String),
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::Closed => write!(f, "channel closed"),
            CommError::Protocol(s) => write!(f, "protocol error: {s}"),
            CommError::Io(s) => write!(f, "io error: {s}"),
        }
    }
}

impl std::error::Error for CommError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::SchemeId;

    #[test]
    fn block_key_roundtrip_and_tensor_compat() {
        // Block 0 packs to the bare tensor id (pre-pipeline keys unchanged).
        assert_eq!(BlockKey::new(17, 0).pack(), 17);
        assert_eq!(BlockKey::unpack(17), BlockKey { tensor: 17, block: 0 });
        // Roundtrip across the sub-key boundary.
        for (t, b) in [(0u64, 0u32), (1, 1), (12345, 7), ((1 << 40) - 1, 1_000_000)] {
            let k = BlockKey::new(t, b).pack();
            assert_eq!(BlockKey::unpack(k), BlockKey { tensor: t, block: b });
        }
        // Distinct blocks of the same tensor get distinct keys.
        assert_ne!(BlockKey::new(3, 0).pack(), BlockKey::new(3, 1).pack());
        // Distinct tensors never collide even at high block indices.
        assert_ne!(BlockKey::new(0, 1).pack(), BlockKey::new(1, 1).pack());
    }

    #[test]
    #[should_panic(expected = "exceeds")]
    fn block_key_rejects_oversized_tensor_id() {
        let _ = BlockKey::new(1 << BLOCK_SHIFT, 0);
    }

    #[test]
    fn payload_bytes_only_for_data_messages() {
        let data = Compressed { scheme: SchemeId::Identity, n: 2, payload: vec![0u8; 8] };
        assert_eq!(Message::Push { key: 1, iter: 0, worker: 0, data: data.clone() }.payload_bytes(), 8);
        assert_eq!(
            Message::PullResp { key: 1, iter: 0, served_with: 2, data }.payload_bytes(),
            8
        );
        assert_eq!(Message::Pull { key: 1, iter: 0, worker: 0 }.payload_bytes(), 0);
        assert_eq!(Message::Ack { key: 1, iter: 0 }.payload_bytes(), 0);
        assert_eq!(Message::Shutdown.payload_bytes(), 0);
    }
}
