//! Communication substrate: wire framing for compressed gradient blocks,
//! the push/pull RPC message set, and two interchangeable transports
//! (in-process channels and TCP over localhost).
//!
//! The paper's system uses BytePS's ZeroMQ/RDMA stack; here the same
//! message flow runs over [`inproc`] for single-process experiments and
//! [`tcp`] for true multi-process runs. The byte counters the benchmarks
//! report come from this layer, so wire volume is measured, not assumed.

pub mod frame;
pub mod inproc;
pub mod tcp;

use crate::compress::Compressed;

/// Key identifying one gradient tensor (block) in the PS keyspace.
pub type Key = u64;

/// A push/pull RPC message. `iter` tags the training step so servers can
/// detect stragglers/duplicates (BSP semantics: one push per worker per
/// key per iteration).
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Worker → server: compressed gradient for `key` at step `iter`.
    Push { key: Key, iter: u64, worker: u32, data: Compressed },
    /// Worker → server: request the aggregated gradient once ready.
    Pull { key: Key, iter: u64, worker: u32 },
    /// Server → worker: aggregated (re-compressed) gradient.
    PullResp { key: Key, iter: u64, data: Compressed },
    /// Server → worker: push acknowledged.
    Ack { key: Key, iter: u64 },
    /// Graceful shutdown.
    Shutdown,
}

impl Message {
    /// Payload bytes this message contributes to wire traffic (headers are
    /// accounted by the frame encoder).
    pub fn payload_bytes(&self) -> usize {
        match self {
            Message::Push { data, .. } | Message::PullResp { data, .. } => data.nbytes(),
            _ => 0,
        }
    }
}

/// A bidirectional, message-oriented channel endpoint.
pub trait Endpoint: Send {
    fn send(&self, msg: Message) -> Result<(), CommError>;
    fn recv(&self) -> Result<Message, CommError>;
    /// Non-blocking receive.
    fn try_recv(&self) -> Result<Option<Message>, CommError>;
    /// Total bytes sent through this endpoint (frame-encoded size).
    fn bytes_sent(&self) -> u64;
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CommError {
    Closed,
    Protocol(String),
    Io(String),
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::Closed => write!(f, "channel closed"),
            CommError::Protocol(s) => write!(f, "protocol error: {s}"),
            CommError::Io(s) => write!(f, "io error: {s}"),
        }
    }
}

impl std::error::Error for CommError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::SchemeId;

    #[test]
    fn payload_bytes_only_for_data_messages() {
        let data = Compressed { scheme: SchemeId::Identity, n: 2, payload: vec![0u8; 8] };
        assert_eq!(Message::Push { key: 1, iter: 0, worker: 0, data: data.clone() }.payload_bytes(), 8);
        assert_eq!(Message::PullResp { key: 1, iter: 0, data }.payload_bytes(), 8);
        assert_eq!(Message::Pull { key: 1, iter: 0, worker: 0 }.payload_bytes(), 0);
        assert_eq!(Message::Ack { key: 1, iter: 0 }.payload_bytes(), 0);
        assert_eq!(Message::Shutdown.payload_bytes(), 0);
    }
}
