//! In-process transport: mpsc-backed endpoint pairs with frame-accurate
//! byte accounting. This is the default transport for experiments — it
//! exercises the full PS/worker protocol without socket overhead, which is
//! what the Table-6 ablation needs (compression cost, not kernel cost).
// Wire-facing module: the static-invariants lint (rust/src/lint) keeps
// this file panic-free outside tests, and clippy enforces the same at
// the `unwrap`/`expect` level.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use super::{CommError, Endpoint, Message};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};

pub struct InprocEndpoint {
    tx: Sender<Message>,
    inbox: Mutex<Receiver<Message>>,
    sent: Arc<AtomicU64>,
}

impl InprocEndpoint {
    /// Lock the receiver, recovering from mutex poisoning: a `Receiver`
    /// holds no invariants a panicking holder could half-update, so the
    /// poison flag carries no information — and propagating the panic
    /// would cascade one worker thread's failure into every thread
    /// sharing the endpoint. Same policy as `comm::BufPool`.
    fn inbox(&self) -> std::sync::MutexGuard<'_, Receiver<Message>> {
        self.inbox.lock().unwrap_or_else(|p| p.into_inner())
    }
}

impl Endpoint for InprocEndpoint {
    fn send(&self, msg: Message) -> Result<(), CommError> {
        // Same frame cap as the TCP transport, so a tensor that would be
        // unsendable over sockets fails identically in-process.
        let body = super::frame::check_len(&msg)?;
        // lint: allow(cast: usize -> u64) — widening on every supported (64-bit) target
        self.sent.fetch_add(4 + body as u64, Ordering::Relaxed);
        self.tx.send(msg).map_err(|_| CommError::Closed)
    }

    fn recv(&self) -> Result<Message, CommError> {
        // lint: allow(block) — the inbox mutex only makes the Receiver shareable; recv() blocking on an empty channel is this method's contract
        self.inbox().recv().map_err(|_| CommError::Closed)
    }

    fn try_recv(&self) -> Result<Option<Message>, CommError> {
        match self.inbox().try_recv() {
            Ok(m) => Ok(Some(m)),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => Err(CommError::Closed),
        }
    }

    fn bytes_sent(&self) -> u64 {
        self.sent.load(Ordering::Relaxed)
    }
}

/// A connected pair of endpoints (worker side, server side).
pub fn pair() -> (InprocEndpoint, InprocEndpoint) {
    let (atx, arx) = channel();
    let (btx, brx) = channel();
    (
        InprocEndpoint { tx: atx, inbox: Mutex::new(brx), sent: Arc::new(AtomicU64::new(0)) },
        InprocEndpoint { tx: btx, inbox: Mutex::new(arx), sent: Arc::new(AtomicU64::new(0)) },
    )
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::comm::frame;
    use crate::compress::{Compressed, SchemeId};

    #[test]
    fn pair_is_bidirectional() {
        let (a, b) = pair();
        a.send(Message::Ack { key: 1, iter: 2 }).unwrap();
        assert_eq!(b.recv().unwrap(), Message::Ack { key: 1, iter: 2 });
        b.send(Message::Shutdown).unwrap();
        assert_eq!(a.recv().unwrap(), Message::Shutdown);
    }

    #[test]
    fn try_recv_nonblocking() {
        let (a, b) = pair();
        assert_eq!(b.try_recv().unwrap(), None);
        a.send(Message::Ack { key: 0, iter: 0 }).unwrap();
        assert!(b.try_recv().unwrap().is_some());
        assert_eq!(b.try_recv().unwrap(), None);
    }

    #[test]
    fn byte_accounting_matches_frames() {
        let (a, b) = pair();
        let m1 = Message::Push {
            key: 1,
            iter: 0,
            worker: 0,
            data: Compressed { scheme: SchemeId::OneBit, n: 80, payload: vec![0u8; 14] },
        };
        let m2 = Message::Pull { key: 1, iter: 0, worker: 0 };
        let expect = (frame::frame_bytes(&m1) + frame::frame_bytes(&m2)) as u64;
        a.send(m1).unwrap();
        a.send(m2).unwrap();
        assert_eq!(a.bytes_sent(), expect);
        let _ = b.recv().unwrap();
        let _ = b.recv().unwrap();
    }

    #[test]
    fn closed_peer_is_an_error() {
        let (a, b) = pair();
        drop(b);
        assert_eq!(a.send(Message::Shutdown), Err(CommError::Closed));
        assert_eq!(a.recv(), Err(CommError::Closed));
    }

    /// The block pipeline sends from many compression jobs concurrently
    /// through one shared endpoint — this test also pins the `Sync`
    /// property of `InprocEndpoint` at compile time.
    #[test]
    fn concurrent_senders_on_one_endpoint() {
        let (a, b) = pair();
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let a = &a;
                s.spawn(move || {
                    for i in 0..50u64 {
                        a.send(Message::Ack { key: t, iter: i }).unwrap();
                    }
                });
            }
        });
        let mut counts = [0usize; 4];
        for _ in 0..200 {
            match b.recv().unwrap() {
                Message::Ack { key, .. } => counts[key as usize] += 1,
                m => panic!("unexpected {m:?}"),
            }
        }
        assert!(counts.iter().all(|&c| c == 50), "{counts:?}");
        assert_eq!(b.try_recv().unwrap(), None);
    }

    #[test]
    fn works_across_threads() {
        let (a, b) = pair();
        let t = std::thread::spawn(move || {
            for i in 0..100u64 {
                a.send(Message::Ack { key: i, iter: i }).unwrap();
            }
        });
        for i in 0..100u64 {
            assert_eq!(b.recv().unwrap(), Message::Ack { key: i, iter: i });
        }
        t.join().unwrap();
    }
}
