//! Bounded global buffer pool for the hot wire paths.
//!
//! The steady-state TCP loop used to allocate per frame: `vec![0u8; len]`
//! for every received body, a fresh `Vec<u8>` for every encoded frame, a
//! fresh `Vec<u8>` payload for every decoded block, and `vec![0.0f32; n]`
//! for every decode/reduce scratch. [`BufPool`] recycles all four:
//! transports and the staged server *rent* buffers here and *give* them
//! back when the data they carry dies (see DESIGN.md §Buffer pool for the
//! ownership rules).
//!
//! Recycling is cooperative, not tracked: a buffer that is never given back
//! is simply dropped by its owner and the pool refills from future gives —
//! a panicking job can never wedge the pool, it only costs one buffer
//! (panic safety). The pool is bounded both in buffer count and per-buffer
//! capacity so a burst or one oversized frame cannot pin memory forever.
// Wire-facing module: the static-invariants lint (rust/src/lint) keeps
// this file panic-free outside tests, and clippy enforces the same at
// the `unwrap`/`expect` level.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::sync::{Mutex, MutexGuard};

/// Maximum buffers retained per element type. Sized for the worst
/// steady-state concurrency in-tree (shards × pipeline depth × in-flight
/// windows); beyond it, `give_*` simply drops.
const MAX_POOLED: usize = 64;

/// Buffers with a larger capacity than this are dropped on `give_*` instead
/// of retained, so one giant frame cannot pin its allocation forever.
const MAX_RETAINED_CAP: usize = 64 << 20;

/// A bounded LIFO pool of `Vec<u8>` / `Vec<f32>` buffers.
pub struct BufPool {
    bytes: Mutex<Vec<Vec<u8>>>,
    f32s: Mutex<Vec<Vec<f32>>>,
}

impl BufPool {
    pub const fn new() -> BufPool {
        BufPool { bytes: Mutex::new(Vec::new()), f32s: Mutex::new(Vec::new()) }
    }

    /// The process-wide pool used by the TCP transport, the staged server,
    /// and the worker pipeline.
    pub fn global() -> &'static BufPool {
        static GLOBAL: BufPool = BufPool::new();
        &GLOBAL
    }

    // A poisoned mutex only means some thread panicked mid-push/pop; the
    // Vec-of-Vecs is still structurally valid, so keep serving.
    fn bytes_guard(&self) -> MutexGuard<'_, Vec<Vec<u8>>> {
        self.bytes.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn f32s_guard(&self) -> MutexGuard<'_, Vec<Vec<f32>>> {
        self.f32s.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Rent a zero-filled byte buffer of exactly `len` elements.
    pub fn rent_bytes(&self, len: usize) -> Vec<u8> {
        let mut v = self.bytes_guard().pop().unwrap_or_default();
        v.clear();
        v.resize(len, 0);
        v
    }

    /// Rent an empty byte buffer (for appenders like `frame::encode_into`).
    pub fn rent_bytes_empty(&self) -> Vec<u8> {
        let mut v = self.bytes_guard().pop().unwrap_or_default();
        v.clear();
        v
    }

    /// Return a byte buffer to the pool (bounded; excess is dropped).
    pub fn give_bytes(&self, v: Vec<u8>) {
        if v.capacity() == 0 || v.capacity() > MAX_RETAINED_CAP {
            return;
        }
        let mut g = self.bytes_guard();
        if g.len() < MAX_POOLED {
            g.push(v);
        }
    }

    /// Rent a zero-filled f32 buffer of exactly `n` elements.
    pub fn rent_f32(&self, n: usize) -> Vec<f32> {
        let mut v = self.f32s_guard().pop().unwrap_or_default();
        v.clear();
        v.resize(n, 0.0);
        v
    }

    /// Rent an f32 buffer initialized as a copy of `src` (the worker
    /// pipeline's per-block gradient staging copy).
    pub fn rent_f32_copy(&self, src: &[f32]) -> Vec<f32> {
        let mut v = self.f32s_guard().pop().unwrap_or_default();
        v.clear();
        v.extend_from_slice(src);
        v
    }

    /// Return an f32 buffer to the pool (bounded; excess is dropped).
    pub fn give_f32(&self, v: Vec<f32>) {
        if v.capacity() == 0 || v.capacity() * 4 > MAX_RETAINED_CAP {
            return;
        }
        let mut g = self.f32s_guard();
        if g.len() < MAX_POOLED {
            g.push(v);
        }
    }

    /// Buffers currently pooled, `(bytes, f32s)` — diagnostics/tests.
    pub fn pooled(&self) -> (usize, usize) {
        (self.bytes_guard().len(), self.f32s_guard().len())
    }
}

impl Default for BufPool {
    fn default() -> Self {
        BufPool::new()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn rent_reuses_returned_buffers() {
        let pool = BufPool::new();
        let mut a = pool.rent_bytes(100);
        a[0] = 7;
        let cap = a.capacity();
        pool.give_bytes(a);
        assert_eq!(pool.pooled().0, 1);
        let b = pool.rent_bytes(50);
        assert_eq!(b.len(), 50);
        assert!(b.capacity() >= cap.min(50));
        assert!(b.iter().all(|&x| x == 0), "rented buffer must be zeroed");
        assert_eq!(pool.pooled().0, 0);
    }

    #[test]
    fn f32_rents_are_zeroed_to_len() {
        let pool = BufPool::new();
        let mut a = pool.rent_f32(8);
        a.fill(3.5);
        pool.give_f32(a);
        let b = pool.rent_f32(16);
        assert_eq!(b.len(), 16);
        assert!(b.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn pool_is_bounded() {
        let pool = BufPool::new();
        for _ in 0..(MAX_POOLED + 10) {
            pool.give_bytes(vec![0u8; 16]);
        }
        assert_eq!(pool.pooled().0, MAX_POOLED);
        // Zero-capacity and oversized buffers are never retained.
        pool.give_f32(Vec::new());
        assert_eq!(pool.pooled().1, 0);
    }

    #[test]
    fn empty_rent_has_zero_len() {
        let pool = BufPool::new();
        pool.give_bytes(vec![1u8; 32]);
        let v = pool.rent_bytes_empty();
        assert!(v.is_empty());
        assert!(v.capacity() >= 32);
    }
}
