//! TCP transport: the same frame protocol over real sockets, driving the
//! multi-process cluster mode (`bytepsc server --listen ADDR --shard I` /
//! `bytepsc worker --servers A,B,... --rank R`, see [`crate::cluster`]).
//! Workers [`connect_retry`] to every server shard at startup and register
//! with the `Hello`/`Welcome` handshake; servers accept one connection per
//! worker. Nothing here assumes a single machine — the addresses in
//! `cluster.addresses` can point anywhere.
//!
//! Frames above [`frame::MAX_FRAME_LEN`] are rejected on *both* sides:
//! `recv` refuses oversized length prefixes and `send` refuses to encode
//! them in the first place.
// Wire-facing module: the static-invariants lint (rust/src/lint) keeps
// this file panic-free outside tests, and clippy enforces the same at
// the `unwrap`/`expect` level.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use super::{frame, CommError, Endpoint, Message};
use std::io::{IoSlice, Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One half of the connection plus its reusable scratch buffer. Scratch
/// lives under the same lock as the stream it serves, so the frame in
/// flight and the buffer holding it can never be split across threads.
struct Half {
    stream: TcpStream,
    scratch: Vec<u8>,
}

pub struct TcpEndpoint {
    // Separate read/write halves so send and recv don't serialize on one lock.
    reader: Mutex<Half>,
    writer: Mutex<Half>,
    sent: Arc<AtomicU64>,
}

/// Lock a connection half, recovering from mutex poisoning instead of
/// propagating the original panic into every thread that shares the
/// endpoint. The state under the lock stays usable: the stream handle is
/// valid at every instant, and a holder that panicked mid-frame leaves at
/// worst a desynced stream, which the next operation surfaces as a
/// counted frame/Io error on this one connection — strictly better than
/// cascading a shard-wide crash. Same policy as `comm::BufPool`.
fn lock_half(m: &Mutex<Half>) -> std::sync::MutexGuard<'_, Half> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

impl TcpEndpoint {
    pub fn from_stream(stream: TcpStream) -> std::io::Result<Self> {
        stream.set_nodelay(true)?;
        let reader = stream.try_clone()?;
        Ok(TcpEndpoint {
            reader: Mutex::new(Half { stream: reader, scratch: Vec::new() }),
            writer: Mutex::new(Half { stream, scratch: Vec::new() }),
            sent: Arc::new(AtomicU64::new(0)),
        })
    }

    pub fn connect<A: ToSocketAddrs>(addr: A) -> std::io::Result<Self> {
        Self::from_stream(TcpStream::connect(addr)?)
    }

    /// Bound the time `recv` may block (used for the cluster handshake so
    /// a connected-but-silent peer cannot stall a server's accept loop).
    /// `None` restores indefinite blocking.
    pub fn set_read_timeout(&self, dur: Option<Duration>) -> std::io::Result<()> {
        lock_half(&self.reader).stream.set_read_timeout(dur)
    }

    /// Non-consuming liveness probe: true once the peer has closed its
    /// end (FIN observed with no buffered data). Unlike
    /// [`Endpoint::try_recv`] this never consumes a frame, so it is safe
    /// to poll on a connection whose traffic someone else will read —
    /// the cluster accept loop uses it to release the rank of a worker
    /// that registered and then died before the run started.
    pub fn peer_closed(&self) -> bool {
        let r = lock_half(&self.reader);
        if r.stream.set_nonblocking(true).is_err() {
            return true;
        }
        let mut b = [0u8; 1];
        let peeked = r.stream.peek(&mut b);
        let restored = r.stream.set_nonblocking(false);
        matches!(peeked, Ok(0)) || restored.is_err()
    }

    /// Like [`Endpoint::recv`] but with a caller-chosen frame cap. The
    /// pre-registration handshake caps at a few dozen bytes so an
    /// untrusted length prefix cannot make the server allocate a gigabyte
    /// before the peer has even identified itself.
    ///
    /// An over-cap length prefix is *connection-fatal* ([`CommError::Io`],
    /// not the recoverable `Protocol`): no compliant sender can produce
    /// one ([`frame::encode`] enforces the same cap), the stream can no
    /// longer be trusted to be frame-aligned, and draining an
    /// attacker-declared length (up to 4 GiB) to realign would hand a
    /// hostile peer exactly the read-pinning the handshake bounds exclude.
    // lint: allow(block, fn) — the per-connection reader mutex serializes whole-frame reads; blocking under it IS the framing discipline (scratch + stream must stay paired across the read)
    pub fn recv_bounded(&self, cap: usize) -> Result<Message, CommError> {
        let mut guard = lock_half(&self.reader);
        let Half { stream, scratch } = &mut *guard;
        let mut len_buf = [0u8; 4];
        read_exact(stream, &mut len_buf)?;
        // lint: allow(cast: u32 -> usize) — widening on every supported (64-bit) target
        let len = u32::from_le_bytes(len_buf) as usize;
        if len > cap {
            return Err(CommError::Io(format!(
                "peer claimed an oversized frame: {len} bytes (cap {cap}); dropping connection"
            )));
        }
        // Per-connection scratch: the body buffer is reused frame to frame,
        // so the steady-state recv path stops allocating once the buffer
        // has grown to the connection's largest frame. A recoverable
        // decode error (`CommError::Protocol`) consumed exactly `len`
        // bytes, so the stream — and the scratch — stay frame-aligned.
        scratch.clear();
        scratch.resize(len, 0);
        read_exact(stream, scratch)?;
        frame::decode_body(scratch)
    }
}

/// Connect to `addr`, retrying until `timeout` elapses — cluster workers
/// start before (or while) their servers bind, so first-connect refusal is
/// normal during startup fan-in.
pub fn connect_retry(addr: &str, timeout: Duration) -> std::io::Result<TcpEndpoint> {
    let start = Instant::now();
    loop {
        match TcpEndpoint::connect(addr) {
            Ok(ep) => return Ok(ep),
            Err(e) => {
                if start.elapsed() >= timeout {
                    return Err(std::io::Error::new(
                        e.kind(),
                        format!("connect to {addr}: {e} (gave up after {timeout:?})"),
                    ));
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

fn read_exact(stream: &mut TcpStream, buf: &mut [u8]) -> Result<(), CommError> {
    stream.read_exact(buf).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            CommError::Closed
        } else {
            CommError::Io(e.to_string())
        }
    })
}

/// Write a frame whose trailing block payload was split out of the send
/// scratch ([`frame::encode_split_into`]): header and payload go out in
/// one `write_vectored` call, so large Push/GroupPush/PullResp payloads
/// are never memcpy'd into scratch first. Partial writes resume by
/// re-slicing both buffers (`IoSlice::advance_slices` is unstable on the
/// MSRV; the manual loop is panic-free by construction — every slice
/// bound is `get`-checked).
fn write_split(stream: &mut TcpStream, head: &[u8], payload: &[u8]) -> Result<(), CommError> {
    let total = head.len() + payload.len();
    let mut off = 0usize;
    while off < total {
        let wrote = if off < head.len() {
            let bufs =
                [IoSlice::new(head.get(off..).unwrap_or(&[])), IoSlice::new(payload)];
            stream.write_vectored(&bufs)
        } else {
            stream.write(payload.get(off - head.len()..).unwrap_or(&[]))
        }
        .map_err(|e| CommError::Io(e.to_string()))?;
        if wrote == 0 {
            return Err(CommError::Io("socket accepted zero bytes mid-frame".into()));
        }
        off += wrote;
    }
    Ok(())
}

impl Endpoint for TcpEndpoint {
    fn send(&self, msg: Message) -> Result<(), CommError> {
        let mut guard = lock_half(&self.writer);
        let Half { stream, scratch } = &mut *guard;
        // Oversized messages fail here, symmetrically with the recv-side
        // cap — never serialized, never on the wire. Serialization reuses
        // the connection's send scratch, so a steady stream of frames
        // costs no allocation once the buffer has grown to the largest.
        // Block-carrying messages keep their payload out of scratch and
        // send it as a second vectored slice straight from the message.
        let split = frame::encode_split_into(&msg, scratch)?;
        let res = if split {
            let payload: &[u8] = match &msg {
                Message::Push { data, .. }
                | Message::GroupPush { data, .. }
                | Message::PullResp { data, .. } => &data.payload,
                _ => &[],
            };
            // lint: allow(cast: usize -> u64) — widening on every supported (64-bit) target
            self.sent.fetch_add((scratch.len() + payload.len()) as u64, Ordering::Relaxed);
            write_split(stream, scratch, payload)
        } else {
            // lint: allow(cast: usize -> u64) — widening on every supported (64-bit) target
            self.sent.fetch_add(scratch.len() as u64, Ordering::Relaxed);
            // lint: allow(block) — the writer mutex exists to serialize whole frames onto the socket; writing outside it would interleave frames
            stream.write_all(scratch).map_err(|e| CommError::Io(e.to_string()))
        };
        // The frame is on the wire (or the connection is dead); either way
        // the message's block payload dies here — recycle it. The in-proc
        // transport must NOT do this: it hands the message itself over.
        if let Message::Push { data, .. }
        | Message::GroupPush { data, .. }
        | Message::PullResp { data, .. } = msg
        {
            super::BufPool::global().give_bytes(data.payload);
        }
        res
    }

    fn recv(&self) -> Result<Message, CommError> {
        self.recv_bounded(frame::MAX_FRAME_LEN)
    }

    fn try_recv(&self) -> Result<Option<Message>, CommError> {
        // Peek the stream without blocking. Whatever peek returns, restore
        // blocking mode *first* — leaving the socket non-blocking would
        // turn every later recv() into a WouldBlock error.
        let r = lock_half(&self.reader);
        r.stream.set_nonblocking(true).map_err(|e| CommError::Io(e.to_string()))?;
        let mut len_buf = [0u8; 4];
        let peeked = r.stream.peek(&mut len_buf);
        let restored = r.stream.set_nonblocking(false);
        drop(r);
        restored.map_err(|e| CommError::Io(e.to_string()))?;
        match peeked {
            // A readable socket peeking 0 bytes is EOF: the peer closed the
            // connection. Reporting it as "partial header" (Ok(None)) made
            // callers busy-poll a dead socket forever.
            Ok(0) => Err(CommError::Closed),
            Ok(4) => self.recv().map(Some),
            Ok(_) => Ok(None), // partial header not yet arrived
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(None),
            Err(e) => Err(CommError::Io(e.to_string())),
        }
    }

    fn bytes_sent(&self) -> u64 {
        self.sent.load(Ordering::Relaxed)
    }
}

/// Listen on `addr` and accept exactly `n` connections (one per worker).
pub fn accept_n<A: ToSocketAddrs>(addr: A, n: usize) -> std::io::Result<(Vec<TcpEndpoint>, u16)> {
    let listener = TcpListener::bind(addr)?;
    let port = listener.local_addr()?.port();
    let mut eps = Vec::with_capacity(n);
    for _ in 0..n {
        let (stream, _) = listener.accept()?;
        eps.push(TcpEndpoint::from_stream(stream)?);
    }
    Ok((eps, port))
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::compress::{Compressed, SchemeId};

    #[test]
    fn tcp_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let ep = TcpEndpoint::from_stream(stream).unwrap();
            loop {
                match ep.recv().unwrap() {
                    Message::Shutdown => break,
                    m @ Message::Push { .. } => {
                        if let Message::Push { key, iter, .. } = &m {
                            ep.send(Message::Ack { key: *key, iter: *iter }).unwrap();
                        }
                    }
                    _ => panic!("unexpected"),
                }
            }
        });

        let client = TcpEndpoint::connect(addr).unwrap();
        // A structurally valid top-k block (decode validates payloads now):
        // k = 123 indices then 123 values over n = 1000.
        let data = Compressed {
            scheme: SchemeId::TopK,
            n: 1000,
            payload: {
                let mut p = Vec::new();
                p.extend_from_slice(&123u32.to_le_bytes());
                for i in 0..123u32 {
                    p.extend_from_slice(&(i * 8).to_le_bytes());
                }
                for i in 0..123 {
                    p.extend_from_slice(&(i as f32).to_le_bytes());
                }
                p
            },
        };
        for i in 0..10u64 {
            client.send(Message::Push { key: 5, iter: i, worker: 0, data: data.clone() }).unwrap();
            assert_eq!(client.recv().unwrap(), Message::Ack { key: 5, iter: i });
        }
        client.send(Message::Shutdown).unwrap();
        server.join().unwrap();
        assert!(client.bytes_sent() > 10 * data.nbytes() as u64);
    }

    /// Regression: peer closes the socket -> try_recv must surface
    /// CommError::Closed instead of returning Ok(None) forever.
    #[test]
    fn try_recv_reports_peer_close() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        let ep = TcpEndpoint::from_stream(stream).unwrap();
        // Nothing sent yet: a quiet socket is Ok(None).
        assert_eq!(ep.try_recv().unwrap(), None);
        drop(client); // peer closes -> FIN
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        loop {
            match ep.try_recv() {
                Err(CommError::Closed) => break,
                Ok(None) => {
                    // FIN may not have arrived yet; poll briefly.
                    assert!(std::time::Instant::now() < deadline, "try_recv never saw EOF");
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        // And the socket is back in blocking mode: recv reports Closed too.
        assert_eq!(ep.recv(), Err(CommError::Closed));
    }

    #[test]
    fn try_recv_delivers_when_data_present() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpEndpoint::connect(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        let ep = TcpEndpoint::from_stream(stream).unwrap();
        client.send(Message::Ack { key: 3, iter: 4 }).unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        loop {
            match ep.try_recv().unwrap() {
                Some(m) => {
                    assert_eq!(m, Message::Ack { key: 3, iter: 4 });
                    break;
                }
                None => {
                    assert!(std::time::Instant::now() < deadline, "message never arrived");
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
            }
        }
    }

    #[test]
    fn accept_n_connects_all() {
        let handle = std::thread::spawn(|| accept_n("127.0.0.1:0", 0).map(|(_, p)| p));
        let port = handle.join().unwrap().unwrap();
        assert!(port > 0);
    }

    /// An over-cap length prefix is connection-fatal: `recv_bounded`
    /// surfaces it as an Io error (not a recoverable Protocol error whose
    /// "drop the frame, keep the peer" handling would desync the stream),
    /// and never reads — let alone allocates — the attacker-declared body.
    #[test]
    fn recv_bounded_treats_oversized_claim_as_fatal() {
        use std::io::Write;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        let ep = TcpEndpoint::from_stream(stream).unwrap();
        // Hand-rolled frame claiming a ~4 GiB body that never arrives.
        client.write_all(&u32::MAX.to_le_bytes()).unwrap();
        let err = ep.recv_bounded(64).unwrap_err();
        assert!(
            matches!(err, CommError::Io(ref m) if m.contains("oversized")),
            "got {err:?}"
        );
    }

    /// A recoverable `Protocol` error (well-framed but undecodable body)
    /// must leave the pooled/scratch-buffered endpoint frame-aligned: the
    /// very next recv on the same connection delivers the next frame
    /// intact. Guards the scratch-reuse recv path against ever consuming
    /// more or fewer bytes than the length prefix declared.
    #[test]
    fn scratch_recv_stays_frame_aligned_after_protocol_error() {
        use std::io::Write;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut raw = TcpStream::connect(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        let ep = TcpEndpoint::from_stream(stream).unwrap();

        // Frame 1: correct length prefix, garbage body (unknown tag).
        let bad_body = [99u8, 1, 2, 3];
        raw.write_all(&(bad_body.len() as u32).to_le_bytes()).unwrap();
        raw.write_all(&bad_body).unwrap();
        // Frame 2: a good message on the same connection.
        raw.write_all(&frame::encode(&Message::Ack { key: 7, iter: 9 }).unwrap()).unwrap();

        let err = ep.recv().unwrap_err();
        assert!(matches!(err, CommError::Protocol(_)), "got {err:?}");
        assert_eq!(ep.recv().unwrap(), Message::Ack { key: 7, iter: 9 });

        // And a third frame, after the error, still round-trips — the
        // reader scratch was reused twice by now.
        raw.write_all(&frame::encode(&Message::Shutdown).unwrap()).unwrap();
        assert_eq!(ep.recv().unwrap(), Message::Shutdown);
    }

    #[test]
    fn large_frame_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let payload: Vec<u8> = (0..4_000_000usize).map(|i| (i % 251) as u8).collect();
        let expect = payload.clone();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let ep = TcpEndpoint::from_stream(stream).unwrap();
            match ep.recv().unwrap() {
                Message::PullResp { data, .. } => assert_eq!(data.payload, expect),
                _ => panic!("unexpected"),
            }
        });
        let client = TcpEndpoint::connect(addr).unwrap();
        client
            .send(Message::PullResp {
                key: 0,
                iter: 0,
                served_with: 1,
                data: Compressed { scheme: SchemeId::Identity, n: 1_000_000, payload },
            })
            .unwrap();
        server.join().unwrap();
    }
}
