//! Scaled 1-bit (sign) compressor — `C(v) = ‖v‖₁/d · sign(v)`
//! (Karimireddy et al. '19; dist-EF-SGD, Zheng et al. '19).
//!
//! δ-approximate with δ = ‖v‖₁² / (d·‖v‖₂²) ∈ (0, 1]; must run under error
//! feedback (paper Alg. 4). Wire format: `[scale: f32][bitmap: ceil(d/8)]`,
//! i.e. ~32× smaller than f32.

use super::{kernels, Compressed, Compressor, Ctx, SchemeId};
use crate::parallel::parallel_map_chunks;

pub struct ScaledOneBit;

impl ScaledOneBit {
    fn scale_of(x: &[f32], intra_threads: usize) -> f32 {
        if x.is_empty() {
            return 0.0;
        }
        let l1: f64 = if intra_threads > 1 {
            parallel_map_chunks(intra_threads, x, |_, c| {
                c.iter().map(|v| v.abs() as f64).sum::<f64>()
            })
            .into_iter()
            .sum()
        } else {
            x.iter().map(|v| v.abs() as f64).sum()
        };
        (l1 / x.len() as f64) as f32
    }
}

impl Compressor for ScaledOneBit {
    fn name(&self) -> &'static str {
        "onebit"
    }

    fn id(&self) -> SchemeId {
        SchemeId::OneBit
    }

    fn unbiased(&self) -> bool {
        false
    }

    fn compress(&self, x: &[f32], ctx: &mut Ctx) -> Compressed {
        let scale = Self::scale_of(x, ctx.intra_threads);
        let nbytes = x.len().div_ceil(8);
        let mut payload = Vec::with_capacity(4 + nbytes);
        super::put_f32(&mut payload, scale);
        payload.resize(4 + nbytes, 0);
        // sign(0) := +1, consistent with the paper's scaled-sign operator.
        kernels::sign_pack(x, &mut payload[4..]);
        Compressed { scheme: SchemeId::OneBit, n: x.len(), payload }
    }

    fn decompress(&self, c: &Compressed, out: &mut [f32]) {
        // lint: allow(panic) — caller contract, not wire data: the output buffer is rented at c.n
        assert_eq!(out.len(), c.n);
        // Wire-data guard (reported upstream by `compress::validate_wire`).
        if c.payload.len() != 4 + c.n.div_ceil(8) {
            out.fill(0.0);
            return;
        }
        let scale = super::get_f32(&c.payload, 0);
        // lint: allow(index) — the length guard above proves payload.len() >= 4
        kernels::sign_unpack_scaled(&c.payload[4..], scale, out);
    }

    fn add_decompressed(&self, c: &Compressed, acc: &mut [f32]) {
        // lint: allow(panic) — caller contract, not wire data: the accumulator is rented at c.n
        assert_eq!(acc.len(), c.n);
        // Wire-data guard: a short payload would panic on the bitmap read
        // (`compress::validate_wire` reports the corruption upstream).
        if c.payload.len() != 4 + c.n.div_ceil(8) {
            return;
        }
        let scale = super::get_f32(&c.payload, 0);
        // lint: allow(index) — the length guard above proves payload.len() >= 4
        kernels::sign_add_scaled(&c.payload[4..], scale, acc);
    }

    fn wire_nbytes(&self, n: usize) -> usize {
        4 + n.div_ceil(8)
    }

    fn compress_ef_fused(&self, q: &mut [f32], ctx: &mut Ctx) -> Compressed {
        // Single pass after the scale reduction: emit bit + residual together.
        let scale = Self::scale_of(q, ctx.intra_threads);
        let nbytes = q.len().div_ceil(8);
        let mut payload = Vec::with_capacity(4 + nbytes);
        super::put_f32(&mut payload, scale);
        payload.resize(4 + nbytes, 0);
        kernels::sign_pack_residual(q, scale, &mut payload[4..]);
        Compressed { scheme: SchemeId::OneBit, n: q.len(), payload }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;
    use crate::util::rng::Xoshiro256;
    use crate::util::{l1_norm, l2_norm};

    #[test]
    fn decode_is_scaled_sign() {
        let x = vec![3.0f32, -1.0, 0.5, -0.5];
        let mut rng = Xoshiro256::seed_from_u64(0);
        let c = ScaledOneBit.compress(&x, &mut Ctx::new(&mut rng));
        assert_eq!(c.nbytes(), 4 + 1);
        let mut out = vec![0.0f32; 4];
        ScaledOneBit.decompress(&c, &mut out);
        let scale = l1_norm(&x) / 4.0; // = 1.25
        assert_eq!(out, vec![scale, -scale, scale, -scale]);
    }

    #[test]
    fn delta_approximate_contract_property() {
        // Definition 2: ||C(x) - x||^2 <= (1 - δ) ||x||^2 with
        // δ = ||x||_1^2 / (d ||x||_2^2). Check the exact identity.
        forall(200, 0x1b17, |g| {
            let n = g.usize_in(1, 400);
            let x = g.f32_vec(n, 10.0);
            if l2_norm(&x) == 0.0 {
                return Ok(());
            }
            let mut rng = Xoshiro256::seed_from_u64(g.seed());
            let c = ScaledOneBit.compress(&x, &mut Ctx::new(&mut rng));
            let mut out = vec![0.0f32; n];
            ScaledOneBit.decompress(&c, &mut out);
            let err2: f64 =
                x.iter().zip(&out).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
            let norm2 = (l2_norm(&x) as f64).powi(2);
            let delta = (l1_norm(&x) as f64).powi(2) / (n as f64 * norm2);
            let bound = (1.0 - delta) * norm2;
            // Small f32 slack on the exact identity.
            if err2 > bound + 1e-3 * norm2 + 1e-6 {
                return Err(format!("err2={err2} bound={bound} n={n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn fused_residual_matches_naive() {
        forall(100, 0xfeed, |g| {
            let n = g.usize_in(1, 300);
            let x = g.f32_vec(n, 4.0);
            let mut rng = Xoshiro256::seed_from_u64(1);
            let mut q = x.clone();
            let c = ScaledOneBit.compress_ef_fused(&mut q, &mut Ctx::new(&mut rng));
            let mut dec = vec![0.0f32; n];
            ScaledOneBit.decompress(&c, &mut dec);
            for i in 0..n {
                let naive = x[i] - dec[i];
                if (q[i] - naive).abs() > 1e-5 {
                    return Err(format!("i={i} fused={} naive={}", q[i], naive));
                }
            }
            // Both compress paths must agree on the wire bytes too.
            let mut rng2 = Xoshiro256::seed_from_u64(1);
            let c2 = ScaledOneBit.compress(&x, &mut Ctx::new(&mut rng2));
            if c != c2 {
                return Err("fused and plain compress disagree".into());
            }
            Ok(())
        });
    }

    #[test]
    fn parallel_scale_matches_serial() {
        let x: Vec<f32> = (0..400_000).map(|i| ((i as f32) * 0.003).sin()).collect();
        let mut r1 = Xoshiro256::seed_from_u64(0);
        let mut r2 = Xoshiro256::seed_from_u64(0);
        let a = ScaledOneBit.compress(&x, &mut Ctx::new(&mut r1));
        let b = ScaledOneBit.compress(&x, &mut Ctx::with_threads(&mut r2, 4));
        // Parallel L1 reduction reassociates f64 adds; scales agree to ~1e-6 rel.
        let sa = super::super::get_f32(&a.payload, 0);
        let sb = super::super::get_f32(&b.payload, 0);
        assert!(((sa - sb) / sa).abs() < 1e-5);
        assert_eq!(a.payload[4..], b.payload[4..]);
    }

    #[test]
    fn empty_and_all_zero() {
        let mut rng = Xoshiro256::seed_from_u64(0);
        let c = ScaledOneBit.compress(&[], &mut Ctx::new(&mut rng));
        let mut out: Vec<f32> = vec![];
        ScaledOneBit.decompress(&c, &mut out);

        let z = vec![0.0f32; 17];
        let c = ScaledOneBit.compress(&z, &mut Ctx::new(&mut rng));
        let mut out = vec![1.0f32; 17];
        ScaledOneBit.decompress(&c, &mut out);
        assert!(out.iter().all(|&v| v == 0.0));
    }
}
