//! FP16 conversion compressor — the paper's "NAG (FP16)" baseline and the
//! intra-node compression stage (§4.1.1).

use super::{kernels, Compressed, Compressor, Ctx, SchemeId};
use crate::parallel::parallel_for_chunks;

/// Round-to-nearest-even f32→f16 per element; 2 bytes on the wire.
///
/// Deterministic rounding makes it *biased* in the Definition-1 sense, but
/// its relative error (≤ 2^-11 for normals) is far below any gradient noise
/// floor, so the paper runs it without error feedback. We still implement
/// the fused-EF path so it can be ablated.
pub struct Fp16;

impl Compressor for Fp16 {
    fn name(&self) -> &'static str {
        "fp16"
    }

    fn id(&self) -> SchemeId {
        SchemeId::Fp16
    }

    fn unbiased(&self) -> bool {
        false
    }

    fn compress(&self, x: &[f32], ctx: &mut Ctx) -> Compressed {
        let mut payload = vec![0u8; 2 * x.len()];
        if ctx.intra_threads > 1 {
            // Chunk the output; each 2-byte slot depends only on x[i].
            parallel_for_chunks(ctx.intra_threads, &mut payload[..], |off, chunk| {
                debug_assert_eq!(off % 2, 0);
                let base = off / 2;
                kernels::f32_to_f16_slice(&x[base..base + chunk.len() / 2], chunk);
            });
        } else {
            kernels::f32_to_f16_slice(x, &mut payload);
        }
        Compressed { scheme: SchemeId::Fp16, n: x.len(), payload }
    }

    fn decompress(&self, c: &Compressed, out: &mut [f32]) {
        // lint: allow(panic) — caller contract, not wire data: the output buffer is rented at c.n
        assert_eq!(out.len(), c.n);
        // Wire-data guard (reported upstream by `compress::validate_wire`).
        if c.payload.len() != 2 * c.n {
            out.fill(0.0);
            return;
        }
        kernels::f16_to_f32_slice(&c.payload, out);
    }

    fn add_decompressed(&self, c: &Compressed, acc: &mut [f32]) {
        // lint: allow(panic) — caller contract, not wire data: the accumulator is rented at c.n
        assert_eq!(acc.len(), c.n);
        // Wire-data guard against short payloads (reported upstream by
        // `compress::validate_wire`).
        if c.payload.len() != 2 * c.n {
            return;
        }
        kernels::f16_add_decoded(&c.payload, acc);
    }

    fn wire_nbytes(&self, n: usize) -> usize {
        2 * n
    }

    fn compress_ef_fused(&self, q: &mut [f32], _ctx: &mut Ctx) -> Compressed {
        // Single pass: emit bits and residual together.
        let mut payload = vec![0u8; 2 * q.len()];
        kernels::f16_encode_residual(q, &mut payload);
        Compressed { scheme: SchemeId::Fp16, n: q.len(), payload }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn roundtrip_error_is_tiny() {
        let x: Vec<f32> = (0..2048).map(|i| ((i as f32) * 0.7).sin() * 10.0).collect();
        let mut rng = Xoshiro256::seed_from_u64(0);
        let mut ctx = Ctx::new(&mut rng);
        let c = Fp16.compress(&x, &mut ctx);
        let mut out = vec![0.0f32; x.len()];
        Fp16.decompress(&c, &mut out);
        for (a, b) in x.iter().zip(&out) {
            let rel = if *a == 0.0 { b.abs() } else { ((a - b) / a).abs() };
            assert!(rel <= 1.0 / 2048.0 + 1e-7, "a={a} b={b}");
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let x: Vec<f32> = (0..300_000).map(|i| ((i as f32) * 0.001).cos() * 3.0).collect();
        let mut rng = Xoshiro256::seed_from_u64(0);
        let serial = Fp16.compress(&x, &mut Ctx::new(&mut rng));
        let mut rng2 = Xoshiro256::seed_from_u64(0);
        let par = Fp16.compress(&x, &mut Ctx::with_threads(&mut rng2, 4));
        assert_eq!(serial, par);
    }

    #[test]
    fn fused_residual_matches_naive() {
        let x: Vec<f32> = (0..777).map(|i| (i as f32 * 0.31).tan().clamp(-5.0, 5.0)).collect();
        let mut rng = Xoshiro256::seed_from_u64(0);
        let mut q = x.clone();
        let c = Fp16.compress_ef_fused(&mut q, &mut Ctx::new(&mut rng));
        let mut dec = vec![0.0f32; x.len()];
        Fp16.decompress(&c, &mut dec);
        for i in 0..x.len() {
            assert!((q[i] - (x[i] - dec[i])).abs() < 1e-7);
        }
    }

    #[test]
    fn delta_approximate_contract() {
        // ||C(x)-x||^2 <= (1-δ)||x||^2 with 1-δ ≈ 2^-22 for fp16 normals.
        let x: Vec<f32> = (0..4096).map(|i| ((i as f32) * 1.7).sin() + 0.01).collect();
        let mut rng = Xoshiro256::seed_from_u64(0);
        let mut ctx = Ctx::new(&mut rng);
        let c = Fp16.compress(&x, &mut ctx);
        let mut out = vec![0.0f32; x.len()];
        Fp16.decompress(&c, &mut out);
        let err: f64 = x.iter().zip(&out).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        let norm: f64 = x.iter().map(|a| (*a as f64).powi(2)).sum();
        assert!(err < norm * 1e-5, "err={err} norm={norm}");
    }
}
