//! Error-feedback residual state (paper §3.1, Alg. 4).
//!
//! Workers keep one residual `e_{t,i}` per tensor key; servers keep one
//! `ẽ_t` per key. [`EfState`] owns those buffers and implements the
//! correct-compress-update cycle:
//!
//! ```text
//! q   = g + e            (correct)
//! δ   = C(q)             (compress)
//! e'  = q − δ            (residual update — fused when the scheme allows)
//! ```
//!
//! The fused path (§4.2.2 "Operator Fusion") asks the compressor to emit
//! the residual during compression (O(k) zero-fill for sparse schemes, one
//! pass for sign/fp16) instead of decompress-then-subtract (O(2d) plus an
//! allocation). The ablation toggle keeps both paths available.

use super::{kernels, Compressed, Compressor, Ctx};
use std::collections::HashMap;

/// One EF compress cycle over an owned buffer, map-free: correct with the
/// residual (if any), compress, return `(wire block, new residual)`. This
/// is Algorithm 4's compress step, and it exists exactly **once**: both
/// [`EfState::compress_owned`] (single-threaded residual map) and the
/// staged server encode (`ps::stage::encode_aggregate`, per-key residual
/// lending) call it, so the two paths can never drift numerically.
pub fn compress_cycle(
    comp: &dyn Compressor,
    fused: bool,
    ctx: &mut Ctx,
    mut g: Vec<f32>,
    residual: Option<&[f32]>,
) -> (Compressed, Vec<f32>) {
    if let Some(e) = residual {
        assert_eq!(e.len(), g.len(), "EF residual size drifted");
        kernels::add_assign(&mut g, e);
    }
    if fused {
        let c = comp.compress_ef_fused(&mut g, ctx);
        (c, g)
    } else {
        let c = comp.compress(&g, ctx);
        let mut dec = vec![0.0f32; g.len()];
        comp.decompress(&c, &mut dec);
        kernels::sub_assign(&mut g, &dec);
        (c, g)
    }
}

/// Residual store keyed by tensor id.
pub struct EfState {
    residuals: HashMap<u64, Vec<f32>>,
    /// Use the compressor's fused residual path (§4.2.2).
    pub fused: bool,
}

impl EfState {
    pub fn new(fused: bool) -> Self {
        EfState { residuals: HashMap::new(), fused }
    }

    /// Total f32 elements held as residual state (for memory accounting).
    pub fn state_elems(&self) -> usize {
        self.residuals.values().map(|v| v.len()).sum()
    }

    /// Peek at a residual (tests / diagnostics).
    pub fn residual(&self, key: u64) -> Option<&[f32]> {
        self.residuals.get(&key).map(|v| v.as_slice())
    }

    /// One EF cycle for tensor `key` with gradient `g`:
    /// returns `C(g + e)` and stores the new residual.
    pub fn compress(
        &mut self,
        key: u64,
        g: &[f32],
        comp: &dyn Compressor,
        ctx: &mut Ctx,
    ) -> Compressed {
        let e = self
            .residuals
            .entry(key)
            .or_insert_with(|| vec![0.0f32; g.len()]);
        assert_eq!(e.len(), g.len(), "tensor {key} changed size");
        // q = g + e, computed into the residual buffer (it will be
        // overwritten with the new residual anyway).
        kernels::add_assign(e, g);
        if self.fused {
            // e' emitted in place by the compressor.
            comp.compress_ef_fused(e, ctx)
        } else {
            // Naive: compress a copy, then decompress and subtract.
            let q = e.clone();
            let c = comp.compress(&q, ctx);
            let mut dec = vec![0.0f32; q.len()];
            comp.decompress(&c, &mut dec);
            for (ei, (qi, di)) in e.iter_mut().zip(q.iter().zip(&dec)) {
                *ei = qi - di;
            }
            c
        }
    }

    /// Same cycle but `g` arrives as an owned buffer that may be consumed
    /// (server-side: the aggregated Δ). Avoids one copy in the fused path.
    /// Thin wrapper over the shared [`compress_cycle`] kernel.
    pub fn compress_owned(
        &mut self,
        key: u64,
        g: Vec<f32>,
        comp: &dyn Compressor,
        ctx: &mut Ctx,
    ) -> Compressed {
        let residual = self.residuals.get(&key).map(|e| e.as_slice());
        let (c, e) = compress_cycle(comp, self.fused, ctx, g, residual);
        self.residuals.insert(key, e);
        c
    }

    /// Drop all residual state (e.g. between training phases).
    pub fn reset(&mut self) {
        self.residuals.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::by_name;
    use crate::testutil::forall;
    use crate::util::rng::Xoshiro256;

    /// EF invariant: decode(δ_t) + e_{t+1} == g_t + e_t exactly
    /// (compression "loses nothing", it only defers).
    #[test]
    fn ef_conserves_mass() {
        for scheme in ["topk", "onebit", "randomk", "fp16"] {
            forall(60, 0xef0, |g| {
                let n = g.usize_in(1, 200);
                let steps = g.usize_in(1, 5);
                let comp = by_name(scheme, 0.1).unwrap();
                let mut ef = EfState::new(true);
                let mut rng = Xoshiro256::seed_from_u64(g.seed());
                for _ in 0..steps {
                    let grad = g.f32_vec(n, 2.0);
                    let e_before: Vec<f32> =
                        ef.residual(1).map(|e| e.to_vec()).unwrap_or_else(|| vec![0.0; n]);
                    let c = ef.compress(1, &grad, comp.as_ref(), &mut Ctx::new(&mut rng));
                    let mut dec = vec![0.0f32; n];
                    comp.decompress(&c, &mut dec);
                    let e_after = ef.residual(1).unwrap();
                    for i in 0..n {
                        let lhs = dec[i] + e_after[i];
                        let rhs = grad[i] + e_before[i];
                        if (lhs - rhs).abs() > 1e-4 * rhs.abs().max(1.0) {
                            return Err(format!(
                                "{scheme}: mass not conserved at {i}: {lhs} vs {rhs}"
                            ));
                        }
                    }
                }
                Ok(())
            });
        }
    }

    /// Fused and naive residual paths must produce identical wire bytes and
    /// (numerically) identical residuals when driven by the same RNG.
    #[test]
    fn fused_equals_naive_over_time() {
        for scheme in ["topk", "onebit", "fp16", "randomk"] {
            let comp = by_name(scheme, 0.05).unwrap();
            let mut fused = EfState::new(true);
            let mut naive = EfState::new(false);
            let mut rf = Xoshiro256::seed_from_u64(42);
            let mut rn = Xoshiro256::seed_from_u64(42);
            let mut data_rng = Xoshiro256::seed_from_u64(7);
            for step in 0..8 {
                let mut grad = vec![0.0f32; 256];
                data_rng.fill_normal(&mut grad, 1.0);
                let cf = fused.compress(3, &grad, comp.as_ref(), &mut Ctx::new(&mut rf));
                let cn = naive.compress(3, &grad, comp.as_ref(), &mut Ctx::new(&mut rn));
                assert_eq!(cf, cn, "{scheme} wire mismatch at step {step}");
                let ef_res = fused.residual(3).unwrap();
                let en_res = naive.residual(3).unwrap();
                for i in 0..256 {
                    assert!(
                        (ef_res[i] - en_res[i]).abs() < 1e-5,
                        "{scheme} residual mismatch at step {step}, idx {i}"
                    );
                }
            }
        }
    }

    /// With the identity compressor, EF is a no-op: residuals stay zero and
    /// the wire carries the exact gradient (Alg. 4 degenerates to Alg. 1).
    #[test]
    fn identity_degenerates_to_plain_pushpull() {
        let comp = by_name("identity", 0.0).unwrap();
        let mut ef = EfState::new(true);
        let mut rng = Xoshiro256::seed_from_u64(1);
        for _ in 0..4 {
            let grad: Vec<f32> = (0..64).map(|i| (i as f32).sin()).collect();
            let c = ef.compress(9, &grad, comp.as_ref(), &mut Ctx::new(&mut rng));
            let mut dec = vec![0.0f32; 64];
            comp.decompress(&c, &mut dec);
            assert_eq!(dec, grad);
            assert!(ef.residual(9).unwrap().iter().all(|&v| v == 0.0));
        }
    }

    /// Residual norm stays bounded for δ-approximate compressors
    /// (Lemma 2's geometric-series argument, checked empirically).
    #[test]
    fn residual_norm_bounded() {
        let comp = by_name("topk", 0.25).unwrap();
        let mut ef = EfState::new(true);
        let mut rng = Xoshiro256::seed_from_u64(5);
        let mut data_rng = Xoshiro256::seed_from_u64(6);
        let mut max_norm: f32 = 0.0;
        for _ in 0..200 {
            let mut grad = vec![0.0f32; 128];
            data_rng.fill_normal(&mut grad, 1.0);
            let _ = ef.compress(1, &grad, comp.as_ref(), &mut Ctx::new(&mut rng));
            max_norm = max_norm.max(crate::util::l2_norm(ef.residual(1).unwrap()));
        }
        // Lemma-2 style bound: sqrt(1-δ)/(1-sqrt(1-δ)) * max||g|| with
        // δ >= k/d = 0.25 => factor ≈ 6.46; ||g|| ~ sqrt(128) ≈ 11.3.
        // Generous envelope:
        assert!(max_norm < 6.46 * 16.0, "residual norm {max_norm} unbounded?");
    }

    #[test]
    fn compress_owned_matches_compress() {
        let comp = by_name("topk", 0.1).unwrap();
        let mut a = EfState::new(true);
        let mut b = EfState::new(true);
        let mut ra = Xoshiro256::seed_from_u64(2);
        let mut rb = Xoshiro256::seed_from_u64(2);
        let mut data_rng = Xoshiro256::seed_from_u64(3);
        for _ in 0..5 {
            let mut grad = vec![0.0f32; 100];
            data_rng.fill_normal(&mut grad, 1.0);
            let ca = a.compress(1, &grad, comp.as_ref(), &mut Ctx::new(&mut ra));
            let cb = b.compress_owned(1, grad.clone(), comp.as_ref(), &mut Ctx::new(&mut rb));
            assert_eq!(ca, cb);
            assert_eq!(a.residual(1).unwrap(), b.residual(1).unwrap());
        }
    }

    #[test]
    #[should_panic(expected = "changed size")]
    fn size_change_panics() {
        let comp = by_name("topk", 0.5).unwrap();
        let mut ef = EfState::new(true);
        let mut rng = Xoshiro256::seed_from_u64(0);
        let _ = ef.compress(1, &[1.0, 2.0], comp.as_ref(), &mut Ctx::new(&mut rng));
        let _ = ef.compress(1, &[1.0, 2.0, 3.0], comp.as_ref(), &mut Ctx::new(&mut rng));
    }
}
