//! Random-k sparsifier — send k uniformly sampled coordinates
//! (Stich et al. '18 with EF; Horváth & Richtárik '21 unbiased variant).
//!
//! Wire format: `[k: u32][seed: u64][values: k × f32]`. The index set is
//! regenerated from the 8-byte seed on the receiver, so random-k ships
//! only ~4 bytes per kept element — the paper's fastest method (Table 2).
//!
//! Two modes:
//! * `rescale = false` (EF mode, the paper's "Random-k with EF"): values
//!   sent verbatim; biased, δ = k/d in expectation.
//! * `rescale = true` (unbiased ω-compressor for Alg. 3): values scaled by
//!   d/k so `E[C(x)] = x`, with ω = d/k − 1 (Definition 1).

use super::{Compressed, Compressor, Ctx, SchemeId};
use crate::util::rng::Xoshiro256;

pub struct RandomK {
    pub ratio: f64,
    pub rescale: bool,
}

impl RandomK {
    pub fn new(ratio: f64, rescale: bool) -> Self {
        assert!(ratio > 0.0 && ratio <= 1.0, "random-k ratio must be in (0,1], got {ratio}");
        RandomK { ratio, rescale }
    }

    pub fn k_for(&self, n: usize) -> usize {
        ((self.ratio * n as f64).ceil() as usize).clamp(1, n.max(1))
    }

    fn indices_from_seed(seed: u64, n: usize, k: usize) -> Vec<u32> {
        Xoshiro256::seed_from_u64(seed).sample_indices(n, k)
    }
}

impl Compressor for RandomK {
    fn name(&self) -> &'static str {
        if self.rescale {
            "randomk_unbiased"
        } else {
            "randomk"
        }
    }

    fn id(&self) -> SchemeId {
        SchemeId::RandomK
    }

    fn unbiased(&self) -> bool {
        self.rescale
    }

    fn compress(&self, x: &[f32], ctx: &mut Ctx) -> Compressed {
        if x.is_empty() {
            let mut payload = Vec::with_capacity(12);
            super::put_u32(&mut payload, 0);
            super::put_u64(&mut payload, 0);
            return Compressed { scheme: SchemeId::RandomK, n: 0, payload };
        }
        let k = self.k_for(x.len());
        let seed = ctx.rng.next_u64();
        let idx = Self::indices_from_seed(seed, x.len(), k);
        let gain = if self.rescale { x.len() as f32 / k as f32 } else { 1.0 };
        let mut payload = Vec::with_capacity(12 + 4 * k);
        super::put_u32(&mut payload, k as u32);
        super::put_u64(&mut payload, seed);
        for &i in &idx {
            super::put_f32(&mut payload, x[i as usize] * gain);
        }
        Compressed { scheme: SchemeId::RandomK, n: x.len(), payload }
    }

    fn decompress(&self, c: &Compressed, out: &mut [f32]) {
        // lint: allow(panic) — caller contract, not wire data: the output buffer is rented at c.n
        assert_eq!(out.len(), c.n);
        out.fill(0.0);
        self.add_decompressed(c, out);
    }

    fn add_decompressed(&self, c: &Compressed, acc: &mut [f32]) {
        // lint: allow(panic) — caller contract, not wire data: the accumulator is rented at c.n
        assert_eq!(acc.len(), c.n);
        // Wire-data guards (see `compress::validate_wire`, which transports
        // and the server call to *report* corruption): a bad k would panic
        // inside `sample_indices`, a short payload inside `get_f32`.
        if c.payload.len() < 12 {
            return; // malformed: missing k/seed header
        }
        let k = super::get_u32(&c.payload, 0) as usize;
        if k == 0 {
            return;
        }
        if k > c.n || c.payload.len() != 12 + 4 * k {
            return; // malformed: inconsistent k / payload length
        }
        let seed = super::get_u64(&c.payload, 4);
        let idx = Self::indices_from_seed(seed, c.n, k);
        // lint: allow(index) — the length guard above proves payload.len() == 12 + 4k
        super::kernels::sparse_add_indexed(&idx, &c.payload[12..], acc);
    }

    fn wire_nbytes(&self, n: usize) -> usize {
        if n == 0 {
            return 12;
        }
        12 + 4 * self.k_for(n)
    }

    /// Fused residual: zero-fill the sampled coordinates (O(k)).
    /// Only valid without rescaling (EF mode); rescaled mode falls back to
    /// the naive residual, which is what the theory prescribes anyway
    /// (unbiased compressors run without EF, paper §3.2).
    fn compress_ef_fused(&self, q: &mut [f32], ctx: &mut Ctx) -> Compressed {
        if self.rescale {
            // E[C(x)] = x but C(x) ≠ x pointwise; residual needs the decode.
            let c = self.compress(q, ctx);
            let mut dec = vec![0.0f32; q.len()];
            self.decompress(&c, &mut dec);
            super::kernels::sub_assign(q, &dec);
            return c;
        }
        if q.is_empty() {
            let mut payload = Vec::with_capacity(12);
            super::put_u32(&mut payload, 0);
            super::put_u64(&mut payload, 0);
            return Compressed { scheme: SchemeId::RandomK, n: 0, payload };
        }
        let k = self.k_for(q.len());
        let seed = ctx.rng.next_u64();
        let idx = Self::indices_from_seed(seed, q.len(), k);
        let mut payload = Vec::with_capacity(12 + 4 * k);
        super::put_u32(&mut payload, k as u32);
        super::put_u64(&mut payload, seed);
        for &i in &idx {
            super::put_f32(&mut payload, q[i as usize]);
            q[i as usize] = 0.0;
        }
        Compressed { scheme: SchemeId::RandomK, n: q.len(), payload }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn decode_reconstructs_sampled_coords() {
        let x: Vec<f32> = (0..100).map(|i| (i + 1) as f32).collect();
        let rk = RandomK::new(0.1, false);
        let mut rng = Xoshiro256::seed_from_u64(7);
        let c = rk.compress(&x, &mut Ctx::new(&mut rng));
        assert_eq!(c.nbytes(), 12 + 4 * 10);
        let mut out = vec![0.0f32; 100];
        rk.decompress(&c, &mut out);
        let kept: Vec<usize> = out.iter().enumerate().filter(|(_, v)| **v != 0.0).map(|(i, _)| i).collect();
        assert_eq!(kept.len(), 10);
        for &i in &kept {
            assert_eq!(out[i], x[i]);
        }
    }

    #[test]
    fn unbiased_mode_statistical() {
        // E[C(x)]_i == x_i: average many independent compressions.
        let n = 64;
        let x: Vec<f32> = (0..n).map(|i| (i as f32 * 0.3).sin() + 0.5).collect();
        let rk = RandomK::new(0.25, true);
        let mut rng = Xoshiro256::seed_from_u64(123);
        let mut mean = vec![0.0f64; n];
        let trials = 4000;
        for _ in 0..trials {
            let c = rk.compress(&x, &mut Ctx::new(&mut rng));
            let mut out = vec![0.0f32; n];
            rk.decompress(&c, &mut out);
            for (m, o) in mean.iter_mut().zip(&out) {
                *m += *o as f64;
            }
        }
        for i in 0..n {
            let m = mean[i] / trials as f64;
            assert!(
                (m - x[i] as f64).abs() < 0.15,
                "coord {i}: mean={m} expected={}",
                x[i]
            );
        }
    }

    #[test]
    fn omega_contract_property() {
        // Definition 1 second moment: E||C(x)-x||^2 <= ω||x||^2 with
        // ω = d/k - 1. Check the average over repeats stays under ω||x||².
        forall(20, 0x5eed, |g| {
            let n = g.usize_in(8, 128);
            let x = g.f32_vec(n, 2.0);
            let norm2: f64 = x.iter().map(|v| (*v as f64).powi(2)).sum();
            if norm2 < 1e-12 {
                return Ok(());
            }
            let rk = RandomK::new(0.25, true);
            let k = rk.k_for(n);
            let omega = n as f64 / k as f64 - 1.0;
            let mut rng = Xoshiro256::seed_from_u64(g.seed());
            let mut err_sum = 0.0f64;
            let trials = 300;
            for _ in 0..trials {
                let c = rk.compress(&x, &mut Ctx::new(&mut rng));
                let mut out = vec![0.0f32; n];
                rk.decompress(&c, &mut out);
                err_sum += x.iter().zip(&out).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>();
            }
            let mean_err = err_sum / trials as f64;
            // Allow 40% statistical slack on the expectation bound.
            if mean_err > omega * norm2 * 1.4 + 1e-9 {
                return Err(format!("mean_err={mean_err} omega*norm2={}", omega * norm2));
            }
            Ok(())
        });
    }

    #[test]
    fn seed_coded_indices_are_stable_across_decode() {
        let x: Vec<f32> = (0..500).map(|i| (i as f32).cos()).collect();
        let rk = RandomK::new(0.05, false);
        let mut rng = Xoshiro256::seed_from_u64(5);
        let c = rk.compress(&x, &mut Ctx::new(&mut rng));
        let mut out1 = vec![0.0f32; 500];
        let mut out2 = vec![0.0f32; 500];
        rk.decompress(&c, &mut out1);
        rk.decompress(&c, &mut out2);
        assert_eq!(out1, out2);
    }

    #[test]
    fn fused_residual_matches_naive_ef_mode() {
        forall(100, 0x4a11, |g| {
            let n = g.usize_in(1, 200);
            let x = g.f32_vec(n, 5.0);
            let rk = RandomK::new(0.2, false);
            // Same rng seed for both paths => same sampled indices.
            let mut r1 = Xoshiro256::seed_from_u64(11);
            let mut r2 = Xoshiro256::seed_from_u64(11);
            let mut q = x.clone();
            let c_fused = rk.compress_ef_fused(&mut q, &mut Ctx::new(&mut r1));
            let c_plain = rk.compress(&x, &mut Ctx::new(&mut r2));
            if c_fused != c_plain {
                return Err("wire mismatch".into());
            }
            let mut dec = vec![0.0f32; n];
            rk.decompress(&c_fused, &mut dec);
            for i in 0..n {
                if (q[i] - (x[i] - dec[i])).abs() > 1e-9 {
                    return Err(format!("residual mismatch at {i}"));
                }
            }
            Ok(())
        });
    }
}
