//! Identity "compressor" — full-precision baseline (paper's plain NAG/LANS).

use super::{kernels, Compressed, Compressor, Ctx, SchemeId};

/// Sends raw f32 bytes. `C(x) = x`, so it is trivially unbiased with ω = 0
/// and δ = 1; both sync algorithms degenerate to Alg. 1 (tested in `optim`).
pub struct Identity;

impl Compressor for Identity {
    fn name(&self) -> &'static str {
        "identity"
    }

    fn id(&self) -> SchemeId {
        SchemeId::Identity
    }

    fn unbiased(&self) -> bool {
        true
    }

    fn compress(&self, x: &[f32], _ctx: &mut Ctx) -> Compressed {
        let mut payload = Vec::with_capacity(4 * x.len());
        kernels::f32_to_le_bytes(x, &mut payload);
        Compressed { scheme: SchemeId::Identity, n: x.len(), payload }
    }

    fn decompress(&self, c: &Compressed, out: &mut [f32]) {
        // lint: allow(panic) — caller contract, not wire data: the output buffer is rented at c.n
        assert_eq!(out.len(), c.n);
        // Wire-data guard (reported upstream by `compress::validate_wire`).
        if c.payload.len() != 4 * c.n {
            out.fill(0.0);
            return;
        }
        kernels::le_bytes_to_f32(&c.payload, out);
    }

    fn add_decompressed(&self, c: &Compressed, acc: &mut [f32]) {
        // lint: allow(panic) — caller contract, not wire data: the accumulator is rented at c.n
        assert_eq!(acc.len(), c.n);
        // Wire-data guard against short payloads (reported upstream by
        // `compress::validate_wire`).
        if c.payload.len() != 4 * c.n {
            return;
        }
        kernels::le_bytes_add_f32(&c.payload, acc);
    }

    fn wire_nbytes(&self, n: usize) -> usize {
        4 * n
    }

    fn compress_ef_fused(&self, q: &mut [f32], ctx: &mut Ctx) -> Compressed {
        // Residual is exactly zero — skip the decompress round trip.
        let c = self.compress(q, ctx);
        q.fill(0.0);
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn exact_roundtrip() {
        let x: Vec<f32> = (0..257).map(|i| (i as f32).sqrt() - 8.0).collect();
        let mut rng = Xoshiro256::seed_from_u64(0);
        let mut ctx = Ctx::new(&mut rng);
        let c = Identity.compress(&x, &mut ctx);
        assert_eq!(c.nbytes(), 4 * x.len());
        let mut out = vec![0.0f32; x.len()];
        Identity.decompress(&c, &mut out);
        assert_eq!(out, x);
    }

    #[test]
    fn residual_is_zero() {
        let mut q = vec![1.5f32, -2.0, 3.25];
        let mut rng = Xoshiro256::seed_from_u64(0);
        let mut ctx = Ctx::new(&mut rng);
        let _ = Identity.compress_ef_fused(&mut q, &mut ctx);
        assert_eq!(q, vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn accumulate_adds() {
        let x = vec![1.0f32, 2.0, 3.0];
        let mut rng = Xoshiro256::seed_from_u64(0);
        let mut ctx = Ctx::new(&mut rng);
        let c = Identity.compress(&x, &mut ctx);
        let mut acc = vec![10.0f32, 20.0, 30.0];
        Identity.add_decompressed(&c, &mut acc);
        assert_eq!(acc, vec![11.0, 22.0, 33.0]);
    }
}
