//! Stochastic dithering quantizers.
//!
//! * [`LinearDither`] — b-bit uniform stochastic quantization with a
//!   per-tensor max-|x| scale (QSGD-style; paper uses 5 bits for CNNs and
//!   7 bits for BERT).
//! * [`NaturalDither`] — power-of-two levels with stochastic rounding
//!   (Horváth et al. '19 natural compression; paper uses 3 bits).
//!
//! Both are **unbiased conditional on the scale** (the scale is a
//! deterministic function of `x`), so they run under Alg. 3 without error
//! feedback. The same numerics are implemented as the L1 Pallas kernel in
//! `python/compile/kernels/quantize.py` and cross-checked in
//! `rust/tests/pallas_parity.rs`.

use super::{kernels, Compressed, Compressor, Ctx, SchemeId};
use crate::util::max_abs;

/// Pack a stream of `bits`-wide codes into bytes (LSB-first).
pub(crate) struct BitPacker {
    buf: Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl BitPacker {
    pub fn new(capacity_codes: usize, bits: u32) -> Self {
        BitPacker {
            buf: Vec::with_capacity((capacity_codes * bits as usize).div_ceil(8)),
            acc: 0,
            nbits: 0,
        }
    }

    #[inline]
    pub fn push(&mut self, code: u32, bits: u32) {
        debug_assert!(bits <= 32 && (code as u64) < (1u64 << bits));
        self.acc |= (code as u64) << self.nbits;
        self.nbits += bits;
        while self.nbits >= 8 {
            self.buf.push((self.acc & 0xFF) as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.buf.push((self.acc & 0xFF) as u8);
        }
        self.buf
    }
}

/// Unpack `bits`-wide codes (LSB-first).
pub(crate) struct BitUnpacker<'a> {
    buf: &'a [u8],
    byte: usize,
    acc: u64,
    nbits: u32,
}

impl<'a> BitUnpacker<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitUnpacker { buf, byte: 0, acc: 0, nbits: 0 }
    }

    #[inline]
    pub fn pull(&mut self, bits: u32) -> u32 {
        while self.nbits < bits {
            // Wire-data guard: treat bytes past the end of a truncated
            // payload as zero instead of panicking (structural corruption
            // is reported upstream by `compress::validate_wire`).
            let b = self.buf.get(self.byte).copied().unwrap_or(0);
            self.acc |= (b as u64) << self.nbits;
            self.byte += 1;
            self.nbits += 8;
        }
        let v = (self.acc & ((1u64 << bits) - 1)) as u32;
        self.acc >>= bits;
        self.nbits -= bits;
        v
    }
}

/// Decode the packed code stream into `out` through `dec`, chunked: eight
/// codes of `bits` bits always span exactly `bits` whole bytes, so the wide
/// path stages a `u128` per group and extracts codes with shifts (no
/// per-code byte feed, no bounds checks). The scalar `BitUnpacker` tail
/// covers `n % 8` codes and truncated payloads (zero-extended), keeping the
/// output bit-identical to pulling every code through `BitUnpacker`.
// lint: allow(panic, fn) — chunks_exact pairs guarantee the CHUNK-array cast and le[..b] (b ≤ 16)
// lint: allow(index, fn) — done counts full chunks, so every slice start is ≤ len
fn unpack_map(packed: &[u8], bits: u32, out: &mut [f32], mut dec: impl FnMut(u32) -> f32) {
    let b = bits as usize;
    let mask = (1u128 << b) - 1;
    let mut done = 0usize;
    let mut oc = out.chunks_exact_mut(kernels::CHUNK);
    for (o, by) in oc.by_ref().zip(packed.chunks_exact(b)) {
        let o: &mut [f32; kernels::CHUNK] = o.try_into().unwrap();
        let mut le = [0u8; 16];
        le[..b].copy_from_slice(by);
        let acc = u128::from_le_bytes(le);
        for (i, slot) in o.iter_mut().enumerate() {
            *slot = dec(((acc >> (i * b)) & mask) as u32);
        }
        done += 1;
    }
    let mut up = BitUnpacker::new(&packed[done * b..]);
    for o in out[done * kernels::CHUNK..].iter_mut() {
        *o = dec(up.pull(bits));
    }
}

/// b-bit linear (uniform) stochastic quantization.
///
/// With `L = 2^(b-1) - 1` levels per sign and scale `s = max|x|`, each value
/// maps to `round_stochastic(x / s * L)` ∈ `[-L, L]`, stored as `b`-bit
/// offset codes. `E[decode] = x`; worst-case ω per Definition 1 is bounded
/// by `d / L²` after normalization (tested statistically).
pub struct LinearDither {
    pub bits: u32,
}

impl LinearDither {
    pub fn new(bits: u32) -> Self {
        assert!((2..=16).contains(&bits), "linear dithering bits must be in [2,16], got {bits}");
        LinearDither { bits }
    }

    fn levels(&self) -> i64 {
        (1i64 << (self.bits - 1)) - 1
    }
}

impl Compressor for LinearDither {
    fn name(&self) -> &'static str {
        "linear_dither"
    }

    fn id(&self) -> SchemeId {
        SchemeId::LinearDither
    }

    fn unbiased(&self) -> bool {
        true
    }

    fn compress(&self, x: &[f32], ctx: &mut Ctx) -> Compressed {
        let scale = max_abs(x);
        let l = self.levels();
        let mut payload = Vec::with_capacity(self.wire_nbytes(x.len()));
        super::put_f32(&mut payload, scale);
        // Stage eight codes at a time, then pack them in one byte-aligned
        // shot (`kernels::pack_codes`). The RNG draw order is unchanged:
        // exactly one `next_f32` per element, in slice order.
        let mut codes = [0u32; kernels::CHUNK];
        if scale > 0.0 {
            let inv = l as f32 / scale;
            let quantize = |v: f32, rng: &mut crate::util::rng::Xoshiro256| {
                let q = v * inv; // in [-L, L]
                let lo = q.floor();
                let p = q - lo;
                let level = lo as i64 + if rng.next_f32() < p { 1 } else { 0 };
                let level = level.clamp(-l, l);
                (level + l) as u32
            };
            let mut xc = x.chunks_exact(kernels::CHUNK);
            for c in xc.by_ref() {
                for (o, &v) in codes.iter_mut().zip(c) {
                    *o = quantize(v, ctx.rng);
                }
                kernels::pack_codes(&codes, self.bits, &mut payload);
            }
            let rem = xc.remainder();
            for (o, &v) in codes.iter_mut().zip(rem) {
                *o = quantize(v, ctx.rng);
            }
            kernels::pack_codes(&codes[..rem.len()], self.bits, &mut payload);
        } else {
            codes.fill(l as u32); // code for level 0; no RNG draws
            let mut left = x.len();
            while left >= kernels::CHUNK {
                kernels::pack_codes(&codes, self.bits, &mut payload);
                left -= kernels::CHUNK;
            }
            kernels::pack_codes(&codes[..left], self.bits, &mut payload);
        }
        Compressed { scheme: SchemeId::LinearDither, n: x.len(), payload }
    }

    fn decompress(&self, c: &Compressed, out: &mut [f32]) {
        // lint: allow(panic) — caller contract, not wire data: the output buffer is rented at c.n
        assert_eq!(out.len(), c.n);
        // Wire-data guard: a payload without even the scale header decodes
        // to zeros (reported upstream by `compress::validate_wire`).
        if c.payload.len() < 4 {
            out.fill(0.0);
            return;
        }
        let scale = super::get_f32(&c.payload, 0);
        let l = self.levels();
        let step = if l > 0 { scale / l as f32 } else { 0.0 };
        // lint: allow(index) — the length guard above proves payload.len() >= 4
        unpack_map(&c.payload[4..], self.bits, out, |code| (code as i64 - l) as f32 * step);
    }

    fn wire_nbytes(&self, n: usize) -> usize {
        4 + (n * self.bits as usize).div_ceil(8)
    }
}

/// b-bit natural (power-of-two) stochastic quantization.
///
/// Levels are `{0} ∪ {±s·2^-j : j = 0..2^(b-1)-2}` with `s = max|x|`.
/// A magnitude `u ∈ (0, s]` lands between two adjacent powers of two and is
/// rounded up with probability `(u - 2^p)/2^p`, which is unbiased; below the
/// smallest level it is rounded against 0 (also unbiased).
pub struct NaturalDither {
    pub bits: u32,
}

impl NaturalDither {
    pub fn new(bits: u32) -> Self {
        assert!((2..=8).contains(&bits), "natural dithering bits must be in [2,8], got {bits}");
        NaturalDither { bits }
    }

    /// Number of nonzero magnitude slots.
    fn slots(&self) -> u32 {
        (1u32 << (self.bits - 1)) - 1
    }
}

impl Compressor for NaturalDither {
    fn name(&self) -> &'static str {
        "natural_dither"
    }

    fn id(&self) -> SchemeId {
        SchemeId::NaturalDither
    }

    fn unbiased(&self) -> bool {
        true
    }

    fn compress(&self, x: &[f32], ctx: &mut Ctx) -> Compressed {
        let scale = max_abs(x);
        let slots = self.slots(); // exponents j = 0..slots-1 => levels 2^-j
        let min_exp = -(slots as i32 - 1);
        let mut payload = Vec::with_capacity(self.wire_nbytes(x.len()));
        super::put_f32(&mut payload, scale);
        // Code layout (2·slots + 1 = 2^b − 1 codes):
        //   0            => zero
        //   1 + j        => +scale · 2^-j   (j = 0..slots-1)
        //   1 + slots + j => −scale · 2^-j
        // RNG conditionality is unchanged: exactly one `next_f32` per
        // nonzero element (none when the scale is zero), in slice order.
        let quantize = |v: f32, ctx: &mut Ctx| -> u32 {
            if scale == 0.0 || v == 0.0 {
                return 0;
            }
            let u = (v.abs() / scale).min(1.0); // in (0, 1]
            // Perf (EXPERIMENTS.md §Perf): floor(log2(u)) and the
            // round-up probability come straight from the f32 bit
            // pattern — for normal u = 2^e·(1+m/2^23) the probability
            // (u − 2^e)/2^e equals m·2^-23 — replacing per-element
            // log2/exp2 libm calls.
            let bits = u.to_bits();
            let e = (((bits >> 23) & 0xFF) as i32 - 127).clamp(min_exp - 1, 0);
            let exp = if e < min_exp {
                // Below the smallest level: round between 0 and 2^min_exp.
                let hi = f32::from_bits(((min_exp + 127) as u32) << 23);
                if ctx.rng.next_f32() < u / hi {
                    min_exp
                } else {
                    i32::MIN // rounded to zero
                }
            } else {
                // Between 2^e and 2^(e+1): round up w.p. mantissa·2^-23.
                let p = (bits & 0x7F_FFFF) as f32 * (1.0 / (1u32 << 23) as f32);
                if ctx.rng.next_f32() < p {
                    (e + 1).min(0)
                } else {
                    e
                }
            };
            if exp == i32::MIN {
                0
            } else {
                let j = (-exp) as u32; // 0..slots-1
                if v < 0.0 {
                    1 + slots + j
                } else {
                    1 + j
                }
            }
        };
        // Stage eight codes, pack them byte-aligned in one shot.
        let mut codes = [0u32; kernels::CHUNK];
        let mut xc = x.chunks_exact(kernels::CHUNK);
        for c in xc.by_ref() {
            for (o, &v) in codes.iter_mut().zip(c) {
                *o = quantize(v, ctx);
            }
            kernels::pack_codes(&codes, self.bits, &mut payload);
        }
        let rem = xc.remainder();
        for (o, &v) in codes.iter_mut().zip(rem) {
            *o = quantize(v, ctx);
        }
        kernels::pack_codes(&codes[..rem.len()], self.bits, &mut payload);
        Compressed { scheme: SchemeId::NaturalDither, n: x.len(), payload }
    }

    fn decompress(&self, c: &Compressed, out: &mut [f32]) {
        // lint: allow(panic) — caller contract, not wire data: the output buffer is rented at c.n
        assert_eq!(out.len(), c.n);
        // Wire-data guard (see LinearDither::decompress).
        if c.payload.len() < 4 {
            out.fill(0.0);
            return;
        }
        let scale = super::get_f32(&c.payload, 0);
        // All 2^b ≤ 256 codes decode to fixed levels: precompute once and
        // turn the per-element exp2 into a table load (bit-identical — each
        // table entry *is* `decode_natural` for that code).
        let mut table = [0.0f32; 256];
        for (code, t) in table.iter_mut().enumerate().take(1usize << self.bits) {
            *t = decode_natural(code as u32, scale, self.bits);
        }
        // lint: allow(index) — payload.len() >= 4 checked above; code & 0xFF is always < 256
        unpack_map(&c.payload[4..], self.bits, out, |code| table[(code & 0xFF) as usize]);
    }

    fn wire_nbytes(&self, n: usize) -> usize {
        4 + (n * self.bits as usize).div_ceil(8)
    }
}

fn decode_natural(code: u32, scale: f32, bits: u32) -> f32 {
    if code == 0 {
        return 0.0;
    }
    let slots = (1u32 << (bits - 1)) - 1;
    let c = code - 1;
    let j = c % slots;
    let sign = if c / slots == 1 { -1.0f32 } else { 1.0 };
    sign * scale * (-(j as f32)).exp2()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn bitpacker_roundtrip() {
        for bits in [2u32, 3, 5, 7, 11, 16] {
            let codes: Vec<u32> = (0..257).map(|i| (i * 2654435761u64 as usize) as u32 & ((1 << bits) - 1)).collect();
            let mut p = BitPacker::new(codes.len(), bits);
            for &c in &codes {
                p.push(c, bits);
            }
            let buf = p.finish();
            assert_eq!(buf.len(), (codes.len() * bits as usize).div_ceil(8));
            let mut u = BitUnpacker::new(&buf);
            for &c in &codes {
                assert_eq!(u.pull(bits), c, "bits={bits}");
            }
        }
    }

    #[test]
    fn linear_dither_unbiased_statistical() {
        let n = 32;
        let x: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.41).sin() * 2.0).collect();
        let q = LinearDither::new(5);
        let mut rng = Xoshiro256::seed_from_u64(77);
        let mut mean = vec![0.0f64; n];
        let trials = 6000;
        for _ in 0..trials {
            let c = q.compress(&x, &mut Ctx::new(&mut rng));
            let mut out = vec![0.0f32; n];
            q.decompress(&c, &mut out);
            for (m, o) in mean.iter_mut().zip(&out) {
                *m += *o as f64;
            }
        }
        for i in 0..n {
            let m = mean[i] / trials as f64;
            // step = scale/L = 2/15 ≈ 0.133; mean error should be << step/10
            assert!((m - x[i] as f64).abs() < 0.02, "i={i} m={m} x={}", x[i]);
        }
    }

    #[test]
    fn linear_dither_error_bounded_by_step() {
        forall(100, 0x11d, |g| {
            let n = g.usize_in(1, 300);
            let x = g.f32_vec(n, 6.0);
            let q = LinearDither::new(5);
            let mut rng = Xoshiro256::seed_from_u64(g.seed());
            let c = q.compress(&x, &mut Ctx::new(&mut rng));
            let mut out = vec![0.0f32; n];
            q.decompress(&c, &mut out);
            let scale = crate::util::max_abs(&x);
            let step = scale / 15.0;
            for i in 0..n {
                if (out[i] - x[i]).abs() > step + 1e-6 {
                    return Err(format!("i={i} err={} step={step}", (out[i] - x[i]).abs()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn linear_dither_zero_tensor() {
        let x = vec![0.0f32; 33];
        let q = LinearDither::new(5);
        let mut rng = Xoshiro256::seed_from_u64(0);
        let c = q.compress(&x, &mut Ctx::new(&mut rng));
        let mut out = vec![1.0f32; 33];
        q.decompress(&c, &mut out);
        assert!(out.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn natural_dither_decodes_to_power_of_two_levels() {
        forall(50, 0x9a7, |g| {
            let n = g.usize_in(1, 200);
            let x = g.f32_vec(n, 3.0);
            let q = NaturalDither::new(3);
            let mut rng = Xoshiro256::seed_from_u64(g.seed());
            let c = q.compress(&x, &mut Ctx::new(&mut rng));
            let mut out = vec![0.0f32; n];
            q.decompress(&c, &mut out);
            let scale = crate::util::max_abs(&x);
            for (i, &o) in out.iter().enumerate() {
                if o == 0.0 {
                    continue;
                }
                let ratio = (o.abs() / scale) as f64;
                let j = -ratio.log2();
                if (j - j.round()).abs() > 1e-5 || !(0.0..=2.1).contains(&j) {
                    return Err(format!("i={i} decode {o} not a 2^-j level (scale {scale})"));
                }
                // sign must match the input's sign
                if o.signum() != x[i].signum() {
                    return Err(format!("i={i} sign flipped"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn natural_dither_unbiased_statistical() {
        let n = 16;
        let x: Vec<f32> = (0..n).map(|i| ((i as f32) - 7.5) * 0.13).collect();
        let q = NaturalDither::new(3);
        let mut rng = Xoshiro256::seed_from_u64(99);
        let mut mean = vec![0.0f64; n];
        let trials = 20_000;
        for _ in 0..trials {
            let c = q.compress(&x, &mut Ctx::new(&mut rng));
            let mut out = vec![0.0f32; n];
            q.decompress(&c, &mut out);
            for (m, o) in mean.iter_mut().zip(&out) {
                *m += *o as f64;
            }
        }
        let scale = crate::util::max_abs(&x) as f64;
        for i in 0..n {
            let m = mean[i] / trials as f64;
            // Natural dithering variance is large; tolerance ~2% of scale.
            assert!((m - x[i] as f64).abs() < 0.03 * scale + 0.01, "i={i} m={m} x={}", x[i]);
        }
    }
}
