//! Top-k sparsifier — keep the k largest-magnitude coordinates
//! (Stich et al. '18; the paper's best-performing method for BERT).
//!
//! δ-approximate with δ ≥ k/d. Must run under error feedback. Wire format:
//! `[k: u32][indices: k × u32][values: k × f32]`, i.e. 8 bytes per kept
//! element — with k = 0.1% that is the paper's 333× rate vs FP16.
//!
//! Selection is a full O(d) quickselect on CPU (the paper's rationale for
//! CPU compressors: top-k parallelizes poorly on GPU, §4.1.2).

use super::{Compressed, Compressor, Ctx, SchemeId};

pub struct TopK {
    /// Fraction of coordinates kept, in (0, 1].
    pub ratio: f64,
}

impl TopK {
    pub fn new(ratio: f64) -> Self {
        assert!(ratio > 0.0 && ratio <= 1.0, "top-k ratio must be in (0,1], got {ratio}");
        TopK { ratio }
    }

    pub fn k_for(&self, n: usize) -> usize {
        ((self.ratio * n as f64).ceil() as usize).clamp(1, n.max(1))
    }

    /// Indices of the k largest |x| values, ascending. Ties broken by
    /// lower index (deterministic). Non-finite values (NaN, ±inf) sort as
    /// zero magnitude — see [`mag_bits`].
    ///
    /// Perf note (EXPERIMENTS.md §Perf): quickselect runs on the raw
    /// magnitude *bits* (|f32| bits order like u32 for finite values), not
    /// on an index permutation with an indirect comparator — ~3x faster on
    /// the 2M-element micro-bench and allocation-free index collection.
    fn select(&self, x: &[f32], k: usize) -> Vec<u32> {
        debug_assert!(k >= 1 && k <= x.len());
        if k == x.len() {
            return (0..x.len() as u32).collect();
        }
        let keys: Vec<u32> = x.iter().map(|v| mag_bits(*v)).collect();
        // Quickselect permutes its input, so it runs on a scratch copy and
        // the collection passes below walk the *unpermuted* `keys` — no
        // per-element `mag_bits` recomputation (is_finite branch per value).
        let mut scratch = keys.clone();
        // k-th largest key = (n-k)-th smallest.
        let nth = scratch.len() - k;
        let (_, &mut thr, _) = scratch.select_nth_unstable(nth);
        // Collect strictly-above-threshold indices, then fill remaining
        // slots with ==threshold entries in index order (lower index wins).
        let mut idx = Vec::with_capacity(k);
        for (i, &kb) in keys.iter().enumerate() {
            if kb > thr {
                idx.push(i as u32);
            }
        }
        if idx.len() < k {
            for (i, &kb) in keys.iter().enumerate() {
                if kb == thr {
                    idx.push(i as u32);
                    if idx.len() == k {
                        break;
                    }
                }
            }
            idx.sort_unstable();
        }
        debug_assert_eq!(idx.len(), k);
        idx
    }
}

/// Magnitude ordering key. For finite f32, `bits & 0x7FFF_FFFF` orders
/// identically to `|v|`; NaN bit patterns (e.g. `0x7FC0_0000`) would sort
/// *above* infinity under that map and get preferentially selected, then
/// poison the error-feedback residual forever. Defined behavior: any
/// non-finite value has zero magnitude (never preferred over real data)
/// and is shipped as 0.0 if selection is forced to include it.
#[inline]
fn mag_bits(v: f32) -> u32 {
    if v.is_finite() {
        v.to_bits() & 0x7FFF_FFFF
    } else {
        0
    }
}

/// A selected value as it goes on the wire: non-finite coordinates are
/// zeroed so NaN/inf can never propagate through the aggregation path.
#[inline]
fn wire_value(v: f32) -> f32 {
    if v.is_finite() {
        v
    } else {
        0.0
    }
}

impl Compressor for TopK {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn id(&self) -> SchemeId {
        SchemeId::TopK
    }

    fn unbiased(&self) -> bool {
        false
    }

    fn compress(&self, x: &[f32], _ctx: &mut Ctx) -> Compressed {
        if x.is_empty() {
            let mut payload = Vec::with_capacity(4);
            super::put_u32(&mut payload, 0);
            return Compressed { scheme: SchemeId::TopK, n: 0, payload };
        }
        let k = self.k_for(x.len());
        let idx = self.select(x, k);
        let mut payload = Vec::with_capacity(4 + 8 * k);
        super::put_u32(&mut payload, k as u32);
        for &i in &idx {
            super::put_u32(&mut payload, i);
        }
        for &i in &idx {
            super::put_f32(&mut payload, wire_value(x[i as usize]));
        }
        Compressed { scheme: SchemeId::TopK, n: x.len(), payload }
    }

    fn decompress(&self, c: &Compressed, out: &mut [f32]) {
        // lint: allow(panic) — caller contract, not wire data: the output buffer is rented at c.n
        assert_eq!(out.len(), c.n);
        out.fill(0.0);
        self.add_decompressed(c, out);
    }

    /// O(k) sparse accumulate — the server aggregation fast path.
    ///
    /// The payload is wire data: `k`, the payload length, and every index
    /// are re-checked against `c.n` so a corrupt or malicious block can
    /// never index out of bounds. Transports and the server reject such
    /// blocks up front via [`crate::compress::validate_wire`] (surfacing
    /// `CommError::Protocol`); the guards here make the scheme panic-free
    /// even when called directly on unvalidated data.
    fn add_decompressed(&self, c: &Compressed, acc: &mut [f32]) {
        // lint: allow(panic) — caller contract, not wire data: the accumulator is rented at c.n
        assert_eq!(acc.len(), c.n);
        if c.payload.len() < 4 {
            return; // malformed: no k header
        }
        let k = super::get_u32(&c.payload, 0) as usize;
        if k > c.n || c.payload.len() != 4 + 8 * k {
            return; // malformed: inconsistent k / payload length
        }
        let vals_off = 4 + 4 * k;
        // lint: allow(index) — the length guard above proves payload.len() == 4 + 8k
        super::kernels::sparse_add_le(&c.payload[4..vals_off], &c.payload[vals_off..], acc);
    }

    fn wire_nbytes(&self, n: usize) -> usize {
        if n == 0 {
            return 4;
        }
        4 + 8 * self.k_for(n)
    }

    /// §4.2.2 fused residual: copy-free — the residual is `q` with the
    /// selected k coordinates zero-filled. O(k) after selection instead of
    /// an O(d) decompress + subtract.
    fn compress_ef_fused(&self, q: &mut [f32], _ctx: &mut Ctx) -> Compressed {
        if q.is_empty() {
            let mut payload = Vec::with_capacity(4);
            super::put_u32(&mut payload, 0);
            return Compressed { scheme: SchemeId::TopK, n: 0, payload };
        }
        let k = self.k_for(q.len());
        let idx = self.select(q, k);
        let mut payload = Vec::with_capacity(4 + 8 * k);
        super::put_u32(&mut payload, k as u32);
        for &i in &idx {
            super::put_u32(&mut payload, i);
        }
        for &i in &idx {
            super::put_f32(&mut payload, wire_value(q[i as usize]));
            // Zero-fill: residual for kept coords is 0. For a selected
            // non-finite coordinate this also drops the NaN/inf from the
            // residual instead of carrying it forever.
            q[i as usize] = 0.0;
        }
        Compressed { scheme: SchemeId::TopK, n: q.len(), payload }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;
    use crate::util::l2_norm;
    use crate::util::rng::Xoshiro256;

    fn ctx(rng: &mut Xoshiro256) -> Ctx<'_> {
        Ctx::new(rng)
    }

    #[test]
    fn keeps_the_largest() {
        let x = vec![0.1f32, -5.0, 0.2, 3.0, -0.05, 1.0];
        let t = TopK::new(0.5); // k = 3
        let mut rng = Xoshiro256::seed_from_u64(0);
        let c = t.compress(&x, &mut ctx(&mut rng));
        let mut out = vec![0.0f32; 6];
        t.decompress(&c, &mut out);
        assert_eq!(out, vec![0.0, -5.0, 0.0, 3.0, 0.0, 1.0]);
    }

    #[test]
    fn k_at_least_one_and_ceil() {
        assert_eq!(TopK::new(0.001).k_for(100), 1);
        assert_eq!(TopK::new(0.001).k_for(1500), 2);
        assert_eq!(TopK::new(1.0).k_for(7), 7);
    }

    #[test]
    fn delta_approximate_contract_property() {
        // Definition 2 with δ = k/d: ||C(x)-x||^2 <= (1 - k/d)||x||^2.
        forall(200, 0x70cc, |g| {
            let n = g.usize_in(1, 500);
            let x = g.f32_vec(n, 8.0);
            let ratio = g.f64_in(0.01, 1.0);
            let t = TopK::new(ratio);
            let k = t.k_for(n);
            let mut rng = Xoshiro256::seed_from_u64(g.seed());
            let c = t.compress(&x, &mut ctx(&mut rng));
            let mut out = vec![0.0f32; n];
            t.decompress(&c, &mut out);
            let err2: f64 = x.iter().zip(&out).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
            let norm2 = (l2_norm(&x) as f64).powi(2);
            let bound = (1.0 - k as f64 / n as f64) * norm2;
            if err2 > bound + 1e-5 * norm2 + 1e-9 {
                return Err(format!("err2={err2} bound={bound} n={n} k={k}"));
            }
            Ok(())
        });
    }

    #[test]
    fn kept_set_is_magnitude_optimal() {
        forall(100, 0xabc, |g| {
            let n = g.usize_in(2, 200);
            let x = g.f32_vec(n, 5.0);
            let t = TopK::new(0.25);
            let k = t.k_for(n);
            let mut rng = Xoshiro256::seed_from_u64(0);
            let c = t.compress(&x, &mut ctx(&mut rng));
            let mut out = vec![0.0f32; n];
            t.decompress(&c, &mut out);
            let kept_min = out
                .iter()
                .filter(|v| **v != 0.0)
                .map(|v| v.abs())
                .fold(f32::INFINITY, f32::min);
            let dropped_max = x
                .iter()
                .zip(&out)
                .filter(|(_, o)| **o == 0.0)
                .map(|(v, _)| v.abs())
                .fold(0.0f32, f32::max);
            // every kept magnitude >= every dropped magnitude
            if out.iter().filter(|v| **v != 0.0).count() == k && kept_min + 1e-9 < dropped_max {
                return Err(format!("kept_min={kept_min} < dropped_max={dropped_max}"));
            }
            Ok(())
        });
    }

    #[test]
    fn fused_residual_is_zero_filled_copy() {
        forall(100, 0xd00d, |g| {
            let n = g.usize_in(1, 300);
            let x = g.f32_vec(n, 3.0);
            let t = TopK::new(0.1);
            let mut rng = Xoshiro256::seed_from_u64(0);
            let mut q = x.clone();
            let c = t.compress_ef_fused(&mut q, &mut ctx(&mut rng));
            // fused wire == plain wire
            let mut rng2 = Xoshiro256::seed_from_u64(0);
            let c2 = t.compress(&x, &mut ctx(&mut rng2));
            if c != c2 {
                return Err("fused and plain compress disagree".into());
            }
            // residual == x - decode(c)
            let mut dec = vec![0.0f32; n];
            t.decompress(&c, &mut dec);
            for i in 0..n {
                if (q[i] - (x[i] - dec[i])).abs() > 1e-9 {
                    return Err(format!("residual mismatch at {i}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sparse_accumulate_matches_dense() {
        let x: Vec<f32> = (0..1000).map(|i| ((i * 31) % 97) as f32 - 48.0).collect();
        let t = TopK::new(0.02);
        let mut rng = Xoshiro256::seed_from_u64(0);
        let c = t.compress(&x, &mut ctx(&mut rng));
        let mut acc1 = vec![1.0f32; 1000];
        t.add_decompressed(&c, &mut acc1);
        let mut dense = vec![0.0f32; 1000];
        t.decompress(&c, &mut dense);
        let acc2: Vec<f32> = dense.iter().map(|v| v + 1.0).collect();
        assert_eq!(acc1, acc2);
    }

    #[test]
    fn ties_are_deterministic() {
        let x = vec![1.0f32; 10];
        let t = TopK::new(0.3); // k = 3 of 10 equal values
        let mut r1 = Xoshiro256::seed_from_u64(1);
        let mut r2 = Xoshiro256::seed_from_u64(2);
        let c1 = t.compress(&x, &mut ctx(&mut r1));
        let c2 = t.compress(&x, &mut ctx(&mut r2));
        assert_eq!(c1, c2);
        let mut out = vec![0.0f32; 10];
        t.decompress(&c1, &mut out);
        // lowest indices win ties
        assert_eq!(out, vec![1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn empty_input() {
        let t = TopK::new(0.5);
        let mut rng = Xoshiro256::seed_from_u64(0);
        let c = t.compress(&[], &mut ctx(&mut rng));
        let mut out: Vec<f32> = vec![];
        t.decompress(&c, &mut out);
    }

    /// Regression: NaN magnitude bits (0x7FC0_0000) order above infinity,
    /// so raw-bit selection used to *prefer* NaNs, which then poisoned the
    /// EF residual forever. Defined behavior: non-finite values have zero
    /// magnitude and are shipped as 0.0 when selection is forced.
    #[test]
    fn non_finite_values_are_never_preferred() {
        let mut x = vec![0.01f32; 10];
        x[1] = 1.5;
        x[3] = f32::NAN;
        x[5] = -2.0;
        x[7] = f32::INFINITY;
        let t = TopK::new(0.2); // k = 2
        let mut rng = Xoshiro256::seed_from_u64(0);
        let c = t.compress(&x, &mut ctx(&mut rng));
        let mut out = vec![0.0f32; 10];
        t.decompress(&c, &mut out);
        assert_eq!(out[1], 1.5, "finite spike must win over NaN/inf");
        assert_eq!(out[5], -2.0);
        assert!(out.iter().all(|v| v.is_finite()), "decode must stay finite: {out:?}");
        assert_eq!(out[3], 0.0);
        assert_eq!(out[7], 0.0);
    }

    #[test]
    fn all_nan_input_ships_zeros_and_clears_residual() {
        let t = TopK::new(1.0); // keep everything: selection forced onto NaNs
        let mut q = vec![f32::NAN; 4];
        let mut rng = Xoshiro256::seed_from_u64(0);
        let c = t.compress_ef_fused(&mut q, &mut ctx(&mut rng));
        let mut out = vec![1.0f32; 4];
        t.decompress(&c, &mut out);
        assert!(out.iter().all(|&v| v == 0.0), "NaNs must ship as 0.0: {out:?}");
        // The fused residual drops the NaNs rather than carrying them.
        assert!(q.iter().all(|&v| v == 0.0), "residual must be cleared: {q:?}");
    }

    #[test]
    fn nan_does_not_stick_in_error_feedback() {
        use crate::compress::ef::EfState;
        let comp = TopK::new(0.25); // k = 1 of 4
        let mut ef = EfState::new(true);
        let mut rng = Xoshiro256::seed_from_u64(0);
        // Step 0: a NaN arrives on one coordinate.
        let g0 = vec![1.0f32, f32::NAN, 0.1, 0.1];
        let c = ef.compress(7, &g0, &comp, &mut ctx(&mut rng));
        let mut out = vec![0.0f32; 4];
        comp.decompress(&c, &mut out);
        assert!(out.iter().all(|v| v.is_finite()));
        // Steps 1..: clean gradients. The wire must stay finite throughout
        // (the poisoned coordinate stays NaN in the residual — NaN + g is
        // NaN — but it can never again outrank finite data or be shipped).
        for _ in 0..5 {
            let g = vec![0.5f32, 0.2, 0.3, 0.4];
            let c = ef.compress(7, &g, &comp, &mut ctx(&mut rng));
            let mut out = vec![0.0f32; 4];
            comp.decompress(&c, &mut out);
            assert!(out.iter().all(|v| v.is_finite()), "wire went non-finite: {out:?}");
        }
    }

    /// Corrupt wire blocks must not panic (the server-crash repro): bad k,
    /// bad payload length, and out-of-range indices all degrade to a
    /// skipped block. Error *reporting* happens upstream via
    /// `compress::validate_wire`.
    #[test]
    fn corrupt_blocks_do_not_panic() {
        let t = TopK::new(0.5);
        let mut acc = vec![0.0f32; 8];
        // Empty payload.
        let c = Compressed { scheme: SchemeId::TopK, n: 8, payload: vec![] };
        t.add_decompressed(&c, &mut acc);
        // k larger than n.
        let mut payload = Vec::new();
        crate::compress::put_u32(&mut payload, 100);
        let c = Compressed { scheme: SchemeId::TopK, n: 8, payload };
        t.add_decompressed(&c, &mut acc);
        // Truncated payload (k says 2, only one entry present).
        let mut payload = Vec::new();
        crate::compress::put_u32(&mut payload, 2);
        crate::compress::put_u32(&mut payload, 1);
        crate::compress::put_f32(&mut payload, 3.0);
        let c = Compressed { scheme: SchemeId::TopK, n: 8, payload };
        t.add_decompressed(&c, &mut acc);
        // Out-of-range index with otherwise consistent layout.
        let mut payload = Vec::new();
        crate::compress::put_u32(&mut payload, 2);
        crate::compress::put_u32(&mut payload, 1);
        crate::compress::put_u32(&mut payload, 4096); // >= n
        crate::compress::put_f32(&mut payload, 3.0);
        crate::compress::put_f32(&mut payload, 5.0);
        let c = Compressed { scheme: SchemeId::TopK, n: 8, payload };
        assert!(crate::compress::validate_wire(&c).is_err());
        t.add_decompressed(&c, &mut acc);
        // Only the in-range entry of the last block landed.
        assert_eq!(acc[1], 3.0);
        assert!(acc.iter().enumerate().all(|(i, &v)| i == 1 || v == 0.0));
    }
}
