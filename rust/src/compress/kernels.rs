//! Chunked, bounds-check-free inner loops shared by the compressors, the
//! error-feedback cycle, and the server reduce path.
//!
//! Everything here is stable Rust: fixed-width chunks via `chunks_exact` /
//! `chunks_exact_mut`, converted to array references with `try_into()` so
//! the optimizer sees a compile-time length and drops the per-element bounds
//! checks, plus an explicit scalar tail for `n % CHUNK != 0`. The loop
//! bodies avoid float reassociation so every kernel stays **bit-identical**
//! to the scalar reference implementations in [`crate::compress::reference`]
//! — the suite in `rust/tests/kernel_identity.rs` pins that contract across
//! `paper_suite()`, including non-finite inputs and tail-sized blocks.

use crate::util::f16::{f16_bits_to_f32, f32_to_f16_bits};

/// Chunk width for element-wise f32 loops: two 128-bit lanes' worth, wide
/// enough for SSE2/NEON autovectorization while keeping tails cheap.
pub const CHUNK: usize = 8;

/// `dst[i] += src[i]` element-wise. Per-lane adds in slice order — no
/// reassociation, so the result is bit-identical to the scalar loop.
#[inline]
pub fn add_assign(dst: &mut [f32], src: &[f32]) {
    let n = dst.len().min(src.len());
    let mut d = dst[..n].chunks_exact_mut(CHUNK);
    let mut s = src[..n].chunks_exact(CHUNK);
    for (dc, sc) in d.by_ref().zip(s.by_ref()) {
        let dc: &mut [f32; CHUNK] = dc.try_into().unwrap();
        let sc: &[f32; CHUNK] = sc.try_into().unwrap();
        for i in 0..CHUNK {
            dc[i] += sc[i];
        }
    }
    for (a, b) in d.into_remainder().iter_mut().zip(s.remainder()) {
        *a += *b;
    }
}

/// `dst[i] -= src[i]` element-wise (error-feedback residual decay).
#[inline]
pub fn sub_assign(dst: &mut [f32], src: &[f32]) {
    let n = dst.len().min(src.len());
    let mut d = dst[..n].chunks_exact_mut(CHUNK);
    let mut s = src[..n].chunks_exact(CHUNK);
    for (dc, sc) in d.by_ref().zip(s.by_ref()) {
        let dc: &mut [f32; CHUNK] = dc.try_into().unwrap();
        let sc: &[f32; CHUNK] = sc.try_into().unwrap();
        for i in 0..CHUNK {
            dc[i] -= sc[i];
        }
    }
    for (a, b) in d.into_remainder().iter_mut().zip(s.remainder()) {
        *a -= *b;
    }
}

/// `x[i] *= s` element-wise (server-side mean scaling).
#[inline]
pub fn scale_assign(x: &mut [f32], s: f32) {
    let mut it = x.chunks_exact_mut(CHUNK);
    for c in it.by_ref() {
        let c: &mut [f32; CHUNK] = c.try_into().unwrap();
        for v in c.iter_mut() {
            *v *= s;
        }
    }
    for v in it.into_remainder() {
        *v *= s;
    }
}

// --- identity (raw f32) ------------------------------------------------------

/// Append `x` as little-endian f32 bytes to `out` in one resize + bulk loop.
#[inline]
pub fn f32_to_le_bytes(x: &[f32], out: &mut Vec<u8>) {
    let start = out.len();
    out.resize(start + 4 * x.len(), 0);
    for (v, o) in x.iter().zip(out[start..].chunks_exact_mut(4)) {
        o.copy_from_slice(&v.to_le_bytes());
    }
}

/// `out[i] = f32::from_le_bytes(bytes[4i..])` for `min` of both lengths.
// lint: allow(panic, fn) — chunks_exact(4) guarantees the 4-byte array cast
#[inline]
pub fn le_bytes_to_f32(bytes: &[u8], out: &mut [f32]) {
    for (b, o) in bytes.chunks_exact(4).zip(out.iter_mut()) {
        *o = f32::from_le_bytes(b.try_into().unwrap());
    }
}

/// `acc[i] += f32::from_le_bytes(bytes[4i..])` for `min` of both lengths.
// lint: allow(panic, fn) — chunks_exact(4) guarantees the 4-byte array cast
#[inline]
pub fn le_bytes_add_f32(bytes: &[u8], acc: &mut [f32]) {
    for (b, a) in bytes.chunks_exact(4).zip(acc.iter_mut()) {
        *a += f32::from_le_bytes(b.try_into().unwrap());
    }
}

// --- fp16 --------------------------------------------------------------------

/// Encode `src` as little-endian binary16 into `dst` (`2 * src.len()` bytes).
#[inline]
pub fn f32_to_f16_slice(src: &[f32], dst: &mut [u8]) {
    debug_assert_eq!(dst.len(), 2 * src.len());
    for (v, o) in src.iter().zip(dst.chunks_exact_mut(2)) {
        o.copy_from_slice(&f32_to_f16_bits(*v).to_le_bytes());
    }
}

/// Decode little-endian binary16 from `src` into `dst`.
// lint: allow(panic, fn) — chunks_exact(2) guarantees the 2-byte array cast
#[inline]
pub fn f16_to_f32_slice(src: &[u8], dst: &mut [f32]) {
    for (b, o) in src.chunks_exact(2).zip(dst.iter_mut()) {
        *o = f16_bits_to_f32(u16::from_le_bytes(b.try_into().unwrap()));
    }
}

/// `acc[i] += decode(src[2i..])` — the fp16 aggregation path.
// lint: allow(panic, fn) — chunks_exact(2) guarantees the 2-byte array cast
#[inline]
pub fn f16_add_decoded(src: &[u8], acc: &mut [f32]) {
    for (b, a) in src.chunks_exact(2).zip(acc.iter_mut()) {
        *a += f16_bits_to_f32(u16::from_le_bytes(b.try_into().unwrap()));
    }
}

/// Fused fp16 encode + residual: write `f16(x[i])` to `dst` and overwrite
/// `x[i]` with `x[i] - decode(f16(x[i]))` in one pass.
#[inline]
pub fn f16_encode_residual(x: &mut [f32], dst: &mut [u8]) {
    debug_assert_eq!(dst.len(), 2 * x.len());
    for (v, o) in x.iter_mut().zip(dst.chunks_exact_mut(2)) {
        let bits = f32_to_f16_bits(*v);
        o.copy_from_slice(&bits.to_le_bytes());
        *v -= f16_bits_to_f32(bits);
    }
}

// --- scaled one-bit ----------------------------------------------------------

/// Decode one sign bit into `±scale` bit-exactly: `-scale` is an IEEE sign
/// flip, so XOR-ing the sign bit in matches `if bit { scale } else { -scale }`
/// for every scale including ±0.0 and non-finite values.
#[inline(always)]
fn sign_decode(scale_bits: u32, bit: u32) -> f32 {
    f32::from_bits(scale_bits ^ ((bit ^ 1) << 31))
}

/// Pack sign bits of `x` (bit set ⇔ `v >= 0.0`, so sign(0) := +1 and
/// NaN := −1) into `bits`, LSB-first, `⌈n/8⌉` bytes.
#[inline]
pub fn sign_pack(x: &[f32], bits: &mut [u8]) {
    debug_assert_eq!(bits.len(), x.len().div_ceil(8));
    let mut xc = x.chunks_exact(CHUNK);
    let mut bc = bits.iter_mut();
    for (c, b) in xc.by_ref().zip(bc.by_ref()) {
        let c: &[f32; CHUNK] = c.try_into().unwrap();
        let mut byte = 0u8;
        for (i, v) in c.iter().enumerate() {
            byte |= ((*v >= 0.0) as u8) << i;
        }
        *b = byte;
    }
    let rem = xc.remainder();
    if !rem.is_empty() {
        let b = bc.next().expect("bitmap sized for input");
        let mut byte = 0u8;
        for (i, v) in rem.iter().enumerate() {
            byte |= ((*v >= 0.0) as u8) << i;
        }
        *b = byte;
    }
}

/// `out[i] = ±scale` from the packed sign bitmap.
// lint: allow(panic, fn) — chunks_exact_mut(CHUNK) guarantees the CHUNK-array cast
#[inline]
pub fn sign_unpack_scaled(bits: &[u8], scale: f32, out: &mut [f32]) {
    let sb = scale.to_bits();
    let mut oc = out.chunks_exact_mut(CHUNK);
    let mut bc = bits.iter();
    for (c, b) in oc.by_ref().zip(bc.by_ref()) {
        let c: &mut [f32; CHUNK] = c.try_into().unwrap();
        let b = *b as u32;
        for (i, o) in c.iter_mut().enumerate() {
            *o = sign_decode(sb, (b >> i) & 1);
        }
    }
    let rem = oc.into_remainder();
    if !rem.is_empty() {
        let b = bc.next().copied().unwrap_or(0) as u32;
        for (i, o) in rem.iter_mut().enumerate() {
            *o = sign_decode(sb, (b >> i) & 1);
        }
    }
}

/// `acc[i] += ±scale` from the packed sign bitmap (IEEE `a - s == a + (-s)`
/// exactly, so this matches the scalar add/sub branches bit-for-bit).
// lint: allow(panic, fn) — chunks_exact_mut(CHUNK) guarantees the CHUNK-array cast
#[inline]
pub fn sign_add_scaled(bits: &[u8], scale: f32, acc: &mut [f32]) {
    let sb = scale.to_bits();
    let mut oc = acc.chunks_exact_mut(CHUNK);
    let mut bc = bits.iter();
    for (c, b) in oc.by_ref().zip(bc.by_ref()) {
        let c: &mut [f32; CHUNK] = c.try_into().unwrap();
        let b = *b as u32;
        for (i, o) in c.iter_mut().enumerate() {
            *o += sign_decode(sb, (b >> i) & 1);
        }
    }
    let rem = oc.into_remainder();
    if !rem.is_empty() {
        let b = bc.next().copied().unwrap_or(0) as u32;
        for (i, o) in rem.iter_mut().enumerate() {
            *o += sign_decode(sb, (b >> i) & 1);
        }
    }
}

/// Fused one-bit encode + residual: set the sign bit and subtract the
/// decoded `±scale` in one pass. The residual update keeps the scalar
/// reference's add/sub branch structure (`v -= scale` / `v += scale`) so
/// even a NaN scale produces bit-identical residuals (`a + s` and
/// `a - (-s)` may disagree in the NaN sign bit); LLVM if-converts the
/// branch to a select.
#[inline]
pub fn sign_pack_residual(x: &mut [f32], scale: f32, bits: &mut [u8]) {
    debug_assert_eq!(bits.len(), x.len().div_ceil(8));
    let mut xc = x.chunks_exact_mut(CHUNK);
    let mut bc = bits.iter_mut();
    for (c, b) in xc.by_ref().zip(bc.by_ref()) {
        let c: &mut [f32; CHUNK] = c.try_into().unwrap();
        let mut byte = 0u8;
        for (i, v) in c.iter_mut().enumerate() {
            if *v >= 0.0 {
                byte |= 1 << i;
                *v -= scale;
            } else {
                *v += scale;
            }
        }
        *b = byte;
    }
    let rem = xc.into_remainder();
    if !rem.is_empty() {
        let b = bc.next().expect("bitmap sized for input");
        let mut byte = 0u8;
        for (i, v) in rem.iter_mut().enumerate() {
            if *v >= 0.0 {
                byte |= 1 << i;
                *v -= scale;
            } else {
                *v += scale;
            }
        }
        *b = byte;
    }
}

// --- dithering bit codec -----------------------------------------------------

/// Pack `codes` (each `< 2^bits`, `bits` in 2..=16) LSB-first into `out`,
/// byte-identical to pushing them through `dither::BitPacker` + `finish()`.
/// Eight codes of `bits` bits always occupy exactly `bits` whole bytes, so
/// the wide path stages them in a `u128` and writes those bytes in one shot;
/// the `< 8`-code tail resumes the identical bit stream with the scalar
/// accumulator (chunk boundaries fall on byte boundaries by construction).
#[inline]
pub fn pack_codes(codes: &[u32], bits: u32, out: &mut Vec<u8>) {
    let b = bits as usize;
    debug_assert!((1..=16).contains(&b));
    let mut cc = codes.chunks_exact(CHUNK);
    for c in cc.by_ref() {
        let c: &[u32; CHUNK] = c.try_into().unwrap();
        let mut acc = 0u128;
        for (i, &code) in c.iter().enumerate() {
            acc |= (code as u128) << (i * b);
        }
        out.extend_from_slice(&acc.to_le_bytes()[..b]);
    }
    let mut acc = 0u64;
    let mut nbits = 0u32;
    for &code in cc.remainder() {
        acc |= (code as u64) << nbits;
        nbits += bits;
        while nbits >= 8 {
            out.push((acc & 0xFF) as u8);
            acc >>= 8;
            nbits -= 8;
        }
    }
    if nbits > 0 {
        out.push((acc & 0xFF) as u8);
    }
}

/// Unpack `codes.len()` codes of `bits` bits LSB-first from `buf`,
/// zero-extending past the end of a truncated buffer exactly like
/// `dither::BitUnpacker` (wire data is untrusted). The wide path reads
/// `bits` whole bytes per eight codes; the scalar tail also takes over for
/// whatever a short buffer cannot back.
// lint: allow(panic, fn) — chunks_exact pairs guarantee the CHUNK-array cast and le[..b] (b ≤ 16)
// lint: allow(index, fn) — done counts full chunks, so every slice start is ≤ len
#[inline]
pub fn unpack_codes(buf: &[u8], bits: u32, codes: &mut [u32]) {
    let b = bits as usize;
    debug_assert!((1..=16).contains(&b));
    let mask = (1u128 << b) - 1;
    let mut done = 0usize;
    {
        let mut cc = codes.chunks_exact_mut(CHUNK);
        for (c, by) in cc.by_ref().zip(buf.chunks_exact(b)) {
            let c: &mut [u32; CHUNK] = c.try_into().unwrap();
            let mut le = [0u8; 16];
            le[..b].copy_from_slice(by);
            let acc = u128::from_le_bytes(le);
            for (i, o) in c.iter_mut().enumerate() {
                *o = ((acc >> (i * b)) & mask) as u32;
            }
            done += 1;
        }
    }
    // Scalar tail: resumes at a byte boundary; `unwrap_or(0)` reproduces the
    // BitUnpacker truncation behavior.
    let mut byte = done * b;
    let mut acc = 0u64;
    let mut nbits = 0u32;
    let mask32 = (1u32 << bits) - 1;
    for o in codes[done * CHUNK..].iter_mut() {
        while nbits < bits {
            acc |= (buf.get(byte).copied().unwrap_or(0) as u64) << nbits;
            byte += 1;
            nbits += 8;
        }
        *o = (acc as u32) & mask32;
        acc >>= bits;
        nbits -= bits;
    }
}

// --- sparse adds -------------------------------------------------------------

/// `acc[idx[j]] += val[j]` for little-endian u32 indices and f32 values in
/// separate byte regions (the top-k wire layout). Indices are untrusted wire
/// data, so out-of-range entries are skipped — the `get_mut` check is the
/// only branch left in the loop.
// lint: allow(panic, fn) — chunks_exact(4) guarantees the 4-byte array cast
#[inline]
pub fn sparse_add_le(idx_bytes: &[u8], val_bytes: &[u8], acc: &mut [f32]) {
    for (ib, vb) in idx_bytes.chunks_exact(4).zip(val_bytes.chunks_exact(4)) {
        let i = u32::from_le_bytes(ib.try_into().unwrap()) as usize;
        let v = f32::from_le_bytes(vb.try_into().unwrap());
        if let Some(a) = acc.get_mut(i) {
            *a += v;
        }
    }
}

/// `acc[indices[j]] += val[j]` where indices are trusted in-range (random-k
/// regenerates them from the wire seed, bounded by construction).
// lint: allow(panic, fn) — chunks_exact(4) guarantees the 4-byte array cast
// lint: allow(index, fn) — random-k regenerates the indices from the wire seed, in range by construction
#[inline]
pub fn sparse_add_indexed(indices: &[u32], val_bytes: &[u8], acc: &mut [f32]) {
    for (&i, vb) in indices.iter().zip(val_bytes.chunks_exact(4)) {
        acc[i as usize] += f32::from_le_bytes(vb.try_into().unwrap());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_sub_scale_match_scalar_loops() {
        let a: Vec<f32> = (0..1003).map(|i| (i as f32 * 0.13).sin()).collect();
        let b: Vec<f32> = (0..1003).map(|i| (i as f32 * 0.29).cos()).collect();
        let mut k = a.clone();
        let mut s = a.clone();
        add_assign(&mut k, &b);
        for (x, y) in s.iter_mut().zip(&b) {
            *x += *y;
        }
        let kb: Vec<u32> = k.iter().map(|v| v.to_bits()).collect();
        let sb: Vec<u32> = s.iter().map(|v| v.to_bits()).collect();
        assert_eq!(kb, sb);
        sub_assign(&mut k, &b);
        for (x, y) in s.iter_mut().zip(&b) {
            *x -= *y;
        }
        assert_eq!(k, s);
        scale_assign(&mut k, 0.25);
        for x in s.iter_mut() {
            *x *= 0.25;
        }
        assert_eq!(k, s);
    }

    #[test]
    fn sign_decode_is_bit_exact() {
        for scale in [0.0f32, -0.0, 1.5, f32::INFINITY, f32::MIN_POSITIVE] {
            let sb = scale.to_bits();
            assert_eq!(sign_decode(sb, 1).to_bits(), scale.to_bits());
            assert_eq!(sign_decode(sb, 0).to_bits(), (-scale).to_bits());
        }
    }

    #[test]
    fn pack_unpack_codes_roundtrip_all_widths() {
        for bits in [2u32, 3, 5, 7, 11, 16] {
            let mask = (1u32 << bits) - 1;
            for n in [0usize, 1, 7, 8, 9, 63, 100] {
                let codes: Vec<u32> = (0..n as u32).map(|i| (i * 2654435761) & mask).collect();
                let mut packed = Vec::new();
                pack_codes(&codes, bits, &mut packed);
                assert_eq!(packed.len(), (n * bits as usize).div_ceil(8));
                let mut back = vec![0u32; n];
                unpack_codes(&packed, bits, &mut back);
                assert_eq!(back, codes, "bits={bits} n={n}");
                // Truncated buffer zero-extends instead of panicking.
                if !packed.is_empty() {
                    let mut short = vec![0u32; n];
                    unpack_codes(&packed[..packed.len() - 1], bits, &mut short);
                    assert_eq!(short.len(), n);
                }
            }
        }
    }

    #[test]
    fn sign_roundtrip_tail_sizes() {
        for n in [0usize, 1, 7, 8, 9, 17, 64, 100] {
            let x: Vec<f32> = (0..n).map(|i| if i % 3 == 0 { -1.0 } else { 1.0 }).collect();
            let mut bits = vec![0u8; n.div_ceil(8)];
            sign_pack(&x, &mut bits);
            let mut out = vec![0.0f32; n];
            sign_unpack_scaled(&bits, 2.0, &mut out);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, if i % 3 == 0 { -2.0 } else { 2.0 });
            }
        }
    }
}
