//! Scalar reference compressors — the pre-vectorization implementations,
//! kept **verbatim** as ground truth for the chunked kernels in
//! [`crate::compress::kernels`].
//!
//! Every scheme's hot loops were rewritten as fixed-width chunked loops
//! (see EXPERIMENTS.md §Perf); the originals live on here so the
//! bit-identity suite (`rust/tests/kernel_identity.rs`) can assert that the
//! fast paths produce byte-identical wire payloads and f32-bit-identical
//! decompress/EF results across `paper_suite()`. Do not "optimize" this
//! module: its entire value is staying a frozen, obviously-correct copy.

use super::dither::{BitPacker, BitUnpacker};
use super::{Compressed, Compressor, Ctx, SchemeId};
use crate::util::f16::{f16_bits_to_f32, f32_to_f16_bits};
use crate::util::max_abs;
use crate::util::rng::Xoshiro256;
use std::sync::Arc;

/// The scalar counterpart of [`super::ef::compress_cycle`] (Alg. 4 compress
/// step) with the accumulate/decay loops written element-wise.
pub fn compress_cycle_scalar(
    comp: &dyn Compressor,
    fused: bool,
    ctx: &mut Ctx,
    mut g: Vec<f32>,
    residual: Option<&[f32]>,
) -> (Compressed, Vec<f32>) {
    if let Some(e) = residual {
        assert_eq!(e.len(), g.len(), "EF residual size drifted");
        for (gi, ei) in g.iter_mut().zip(e) {
            *gi += *ei;
        }
    }
    if fused {
        let c = comp.compress_ef_fused(&mut g, ctx);
        (c, g)
    } else {
        let c = comp.compress(&g, ctx);
        let mut dec = vec![0.0f32; g.len()];
        comp.decompress(&c, &mut dec);
        for (gi, di) in g.iter_mut().zip(&dec) {
            *gi -= *di;
        }
        (c, g)
    }
}

/// Scalar references for the full paper suite, labels matching
/// [`super::paper_suite`] pairwise.
pub fn scalar_suite() -> Vec<(&'static str, Arc<dyn Compressor>)> {
    vec![
        ("NAG", Arc::new(ScalarIdentity)),
        ("NAG (FP16)", Arc::new(ScalarFp16)),
        ("Scaled 1-bit with EF", Arc::new(ScalarOneBit)),
        ("Random-k with EF", Arc::new(ScalarRandomK { ratio: 1.0 / 32.0, rescale: false })),
        ("Top-k with EF", Arc::new(ScalarTopK { ratio: 0.001 })),
        ("Linear Dithering", Arc::new(ScalarLinearDither { bits: 5 })),
        ("Natural Dithering", Arc::new(ScalarNaturalDither { bits: 3 })),
    ]
}

// --- identity ----------------------------------------------------------------

pub struct ScalarIdentity;

impl Compressor for ScalarIdentity {
    fn name(&self) -> &'static str {
        "identity"
    }

    fn id(&self) -> SchemeId {
        SchemeId::Identity
    }

    fn unbiased(&self) -> bool {
        true
    }

    fn compress(&self, x: &[f32], _ctx: &mut Ctx) -> Compressed {
        let mut payload = Vec::with_capacity(4 * x.len());
        for &v in x {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        Compressed { scheme: SchemeId::Identity, n: x.len(), payload }
    }

    fn decompress(&self, c: &Compressed, out: &mut [f32]) {
        assert_eq!(out.len(), c.n);
        if c.payload.len() != 4 * c.n {
            out.fill(0.0);
            return;
        }
        for (i, o) in out.iter_mut().enumerate() {
            *o = super::get_f32(&c.payload, 4 * i);
        }
    }

    fn add_decompressed(&self, c: &Compressed, acc: &mut [f32]) {
        assert_eq!(acc.len(), c.n);
        if c.payload.len() != 4 * c.n {
            return;
        }
        for (i, a) in acc.iter_mut().enumerate() {
            *a += super::get_f32(&c.payload, 4 * i);
        }
    }

    fn wire_nbytes(&self, n: usize) -> usize {
        4 * n
    }

    fn compress_ef_fused(&self, q: &mut [f32], ctx: &mut Ctx) -> Compressed {
        let c = self.compress(q, ctx);
        q.fill(0.0);
        c
    }
}

// --- fp16 --------------------------------------------------------------------

pub struct ScalarFp16;

impl Compressor for ScalarFp16 {
    fn name(&self) -> &'static str {
        "fp16"
    }

    fn id(&self) -> SchemeId {
        SchemeId::Fp16
    }

    fn unbiased(&self) -> bool {
        false
    }

    fn compress(&self, x: &[f32], _ctx: &mut Ctx) -> Compressed {
        let mut payload = vec![0u8; 2 * x.len()];
        for (i, &v) in x.iter().enumerate() {
            let bits = f32_to_f16_bits(v);
            payload[2 * i..2 * i + 2].copy_from_slice(&bits.to_le_bytes());
        }
        Compressed { scheme: SchemeId::Fp16, n: x.len(), payload }
    }

    fn decompress(&self, c: &Compressed, out: &mut [f32]) {
        assert_eq!(out.len(), c.n);
        if c.payload.len() != 2 * c.n {
            out.fill(0.0);
            return;
        }
        for (i, o) in out.iter_mut().enumerate() {
            let bits = u16::from_le_bytes(c.payload[2 * i..2 * i + 2].try_into().unwrap());
            *o = f16_bits_to_f32(bits);
        }
    }

    fn add_decompressed(&self, c: &Compressed, acc: &mut [f32]) {
        assert_eq!(acc.len(), c.n);
        if c.payload.len() != 2 * c.n {
            return;
        }
        for (i, a) in acc.iter_mut().enumerate() {
            let bits = u16::from_le_bytes(c.payload[2 * i..2 * i + 2].try_into().unwrap());
            *a += f16_bits_to_f32(bits);
        }
    }

    fn wire_nbytes(&self, n: usize) -> usize {
        2 * n
    }

    fn compress_ef_fused(&self, q: &mut [f32], _ctx: &mut Ctx) -> Compressed {
        let mut payload = vec![0u8; 2 * q.len()];
        for (i, v) in q.iter_mut().enumerate() {
            let bits = f32_to_f16_bits(*v);
            payload[2 * i..2 * i + 2].copy_from_slice(&bits.to_le_bytes());
            *v -= f16_bits_to_f32(bits);
        }
        Compressed { scheme: SchemeId::Fp16, n: q.len(), payload }
    }
}

// --- scaled one-bit ----------------------------------------------------------

pub struct ScalarOneBit;

impl ScalarOneBit {
    fn scale_of(x: &[f32]) -> f32 {
        if x.is_empty() {
            return 0.0;
        }
        let l1: f64 = x.iter().map(|v| v.abs() as f64).sum();
        (l1 / x.len() as f64) as f32
    }
}

impl Compressor for ScalarOneBit {
    fn name(&self) -> &'static str {
        "onebit"
    }

    fn id(&self) -> SchemeId {
        SchemeId::OneBit
    }

    fn unbiased(&self) -> bool {
        false
    }

    fn compress(&self, x: &[f32], _ctx: &mut Ctx) -> Compressed {
        let scale = Self::scale_of(x);
        let nbytes = x.len().div_ceil(8);
        let mut payload = Vec::with_capacity(4 + nbytes);
        super::put_f32(&mut payload, scale);
        payload.resize(4 + nbytes, 0);
        let bits = &mut payload[4..];
        for (i, &v) in x.iter().enumerate() {
            if v >= 0.0 {
                bits[i / 8] |= 1 << (i % 8);
            }
        }
        Compressed { scheme: SchemeId::OneBit, n: x.len(), payload }
    }

    fn decompress(&self, c: &Compressed, out: &mut [f32]) {
        assert_eq!(out.len(), c.n);
        if c.payload.len() != 4 + c.n.div_ceil(8) {
            out.fill(0.0);
            return;
        }
        let scale = super::get_f32(&c.payload, 0);
        let bits = &c.payload[4..];
        for (i, o) in out.iter_mut().enumerate() {
            *o = if bits[i / 8] & (1 << (i % 8)) != 0 { scale } else { -scale };
        }
    }

    fn add_decompressed(&self, c: &Compressed, acc: &mut [f32]) {
        assert_eq!(acc.len(), c.n);
        if c.payload.len() != 4 + c.n.div_ceil(8) {
            return;
        }
        let scale = super::get_f32(&c.payload, 0);
        let bits = &c.payload[4..];
        for (i, a) in acc.iter_mut().enumerate() {
            *a += if bits[i / 8] & (1 << (i % 8)) != 0 { scale } else { -scale };
        }
    }

    fn wire_nbytes(&self, n: usize) -> usize {
        4 + n.div_ceil(8)
    }

    fn compress_ef_fused(&self, q: &mut [f32], _ctx: &mut Ctx) -> Compressed {
        let scale = Self::scale_of(q);
        let nbytes = q.len().div_ceil(8);
        let mut payload = Vec::with_capacity(4 + nbytes);
        super::put_f32(&mut payload, scale);
        payload.resize(4 + nbytes, 0);
        let bits = &mut payload[4..];
        for (i, v) in q.iter_mut().enumerate() {
            if *v >= 0.0 {
                bits[i / 8] |= 1 << (i % 8);
                *v -= scale;
            } else {
                *v += scale;
            }
        }
        Compressed { scheme: SchemeId::OneBit, n: q.len(), payload }
    }
}

// --- top-k -------------------------------------------------------------------

pub struct ScalarTopK {
    pub ratio: f64,
}

impl ScalarTopK {
    fn k_for(&self, n: usize) -> usize {
        ((self.ratio * n as f64).ceil() as usize).clamp(1, n.max(1))
    }

    /// The original selection, including the redundant per-pass `mag_bits`
    /// recomputation that `TopK::select` no longer does.
    fn select(&self, x: &[f32], k: usize) -> Vec<u32> {
        debug_assert!(k >= 1 && k <= x.len());
        if k == x.len() {
            return (0..x.len() as u32).collect();
        }
        let mut keys: Vec<u32> = x.iter().map(|v| mag_bits(*v)).collect();
        let nth = keys.len() - k;
        let (_, &mut thr, _) = keys.select_nth_unstable(nth);
        let mut idx = Vec::with_capacity(k);
        for (i, v) in x.iter().enumerate() {
            if mag_bits(*v) > thr {
                idx.push(i as u32);
            }
        }
        if idx.len() < k {
            for (i, v) in x.iter().enumerate() {
                if mag_bits(*v) == thr {
                    idx.push(i as u32);
                    if idx.len() == k {
                        break;
                    }
                }
            }
            idx.sort_unstable();
        }
        debug_assert_eq!(idx.len(), k);
        idx
    }
}

#[inline]
fn mag_bits(v: f32) -> u32 {
    if v.is_finite() {
        v.to_bits() & 0x7FFF_FFFF
    } else {
        0
    }
}

#[inline]
fn wire_value(v: f32) -> f32 {
    if v.is_finite() {
        v
    } else {
        0.0
    }
}

impl Compressor for ScalarTopK {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn id(&self) -> SchemeId {
        SchemeId::TopK
    }

    fn unbiased(&self) -> bool {
        false
    }

    fn compress(&self, x: &[f32], _ctx: &mut Ctx) -> Compressed {
        if x.is_empty() {
            let mut payload = Vec::with_capacity(4);
            super::put_u32(&mut payload, 0);
            return Compressed { scheme: SchemeId::TopK, n: 0, payload };
        }
        let k = self.k_for(x.len());
        let idx = self.select(x, k);
        let mut payload = Vec::with_capacity(4 + 8 * k);
        super::put_u32(&mut payload, k as u32);
        for &i in &idx {
            super::put_u32(&mut payload, i);
        }
        for &i in &idx {
            super::put_f32(&mut payload, wire_value(x[i as usize]));
        }
        Compressed { scheme: SchemeId::TopK, n: x.len(), payload }
    }

    fn decompress(&self, c: &Compressed, out: &mut [f32]) {
        assert_eq!(out.len(), c.n);
        out.fill(0.0);
        self.add_decompressed(c, out);
    }

    fn add_decompressed(&self, c: &Compressed, acc: &mut [f32]) {
        assert_eq!(acc.len(), c.n);
        if c.payload.len() < 4 {
            return;
        }
        let k = super::get_u32(&c.payload, 0) as usize;
        if k > c.n || c.payload.len() != 4 + 8 * k {
            return;
        }
        let vals_off = 4 + 4 * k;
        for j in 0..k {
            let i = super::get_u32(&c.payload, 4 + 4 * j) as usize;
            if let Some(a) = acc.get_mut(i) {
                *a += super::get_f32(&c.payload, vals_off + 4 * j);
            }
        }
    }

    fn wire_nbytes(&self, n: usize) -> usize {
        if n == 0 {
            return 4;
        }
        4 + 8 * self.k_for(n)
    }

    fn compress_ef_fused(&self, q: &mut [f32], _ctx: &mut Ctx) -> Compressed {
        if q.is_empty() {
            let mut payload = Vec::with_capacity(4);
            super::put_u32(&mut payload, 0);
            return Compressed { scheme: SchemeId::TopK, n: 0, payload };
        }
        let k = self.k_for(q.len());
        let idx = self.select(q, k);
        let mut payload = Vec::with_capacity(4 + 8 * k);
        super::put_u32(&mut payload, k as u32);
        for &i in &idx {
            super::put_u32(&mut payload, i);
        }
        for &i in &idx {
            super::put_f32(&mut payload, wire_value(q[i as usize]));
            q[i as usize] = 0.0;
        }
        Compressed { scheme: SchemeId::TopK, n: q.len(), payload }
    }
}

// --- random-k ----------------------------------------------------------------

pub struct ScalarRandomK {
    pub ratio: f64,
    pub rescale: bool,
}

impl ScalarRandomK {
    fn k_for(&self, n: usize) -> usize {
        ((self.ratio * n as f64).ceil() as usize).clamp(1, n.max(1))
    }

    fn indices_from_seed(seed: u64, n: usize, k: usize) -> Vec<u32> {
        Xoshiro256::seed_from_u64(seed).sample_indices(n, k)
    }
}

impl Compressor for ScalarRandomK {
    fn name(&self) -> &'static str {
        if self.rescale {
            "randomk_unbiased"
        } else {
            "randomk"
        }
    }

    fn id(&self) -> SchemeId {
        SchemeId::RandomK
    }

    fn unbiased(&self) -> bool {
        self.rescale
    }

    fn compress(&self, x: &[f32], ctx: &mut Ctx) -> Compressed {
        if x.is_empty() {
            let mut payload = Vec::with_capacity(12);
            super::put_u32(&mut payload, 0);
            super::put_u64(&mut payload, 0);
            return Compressed { scheme: SchemeId::RandomK, n: 0, payload };
        }
        let k = self.k_for(x.len());
        let seed = ctx.rng.next_u64();
        let idx = Self::indices_from_seed(seed, x.len(), k);
        let gain = if self.rescale { x.len() as f32 / k as f32 } else { 1.0 };
        let mut payload = Vec::with_capacity(12 + 4 * k);
        super::put_u32(&mut payload, k as u32);
        super::put_u64(&mut payload, seed);
        for &i in &idx {
            super::put_f32(&mut payload, x[i as usize] * gain);
        }
        Compressed { scheme: SchemeId::RandomK, n: x.len(), payload }
    }

    fn decompress(&self, c: &Compressed, out: &mut [f32]) {
        assert_eq!(out.len(), c.n);
        out.fill(0.0);
        self.add_decompressed(c, out);
    }

    fn add_decompressed(&self, c: &Compressed, acc: &mut [f32]) {
        assert_eq!(acc.len(), c.n);
        if c.payload.len() < 12 {
            return;
        }
        let k = super::get_u32(&c.payload, 0) as usize;
        if k == 0 {
            return;
        }
        if k > c.n || c.payload.len() != 12 + 4 * k {
            return;
        }
        let seed = super::get_u64(&c.payload, 4);
        let idx = Self::indices_from_seed(seed, c.n, k);
        for (j, &i) in idx.iter().enumerate() {
            acc[i as usize] += super::get_f32(&c.payload, 12 + 4 * j);
        }
    }

    fn wire_nbytes(&self, n: usize) -> usize {
        if n == 0 {
            return 12;
        }
        12 + 4 * self.k_for(n)
    }

    fn compress_ef_fused(&self, q: &mut [f32], ctx: &mut Ctx) -> Compressed {
        if self.rescale {
            let c = self.compress(q, ctx);
            let mut dec = vec![0.0f32; q.len()];
            self.decompress(&c, &mut dec);
            for (qi, di) in q.iter_mut().zip(&dec) {
                *qi -= di;
            }
            return c;
        }
        if q.is_empty() {
            let mut payload = Vec::with_capacity(12);
            super::put_u32(&mut payload, 0);
            super::put_u64(&mut payload, 0);
            return Compressed { scheme: SchemeId::RandomK, n: 0, payload };
        }
        let k = self.k_for(q.len());
        let seed = ctx.rng.next_u64();
        let idx = Self::indices_from_seed(seed, q.len(), k);
        let mut payload = Vec::with_capacity(12 + 4 * k);
        super::put_u32(&mut payload, k as u32);
        super::put_u64(&mut payload, seed);
        for &i in &idx {
            super::put_f32(&mut payload, q[i as usize]);
            q[i as usize] = 0.0;
        }
        Compressed { scheme: SchemeId::RandomK, n: q.len(), payload }
    }
}

// --- linear dithering --------------------------------------------------------

pub struct ScalarLinearDither {
    pub bits: u32,
}

impl ScalarLinearDither {
    fn levels(&self) -> i64 {
        (1i64 << (self.bits - 1)) - 1
    }
}

impl Compressor for ScalarLinearDither {
    fn name(&self) -> &'static str {
        "linear_dither"
    }

    fn id(&self) -> SchemeId {
        SchemeId::LinearDither
    }

    fn unbiased(&self) -> bool {
        true
    }

    fn compress(&self, x: &[f32], ctx: &mut Ctx) -> Compressed {
        let scale = max_abs(x);
        let l = self.levels();
        let mut payload = Vec::new();
        super::put_f32(&mut payload, scale);
        let mut packer = BitPacker::new(x.len(), self.bits);
        if scale > 0.0 {
            let inv = l as f32 / scale;
            for &v in x {
                let q = v * inv; // in [-L, L]
                let lo = q.floor();
                let p = q - lo;
                let level = lo as i64 + if ctx.rng.next_f32() < p { 1 } else { 0 };
                let level = level.clamp(-l, l);
                packer.push((level + l) as u32, self.bits);
            }
        } else {
            for _ in x {
                packer.push(l as u32, self.bits); // code for level 0
            }
        }
        payload.extend_from_slice(&packer.finish());
        Compressed { scheme: SchemeId::LinearDither, n: x.len(), payload }
    }

    fn decompress(&self, c: &Compressed, out: &mut [f32]) {
        assert_eq!(out.len(), c.n);
        if c.payload.len() < 4 {
            out.fill(0.0);
            return;
        }
        let scale = super::get_f32(&c.payload, 0);
        let l = self.levels();
        let step = if l > 0 { scale / l as f32 } else { 0.0 };
        let mut up = BitUnpacker::new(&c.payload[4..]);
        for o in out.iter_mut() {
            let code = up.pull(self.bits) as i64 - l;
            *o = code as f32 * step;
        }
    }

    fn wire_nbytes(&self, n: usize) -> usize {
        4 + (n * self.bits as usize).div_ceil(8)
    }
}

// --- natural dithering -------------------------------------------------------

pub struct ScalarNaturalDither {
    pub bits: u32,
}

impl ScalarNaturalDither {
    fn slots(&self) -> u32 {
        (1u32 << (self.bits - 1)) - 1
    }
}

impl Compressor for ScalarNaturalDither {
    fn name(&self) -> &'static str {
        "natural_dither"
    }

    fn id(&self) -> SchemeId {
        SchemeId::NaturalDither
    }

    fn unbiased(&self) -> bool {
        true
    }

    fn compress(&self, x: &[f32], ctx: &mut Ctx) -> Compressed {
        let scale = max_abs(x);
        let slots = self.slots();
        let min_exp = -(slots as i32 - 1);
        let mut payload = Vec::new();
        super::put_f32(&mut payload, scale);
        let mut packer = BitPacker::new(x.len(), self.bits);
        for &v in x {
            let code: u32 = if scale == 0.0 || v == 0.0 {
                0
            } else {
                let u = (v.abs() / scale).min(1.0); // in (0, 1]
                let bits = u.to_bits();
                let e = (((bits >> 23) & 0xFF) as i32 - 127).clamp(min_exp - 1, 0);
                let exp = if e < min_exp {
                    let hi = f32::from_bits(((min_exp + 127) as u32) << 23);
                    if ctx.rng.next_f32() < u / hi {
                        min_exp
                    } else {
                        i32::MIN // rounded to zero
                    }
                } else {
                    let p = (bits & 0x7F_FFFF) as f32 * (1.0 / (1u32 << 23) as f32);
                    if ctx.rng.next_f32() < p {
                        (e + 1).min(0)
                    } else {
                        e
                    }
                };
                if exp == i32::MIN {
                    0
                } else {
                    let j = (-exp) as u32;
                    if v < 0.0 {
                        1 + slots + j
                    } else {
                        1 + j
                    }
                }
            };
            packer.push(code, self.bits);
        }
        payload.extend_from_slice(&packer.finish());
        Compressed { scheme: SchemeId::NaturalDither, n: x.len(), payload }
    }

    fn decompress(&self, c: &Compressed, out: &mut [f32]) {
        assert_eq!(out.len(), c.n);
        if c.payload.len() < 4 {
            out.fill(0.0);
            return;
        }
        let scale = super::get_f32(&c.payload, 0);
        let mut up = BitUnpacker::new(&c.payload[4..]);
        for o in out.iter_mut() {
            let code = up.pull(self.bits);
            *o = decode_natural_ref(code, scale, self.bits);
        }
    }

    fn wire_nbytes(&self, n: usize) -> usize {
        4 + (n * self.bits as usize).div_ceil(8)
    }
}

fn decode_natural_ref(code: u32, scale: f32, bits: u32) -> f32 {
    if code == 0 {
        return 0.0;
    }
    let slots = (1u32 << (bits - 1)) - 1;
    let c = code - 1;
    let j = c % slots;
    let sign = if c / slots == 1 { -1.0f32 } else { 1.0 };
    sign * scale * (-(j as f32)).exp2()
}
