//! Size-threshold bypass (paper §4.2.3).
//!
//! Small tensors pay a fixed compression overhead that exceeds the wire
//! saving, so tensors under a byte threshold (default 1 MiB) are sent in
//! full precision. Implemented as a wrapper compressor so the rest of the
//! stack stays scheme-agnostic.

use super::{identity::Identity, Compressed, Compressor, Ctx, SchemeId};
use std::sync::Arc;

pub struct SizeThreshold {
    pub inner: Arc<dyn Compressor>,
    /// Tensors with fewer than `threshold_bytes` of f32 data bypass `inner`.
    pub threshold_bytes: usize,
}

impl SizeThreshold {
    pub fn new(inner: Arc<dyn Compressor>, threshold_bytes: usize) -> Self {
        SizeThreshold { inner, threshold_bytes }
    }

    #[inline]
    pub fn bypasses(&self, n: usize) -> bool {
        4 * n < self.threshold_bytes
    }
}

impl Compressor for SizeThreshold {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn id(&self) -> SchemeId {
        // Wire blocks carry the *actual* scheme id per block, so threshold
        // wrapping stays transparent to the receiver.
        self.inner.id()
    }

    fn unbiased(&self) -> bool {
        // Identity is unbiased, so the wrapper inherits the inner contract.
        self.inner.unbiased()
    }

    fn compress(&self, x: &[f32], ctx: &mut Ctx) -> Compressed {
        if self.bypasses(x.len()) {
            Identity.compress(x, ctx)
        } else {
            self.inner.compress(x, ctx)
        }
    }

    fn decompress(&self, c: &Compressed, out: &mut [f32]) {
        // Dispatch on the block's own scheme id — a bypassed block arrives
        // as Identity regardless of the configured scheme.
        if c.scheme == SchemeId::Identity {
            Identity.decompress(c, out)
        } else {
            self.inner.decompress(c, out)
        }
    }

    fn add_decompressed(&self, c: &Compressed, acc: &mut [f32]) {
        if c.scheme == SchemeId::Identity {
            Identity.add_decompressed(c, acc)
        } else {
            self.inner.add_decompressed(c, acc)
        }
    }

    fn wire_nbytes(&self, n: usize) -> usize {
        if self.bypasses(n) {
            Identity.wire_nbytes(n)
        } else {
            self.inner.wire_nbytes(n)
        }
    }

    fn compress_ef_fused(&self, q: &mut [f32], ctx: &mut Ctx) -> Compressed {
        if self.bypasses(q.len()) {
            Identity.compress_ef_fused(q, ctx)
        } else {
            self.inner.compress_ef_fused(q, ctx)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::by_name;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn small_tensor_bypasses_to_identity() {
        let t = SizeThreshold::new(by_name("topk", 0.01).unwrap(), 1024);
        let x: Vec<f32> = (0..100).map(|i| i as f32).collect(); // 400 B < 1 KiB
        let mut rng = Xoshiro256::seed_from_u64(0);
        let c = t.compress(&x, &mut Ctx::new(&mut rng));
        assert_eq!(c.scheme, SchemeId::Identity);
        let mut out = vec![0.0f32; 100];
        t.decompress(&c, &mut out);
        assert_eq!(out, x);
    }

    #[test]
    fn large_tensor_uses_inner() {
        let t = SizeThreshold::new(by_name("topk", 0.01).unwrap(), 1024);
        let x: Vec<f32> = (0..1000).map(|i| i as f32).collect(); // 4 KB >= 1 KiB
        let mut rng = Xoshiro256::seed_from_u64(0);
        let c = t.compress(&x, &mut Ctx::new(&mut rng));
        assert_eq!(c.scheme, SchemeId::TopK);
        assert!(c.nbytes() < 400);
    }

    #[test]
    fn boundary_is_strictly_less_than() {
        let t = SizeThreshold::new(by_name("onebit", 0.0).unwrap(), 400);
        assert!(t.bypasses(99)); // 396 < 400
        assert!(!t.bypasses(100)); // 400 !< 400
    }

    #[test]
    fn fused_ef_respects_bypass() {
        let t = SizeThreshold::new(by_name("topk", 0.01).unwrap(), 1024);
        let mut q = vec![1.0f32; 10];
        let mut rng = Xoshiro256::seed_from_u64(0);
        let c = t.compress_ef_fused(&mut q, &mut Ctx::new(&mut rng));
        assert_eq!(c.scheme, SchemeId::Identity);
        assert!(q.iter().all(|&v| v == 0.0)); // identity residual is zero
    }

    #[test]
    fn wire_nbytes_tracks_bypass() {
        let t = SizeThreshold::new(by_name("topk", 0.01).unwrap(), 1 << 20);
        assert_eq!(t.wire_nbytes(100), 400); // bypass: raw f32
        let big = 1 << 20;
        assert!(t.wire_nbytes(big) < 4 * big / 10); // compressed
    }
}
