//! Online per-key compression controller (the paper's *adaptive* arm).
//!
//! Every static sparsifier ratio is wrong twice: too aggressive for the
//! layers/steps where the gradient energy is spread out (information loss
//! stalls convergence) and too timid where it is concentrated (wire bytes
//! wasted). GraVAC and AdaComp close this loop online; this module is the
//! reproduction's version of that controller, driven by a gain signal the
//! error-feedback pipeline already holds.
//!
//! ## The gain metric
//!
//! For one block push, let `q = g + e_prev` be the EF-corrected gradient
//! and `e` the residual left after compression. The **compression gain**
//! is the fraction of the block's energy that made it onto the wire:
//!
//! ```text
//! gain = ‖C(q)‖² / (‖C(q)‖² + ‖e‖²)  =  (‖q‖² − ‖e‖²) / ‖q‖²
//! ```
//!
//! The second form holds exactly for the sparsifiers (top-k / random-k
//! zero the selected coordinates in the residual, so `C(q) ⟂ e`) and costs
//! two sum-of-squares passes over buffers the pipeline already owns — no
//! decompression round trip. A gain of 1 means lossless; a gain of 0 means
//! the whole update went into the residual.
//!
//! ## The control law (EMA + dead-band hysteresis)
//!
//! Per key, gains are smoothed with an EMA (`adaptive.ema`) and the keep
//! ratio — tracked in **ppm** (parts-per-million, the wire/negotiation
//! unit) — moves multiplicatively toward `adaptive.target_gain`:
//!
//! * `ema < target − DEAD_BAND` → too much energy lost: ppm ×= STEP (↑ k)
//! * `ema > target + DEAD_BAND` → comfortably lossless: ppm /= STEP (↓ k)
//! * otherwise → inside the dead band: hold (hysteresis — alternating
//!   gradients average out in the EMA instead of thrashing `k`)
//!
//! every move clamped to the **negotiated** `[k_min, k_max]` ppm bounds
//! (see `cluster`: `Hello` requests, `Welcome` grants, and the server's
//! ingress rejects any per-block `k` outside the granted envelope).
//! `k_for_ppm` is monotone in ppm and shared verbatim with the server's
//! envelope check, so a worker whose ppm stays in bounds can never emit a
//! block the server counts as `bounds_rejected`.

use crate::comm::Key;
use crate::compress::{randomk::RandomK, topk::TopK, Compressor};
use crate::configx::TrainConfig;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One million: the fixed-point scale of a keep ratio on the wire.
pub const PPM_SCALE: f64 = 1_000_000.0;

/// Hysteresis half-width around `target_gain` (absolute gain units): the
/// EMA must leave `target ± DEAD_BAND` before the ratio moves.
pub const DEAD_BAND: f64 = 0.05;

/// Multiplicative ratio step per adjustment (both directions).
pub const STEP: f64 = 1.25;

/// Keep ratio → ppm fixed point, clamped to [1, 1_000_000]. Zero is never
/// produced: `(0, 0)` is the wire sentinel for "static run, no bounds".
pub fn ppm_of(ratio: f64) -> u32 {
    let ppm = (ratio * PPM_SCALE).round();
    if ppm < 1.0 {
        1
    } else if ppm >= PPM_SCALE {
        1_000_000
    } else {
        ppm as u32
    }
}

/// ppm fixed point → keep ratio in (0, 1].
pub fn ratio_of(ppm: u32) -> f64 {
    f64::from(ppm.clamp(1, 1_000_000)) / PPM_SCALE
}

/// The per-block element budget a ppm ratio grants an `n`-element block —
/// the *same* `ceil(ratio·n).clamp(1, n)` the sparsifiers use, shared so
/// the server's envelope check and the worker's compressor can never
/// disagree. Monotone in `ppm`, so `ppm ∈ [lo, hi]` implies
/// `k ∈ [k_for_ppm(lo, n), k_for_ppm(hi, n)]`.
pub fn k_for_ppm(ppm: u32, n: usize) -> usize {
    ((ratio_of(ppm) * n as f64).ceil() as usize).clamp(1, n.max(1))
}

/// Server side of the negotiation: clamp a worker's requested ppm bounds
/// into this server's configured envelope. Order-preserving for ordered
/// inputs, so the grant is always a well-formed sub-range of the envelope.
pub fn clamp_bounds(req: (u32, u32), envelope: (u32, u32)) -> (u32, u32) {
    let (lo, hi) = envelope;
    (req.0.clamp(lo, hi), req.1.clamp(lo, hi))
}

/// Which sparsifier family the controller re-parameterizes per block.
/// Dense/dither schemes have no keep ratio and never adapt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdaptiveKind {
    TopK,
    RandomK { rescale: bool },
}

impl AdaptiveKind {
    pub fn from_scheme(scheme: &str) -> Option<AdaptiveKind> {
        match scheme {
            "topk" => Some(AdaptiveKind::TopK),
            "randomk" => Some(AdaptiveKind::RandomK { rescale: false }),
            "randomk_unbiased" => Some(AdaptiveKind::RandomK { rescale: true }),
            _ => None,
        }
    }
}

/// Per-key controller state: current ratio plus the smoothed gain.
struct KeyCtl {
    ppm: u32,
    ema: f64,
    primed: bool,
}

/// The per-key online controller one worker owns for a run. Thread-safe:
/// pipeline push jobs for different blocks observe concurrently.
pub struct GainController {
    kind: AdaptiveKind,
    lo_ppm: u32,
    hi_ppm: u32,
    initial_ppm: u32,
    ema_alpha: f64,
    target_gain: f64,
    keys: Mutex<HashMap<Key, KeyCtl>>,
    adjustments: AtomicU64,
}

impl GainController {
    /// Build a controller over the granted `[lo, hi]` ppm bounds. Inputs
    /// are normalized (never panics on hostile/degenerate values): bounds
    /// are forced into [1, 1e6] with `lo ≤ hi`, and the starting ratio is
    /// clamped into them.
    pub fn new(
        kind: AdaptiveKind,
        lo_ppm: u32,
        hi_ppm: u32,
        initial_ppm: u32,
        ema_alpha: f64,
        target_gain: f64,
    ) -> GainController {
        let lo = lo_ppm.clamp(1, 1_000_000);
        let hi = hi_ppm.clamp(lo, 1_000_000);
        GainController {
            kind,
            lo_ppm: lo,
            hi_ppm: hi,
            initial_ppm: initial_ppm.clamp(lo, hi),
            ema_alpha: if ema_alpha.is_finite() { ema_alpha.clamp(1e-6, 1.0) } else { 1.0 },
            target_gain: if target_gain.is_finite() { target_gain.clamp(0.0, 1.0) } else { 1.0 },
            keys: Mutex::new(HashMap::new()),
            adjustments: AtomicU64::new(0),
        }
    }

    /// The granted `[lo, hi]` ppm bounds this controller honors.
    pub fn bounds_ppm(&self) -> (u32, u32) {
        (self.lo_ppm, self.hi_ppm)
    }

    /// Current keep ratio for `key` in ppm (keys start at the initial
    /// ratio the first time they are asked for).
    pub fn ppm_for(&self, key: Key) -> u32 {
        // Poison recovery (here and below, mirroring BlockEf): controller
        // state is advisory — a panicking observer must not cascade into
        // every subsequent push job.
        let mut keys = self.keys.lock().unwrap_or_else(|p| p.into_inner());
        keys.entry(key)
            .or_insert_with(|| KeyCtl { ppm: self.initial_ppm, ema: 0.0, primed: false })
            .ppm
    }

    /// A compressor parameterized with `key`'s *current* ratio — built per
    /// push job, so two in-flight blocks can run different `k`.
    pub fn compressor_for(&self, key: Key) -> Arc<dyn Compressor> {
        let ratio = ratio_of(self.ppm_for(key));
        match self.kind {
            AdaptiveKind::TopK => Arc::new(TopK::new(ratio)),
            AdaptiveKind::RandomK { rescale } => Arc::new(RandomK::new(ratio, rescale)),
        }
    }

    /// Feed one measured gain for `key` and apply the control law (EMA →
    /// dead band → clamped multiplicative step). Non-finite gains are
    /// dropped — a poisoned residual must not steer the ratio.
    pub fn observe(&self, key: Key, gain: f64) {
        if !gain.is_finite() {
            return;
        }
        let gain = gain.clamp(0.0, 1.0);
        let mut keys = self.keys.lock().unwrap_or_else(|p| p.into_inner());
        let ctl = keys
            .entry(key)
            .or_insert_with(|| KeyCtl { ppm: self.initial_ppm, ema: 0.0, primed: false });
        ctl.ema = if ctl.primed {
            self.ema_alpha * gain + (1.0 - self.ema_alpha) * ctl.ema
        } else {
            gain
        };
        ctl.primed = true;
        let old = ctl.ppm;
        if ctl.ema < self.target_gain - DEAD_BAND {
            // Too much energy left in the residual: keep more coordinates.
            // The `+1` floor guarantees progress at tiny ppm where the
            // multiplicative step rounds to a no-op.
            ctl.ppm =
                ((f64::from(ctl.ppm) * STEP).ceil() as u32).max(old.saturating_add(1)).min(self.hi_ppm);
        } else if ctl.ema > self.target_gain + DEAD_BAND {
            // Comfortably above target: spend fewer bytes.
            ctl.ppm =
                ((f64::from(ctl.ppm) / STEP).floor() as u32).min(old.saturating_sub(1)).max(self.lo_ppm);
        }
        if ctl.ppm != old {
            self.adjustments.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Total ratio adjustments made across all keys (trajectory counter).
    pub fn adjustments(&self) -> u64 {
        self.adjustments.load(Ordering::Relaxed)
    }

    /// The current `[min, max]` per-key ppm across all keys — the
    /// trajectory envelope the worker counters report. Before any key is
    /// touched it degenerates to the initial ratio.
    pub fn ppm_span(&self) -> (u32, u32) {
        let keys = self.keys.lock().unwrap_or_else(|p| p.into_inner());
        if keys.is_empty() {
            return (self.initial_ppm, self.initial_ppm);
        }
        let (mut lo, mut hi) = (u32::MAX, 0u32);
        for ctl in keys.values() {
            lo = lo.min(ctl.ppm);
            hi = hi.max(ctl.ppm);
        }
        (lo, hi)
    }

    /// Per-key `(key, ppm)` snapshot, sorted by key (tests/diagnostics).
    pub fn snapshot(&self) -> Vec<(Key, u32)> {
        let keys = self.keys.lock().unwrap_or_else(|p| p.into_inner());
        let mut out: Vec<(Key, u32)> = keys.iter().map(|(k, c)| (*k, c.ppm)).collect();
        out.sort_unstable();
        out
    }
}

/// Sum of squares in f64 (the gain metric's accumulator — f64 so blocks of
/// millions of f32 elements don't lose the small-residual signal).
pub fn sumsq(x: &[f32]) -> f64 {
    x.iter().map(|&v| f64::from(v) * f64::from(v)).sum()
}

/// Gain from the pre-compression energy `t2 = ‖q‖²` and the post-
/// compression residual energy `e2 = ‖e‖²`. An all-zero block is lossless
/// by definition.
pub fn gain_from(t2: f64, e2: f64) -> f64 {
    if t2 <= 0.0 {
        1.0
    } else {
        ((t2 - e2) / t2).clamp(0.0, 1.0)
    }
}

/// The ppm bounds this run's config *requests* at registration: the
/// `adaptive.{k_min,k_max}` pair when the controller applies (enabled, a
/// sparsifier scheme, and error feedback — the gain signal lives in the EF
/// residual), else the `(0, 0)` static sentinel.
pub fn requested_bounds(cfg: &TrainConfig) -> (u32, u32) {
    if cfg.adaptive.enabled
        && cfg.compression.sync == crate::configx::SyncMode::CompressedEf
        && AdaptiveKind::from_scheme(&cfg.compression.scheme).is_some()
    {
        (ppm_of(cfg.adaptive.k_min), ppm_of(cfg.adaptive.k_max))
    } else {
        (0, 0)
    }
}

/// Build the worker's controller from the run config and the **granted**
/// bounds echoed in `Welcome` (the inproc fabric grants the config's own
/// request). `None` — run static — when adaptive mode is off, the scheme
/// has no keep ratio, or the grant is the static sentinel.
pub fn from_negotiated(cfg: &TrainConfig, granted_ppm: (u32, u32)) -> Option<Arc<GainController>> {
    if requested_bounds(cfg) == (0, 0) || granted_ppm == (0, 0) {
        return None;
    }
    let kind = AdaptiveKind::from_scheme(&cfg.compression.scheme)?;
    let initial = ppm_of(cfg.compression.param).clamp(granted_ppm.0, granted_ppm.1);
    Some(Arc::new(GainController::new(
        kind,
        granted_ppm.0,
        granted_ppm.1,
        initial,
        cfg.adaptive.ema,
        cfg.adaptive.target_gain,
    )))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl(lo: f64, hi: f64, init: f64, ema: f64, target: f64) -> GainController {
        GainController::new(AdaptiveKind::TopK, ppm_of(lo), ppm_of(hi), ppm_of(init), ema, target)
    }

    #[test]
    fn ppm_roundtrip_and_clamps() {
        assert_eq!(ppm_of(0.001), 1000);
        assert_eq!(ppm_of(1.0), 1_000_000);
        assert_eq!(ppm_of(0.0), 1, "zero ratio must map to the 1-ppm floor");
        assert_eq!(ppm_of(7.5), 1_000_000);
        assert!((ratio_of(1000) - 0.001).abs() < 1e-12);
        assert_eq!(ratio_of(0), ratio_of(1), "ppm 0 reads as the floor");
    }

    /// The shared budget function must agree with the sparsifiers' own
    /// `k_for` and be monotone in ppm — the envelope-soundness argument.
    #[test]
    fn k_for_ppm_matches_topk_and_is_monotone() {
        for &n in &[1usize, 7, 100, 1500, 1 << 20] {
            for &ppm in &[1u32, 500, 1000, 50_000, 500_000, 1_000_000] {
                let t = TopK::new(ratio_of(ppm));
                assert_eq!(k_for_ppm(ppm, n), t.k_for(n), "n={n} ppm={ppm}");
            }
            let mut last = 0usize;
            for ppm in (1..=1_000_000u32).step_by(9973) {
                let k = k_for_ppm(ppm, n);
                assert!(k >= last, "k_for_ppm not monotone at n={n} ppm={ppm}");
                last = k;
            }
        }
    }

    #[test]
    fn clamp_bounds_is_a_subrange_of_the_envelope() {
        let env = (1000, 100_000);
        assert_eq!(clamp_bounds((500, 200_000), env), env, "wider request clamps to envelope");
        assert_eq!(clamp_bounds((2000, 50_000), env), (2000, 50_000), "inner request unchanged");
        assert_eq!(clamp_bounds((1, 10), env), (1000, 1000), "request below collapses to lo");
        let (lo, hi) = clamp_bounds((200_000, 900_000), env);
        assert!(lo <= hi && lo >= env.0 && hi <= env.1);
    }

    /// ISSUE acceptance: gain persistently below target drives k up to the
    /// k_max bound (and never beyond it).
    #[test]
    fn low_gain_converges_to_k_max() {
        let c = ctl(0.001, 0.1, 0.005, 0.5, 0.8);
        let key = 7u64;
        let mut trail = vec![c.ppm_for(key)];
        for _ in 0..64 {
            c.observe(key, 0.2); // far below target - DEAD_BAND
            trail.push(c.ppm_for(key));
        }
        assert_eq!(*trail.last().unwrap(), ppm_of(0.1), "must saturate at k_max");
        assert!(trail.windows(2).all(|w| w[1] >= w[0]), "monotone rise: {trail:?}");
        assert!(c.adjustments() > 0);
    }

    #[test]
    fn high_gain_converges_to_k_min() {
        let c = ctl(0.001, 0.1, 0.05, 0.5, 0.5);
        let key = 3u64;
        for _ in 0..96 {
            c.observe(key, 0.99);
        }
        assert_eq!(c.ppm_for(key), ppm_of(0.001), "must saturate at k_min");
    }

    /// ISSUE acceptance: alternating gradients (gains straddling the
    /// target) must not thrash k — the EMA settles inside the dead band
    /// and hysteresis holds the ratio still.
    #[test]
    fn hysteresis_prevents_oscillation_on_alternating_gains() {
        let c = ctl(0.001, 0.5, 0.02, 0.3, 0.6);
        let key = 11u64;
        // Warm-up: let the EMA settle around the mean of the two gains
        // (0.6, exactly the target).
        for i in 0..32 {
            c.observe(key, if i % 2 == 0 { 0.55 } else { 0.65 });
        }
        let settled = c.ppm_for(key);
        let before = c.adjustments();
        for i in 0..64 {
            c.observe(key, if i % 2 == 0 { 0.55 } else { 0.65 });
            assert_eq!(c.ppm_for(key), settled, "ratio moved inside the dead band at step {i}");
        }
        assert_eq!(c.adjustments(), before, "no adjustments inside the dead band");
    }

    #[test]
    fn keys_adapt_independently() {
        let c = ctl(0.001, 0.2, 0.01, 1.0, 0.7);
        for _ in 0..8 {
            c.observe(1, 0.1); // starving: k rises
            c.observe(2, 0.99); // lossless: k falls
        }
        assert!(c.ppm_for(1) > ppm_of(0.01));
        assert!(c.ppm_for(2) < ppm_of(0.01));
        let (lo, hi) = c.ppm_span();
        assert_eq!((lo, hi), (c.ppm_for(2), c.ppm_for(1)));
        let snap = c.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].0, 1);
    }

    #[test]
    fn tiny_ppm_still_makes_progress() {
        // ppm=1: the multiplicative step rounds to 1.25 -> ceil 2; the +1
        // floor would also guarantee motion.
        let c = GainController::new(AdaptiveKind::TopK, 1, 100, 1, 1.0, 0.9);
        c.observe(5, 0.0);
        assert!(c.ppm_for(5) > 1);
    }

    #[test]
    fn non_finite_gain_is_ignored() {
        let c = ctl(0.001, 0.1, 0.01, 1.0, 0.9);
        c.observe(9, f64::NAN);
        c.observe(9, f64::INFINITY);
        assert_eq!(c.ppm_for(9), ppm_of(0.01));
        assert_eq!(c.adjustments(), 0);
    }

    #[test]
    fn gain_from_is_exact_for_orthogonal_sparsifiers() {
        use crate::compress::Ctx;
        use crate::util::rng::Xoshiro256;
        let t = TopK::new(0.25);
        let q: Vec<f32> = (0..64).map(|i| ((i * 37) % 19) as f32 - 9.0).collect();
        let t2 = sumsq(&q);
        let mut res = q.clone();
        let mut rng = Xoshiro256::seed_from_u64(0);
        let c = t.compress_ef_fused(&mut res, &mut Ctx::new(&mut rng));
        let e2 = sumsq(&res);
        // Reference: decode the wire block and take ‖C(q)‖²/(‖C(q)‖²+‖e‖²).
        let mut dec = vec![0.0f32; q.len()];
        t.decompress(&c, &mut dec);
        let c2 = sumsq(&dec);
        let want = c2 / (c2 + e2);
        assert!((gain_from(t2, e2) - want).abs() < 1e-12, "{} vs {want}", gain_from(t2, e2));
        assert_eq!(gain_from(0.0, 0.0), 1.0, "empty block is lossless");
    }

    #[test]
    fn requested_bounds_gate_on_scheme_sync_and_enable() {
        let mut cfg = TrainConfig::default();
        cfg.compression.scheme = "topk".into();
        cfg.compression.sync = crate::configx::SyncMode::CompressedEf;
        assert_eq!(requested_bounds(&cfg), (0, 0), "disabled by default");
        cfg.adaptive.enabled = true;
        let req = requested_bounds(&cfg);
        assert_eq!(req, (ppm_of(cfg.adaptive.k_min), ppm_of(cfg.adaptive.k_max)));
        assert!(from_negotiated(&cfg, req).is_some());
        assert!(from_negotiated(&cfg, (0, 0)).is_none(), "static grant means static run");
        cfg.compression.scheme = "fp16".into();
        assert_eq!(requested_bounds(&cfg), (0, 0), "dense schemes never adapt");
        cfg.compression.scheme = "topk".into();
        cfg.compression.sync = crate::configx::SyncMode::Compressed;
        assert_eq!(requested_bounds(&cfg), (0, 0), "no EF residual, no gain signal");
    }

    #[test]
    fn negotiated_controller_clamps_initial_ratio_into_grant() {
        let mut cfg = TrainConfig::default();
        cfg.compression.scheme = "topk".into();
        cfg.compression.sync = crate::configx::SyncMode::CompressedEf;
        cfg.compression.param = 0.5; // outside [k_min, k_max]
        cfg.adaptive.enabled = true;
        let grant = (ppm_of(cfg.adaptive.k_min), ppm_of(cfg.adaptive.k_max));
        let c = from_negotiated(&cfg, grant).unwrap();
        assert_eq!(c.ppm_for(0), grant.1, "initial ratio clamps to the granted hi");
        assert_eq!(c.bounds_ppm(), grant);
    }
}
