//! Gradient compressors (paper §2.3, §4.1) and error feedback (§3.1, §4.2.2).
//!
//! All inter-node compressors run on the **CPU** (paper §4.1.2): they are
//! invoked by workers before push and by servers before answering pulls.
//! The seven methods benchmarked in the paper are implemented:
//!
//! | scheme | kind | paper ref |
//! |---|---|---|
//! | `identity` | none (full precision) | NAG baseline |
//! | `fp16` | half-precision conversion | "NAG (FP16)" |
//! | `onebit` | scaled sign, δ-approximate | Zheng et al. '19 |
//! | `topk` | k largest magnitudes, δ-approximate | Stich et al. '18 |
//! | `randomk` | k random coords (seed-coded), unbiased w/ rescale | Stich '18 / Horváth '21 |
//! | `linear_dither` | b-bit stochastic linear quantization, unbiased | QSGD-style |
//! | `natural_dither` | power-of-two stochastic quantization, unbiased | Horváth et al. '19 |
//!
//! Biased compressors (`onebit`, `topk`) must be driven through error
//! feedback (Alg. 4); unbiased ones may use plain two-way compression
//! (Alg. 3). Property tests in each submodule verify the paper's
//! Definition 1 (ω-compressor, unbiased) and Definition 2 (δ-approximate)
//! contracts, which the convergence theory relies on.
//!
//! Module layout:
//!
//! * The per-scheme modules above hold the wire formats and `Compressor`
//!   impls; their hot loops live in [`kernels`] (vectorization-friendly
//!   flat passes shared across schemes), while [`reference`] keeps the
//!   scalar textbook implementations the identity tests compare against —
//!   when a kernel and its reference disagree, the kernel is wrong.
//! * [`controller`] is the online per-key adaptive controller: it turns
//!   the EF residual's energy into a compression-gain signal and steers
//!   the sparsifier keep ratio inside bounds negotiated at registration
//!   (see DESIGN.md §Adaptive controller).
//! * [`ef`] holds the worker/server error-feedback state, [`threshold`]
//!   the §4.2.3 size bypass.

pub mod controller;
pub mod dither;
pub mod ef;
pub mod fp16;
pub mod identity;
pub mod kernels;
pub mod onebit;
pub mod randomk;
pub mod reference;
pub mod threshold;
pub mod topk;

use crate::util::rng::Xoshiro256;
use std::sync::Arc;

/// Numeric ids used on the wire (stable; see `comm::frame`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum SchemeId {
    Identity = 0,
    Fp16 = 1,
    OneBit = 2,
    TopK = 3,
    RandomK = 4,
    LinearDither = 5,
    NaturalDither = 6,
}

impl SchemeId {
    pub fn from_u8(v: u8) -> Option<SchemeId> {
        Some(match v {
            0 => SchemeId::Identity,
            1 => SchemeId::Fp16,
            2 => SchemeId::OneBit,
            3 => SchemeId::TopK,
            4 => SchemeId::RandomK,
            5 => SchemeId::LinearDither,
            6 => SchemeId::NaturalDither,
            _ => return None,
        })
    }

    /// Stable wire discriminant: the exhaustive inverse of [`from_u8`],
    /// so the frame encoder never needs a raw `as` cast of the enum.
    pub fn wire_id(self) -> u8 {
        match self {
            SchemeId::Identity => 0,
            SchemeId::Fp16 => 1,
            SchemeId::OneBit => 2,
            SchemeId::TopK => 3,
            SchemeId::RandomK => 4,
            SchemeId::LinearDither => 5,
            SchemeId::NaturalDither => 6,
        }
    }
}

/// A compressed gradient block as it travels on the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct Compressed {
    pub scheme: SchemeId,
    /// Original element count.
    pub n: usize,
    /// Scheme-specific packed payload.
    pub payload: Vec<u8>,
}

impl Compressed {
    /// Wire size in bytes (payload + the 10-byte frame header contribution
    /// is accounted separately by `comm`).
    pub fn nbytes(&self) -> usize {
        self.payload.len()
    }

    /// Compression rate vs f32 (paper reports vs FP16 for BERT — that is
    /// `rate_vs_f32() / 2`).
    pub fn rate_vs_f32(&self) -> f64 {
        (4 * self.n) as f64 / self.payload.len().max(1) as f64
    }
}

/// Execution context threaded through compress/decompress calls: the
/// deterministic RNG plus the intra-task thread budget (§4.2.1).
pub struct Ctx<'a> {
    pub rng: &'a mut Xoshiro256,
    pub intra_threads: usize,
}

impl<'a> Ctx<'a> {
    pub fn new(rng: &'a mut Xoshiro256) -> Self {
        Ctx { rng, intra_threads: 1 }
    }

    pub fn with_threads(rng: &'a mut Xoshiro256, intra_threads: usize) -> Self {
        Ctx { rng, intra_threads }
    }
}

/// A gradient compressor. Implementations must be deterministic given the
/// RNG stream and must satisfy either the unbiased (Definition 1) or the
/// δ-approximate (Definition 2) contract — property-tested per scheme.
pub trait Compressor: Send + Sync {
    fn name(&self) -> &'static str;
    fn id(&self) -> SchemeId;

    /// True if `E[decompress(compress(x))] == x` (ω-compressor family).
    fn unbiased(&self) -> bool;

    /// Compress `x` into a wire block.
    fn compress(&self, x: &[f32], ctx: &mut Ctx) -> Compressed;

    /// Decompress into `out` (len == c.n), overwriting every element.
    fn decompress(&self, c: &Compressed, out: &mut [f32]);

    /// `acc[i] += decode(c)[i]` — the server-side aggregation fast path.
    /// Sparse schemes override this to touch only k entries.
    fn add_decompressed(&self, c: &Compressed, acc: &mut [f32]) {
        let mut tmp = vec![0.0f32; c.n];
        self.decompress(c, &mut tmp);
        for (a, t) in acc.iter_mut().zip(&tmp) {
            *a += t;
        }
    }

    /// Predicted wire bytes for an n-element tensor (used by `simnet`).
    fn wire_nbytes(&self, n: usize) -> usize;

    /// Fused compress + residual (§4.2.2 "Operator Fusion"): compress `q`
    /// and overwrite it **in place** with the residual `e = q - C(q)`,
    /// avoiding the decompress-and-subtract round trip. The default is the
    /// naive path (O(2d) + allocation); sparse/sign schemes override with
    /// the O(k) / single-pass version.
    fn compress_ef_fused(&self, q: &mut [f32], ctx: &mut Ctx) -> Compressed {
        let c = self.compress(q, ctx);
        let mut dec = vec![0.0f32; q.len()];
        self.decompress(&c, &mut dec);
        for (qi, di) in q.iter_mut().zip(&dec) {
            *qi -= di;
        }
        c
    }
}

/// Construct a compressor by scheme name.
///
/// `param` meaning: `topk`/`randomk` — keep ratio in (0,1];
/// `linear_dither`/`natural_dither` — bit width; others — ignored.
pub fn by_name(scheme: &str, param: f64) -> Result<Arc<dyn Compressor>, String> {
    Ok(match scheme {
        "identity" => Arc::new(identity::Identity),
        "fp16" => Arc::new(fp16::Fp16),
        "onebit" => Arc::new(onebit::ScaledOneBit),
        "topk" => Arc::new(topk::TopK::new(param)),
        "randomk" => Arc::new(randomk::RandomK::new(param, false)),
        "randomk_unbiased" => Arc::new(randomk::RandomK::new(param, true)),
        "linear_dither" => Arc::new(dither::LinearDither::new(param as u32)),
        "natural_dither" => Arc::new(dither::NaturalDither::new(param as u32)),
        other => return Err(format!("unknown compression scheme '{other}'")),
    })
}

/// Structural validation of a wire block against its declared scheme and
/// element count. Wire data is untrusted: a corrupt or malicious frame must
/// be rejected at the transport/server boundary (`comm::frame::decode_body`
/// and `ps::ServerCore`) instead of panicking deep inside a decompressor.
///
/// Checks are parameter-free (the receiver's scheme parameters are not on
/// the wire): exact payload lengths where the scheme determines them,
/// length envelopes for the dithering schemes (bit width 2..=16), and —
/// for top-k — that every index addresses the tensor.
pub fn validate_wire(c: &Compressed) -> Result<(), String> {
    let n = c.n;
    let plen = c.payload.len();
    match c.scheme {
        SchemeId::Identity => {
            if plen != 4 * n {
                return Err(format!("identity block: payload {plen} B for {n} elems"));
            }
        }
        SchemeId::Fp16 => {
            if plen != 2 * n {
                return Err(format!("fp16 block: payload {plen} B for {n} elems"));
            }
        }
        SchemeId::OneBit => {
            if plen != 4 + n.div_ceil(8) {
                return Err(format!("onebit block: payload {plen} B for {n} elems"));
            }
        }
        SchemeId::TopK => {
            if plen < 4 {
                return Err(format!("topk block: payload {plen} B lacks the k header"));
            }
            let k = get_u32(&c.payload, 0) as usize;
            if k > n {
                return Err(format!("topk block: k={k} exceeds n={n}"));
            }
            if plen != 4 + 8 * k {
                return Err(format!("topk block: payload {plen} B for k={k}"));
            }
            for j in 0..k {
                let i = get_u32(&c.payload, 4 + 4 * j) as usize;
                if i >= n {
                    return Err(format!("topk block: index {i} out of range (n={n})"));
                }
            }
        }
        SchemeId::RandomK => {
            if plen < 12 {
                return Err(format!("randomk block: payload {plen} B lacks the header"));
            }
            let k = get_u32(&c.payload, 0) as usize;
            if k > n {
                return Err(format!("randomk block: k={k} exceeds n={n}"));
            }
            if plen != 12 + 4 * k {
                return Err(format!("randomk block: payload {plen} B for k={k}"));
            }
        }
        SchemeId::LinearDither | SchemeId::NaturalDither => {
            // Bit width is receiver config, not wire data: accept the
            // envelope spanned by 2..=16 bits per element plus the scale.
            let lo = 4 + (2 * n).div_ceil(8);
            let hi = 4 + 2 * n;
            if plen < lo || plen > hi {
                return Err(format!(
                    "dither block: payload {plen} B outside [{lo}, {hi}] for {n} elems"
                ));
            }
        }
    }
    Ok(())
}

/// All scheme names benchmarked in the paper's Figures 2–4 (with their
/// paper parameters), in presentation order.
pub fn paper_suite() -> Vec<(&'static str, Arc<dyn Compressor>)> {
    vec![
        ("NAG", by_name("identity", 0.0).unwrap()),
        ("NAG (FP16)", by_name("fp16", 0.0).unwrap()),
        ("Scaled 1-bit with EF", by_name("onebit", 0.0).unwrap()),
        ("Random-k with EF", by_name("randomk", 1.0 / 32.0).unwrap()),
        ("Top-k with EF", by_name("topk", 0.001).unwrap()),
        ("Linear Dithering", by_name("linear_dither", 5.0).unwrap()),
        ("Natural Dithering", by_name("natural_dither", 3.0).unwrap()),
    ]
}

// --- shared helpers for payload packing --------------------------------------

/// Append an f32 (little-endian) to a payload.
#[inline]
pub(crate) fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

#[inline]
// lint: allow(panic, fn) — the slice is exactly 4 bytes, so the array cast cannot fail
// lint: allow(index, fn) — callers read offsets validate_wire already bounded
pub(crate) fn get_f32(buf: &[u8], off: usize) -> f32 {
    f32::from_le_bytes(buf[off..off + 4].try_into().unwrap())
}

#[inline]
pub(crate) fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

#[inline]
// lint: allow(panic, fn) — the slice is exactly 4 bytes, so the array cast cannot fail
// lint: allow(index, fn) — callers read offsets validate_wire already bounded
pub(crate) fn get_u32(buf: &[u8], off: usize) -> u32 {
    u32::from_le_bytes(buf[off..off + 4].try_into().unwrap())
}

#[inline]
pub(crate) fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

#[inline]
// lint: allow(panic, fn) — the slice is exactly 8 bytes, so the array cast cannot fail
// lint: allow(index, fn) — callers read offsets validate_wire already bounded
pub(crate) fn get_u64(buf: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(buf[off..off + 8].try_into().unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn roundtrip(scheme: &str, param: f64, x: &[f32]) -> Vec<f32> {
        let c = by_name(scheme, param).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(9);
        let mut ctx = Ctx::new(&mut rng);
        let w = c.compress(x, &mut ctx);
        assert_eq!(w.n, x.len());
        let mut out = vec![0.0f32; x.len()];
        c.decompress(&w, &mut out);
        out
    }

    #[test]
    fn every_scheme_roundtrips_shape() {
        let x: Vec<f32> = (0..1000).map(|i| ((i as f32) * 0.37).sin()).collect();
        for (scheme, param) in [
            ("identity", 0.0),
            ("fp16", 0.0),
            ("onebit", 0.0),
            ("topk", 0.01),
            ("randomk", 0.05),
            ("randomk_unbiased", 0.05),
            ("linear_dither", 5.0),
            ("natural_dither", 3.0),
        ] {
            let out = roundtrip(scheme, param, &x);
            assert_eq!(out.len(), x.len(), "{scheme}");
            assert!(out.iter().all(|v| v.is_finite()), "{scheme} produced non-finite");
        }
    }

    #[test]
    fn unknown_scheme_is_error() {
        assert!(by_name("zstd", 0.0).is_err());
    }

    #[test]
    fn paper_suite_has_seven_methods() {
        let suite = paper_suite();
        assert_eq!(suite.len(), 7);
        assert_eq!(suite[0].0, "NAG");
    }

    #[test]
    fn wire_nbytes_matches_actual_payload() {
        let x: Vec<f32> = (0..4096).map(|i| (i as f32 * 0.11).cos()).collect();
        for (name, c) in paper_suite() {
            let mut rng = Xoshiro256::seed_from_u64(1);
            let mut ctx = Ctx::new(&mut rng);
            let w = c.compress(&x, &mut ctx);
            assert_eq!(w.nbytes(), c.wire_nbytes(x.len()), "{name}");
        }
    }

    #[test]
    fn topk_compression_rate_is_paperlike() {
        // Paper: top-k k=0.1% with int32 indices + f32 values => 333x vs FP16,
        // i.e. 666x vs f32 (here: 500x vs f32 for the values+indices payload
        // on 1M elements, ≥ 400x after header).
        let c = by_name("topk", 0.001).unwrap();
        let n = 1 << 20;
        let rate = (4 * n) as f64 / c.wire_nbytes(n) as f64;
        assert!(rate > 400.0, "rate={rate}");
    }

    #[test]
    fn validate_wire_accepts_every_schemes_output() {
        let x: Vec<f32> = (0..777).map(|i| ((i as f32) * 0.21).sin()).collect();
        for (name, c) in paper_suite() {
            let mut rng = Xoshiro256::seed_from_u64(4);
            let w = c.compress(&x, &mut Ctx::new(&mut rng));
            validate_wire(&w).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
        // Empty tensors too.
        for (name, c) in paper_suite() {
            let mut rng = Xoshiro256::seed_from_u64(4);
            let w = c.compress(&[], &mut Ctx::new(&mut rng));
            validate_wire(&w).unwrap_or_else(|e| panic!("{name} empty: {e}"));
        }
    }

    #[test]
    fn validate_wire_rejects_corruption() {
        // Wrong payload length for the dense schemes.
        for scheme in [SchemeId::Identity, SchemeId::Fp16, SchemeId::OneBit] {
            let c = Compressed { scheme, n: 10, payload: vec![0u8; 3] };
            assert!(validate_wire(&c).is_err(), "{scheme:?}");
        }
        // top-k: k exceeding n.
        let mut payload = Vec::new();
        put_u32(&mut payload, 5); // k = 5 > n = 4
        for _ in 0..5 {
            put_u32(&mut payload, 0);
        }
        for _ in 0..5 {
            put_f32(&mut payload, 1.0);
        }
        assert!(validate_wire(&Compressed { scheme: SchemeId::TopK, n: 4, payload }).is_err());
        // top-k: out-of-range index (the server-crash repro).
        let mut payload = Vec::new();
        put_u32(&mut payload, 1);
        put_u32(&mut payload, 9999); // index >= n
        put_f32(&mut payload, 1.0);
        let c = Compressed { scheme: SchemeId::TopK, n: 16, payload };
        assert!(validate_wire(&c).unwrap_err().contains("out of range"));
        // top-k: truncated value section.
        let mut payload = Vec::new();
        put_u32(&mut payload, 2);
        put_u32(&mut payload, 0);
        put_u32(&mut payload, 1);
        put_f32(&mut payload, 1.0); // second value missing
        assert!(validate_wire(&Compressed { scheme: SchemeId::TopK, n: 16, payload }).is_err());
        // randomk: k exceeding n (would panic in sample_indices).
        let mut payload = Vec::new();
        put_u32(&mut payload, 8);
        put_u64(&mut payload, 0xBEEF);
        for _ in 0..8 {
            put_f32(&mut payload, 0.5);
        }
        assert!(validate_wire(&Compressed { scheme: SchemeId::RandomK, n: 4, payload }).is_err());
        // dither: payload outside the representable envelope.
        let c = Compressed { scheme: SchemeId::LinearDither, n: 100, payload: vec![0u8; 4] };
        assert!(validate_wire(&c).is_err());
        let c = Compressed { scheme: SchemeId::NaturalDither, n: 4, payload: vec![0u8; 500] };
        assert!(validate_wire(&c).is_err());
    }

    #[test]
    fn default_ef_fused_matches_manual_residual() {
        let x: Vec<f32> = (0..512).map(|i| ((i * 7919) % 23) as f32 - 11.0).collect();
        let c = by_name("fp16", 0.0).unwrap();
        let mut rng = Xoshiro256::seed_from_u64(3);
        let mut ctx = Ctx::new(&mut rng);
        let mut q = x.clone();
        let w = c.compress_ef_fused(&mut q, &mut ctx);
        let mut dec = vec![0.0f32; x.len()];
        c.decompress(&w, &mut dec);
        for i in 0..x.len() {
            assert!((q[i] - (x[i] - dec[i])).abs() < 1e-6);
        }
    }
}
